"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes/dtypes, plus end-to-end equivalence with the core sketches."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.core import EdgeBatch, MatrixSketch, vertex_stats_from_sample
from repro.core import matrix_sketch
from repro.kernels import matrix_ingest, matrix_lookup, reach_step, embedding_bag
from repro.kernels import ref
from repro.kernels.ops import (
    KMatrixAccel,
    accel_matrix_edge_freq,
    accel_matrix_ingest,
    accel_reach_closure,
    kmatrix_accel_edge_freq,
    kmatrix_accel_ingest,
)


# ---------------------------------------------------------------- ingest --
@pytest.mark.parametrize("d,p,w,c,tb", [
    (1, 1, 8, 32, 32),
    (3, 1, 64, 128, 64),
    (2, 4, 16, 64, 32),
    (7, 2, 128, 256, 128),
])
def test_matrix_ingest_matches_ref(d, p, w, c, tb):
    rng = np.random.default_rng(d * 100 + w)
    pool = jnp.asarray(rng.integers(0, 50, (d, p, w, w)), jnp.int32)
    hi = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    hj = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    wt = jnp.asarray(rng.integers(0, 4, (p, c)), jnp.int32)
    out = matrix_ingest(pool, hi, hj, wt, block_b=tb, interpret=True)
    expect = ref.matrix_ingest_ref(pool, hi, hj, wt)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_matrix_ingest_property(seed):
    rng = np.random.default_rng(seed)
    d, p, w, c = 2, 2, 16, 64
    pool = jnp.zeros((d, p, w, w), jnp.int32)
    hi = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    hj = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    wt = jnp.asarray(rng.integers(0, 3, (p, c)), jnp.int32)
    out = matrix_ingest(pool, hi, hj, wt, block_b=32, interpret=True)
    # mass conservation per (layer, partition)
    np.testing.assert_array_equal(
        np.asarray(out).sum(axis=(2, 3)),
        np.broadcast_to(np.asarray(wt).sum(axis=1), (d, p)),
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.matrix_ingest_ref(pool, hi, hj, wt))
    )


# ---------------------------------------------------------------- lookup --
@pytest.mark.parametrize("d,p,w,c,tq", [
    (1, 1, 8, 32, 32),
    (4, 1, 64, 128, 64),
    (3, 2, 32, 64, 32),
])
def test_matrix_lookup_matches_ref(d, p, w, c, tq):
    rng = np.random.default_rng(w + c)
    pool = jnp.asarray(rng.integers(0, 100, (d, p, w, w)), jnp.int32)
    hi = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    hj = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    out = matrix_lookup(pool, hi, hj, block_q=tq, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.matrix_lookup_ref(pool, hi, hj))
    )


def test_ingest_then_lookup_roundtrip():
    d, p, w, c = 3, 1, 32, 128
    rng = np.random.default_rng(9)
    hi = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    hj = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    wt = jnp.ones((p, c), jnp.int32)
    pool = matrix_ingest(jnp.zeros((d, p, w, w), jnp.int32), hi, hj, wt,
                         block_b=64, interpret=True)
    est = matrix_lookup(pool, hi, hj, block_q=64, interpret=True)
    assert (np.asarray(est) >= 1).all()  # one-sided


# --------------------------------------------------------------- closure --
@pytest.mark.parametrize("w,block", [(128, 128), (256, 128), (512, 256)])
def test_reach_step_matches_ref(w, block):
    rng = np.random.default_rng(w)
    reach = jnp.asarray((rng.random((w, w)) < 0.02), jnp.float32)
    out = reach_step(reach, block=block, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.reach_step_ref(reach)), rtol=1e-6
    )


def test_accel_closure_matches_queries_closure():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.integers(0, 2, (2, 100, 100)), jnp.int32)
    closed = accel_reach_closure(table, block=128)
    expect = ref.reach_closure_ref(
        (table[0] > 0).astype(jnp.float32), n_steps=7
    )
    np.testing.assert_array_equal(np.asarray(closed[0]), np.asarray(expect) > 0.5)


# ------------------------------------------- closure backend dispatch ----
@pytest.mark.parametrize("max_hops", [None, 1, 2, 7])
def test_build_closure_pallas_backend_parity(max_hops):
    """queries.build_closure must answer identically through the Pallas
    kernel (interpret mode off-TPU) and the pure-jnp cascade — the dispatch
    that closes the ROADMAP `kernels/reach_closure.py` item."""
    from repro.core import queries

    rng = np.random.default_rng(7)
    # deliberately non-power-of-two width: exercises the kernel's padding
    table = jnp.asarray(rng.integers(0, 3, (3, 37, 37)) *
                        (rng.random((3, 37, 37)) < 0.05), jnp.int32)
    jnp_closure = queries.build_closure(table, max_hops, backend="jnp")
    pallas_closure = queries.build_closure(table, max_hops, backend="pallas")
    assert pallas_closure.shape == jnp_closure.shape
    assert pallas_closure.dtype == jnp_closure.dtype == jnp.bool_
    np.testing.assert_array_equal(np.asarray(pallas_closure),
                                  np.asarray(jnp_closure))


def test_build_closure_backend_resolution(monkeypatch):
    from repro.core import queries

    monkeypatch.delenv("REPRO_CLOSURE_BACKEND", raising=False)
    assert queries.closure_backend("pallas") == "pallas"
    assert queries.closure_backend(None) in ("jnp", "pallas")  # platform pick
    monkeypatch.setenv("REPRO_CLOSURE_BACKEND", "pallas")
    assert queries.closure_backend(None) == "pallas"
    with pytest.raises(ValueError, match="closure backend"):
        queries.closure_backend("cuda")


def test_reachability_end_to_end_on_pallas_backend():
    """Full query path (closure_layers -> build_closure -> pair lookup) on
    the Pallas backend agrees with the jnp backend for a real sketch."""
    from repro.core import EdgeBatch, MatrixSketch, queries
    from repro.core import matrix_sketch

    rng = np.random.default_rng(3)
    src = rng.integers(0, 50, 120).astype(np.int32)
    dst = rng.integers(0, 50, 120).astype(np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 14, depth=3, seed=2)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    qs = jnp.asarray(src[:32], jnp.int32)
    qd = jnp.asarray(dst[::-1][:32], jnp.int32)
    hi, hj = queries.reach_cells(sk, qs), queries.reach_cells(sk, qd)
    layers = queries.closure_layers(sk)
    for max_hops in (None, 2):
        a = queries.reachability_from_closure(
            queries.build_closure(layers, max_hops, backend="jnp"), hi, hj)
        b = queries.reachability_from_closure(
            queries.build_closure(layers, max_hops, backend="pallas"), hi, hj)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- embedding ----
@pytest.mark.parametrize("v,d_,b,f", [(64, 128, 8, 4), (1000, 128, 16, 39), (32, 256, 4, 2)])
def test_embedding_bag_matches_ref(v, d_, b, f):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.normal(size=(v, d_)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, (b, f)), jnp.int32)
    out = embedding_bag(table, idx, interpret=True)
    # Sequential in-kernel accumulation vs XLA tree-reduce: order differs,
    # so allow a few ULPs on the long (F=39) reductions.
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.embedding_bag_ref(table, idx)),
        rtol=1e-5, atol=1e-5,
    )


def test_embedding_bag_weighted():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 50, (6, 5)), jnp.int32)
    wts = jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)
    out = embedding_bag(table, idx, wts, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.embedding_bag_ref(table, idx, wts)),
        rtol=1e-5, atol=1e-5,
    )


# ------------------------------------------------- end-to-end ops layer ---
def test_accel_matrix_sketch_equals_core():
    """Pallas path and pure-JAX core produce IDENTICAL sketch states."""
    rng = np.random.default_rng(5)
    sk = MatrixSketch.create(bytes_budget=1 << 14, depth=3, seed=2)
    src = rng.integers(0, 500, 700).astype(np.int32)
    dst = rng.integers(0, 500, 700).astype(np.int32)
    w = rng.integers(1, 4, 700).astype(np.int32)
    batch = EdgeBatch.from_numpy(src, dst, w)
    core_state = matrix_sketch.ingest(sk, batch)
    accel_state = accel_matrix_ingest(sk, batch, block_b=128)
    np.testing.assert_array_equal(
        np.asarray(core_state.table), np.asarray(accel_state.table)
    )
    qs, qd = jnp.asarray(src[:100]), jnp.asarray(dst[:100])
    np.testing.assert_array_equal(
        np.asarray(matrix_sketch.edge_freq(core_state, qs, qd)),
        np.asarray(accel_matrix_edge_freq(accel_state, qs, qd, block_q=128)),
    )


def test_kmatrix_accel_exact_counting():
    """Class-layout ingest (dispatch + kernel + overflow) never loses edges."""
    rng = np.random.default_rng(11)
    src = rng.zipf(1.3, 4096).astype(np.int32) % 2000
    dst = rng.integers(0, 2000, 4096).astype(np.int32)
    stats = vertex_stats_from_sample(src[:1000], dst[:1000])
    sk = KMatrixAccel.create(bytes_budget=1 << 16, stats=stats, depth=3, seed=1)
    batch = EdgeBatch.from_numpy(src, dst)
    # tiny capacity forces the overflow path
    out = kmatrix_accel_ingest(sk, batch, capacity=128, block_b=128)
    total = sum(np.asarray(p).sum(axis=(1, 2, 3)) for p in out.pools)
    np.testing.assert_array_equal(total, np.full(3, 4096))  # per-layer mass
    est = np.asarray(kmatrix_accel_edge_freq(out, jnp.asarray(src), jnp.asarray(dst)))
    from repro.core.metrics import exact_edge_frequencies, lookup_exact
    true = lookup_exact(exact_edge_frequencies(src, dst), src, dst)
    assert (est >= true - 1e-6).all()


def test_kmatrix_accel_capacity_invariance():
    """Estimates identical whichever path (kernel vs overflow) edges took."""
    rng = np.random.default_rng(12)
    src = rng.integers(0, 300, 1024).astype(np.int32)
    dst = rng.integers(0, 300, 1024).astype(np.int32)
    stats = vertex_stats_from_sample(src[:400], dst[:400])
    sk = KMatrixAccel.create(bytes_budget=1 << 15, stats=stats, depth=2, seed=3)
    batch = EdgeBatch.from_numpy(src, dst)
    small = kmatrix_accel_ingest(sk, batch, capacity=128, block_b=128)
    large = kmatrix_accel_ingest(sk, batch, capacity=1024, block_b=128)
    for a, b in zip(small.pools, large.pools):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
