"""Sharded serving seams (ISSUE 4 tentpole): hash-band routing invariants,
merged-vs-unsharded bit-exactness, scatter/gather engine == direct oracle,
per-shard runtime conservation, and sharded crash-resume through the shard
manifest (DESIGN.md §Sharding)."""
import time

import numpy as np
import jax
import pytest

from repro.core import ShardPlan, kmatrix
from repro.core.partitioning import ShardPlan as ShardPlanDirect
from repro.runtime import Runtime
from repro.serving import (
    QueryEngine,
    ShardStreamView,
    ShardedQueryEngine,
    SketchRegistry,
    attach_shards,
    mix_for_sketch,
    read_shard_manifest,
    sharded_conservation,
    sharded_direct_answers,
    synth_requests,
)
from repro.serving import engine as eng


def _registry(**kw):
    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


def _single_shot(registry_kwargs=None, dataset="cit-HepPh", kind="kmatrix",
                 budget_kb=64, seed=0):
    """Oracle: the whole stream ingested once into one sketch, no sharding."""
    reg = _registry(**(registry_kwargs or {}))
    t = reg.open(dataset, kind, budget_kb, seed=seed)
    sk = t.snapshot.sketch
    ing = jax.jit(kmatrix.ingest)
    for b in t.stream:
        sk = ing(sk, b)
    return t.stream, sk


def _values_match(a, b) -> bool:
    if isinstance(a, tuple):
        return np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    return a == b


def _wait(cond, timeout_s=60.0, poll_s=0.005):
    deadline = time.monotonic() + timeout_s
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError("condition not met in time")
        time.sleep(poll_s)


# ----------------------------------------------------------------- routing
def test_shard_plan_is_deterministic_and_total():
    plan = ShardPlan(4, seed=3)
    v = np.arange(50_000, dtype=np.int64)
    a = plan.shard_of(v)
    b = ShardPlanDirect(4, seed=3).shard_of(v)  # same export, fresh instance
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 4
    counts = np.bincount(a, minlength=4)
    assert counts.min() > 0, "a band received nothing on 50k vertices"
    # scalar path agrees with the vectorized path
    assert plan.shard_of_one(12345) == int(a[12345])
    # a different routing seed produces a different banding
    assert not np.array_equal(a, ShardPlan(4, seed=4).shard_of(v))
    with pytest.raises(ValueError, match="n_shards"):
        ShardPlan(0)


def test_shard_views_partition_the_stream():
    """Every non-padding edge of every batch lands in exactly one view."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    plan = ShardPlan(3, seed=0)
    views = [ShardStreamView(t.stream, plan, s) for s in range(3)]
    total = 0
    for i in range(t.stream.num_batches):
        _, _, w = t.stream.batch_numpy(i)
        base_edges = int((w > 0).sum())
        shard_edges = 0
        for view in views:
            _, _, vw = view.batch_numpy(i)
            shard_edges += int((vw > 0).sum())
        assert shard_edges == base_edges, f"batch {i} lost/duplicated edges"
        total += base_edges
    assert total == t.stream.spec.n_edges


def test_shard_view_batches_are_replayable_and_bucketed():
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=1)
    view = ShardStreamView(t.stream, ShardPlan(2, seed=0), 0)
    s1, d1, w1 = view.batch_numpy(0)
    s2, d2, w2 = view.batch_numpy(0)  # pure fn of (base, plan, shard, i)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(w1, w2)
    assert len(s1) >= view.min_bucket and len(s1) % view.granule == 0
    own = w1 > 0
    assert np.all(view.plan.shard_of(s1[own]) == 0)


# ------------------------------------------------- merged == single sketch
def test_sharded_merge_equals_single_sketch_replay():
    """Tentpole gate: after a full cooperative ingest, the merge of the K
    shard sketches is bit-identical to one sketch that saw the whole
    stream, and so are its estimates."""
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=3)
    st.step(st.stream.num_batches)
    snap = st.publish()
    assert snap.n_edges == st.stream.spec.n_edges
    merged = st.merged_snapshot()

    stream, oracle = _single_shot()
    np.testing.assert_array_equal(np.asarray(merged.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(merged.sketch.conn),
                                  np.asarray(oracle.conn))


def test_open_sharded_is_idempotent_and_shards_share_layout():
    reg = _registry()
    a = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    assert reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                            n_shards=2) is a
    sk0 = a.shards[0].snapshot.sketch
    sk1 = a.shards[1].snapshot.sketch
    # same hash family and routing -> merge is legal and meaningful
    np.testing.assert_array_equal(np.asarray(sk0.hashes.a),
                                  np.asarray(sk1.hashes.a))
    np.testing.assert_array_equal(np.asarray(sk0.route.offsets),
                                  np.asarray(sk1.route.offsets))
    ids = [s.key.tenant_id for s in a.shards]
    assert len(set(ids)) == 2 and all("shard" in i for i in ids)


# --------------------------------------------------------- engine == oracle
def test_sharded_engine_matches_sharded_direct_oracle():
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=3)
    st.step(4)
    snap = st.publish()
    engine = ShardedQueryEngine(QueryEngine(min_bucket=8))
    reqs = synth_requests(64, mix_for_sketch("kmatrix"),
                          n_nodes=st.stream.spec.n_nodes, seed=5,
                          heavy_universe=512, heavy_threshold=5.0)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = sharded_direct_answers(snap, reqs)
    for i, (g, w) in enumerate(zip(got, want)):
        assert _values_match(g, w), (i, reqs[i].family, g, w)
    # every result in a batch carries ONE epoch-vector stamp
    stamps = {r.epoch for r in engine.execute(snap, reqs[:8])}
    assert stamps == {snap.epochs}


def test_sharded_reach_closure_cache_keys_on_epoch_vector():
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    st.step(2)
    snap = st.publish()
    engine = ShardedQueryEngine(QueryEngine(min_bucket=8))
    reqs = [eng.reach(1, 9), eng.reach(4, 2)]
    engine.execute(snap, reqs)
    assert engine.closures.misses == 1
    engine.execute(snap, reqs)
    assert engine.closures.hits >= 1
    # ONE shard publishing invalidates (new epoch vector -> new key)
    st.shards[0].step(1)
    st.shards[0].publish()
    misses_before = engine.closures.misses
    engine.execute(st.snapshot, reqs)
    assert engine.closures.misses == misses_before + 1


# ------------------------------------------------------- runtime + restore
def test_sharded_runtime_drain_conserves_across_shards():
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=3)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                 poll_s=0.01)
    handles = attach_shards(rt, st)
    rt.start()
    assert rt.join_pumps(120)
    rt.stop(drain=True)
    cons = sharded_conservation(handles, st.stream.spec.n_edges)
    assert cons["conservation_ok"], cons
    assert cons["dropped_edges"] == 0
    assert cons["published_edges"] == st.stream.spec.n_edges
    # and the merged result is STILL the single-sketch replay
    stream, oracle = _single_shot()
    merged = st.merged_snapshot()
    np.testing.assert_array_equal(np.asarray(merged.sketch.pool),
                                  np.asarray(oracle.pool))


def test_sharded_crash_resume_conserves_and_serves_exactly(tmp_path):
    """Satellite acceptance: kill K shards mid-stream at DIFFERENT offsets,
    restore each from the shard manifest's per-shard checkpoints into a
    fresh registry, drain — per-shard conservation holds and the restored
    registry serves engine == direct answers, with the merged state
    bit-identical to a never-crashed single sketch."""
    ckpt = str(tmp_path / "ckpt")
    reg_a = _registry()
    st_a = reg_a.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=3)
    rt_a = Runtime(queue_capacity=2, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01)
    # different throttles drive the shards to different stream offsets
    handles_a = attach_shards(rt_a, st_a, throttle_s=[0.01, 0.05, 0.09])
    rt_a.start()
    _wait(lambda: all(h.worker.metrics.ingested_batches >= 1
                      for h in handles_a))
    _wait(lambda: handles_a[0].worker.metrics.ingested_batches >= 3)
    rt_a.kill()
    offsets = [s.offset for s in st_a.shards]
    assert any(o < st_a.stream.num_batches for o in offsets), \
        "kill was not mid-stream"

    manifest = read_shard_manifest(ckpt)
    assert manifest["n_shards"] == 3
    assert len(manifest["shard_tenant_ids"]) == 3

    reg_b = _registry()
    st_b = reg_b.open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                              n_shards=manifest["n_shards"],
                              shard_seed=manifest["shard_seed"])
    rt_b = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, poll_s=0.01)
    handles_b = attach_shards(rt_b, st_b, restore=True)
    restored_offsets = [s.offset for s in st_b.shards]
    assert any(o > 0 for o in restored_offsets), \
        "restore must resume mid-stream, not replay from scratch"
    rt_b.start()
    assert rt_b.join_pumps(120)
    rt_b.stop(drain=True)

    cons = sharded_conservation(handles_b, st_b.stream.spec.n_edges)
    assert all(u == 0 for u in cons["per_shard_unaccounted"]), cons

    stream, oracle = _single_shot()
    merged = st_b.merged_snapshot()
    np.testing.assert_array_equal(np.asarray(merged.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(merged.sketch.conn),
                                  np.asarray(oracle.conn))
    assert merged.n_edges == stream.spec.n_edges

    # engine == direct on the restored registry's live snapshot
    engine = ShardedQueryEngine(QueryEngine(min_bucket=8))
    snap = st_b.snapshot
    reqs = synth_requests(32, mix_for_sketch("kmatrix"),
                          n_nodes=stream.spec.n_nodes, seed=11,
                          heavy_universe=256, heavy_threshold=5.0)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = sharded_direct_answers(snap, reqs)
    for g, w in zip(got, want):
        assert _values_match(g, w)


def test_attach_shards_rejects_mismatched_manifest(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                 checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01)
    attach_shards(rt, st, max_batches=1)
    rt.start()
    rt.join_pumps(120)
    rt.stop(drain=True)

    other = _registry().open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                                     n_shards=3)
    rt2 = Runtime(queue_capacity=4, reservoir_k=0, checkpoint_dir=ckpt,
                  poll_s=0.01)
    with pytest.raises(ValueError, match="manifest"):
        attach_shards(rt2, other, restore=True)
