"""Metric definitions (paper Eqs. 9-12) + estimator-theory sanity checks."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.core.metrics import (
    average_relative_error,
    effective_queries,
    exact_edge_frequencies,
    lookup_exact,
    percent_effective_queries,
    relative_error,
)


def test_relative_error_eq9():
    est = jnp.asarray([4.0, 2.0, 10.0])
    true = jnp.asarray([2.0, 2.0, 5.0])
    np.testing.assert_allclose(np.asarray(relative_error(est, true)),
                               [1.0, 0.0, 1.0])


def test_are_eq10_with_mask():
    est = jnp.asarray([4.0, 2.0, 100.0])
    true = jnp.asarray([2.0, 2.0, 1.0])
    valid = jnp.asarray([1.0, 1.0, 0.0])
    assert float(average_relative_error(est, true, valid)) == pytest.approx(0.5)


def test_neq_peq_eq11_12():
    est = jnp.asarray([5.0, 10.0, 100.0, 7.0])
    true = jnp.asarray([4.0, 4.0, 4.0, 7.0])
    assert int(effective_queries(est, true, g0=1.0)) == 2  # |err| <= 1
    assert float(percent_effective_queries(est, true, g0=1.0)) == 50.0


@given(seed=st.integers(0, 500), n=st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_exact_frequency_oracle(seed, n):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 20, n).astype(np.int32)
    dst = rng.integers(0, 20, n).astype(np.int32)
    w = rng.integers(1, 5, n).astype(np.int64)
    fmap = exact_edge_frequencies(src, dst, w)
    # total mass conserved
    assert sum(fmap.values()) == pytest.approx(float(w.sum()))
    # lookups match a brute-force count
    got = lookup_exact(fmap, src[:5], dst[:5])
    for i in range(min(5, n)):
        brute = w[(src == src[i]) & (dst == dst[i])].sum()
        assert got[i] == pytest.approx(float(brute))


def test_unseen_edges_zero():
    fmap = exact_edge_frequencies(np.asarray([1]), np.asarray([2]),
                                  np.asarray([3]))
    out = lookup_exact(fmap, np.asarray([9]), np.asarray([9]))
    assert out[0] == 0.0
