"""Device-resident ingest fast path (ISSUE 10): buffer donation,
exact duplicate-edge pre-aggregation, and pipelined dispatch.

The contract under test is *bit-exactness*: every fast-path arm
(donation on/off x dedup on/off) must publish counters, pending ledgers,
and estimates identical to the plain path — donation because the kernels
are alias-safe rewrites, dedup because sketch counters are linear in the
update stream (int32 wrap-add is associative and commutative).
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import countmin, kmatrix
from repro.core.types import EdgeBatch
from repro.runtime import QueueItem, Runtime
from repro.runtime.worker import IngestWorker, _item_nbytes, preaggregate_edges
from repro.serving import SketchRegistry
from repro.serving.gates import layout_counters_equal
from repro.serving.snapshot import SnapshotBuffer, donation_enabled


def _registry(**kw):
    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


def _random_edges(rng, n, n_nodes=200, wrap=False):
    src = rng.integers(-5, n_nodes, n).astype(np.int32)
    dst = rng.integers(-5, n_nodes, n).astype(np.int32)
    if wrap:
        w = rng.integers(-(2 ** 31), 2 ** 31, n, dtype=np.int64) \
            .astype(np.int32)
    else:
        w = rng.integers(-3, 4, n).astype(np.int32)
    return src, dst, w


def _oracle(src, dst, w):
    """Wrap-accurate int32 per-(src, dst) sums, zero-weight rows dropped."""
    acc = {}
    for s, d, x in zip(src.tolist(), dst.tolist(), w.tolist()):
        if x == 0:
            continue
        k = (s, d)
        v = (acc.get(k, 0) + x) & 0xFFFFFFFF
        acc[k] = v
    out = {k: v - (1 << 32) if v >= (1 << 31) else v
           for k, v in acc.items()}
    return {k: v for k, v in out.items() if v != 0}


# -------------------------------------------------------- pre-aggregation
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("wrap", [False, True])
def test_preaggregate_matches_wraparound_oracle(seed, wrap):
    """Randomized bit-exactness incl. negative weights (turnstile), heavy
    duplicates, negative node ids, and int32 wrap-add."""
    rng = np.random.default_rng(seed)
    src, dst, w = _random_edges(rng, 4096, n_nodes=64, wrap=wrap)
    us, ud, uw = preaggregate_edges(src, dst, w)
    got = dict(zip(zip(us.tolist(), ud.tolist()), uw.tolist()))
    assert got == _oracle(src, dst, w)
    # unique keys, no zero weights in the output
    assert len(got) == us.shape[0]
    assert np.all(uw != 0)


def test_preaggregate_drops_cancelled_and_zero_rows():
    src = np.array([1, 1, 2, 3], np.int32)
    dst = np.array([9, 9, 8, 7], np.int32)
    w = np.array([3, -3, 0, 5], np.int32)
    us, ud, uw = preaggregate_edges(src, dst, w)
    assert us.tolist() == [3] and ud.tolist() == [7] and uw.tolist() == [5]


def test_preaggregated_ingest_is_bit_identical_on_countmin():
    """Counter linearity, end to end: raw batch vs its pre-aggregate land
    in identical sketches."""
    rng = np.random.default_rng(7)
    src, dst, w = _random_edges(rng, 2048, n_nodes=50)
    sk_raw = countmin.CountMin.create(bytes_budget=4096, depth=3, seed=1)
    sk_agg = countmin.CountMin.create(bytes_budget=4096, depth=3, seed=1)
    sk_raw = countmin.ingest(sk_raw, EdgeBatch.from_numpy(src, dst, w))
    us, ud, uw = preaggregate_edges(src, dst, w)
    sk_agg = countmin.ingest(sk_agg, EdgeBatch.from_numpy(us, ud, uw))
    np.testing.assert_array_equal(np.asarray(sk_raw.table),
                                  np.asarray(sk_agg.table))


# ----------------------------------------------------------- byte ledger
def test_coalesce_byte_ledger_uses_actual_column_dtypes():
    """The cap ledger derives bytes from the item's real dtypes — an int64
    weight column costs 16 B/row, not the int32-era hardcoded 12."""
    n = 100
    item32 = QueueItem.from_arrays(
        0, np.ones(n, np.int32), np.ones(n, np.int32), np.ones(n, np.int32))
    item64 = QueueItem.from_arrays(
        1, np.ones(n, np.int32), np.ones(n, np.int32), np.ones(n, np.int64))
    assert _item_nbytes(item32) == n * 12
    assert _item_nbytes(item64) == n * 16


# ---------------------------------------------------------------- donation
def _feed(buf, batches):
    for src, dst, w in batches:
        buf.ingest(EdgeBatch.from_numpy(src, dst, w))


def _batches(seed, k=6, n=512):
    rng = np.random.default_rng(seed)
    return [_random_edges(rng, n, n_nodes=100) for _ in range(k)]


def test_donation_kill_switch_parity_countmin():
    """donate=True and donate=False buffers publish bit-identical fronts,
    pending ledgers, and estimates across multiple publish rounds."""
    sk = countmin.CountMin.create(bytes_budget=8192, depth=3, seed=2)
    bufs = {d: SnapshotBuffer(jax.tree_util.tree_map(jnp.array, sk),
                              countmin, tenant_id="t", donate=d)
            for d in (False, True)}
    assert bufs[True].donate or not donation_enabled()
    batches = _batches(3)
    for i in range(3):
        for d, buf in bufs.items():
            _feed(buf, batches[i * 2:(i + 1) * 2])
            buf.publish()
    a, b = bufs[False].snapshot, bufs[True].snapshot
    assert a.n_edges == b.n_edges and a.epoch == b.epoch
    assert layout_counters_equal(a.sketch, b.sketch)
    q = np.arange(64, dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(countmin.edge_freq(a.sketch, q, q[::-1].copy())),
        np.asarray(countmin.edge_freq(b.sketch, q, q[::-1].copy())))


def test_donation_checkpoint_restore_roundtrip():
    """state() under donation hands out private copies that survive later
    donating dispatches, and a buffer restored from it converges to the
    same front as the uninterrupted one."""
    sk = countmin.CountMin.create(bytes_budget=8192, depth=3, seed=4)
    buf = SnapshotBuffer(sk, countmin, tenant_id="t", donate=True)
    batches = _batches(5, k=4)
    _feed(buf, batches[:2])
    state = buf.state()
    saved_delta = jax.tree_util.tree_map(np.asarray, state["delta"])
    saved_pending = int(np.asarray(state["pending"]))

    # keep ingesting + publishing on the live buffer: if state() aliased
    # the live delta, these donations would delete the saved leaves
    _feed(buf, batches[2:])
    buf.publish()
    for a, b in zip(jax.tree_util.tree_leaves(saved_delta),
                    jax.tree_util.tree_leaves(
                        jax.tree_util.tree_map(np.asarray, state["delta"]))):
        np.testing.assert_array_equal(a, b)
    assert int(np.asarray(state["pending"])) == saved_pending

    sk2 = countmin.CountMin.create(bytes_budget=8192, depth=3, seed=4)
    buf2 = SnapshotBuffer(sk2, countmin, tenant_id="t", donate=True)
    buf2.load_state(state)
    _feed(buf2, batches[2:])
    buf2.publish()
    assert buf2.snapshot.n_edges == buf.snapshot.n_edges
    assert layout_counters_equal(buf2.snapshot.sketch, buf.snapshot.sketch)


def test_donated_buffer_capture_publish_delta_stays_readable():
    """capture_publish_delta forces the never-donating publish kernel, so
    the stashed delta survives the publish that folded it in."""
    sk = countmin.CountMin.create(bytes_budget=4096, depth=3, seed=5)
    buf = SnapshotBuffer(sk, countmin, tenant_id="t", donate=True)
    buf.capture_publish_delta = True
    for batches in (_batches(6, k=2), _batches(7, k=2)):
        _feed(buf, batches)
        buf.publish()
        total = sum(int(np.asarray(x).sum())
                    for x in jax.tree_util.tree_leaves(
                        buf.last_publish_delta)
                    if np.issubdtype(np.asarray(x).dtype, np.integer))
        assert isinstance(total, int)  # readable, not deleted


# ------------------------------------------------- runtime fast-path A/B
def _run_runtime(dataset="email-EuAll", *, dedup, backend="thread",
                 max_batches=12, **rt_kw):
    reg = _registry(scale=0.05)
    t = reg.open(dataset, "kmatrix", 64, seed=7)
    rt = Runtime(publish_policy="drain:0", reservoir_k=0,
                 coalesce_batches=4, coalesce_target=4096,
                 dedup=dedup, backend=backend, **rt_kw)
    rt.attach(t, max_batches=max_batches)
    rt.start(pumps=False)
    assert rt.wait_ready(300)
    rt.start_pumps()
    assert rt.join_pumps(300)
    rep = rt.stop(drain=True)[t.key.tenant_id]
    assert rep["unaccounted_edges"] == 0
    return t.snapshot, rep


def test_dedup_runtime_bit_identical_and_counts_compression():
    """Thread-backend A/B: the dedup arm publishes the same counters and
    pending totals as the plain coalesced path, and reports its
    compression through the metrics surface."""
    base, rep0 = _run_runtime(dedup=False)
    fast, rep1 = _run_runtime(dedup=True)
    assert fast.n_edges == base.n_edges
    assert layout_counters_equal(fast.sketch, base.sketch)
    assert rep0.get("dedup_ratio") is None
    assert rep1["dedup_ratio"] >= 1.0
    assert rep1["dedup_unique_rows"] <= rep1["dedup_raw_rows"]


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["process", "socket"])
def test_remote_backend_dedup_donation_conserves_and_matches(backend):
    """The dedup flag and the donation env both cross the spawn/dial
    boundary (child-spec field + spec.env): a remote-backend drain with
    dedup on stays bit-identical to the in-process plain run."""
    base, _ = _run_runtime(dedup=False)
    fast, rep = _run_runtime(dedup=True, backend=backend,
                             queue_capacity=4, poll_s=0.01)
    assert fast.n_edges == base.n_edges
    assert layout_counters_equal(fast.sketch, base.sketch)


def test_donation_defaults_and_kill_switch_env(monkeypatch):
    monkeypatch.delenv("REPRO_DONATE", raising=False)
    assert donation_enabled()
    for off in ("0", "false", "OFF"):
        monkeypatch.setenv("REPRO_DONATE", off)
        assert not donation_enabled()
    monkeypatch.setenv("REPRO_DONATE", "1")
    assert donation_enabled()
    sk = countmin.CountMin.create(bytes_budget=1024, depth=2, seed=0)
    assert SnapshotBuffer(sk, countmin, tenant_id="t").donate
    monkeypatch.setenv("REPRO_DONATE", "0")
    assert not SnapshotBuffer(sk, countmin, tenant_id="t").donate
