"""Network transport tier (ISSUE 6 tentpole): the shared wire codec, the
socket execution backend, and the TCP query front-end (DESIGN.md §Net).

Covers the satellites end to end: malformed/truncated frames are loud
``WireError``/``ConnectionError`` (never hangs — the poll/deadline split is
exercised on real sockets), a killed self-hosted socket worker restores
through the manifest with conservation + bit-exactness + engine==direct, a
dead TCP peer surfaces as ``WorkerFailure`` carrying last-known accounting,
admission-control shed is always accounted (offered == admitted + shed on
the server, accepted + shed + errors == offered at the client), and the
remote ``stream_ingest --listen`` placement drains bit-exactly.  The
multi-connection soak is ``slow``-marked for the dedicated CI lane."""
import os
import signal
import socket
import threading
import time
import types

import numpy as np
import jax
import pytest

from repro.core import kmatrix
from repro.net import wire
from repro.runtime import Runtime, WorkerFailure
from repro.serving import (
    QueryEngine,
    ShardedQueryEngine,
    SketchRegistry,
    attach_shards,
    mix_for_sketch,
    read_shard_manifest,
    sharded_conservation,
    sharded_direct_answers,
    synth_requests,
)
from repro.serving.gates import values_match


def _registry(**kw):
    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


def _single_shot(dataset="cit-HepPh", kind="kmatrix", budget_kb=64, seed=0):
    reg = _registry()
    t = reg.open(dataset, kind, budget_kb, seed=seed)
    sk = t.snapshot.sketch
    ing = jax.jit(kmatrix.ingest)
    for b in t.stream:
        sk = ing(sk, b)
    return t.stream, sk


def _wait(cond, timeout_s=120.0, poll_s=0.01):
    deadline = time.monotonic() + timeout_s
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError("condition not met in time")
        time.sleep(poll_s)


# ------------------------------------------------------------- wire codec
def test_wire_roundtrip_all_kinds():
    """One codec for pipe and socket: every frame kind round-trips, numpy
    leaves included, byte-for-byte through encode/decode."""
    arr = np.arange(6, dtype=np.int32)
    for msg in [
        ("hello", {"tenant_id": "t", "nested": [1, 2, 3]}),
        ("item", 4, arr, arr + 1, arr * 2, 6),
        ("publish", 3, [arr, arr.astype(np.int64)], 1024, {"m": 1}),
        ("stop", True),
        ("ping",),
    ]:
        out = wire.decode_message(wire.encode_message(msg))
        assert out[0] == msg[0] and len(out) == len(msg)
    got = wire.decode_message(wire.encode_message(("item", 4, arr, arr,
                                                   arr, 6)))
    np.testing.assert_array_equal(got[2], arr)


def test_wire_rejects_malformed_frames_loudly():
    frame = wire.encode_message(("ping",))
    # bad magic: not our stream at all
    with pytest.raises(wire.WireError, match="bad magic"):
        wire.decode_message(b"HTTP" + frame[4:])
    # version skew names both versions
    skew = bytearray(frame)
    skew[5] = 99
    with pytest.raises(wire.WireError, match="version mismatch"):
        wire.decode_message(bytes(skew))
    # unknown frame type
    bad_type = bytearray(frame)
    bad_type[7] = 250
    with pytest.raises(wire.WireError, match="unknown frame type"):
        wire.decode_message(bytes(bad_type))
    # truncated payload: header promises more than arrived
    with pytest.raises(wire.WireError, match="truncated frame"):
        wire.decode_message(frame[:-1])
    # header length field beyond the ceiling
    huge = bytearray(frame)
    huge[8:12] = (wire.MAX_PAYLOAD + 1).to_bytes(4, "big")
    with pytest.raises(wire.WireError, match="exceeds MAX_PAYLOAD"):
        wire.decode_message(bytes(huge))
    # frame type / payload kind disagreement (torn stream)
    pong = wire.encode_message(("pong",))
    franken = frame[:wire.HEADER_SIZE] + pong[wire.HEADER_SIZE:]
    with pytest.raises(wire.WireError, match="frame type says"):
        wire.decode_message(franken)
    # unknown kinds refuse to encode at the sender
    with pytest.raises(wire.WireError, match="unknown wire message kind"):
        wire.encode_message(("warp-drive", 1))
    with pytest.raises(wire.WireError, match="tuples"):
        wire.encode_message(["ping"])


def test_recv_message_poll_deadline_split():
    """Idle peer → None (poll); started-then-stalled frame → TimeoutError;
    peer death mid-frame → ConnectionError.  No path hangs."""
    a, b = socket.socketpair()
    try:
        assert wire.recv_message(b, poll_s=0.05) is None  # idle, not an error
        a.sendall(wire.encode_message(("ping",)))
        assert wire.recv_message(b, poll_s=0.5) == ("ping",)
        frame = wire.encode_message(("stop", True))
        a.sendall(frame[:7])  # a frame STARTS but never finishes
        with pytest.raises(TimeoutError, match="mid-header"):
            wire.recv_message(b, poll_s=0.5, frame_deadline_s=0.3)
    finally:
        a.close()
        b.close()
    a, b = socket.socketpair()
    try:
        frame = wire.encode_message(("stop", True))
        a.sendall(frame[:-2])
        a.close()  # peer dies mid-payload
        with pytest.raises(ConnectionError, match="short read"):
            wire.recv_message(b, poll_s=0.5, frame_deadline_s=5.0)
    finally:
        b.close()
    a, b = socket.socketpair()
    try:
        a.close()  # clean EOF before any frame
        with pytest.raises(ConnectionError, match="closed by peer"):
            wire.recv_message(b, poll_s=0.5)
    finally:
        b.close()


def test_parse_hostport():
    assert wire.parse_hostport("127.0.0.1:80") == ("127.0.0.1", 80)
    for junk in ("nope", ":80", "host:", "host:eighty"):
        with pytest.raises(ValueError, match="HOST:PORT"):
            wire.parse_hostport(junk)


# ----------------------------------------------- v3 columnar item frames
def _cols_item(dtype=np.int32, n=5, n_edges=None, trace="tr-0"):
    rng = np.random.default_rng(3)
    col = lambda: rng.integers(0, 99, n).astype(dtype)  # noqa: E731
    return types.SimpleNamespace(
        offset=7, src=col(), dst=col(), weight=col(),
        n_edges=n if n_edges is None else n_edges, trace_id=trace)


def test_item_cols_roundtrip_across_dtypes():
    """The zero-pickle item path: every allowlisted column dtype round-trips
    exactly, the decode is zero-copy (read-only frombuffer views), and the
    canonical ``("item", ...)`` tuple shape matches the v2 contract."""
    for dtype in (np.int8, np.uint8, np.int32, np.uint32, np.int64,
                  np.float32, np.float64):
        item = _cols_item(dtype=dtype)
        out = wire.decode_message(wire.encode_item_frame(item))
        kind, offset, src, dst, weight, n_edges, trace = out
        assert kind == "item" and offset == 7 and n_edges == 5
        assert trace == "tr-0"
        for got, want in ((src, item.src), (dst, item.dst),
                          (weight, item.weight)):
            assert got.dtype == want.dtype
            np.testing.assert_array_equal(got, want)
            assert not got.flags.writeable  # frombuffer view, not a copy
    # empty batch and empty trace are legal
    empty = _cols_item(n=0, n_edges=0, trace="")
    out = wire.decode_message(wire.encode_item_frame(empty))
    assert out[2].size == 0 and out[5] == 0 and out[6] == ""


def _raw_cols_frame(offset=0, n_edges=2, counts=(2, 2, 2),
                    dtags=(b"<i4", b"<i4", b"<i4"), trace=b"",
                    col_bytes=None):
    """Hand-assemble an ``item_cols`` frame, valid or hostile."""
    if col_bytes is None:
        col_bytes = b"".join(
            np.arange(c, dtype=np.int32).tobytes() for c in counts)
    body = wire._ITEM_COLS.pack(
        offset, n_edges, *counts,
        dtags[0].ljust(8, b"\x00"), dtags[1].ljust(8, b"\x00"),
        dtags[2].ljust(8, b"\x00"), len(trace)) + trace + col_bytes
    return wire._HEADER.pack(wire.MAGIC, wire.WIRE_VERSION,
                             wire.FRAME_TYPES["item_cols"],
                             len(body)) + body


def test_item_cols_malformed_frames_raise_wireerror():
    """Hostile/torn columnar frames die as WireError naming the defect —
    truncation, ragged columns, impossible edge counts, length lies,
    smuggled dtypes, bad trace bytes — never an np.frombuffer crash."""
    ok = _raw_cols_frame()
    assert wire.decode_message(ok)[0] == "item"  # the baseline is valid
    # body shorter than the inner header
    short = wire._HEADER.pack(wire.MAGIC, wire.WIRE_VERSION,
                              wire.FRAME_TYPES["item_cols"], 4) + b"abcd"
    with pytest.raises(wire.WireError, match="truncated columnar"):
        wire.decode_message(short)
    with pytest.raises(wire.WireError, match="ragged"):
        wire.decode_message(_raw_cols_frame(counts=(2, 3, 2)))
    with pytest.raises(wire.WireError, match="non-padding"):
        wire.decode_message(_raw_cols_frame(n_edges=9))
    # header counts promise more column bytes than arrived (oversize lie)
    with pytest.raises(wire.WireError, match="length mismatch"):
        wire.decode_message(_raw_cols_frame(
            counts=(64, 64, 64), n_edges=2, col_bytes=b""))
    # dtype smuggling: object/str dtypes must never reach np.frombuffer
    for tag in (b"|O", b"<U4", b"|V8", b"garbage!"):
        with pytest.raises(wire.WireError,
                           match="disallowed|undecodable"):
            wire.decode_message(_raw_cols_frame(dtags=(tag, b"<i4", b"<i4")))
    with pytest.raises(wire.WireError, match="trace_id"):
        wire.decode_message(_raw_cols_frame(
            trace=b"\xff\xfe", col_bytes=None))
    # encoder refuses what the decoder would refuse
    with pytest.raises(wire.WireError, match="unframeable dtype"):
        wire.encode_item_frame(types.SimpleNamespace(
            offset=0, src=np.array(["a"]), dst=np.zeros(1, np.int32),
            weight=np.zeros(1, np.int32), n_edges=1, trace_id=""))
    with pytest.raises(wire.WireError, match="1-D"):
        wire.encode_item_frame(types.SimpleNamespace(
            offset=0, src=np.zeros((2, 2), np.int32),
            dst=np.zeros(4, np.int32), weight=np.zeros(4, np.int32),
            n_edges=4, trace_id=""))
    with pytest.raises(wire.WireError, match="65535"):
        wire.encode_item_frame(_cols_item(trace="x" * 70000))


def test_v2_item_frames_still_decode():
    """Version compat: a peer still speaking WIRE_VERSION 2 (pickled
    ``item`` tuples) decodes fine — COMPAT_VERSIONS covers the handoff."""
    import pickle

    arr = np.arange(4, dtype=np.int32)
    msg = ("item", 11, arr, arr + 1, arr * 2, 4, "tr-v2")
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    frame = wire._HEADER.pack(wire.MAGIC, 2, wire.FRAME_TYPES["item"],
                              len(payload)) + payload
    out = wire.decode_message(frame)
    assert out[0] == "item" and out[1] == 11 and out[6] == "tr-v2"
    np.testing.assert_array_equal(out[2], arr)


def test_leaf_codec_sparse_dense_adaptive_and_exact():
    """Delta leaf codec: sparse leaves ship as COO and reconstruct exactly;
    dense/tiny/scalar leaves ship dense; malformed entries are loud."""
    rng = np.random.default_rng(5)
    sparse = np.zeros((64, 64), np.int64)
    sparse[rng.integers(0, 64, 30), rng.integers(0, 64, 30)] = 7
    dense = rng.integers(1, 9, (16, 16)).astype(np.int32)
    scalar = np.int64(42)
    leaves = [sparse, dense, scalar, np.zeros(0, np.float32)]
    entries = wire.encode_leaves(leaves)
    assert entries[0][0] == "sparse" and entries[1][0] == "dense"
    assert entries[2][0] == "dense" and entries[3][0] == "dense"
    back = wire.decode_leaves(entries)
    for want, got in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(want), got)
        assert np.asarray(want).dtype == got.dtype
    # sparse must actually be smaller for a sparse leaf
    idx, vals = entries[0][3], entries[0][4]
    assert idx.nbytes + vals.nbytes < sparse.nbytes
    with pytest.raises(wire.WireError, match="unknown leaf encoding"):
        wire.decode_leaves([("mystery", 1)])
    with pytest.raises(wire.WireError, match="do not fit"):
        wire.decode_leaves([("sparse", (2, 2), "<i8",
                             np.array([9], np.uint32),
                             np.array([1], np.int64))])


# --------------------------------------------------------- wire security
def test_wire_restricted_unpickler_blocks_code_execution():
    """A crafted frame whose pickle names an executable global (the classic
    ``__reduce__`` RCE gadget) must die as a WireError at decode — never
    reach the interpreter — while every legitimate payload shape (repro
    dataclasses, numpy arrays AND scalars, containers) still round-trips."""
    import subprocess

    class Evil:
        def __reduce__(self):
            return (subprocess.check_output, (["true"],))

    frame = wire.encode_message(("query", {"requests": [Evil()]}))
    with pytest.raises(wire.WireError, match="not allowed"):
        wire.decode_message(frame)

    class EvilEval:
        def __reduce__(self):
            return (eval, ("1+1",))

    with pytest.raises(wire.WireError, match="not allowed"):
        wire.decode_message(wire.encode_message(("query", EvilEval())))

    # the allowlist still admits everything the protocol actually ships
    from repro.serving import engine as eng

    legit = ("query", {"id": 1, "tenant": "t", "requests": [
        eng.edge_freq(1, 2), eng.heavy_nodes(16, 2.0)]})
    out = wire.decode_message(wire.encode_message(legit))
    assert out[1]["requests"][0].family == "edge_freq"
    npy = ("publish", 3, [np.arange(4, dtype=np.int32)], np.int64(7),
           {"m": np.float64(1.5), "d": collections_roundtrip()})
    got = wire.decode_message(wire.encode_message(npy))
    np.testing.assert_array_equal(got[2][0], np.arange(4, dtype=np.int32))
    assert int(got[3]) == 7


def collections_roundtrip():
    from collections import OrderedDict

    return OrderedDict(a=1)


def test_listeners_refuse_non_loopback_bind_without_token(monkeypatch):
    """REVIEW fix: an open, unauthenticated pickle-speaking port must never
    happen by accident — non-loopback binds are an explicit opt-in that
    requires a shared token."""
    from repro.net.ingest_server import WorkerServer
    from repro.net.query_server import QueryServer

    monkeypatch.delenv(wire.AUTH_TOKEN_ENV, raising=False)
    with pytest.raises(ValueError, match="auth token"):
        WorkerServer("0.0.0.0", 0)
    with pytest.raises(ValueError, match="auth token"):
        QueryServer(_StubEngine(), _stub_snapshot, host="0.0.0.0")
    # loopback stays the no-ceremony default
    s = WorkerServer("127.0.0.1", 0)
    s.close()
    # with a token, a routable bind is allowed
    s2 = WorkerServer("0.0.0.0", 0, auth_token="sekrit")
    s2.close()
    # the env var is an equivalent opt-in (deploys set it on both ends)
    monkeypatch.setenv(wire.AUTH_TOKEN_ENV, "sekrit")
    s3 = WorkerServer("0.0.0.0", 0)
    assert s3.auth_token == "sekrit"
    s3.close()


def test_query_server_auth_token_enforced(monkeypatch):
    """With a token configured, an unauthenticated (or wrong-token) client
    gets its connection refused and counted; the right token works."""
    from repro.net.query_server import QueryClient, QueryServer

    monkeypatch.delenv(wire.AUTH_TOKEN_ENV, raising=False)
    server = QueryServer(_StubEngine(), _stub_snapshot,
                         auth_token="sekrit").start()
    try:
        bad = QueryClient(server.address)  # never presents the token
        with pytest.raises((RuntimeError, ConnectionError, TimeoutError,
                            OSError)):
            bad.query(["a"], timeout_s=15)
        bad.close()
        wrong = QueryClient(server.address, auth_token="not-it")
        with pytest.raises((RuntimeError, ConnectionError, TimeoutError,
                            OSError)):
            wrong.query(["a"], timeout_s=15)
        wrong.close()
        good = QueryClient(server.address, auth_token="sekrit")
        assert good.query(["a"])[0] == [0.0]
        good.close()
        stats = server.stats()
        assert stats["auth_failures"] >= 1
        assert stats["served_requests"] == 1
    finally:
        server.stop()


def test_worker_server_auth_token_enforced(monkeypatch):
    """A worker host with a token aborts sessions that skip or flub auth,
    and lets an authed peer through to the hello validation."""
    from repro.net.ingest_server import WorkerServer

    monkeypatch.delenv(wire.AUTH_TOKEN_ENV, raising=False)
    server = WorkerServer("127.0.0.1", 0, auth_token="sekrit",
                          hello_timeout_s=10.0)
    host, port = server.address
    srv_thread = threading.Thread(
        target=lambda: server.serve_forever(max_sessions=2), daemon=True)
    srv_thread.start()
    try:
        # no auth: the hello is refused
        conn = socket.create_connection((host, port), timeout=10)
        wire.send_message(conn, ("hello", {"nope": True}))
        conn.close()
        _wait(lambda: server.sessions_served >= 1, timeout_s=60)
        assert "auth" in server.session_results[0]
        # authed peer reaches hello validation (junk hello, but PAST auth)
        conn2 = socket.create_connection((host, port), timeout=10)
        wire.send_message(conn2, ("auth", "sekrit"))
        wire.send_message(conn2, ("ping",))
        conn2.close()
        _wait(lambda: server.sessions_served >= 2, timeout_s=60)
        assert "expected a hello" in server.session_results[1]
    finally:
        server.stop()
        srv_thread.join(timeout=30)


# ----------------------------------------------------------- socket drain
def test_socket_backend_drain_conserves_and_matches_single_shot():
    """Tentpole gate over real TCP: a self-hosted socket worker drains the
    whole stream, epochs adopt in order into the PARENT snapshot buffer,
    conservation balances, and the counters are bit-identical to both a
    single-shot ingest (transport adds nothing, loses nothing)."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    epochs = []
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=64,
                 poll_s=0.01, backend="socket")
    rt.attach(t, on_publish=lambda s: epochs.append(s.epoch))
    rt.start(pumps=False)
    assert rt.wait_ready(300)
    rt.start_pumps()
    assert rt.join_pumps(300)
    rep = rt.stop(drain=True)[t.key.tenant_id]

    assert rep["state"] == "stopped"
    assert rep["unaccounted_edges"] == 0
    assert rep["dropped_edges"] == 0
    assert rep["offered_edges"] == rep["ingested_edges"]
    assert epochs == sorted(epochs) and len(epochs) >= 1
    stream, oracle = _single_shot()
    assert rep["published_edges"] == stream.spec.n_edges
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.conn),
                                  np.asarray(oracle.conn))


def test_remote_worker_host_drains_bit_exactly():
    """The ``stream_ingest --listen`` placement: an in-process WorkerServer
    plays the remote host, the runtime dials it via the
    ``socket:HOST:PORT`` spec, and the drain is bit-exact — the same
    contract whether the worker is a spawned child or a standing host."""
    from repro.net.ingest_server import WorkerServer

    server = WorkerServer("127.0.0.1", 0)
    host, port = server.address
    srv_thread = threading.Thread(
        target=lambda: server.serve_forever(max_sessions=1), daemon=True)
    srv_thread.start()
    try:
        reg = _registry()
        t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
        rt = Runtime(queue_capacity=4, publish_policy="every:2",
                     reservoir_k=0, poll_s=0.01,
                     backend=f"socket:{host}:{port}")
        rt.attach(t)
        rt.start(pumps=False)
        assert rt.wait_ready(300)
        rt.start_pumps()
        assert rt.join_pumps(300)
        rep = rt.stop(drain=True)[t.key.tenant_id]
        assert rep["state"] == "stopped"
        assert rep["unaccounted_edges"] == 0
        _, oracle = _single_shot()
        np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.pool),
                                      np.asarray(oracle.pool))
        srv_thread.join(timeout=60)
        assert server.session_results == ["stopped"]
    finally:
        server.stop()
        server.close()


def test_worker_host_aborts_junk_session_and_stays_up():
    """A client speaking junk must kill ITS session loudly (recorded as
    aborted), not the host: a well-formed session afterwards still works."""
    from repro.net.ingest_server import WorkerServer

    server = WorkerServer("127.0.0.1", 0)
    host, port = server.address
    srv_thread = threading.Thread(
        target=lambda: server.serve_forever(max_sessions=2), daemon=True)
    srv_thread.start()
    try:
        junk = socket.create_connection((host, port), timeout=10)
        junk.sendall(b"GET / HTTP/1.1\r\n\r\n")
        junk.close()
        _wait(lambda: server.sessions_served >= 1, timeout_s=60)
        assert server.session_results[0].startswith("aborted")

        reg = _registry()
        t = reg.open("cit-HepPh", "kmatrix", 64, seed=3)
        rt = Runtime(queue_capacity=4, publish_policy="drain:0",
                     reservoir_k=0, poll_s=0.01,
                     backend=f"socket:{host}:{port}")
        rt.attach(t, max_batches=2)
        rt.start()
        assert rt.join_pumps(300)
        rep = rt.stop(drain=True)[t.key.tenant_id]
        assert rep["state"] == "stopped"
        assert rep["unaccounted_edges"] == 0
    finally:
        server.stop()
        server.close()
        srv_thread.join(timeout=30)


# ------------------------------------------------ dead peer + crash-resume
def test_dead_tcp_peer_fails_worker_with_accounting():
    """Satellite: killing the remote end mid-stream must surface as a
    FAILED worker whose error carries last-known accounting, and
    ``Runtime.stop()`` must raise ``WorkerFailure`` with the report —
    never a silent hang (mirror of the process backend's SIGKILL path)."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=4)
    rt = Runtime(queue_capacity=2, publish_policy="every:2", reservoir_k=0,
                 poll_s=0.01, backend="socket")
    h = rt.attach(t, throttle_s=0.05)
    rt.start(pumps=False)
    assert rt.wait_ready(300)
    rt.start_pumps()
    _wait(lambda: h.worker.metrics_snapshot()["ingested_batches"] >= 2,
          timeout_s=300)
    os.kill(h.worker.process.pid, signal.SIGKILL)
    _wait(lambda: h.worker.state == "failed", timeout_s=60)
    assert "lost its TCP peer" in repr(h.worker.error)
    assert "last-known accounting" in repr(h.worker.error)
    assert "ingested_edges=" in repr(h.worker.error)
    with pytest.raises(WorkerFailure, match="lost its TCP peer") as excinfo:
        rt.stop(drain=True)
    assert excinfo.value.report[t.key.tenant_id]["state"] == "failed"


def test_standing_host_connection_blip_redials_quietly():
    """ISSUE 8 satellite: a dropped connection to a STANDING worker host
    gets ONE quiet re-dial — the parent replays retained unadopted items
    into a fresh session (whose first publish is a full resync by
    construction) — and the drain stays conserving and bit-exact with no
    WorkerFailure.  Self-hosted peers keep the loud fail-fast path (see
    ``test_dead_tcp_peer_fails_worker_with_accounting``)."""
    from repro.net.ingest_server import WorkerServer

    server = WorkerServer("127.0.0.1", 0)
    host, port = server.address
    srv_thread = threading.Thread(
        target=lambda: server.serve_forever(max_sessions=2), daemon=True)
    srv_thread.start()
    try:
        reg = _registry()
        t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
        rt = Runtime(queue_capacity=4, publish_policy="every:2",
                     reservoir_k=0, poll_s=0.01,
                     backend=f"socket:{host}:{port}")
        h = rt.attach(t, throttle_s=0.05)
        rt.start(pumps=False)
        assert rt.wait_ready(300)
        rt.start_pumps()
        # mid-stream, with adopted publishes behind us, sever the link
        _wait(lambda: h.worker.metrics_snapshot()["ingested_batches"] >= 2,
              timeout_s=300)
        h.worker._sock.shutdown(socket.SHUT_RDWR)
        assert rt.join_pumps(300)
        rep = rt.stop(drain=True)[t.key.tenant_id]
        assert rep["state"] == "stopped"
        assert rep["unaccounted_edges"] == 0
        assert rep["dropped_edges"] == 0
        assert h.worker._redial_used, "the blip must have used the re-dial"
        stream, oracle = _single_shot()
        assert rep["published_edges"] == stream.spec.n_edges
        np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.pool),
                                      np.asarray(oracle.pool))
        np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.conn),
                                      np.asarray(oracle.conn))
        srv_thread.join(timeout=60)
        assert server.sessions_served == 2, server.session_results
        # first session died with the link (worker-side "failed" or a
        # transport abort, depending on who noticed first); the re-dialed
        # session is the one that must finish cleanly
        assert server.session_results[0] != "stopped"
        assert server.session_results[1] == "stopped"
    finally:
        server.stop()
        server.close()


def test_socket_sharded_sigkill_resume_conserves_and_serves_exactly(
        tmp_path):
    """Satellite acceptance over TCP (mirror of the process-backend crash
    test): SIGKILL one shard's self-hosted socket worker mid-stream, tear
    the rest down crash-like, restore every shard from its checkpoint via
    the manifest (which must record the socket backend), drain — per-shard
    conservation holds, the merged state is bit-identical to a
    never-crashed single sketch, and engine == direct on the restore."""
    ckpt = str(tmp_path / "ckpt")
    reg_a = _registry()
    st_a = reg_a.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    rt_a = Runtime(queue_capacity=2, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01,
                   backend="socket")
    handles_a = attach_shards(rt_a, st_a, throttle_s=[0.05, 0.12])
    rt_a.start(pumps=False)
    assert rt_a.wait_ready(300)
    rt_a.start_pumps()
    _wait(lambda: all(h.worker.metrics_snapshot()["checkpoints"] >= 1
                      for h in handles_a), timeout_s=300)
    _wait(lambda: handles_a[0].worker.metrics_snapshot()["ingested_batches"]
          >= 3, timeout_s=300)
    victim = handles_a[0].worker
    os.kill(victim.process.pid, signal.SIGKILL)
    _wait(lambda: victim.state == "failed", timeout_s=60)
    assert "lost its TCP peer" in repr(victim.error)
    rt_a.kill()
    nb = st_a.stream.num_batches
    manifest = read_shard_manifest(ckpt)
    assert manifest["n_shards"] == 2
    assert manifest["runtime_backend"] == "socket"

    reg_b = _registry()
    st_b = reg_b.open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                              n_shards=manifest["n_shards"],
                              shard_seed=manifest["shard_seed"])
    rt_b = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, poll_s=0.01, backend="socket")
    handles_b = attach_shards(rt_b, st_b, restore=True)
    restored_offsets = [s.offset for s in st_b.shards]
    assert any(0 < o for o in restored_offsets), \
        "restore must resume from the checkpoints, not from scratch"
    assert any(o < nb for o in restored_offsets), "kill was not mid-stream"
    rt_b.start(pumps=False)
    assert rt_b.wait_ready(300)
    rt_b.start_pumps()
    assert rt_b.join_pumps(300)
    rt_b.stop(drain=True)

    cons = sharded_conservation(handles_b, st_b.stream.spec.n_edges)
    assert all(u == 0 for u in cons["per_shard_unaccounted"]), cons

    stream, oracle = _single_shot()
    merged = st_b.merged_snapshot()
    np.testing.assert_array_equal(np.asarray(merged.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(merged.sketch.conn),
                                  np.asarray(oracle.conn))
    assert merged.n_edges == stream.spec.n_edges

    engine = ShardedQueryEngine(QueryEngine(min_bucket=8))
    snap = st_b.snapshot
    reqs = synth_requests(32, mix_for_sketch("kmatrix"),
                          n_nodes=stream.spec.n_nodes, seed=11,
                          heavy_universe=256, heavy_threshold=5.0)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = sharded_direct_answers(snap, reqs)
    for g, w in zip(got, want):
        assert values_match(g, w)


# ------------------------------------------------------- query front-end
class _StubEngine:
    """Duck-typed engine: QueryServer only needs execute()."""

    def __init__(self, delay_s=0.0, fail=False):
        self.delay_s = delay_s
        self.fail = fail
        self.calls = 0

    def execute(self, snapshot, requests):
        self.calls += 1
        if self.fail:
            raise RuntimeError("engine-kaboom")
        if self.delay_s:
            time.sleep(self.delay_s)
        return [types.SimpleNamespace(epoch=snapshot.epoch, value=float(i))
                for i, _ in enumerate(requests)]


def _stub_snapshot(epoch=5, n_edges=1234):
    return types.SimpleNamespace(epoch=epoch, n_edges=n_edges)


def test_query_server_roundtrip_epoch_stamped():
    from repro.net.query_server import QueryClient, QueryServer

    snap = _stub_snapshot(epoch=7)
    server = QueryServer(_StubEngine(), lambda: snap,
                         info={"kind": "stub"}).start()
    try:
        client = QueryClient(server.address)
        info = client.info()
        assert info["kind"] == "stub" and info["epoch"] == 7
        values, epoch = client.query(["a", "b", "c"])
        assert values == [0.0, 1.0, 2.0]
        assert epoch == 7  # every answer names the epoch it came from
        snap.epoch = 9  # snapshot_fn is re-polled per batch: fresh epochs
        _, epoch = client.query(["a"])
        assert epoch == 9
        client.close()
        # replies are sent before the ledger update; wait out the race
        _wait(lambda: server.stats()["served_requests"] == 4, timeout_s=30)
        stats = server.stats()
        assert stats["offered_requests"] == stats["admitted_requests"] == 4
    finally:
        server.stop()


def test_query_server_admission_shed_is_accounted():
    """Satellite: overload shed is never silent.  With a slow engine and a
    tiny inflight budget, concurrent clients MUST see rejections carrying a
    positive Retry-After hint, and the server ledger must balance exactly:
    offered == admitted + shed, admitted == served."""
    from repro.net.query_server import QueryClient, QueryServer

    server = QueryServer(_StubEngine(delay_s=0.05), _stub_snapshot,
                         max_inflight=2, batch_max=2).start()
    outcomes = []
    lock = threading.Lock()

    def hammer():
        client = QueryClient(server.address)
        for _ in range(5):
            payload = client.call(["q", "r"])
            with lock:
                outcomes.append(payload)
        client.close()

    try:
        threads = [threading.Thread(target=hammer) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        _wait(lambda: server.stats()["inflight"] == 0, timeout_s=30)
        stats = server.stats()
    finally:
        server.stop()
    kinds = [p["kind"] for p in outcomes]
    assert kinds.count("result") + kinds.count("reject") == len(outcomes)
    assert kinds.count("reject") > 0, "6x2 concurrent vs max_inflight=2 " \
        "never shed — admission control is not engaging"
    for p in outcomes:
        if p["kind"] == "reject":
            assert p["reason"] == "overloaded"
            assert p["retry_after_ms"] > 0
    assert stats["offered_requests"] == (stats["admitted_requests"]
                                         + stats["shed_overload"]
                                         + stats["shed_rate_limited"]
                                         + stats["shed_too_large"])
    assert stats["offered_requests"] == 2 * len(outcomes)
    assert stats["served_requests"] == stats["admitted_requests"]
    assert 2 * kinds.count("result") == stats["served_requests"]


def test_query_server_per_tenant_rate_limit():
    from repro.net.query_server import QueryClient, QueryServer, Rejected

    server = QueryServer(_StubEngine(), _stub_snapshot,
                         tenant_qps=1.0, tenant_burst=2.0).start()
    try:
        noisy = QueryClient(server.address, tenant="noisy")
        noisy.query(["a", "b"])  # burst allows this
        with pytest.raises(Rejected) as excinfo:
            noisy.query(["c"])  # bucket empty: ~1s to refill
        assert excinfo.value.reason == "rate_limited"
        assert excinfo.value.retry_after_ms > 0
        # another tenant has its own bucket — not collateral damage
        quiet = QueryClient(server.address, tenant="quiet")
        assert quiet.query(["x"])[0] == [0.0]
        noisy.close()
        quiet.close()
        assert server.stats()["shed_rate_limited"] == 1
    finally:
        server.stop()


def test_query_server_rejects_never_admittable_frames_as_too_large():
    """REVIEW fix: a frame bigger than the smallest admission ceiling can
    never succeed, so it must be rejected with a distinct ``too_large``
    verdict (naming the limit, no lying retry-after) — and counted."""
    from repro.net.query_server import QueryClient, QueryServer, Rejected

    # bigger than tenant_burst: the token bucket caps at burst forever
    server = QueryServer(_StubEngine(), _stub_snapshot,
                         tenant_qps=5.0, tenant_burst=2.0).start()
    try:
        client = QueryClient(server.address)
        with pytest.raises(Rejected) as excinfo:
            client.query(["a", "b", "c"])
        assert excinfo.value.reason == "too_large"
        assert client.query(["a", "b"])[0] == [0.0, 1.0]  # burst-sized: fine
        client.close()
        stats = server.stats()
        assert stats["shed_too_large"] == 3
        assert stats["offered_requests"] == (stats["admitted_requests"]
                                             + stats["shed_overload"]
                                             + stats["shed_rate_limited"]
                                             + stats["shed_too_large"])
    finally:
        server.stop()
    # bigger than max_inflight: inflight + n > cap for every inflight >= 0
    server = QueryServer(_StubEngine(), _stub_snapshot,
                         max_inflight=2).start()
    try:
        client = QueryClient(server.address)
        payload = client.call(["a", "b", "c"])
        assert payload["kind"] == "reject"
        assert payload["reason"] == "too_large"
        assert payload["max_requests"] == 2
        client.close()
    finally:
        server.stop()


def test_slow_reader_stalls_only_its_own_connection():
    """REVIEW fix (head-of-line blocking): replies go through bounded
    per-connection writer queues, so a client that never reads its socket
    cannot stall the shared executor — concurrent well-behaved clients
    keep getting answers immediately while the stalled connection alone
    overflows and is dropped."""
    from repro.net.query_server import QueryClient, QueryServer

    class BigEngine:
        def execute(self, snapshot, requests):
            # ~1 MB per reply so a handful overfills any socket buffer
            return [types.SimpleNamespace(epoch=snapshot.epoch,
                                          value="x" * (1 << 20))
                    for _ in requests]

    server = QueryServer(BigEngine(), _stub_snapshot,
                         frame_deadline_s=2.0, reply_queue_max=4).start()
    try:
        stall = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        stall.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        stall.connect(server.address)
        for i in range(16):  # ~16 MB of replies nobody will ever read
            wire.send_message(stall, ("query", {"id": i, "tenant": "stall",
                                                "requests": ["q"]}))
        fast = QueryClient(server.address, frame_deadline_s=30.0)
        t0 = time.monotonic()
        values, _ = fast.query(["a"], timeout_s=30)
        fast_latency = time.monotonic() - t0
        assert values == ["x" * (1 << 20)]
        # without per-connection writers the executor would be wedged in
        # 2 s-deadline sends to the stalled socket and this would take
        # many seconds; with them it's immediate
        assert fast_latency < 2.0, \
            f"well-behaved client waited {fast_latency:.1f}s behind a " \
            "stalled one — executor is blocking on slow-client sends"
        fast.close()
        stall.close()
        # nothing silently lost: everything offered is accounted admitted
        # (the stalled client's replies were executed then dropped at ITS
        # dead connection, which is a delivery failure, not a shed)
        stats = server.stats()
        assert stats["offered_requests"] == (stats["admitted_requests"]
                                             + stats["shed_overload"]
                                             + stats["shed_rate_limited"]
                                             + stats["shed_too_large"])
    finally:
        server.stop()


def test_netloadgen_counts_transport_death_as_aborted_not_shed():
    """REVIEW fix: a connection that dies mid-run (reset/timeout) must
    surface as ``aborted`` + ``transport_error`` in the report, never be
    folded into ``shed`` — sheds are the server's accounted admission
    decisions, not client-side casualties."""
    from repro.serving.loadgen import NetLoadGen

    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)
    address = listener.getsockname()[:2]

    def half_server():
        conn, _ = listener.accept()
        try:
            msg = wire.recv_message(conn, poll_s=30.0)
            wire.send_message(conn, ("result", {
                "id": msg[1]["id"], "epoch": 1,
                "values": [0.0] * len(msg[1]["requests"])}))
        finally:
            conn.close()  # dies after one answer

    srv = threading.Thread(target=half_server, daemon=True)
    srv.start()
    try:
        gen = NetLoadGen(target_qps=100000.0, connections=1, batch_max=4)
        rep = gen.run(address, list(range(40)))
    finally:
        srv.join(timeout=30)
        listener.close()
    assert rep.accepted == 4  # the one answered batch
    assert rep.aborted == 36  # in-flight + unsent remainder
    assert rep.shed == 0, "transport death was misaccounted as shed"
    assert rep.errors == 0
    assert rep.transport_error is not None
    assert (rep.accepted + rep.shed + rep.errors + rep.aborted
            == rep.n_requests)


def test_query_server_engine_error_answered_not_fatal():
    """An engine exception answers THAT call as an error and the server
    keeps serving; junk frames kill only their own session."""
    from repro.net.query_server import QueryClient, QueryServer

    engine = _StubEngine(fail=True)
    snap = _stub_snapshot()
    server = QueryServer(engine, lambda: snap).start()
    try:
        client = QueryClient(server.address)
        with pytest.raises(RuntimeError, match="engine-kaboom"):
            client.query(["a"])
        engine.fail = False
        assert client.query(["a"])[0] == [0.0]  # same connection, recovered
        client.close()
        # a junk-speaking client: its session dies, the server does not
        junk = socket.create_connection(server.address, timeout=10)
        junk.sendall(b"\x00" * 64)
        junk.close()
        c2 = QueryClient(server.address)
        assert c2.query(["a"])[0] == [0.0]
        c2.close()
        _wait(lambda: server.stats()["served_requests"] == 2, timeout_s=30)
        assert server.stats()["errored_requests"] == 1
    finally:
        server.stop()


@pytest.mark.slow
def test_multi_connection_soak_live_ingest():
    """Soak (slow lane): 8 loadgen connections against the TCP front-end
    over a LIVE-ingesting tenant for thousands of requests — zero errors,
    every request accounted, answers epoch-stamped and the freshest answer
    at least as new as the first publish."""
    from repro.net.query_server import QueryServer
    from repro.serving import warm_bucket_ladder
    from repro.serving.loadgen import NetLoadGen

    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    t.step(2)
    t.publish()
    n_nodes = t.stream.spec.n_nodes
    engine = QueryEngine(min_bucket=8)
    mix = mix_for_sketch("kmatrix")
    kw = dict(n_nodes=n_nodes, heavy_universe=256, heavy_threshold=5.0)
    warm_bucket_ladder(engine, t.snapshot, synth_requests(64, mix, seed=99,
                                                          **kw))
    stop_ingest = threading.Event()

    def live_ingest():
        while not stop_ingest.is_set():
            if not t.step(1):
                break
            t.publish()
            time.sleep(0.02)

    ingester = threading.Thread(target=live_ingest, daemon=True)
    server = QueryServer(engine, lambda: t.snapshot).start()
    first_epoch = t.snapshot.epoch
    ingester.start()
    try:
        reqs = synth_requests(4000, mix, seed=13, **kw)
        rep = NetLoadGen(target_qps=400.0, connections=8,
                         batch_max=64).run(server.address, reqs)
    finally:
        stop_ingest.set()
        ingester.join(timeout=60)
        server.stop()
    assert rep.errors == 0
    assert rep.accepted + rep.shed == rep.n_requests
    assert rep.accepted == rep.n_requests  # nominal load: nothing shed
    assert rep.last_epoch is not None and rep.last_epoch >= first_epoch
    assert np.isfinite(rep.p99_ms)
    stats = server.stats()
    assert stats["offered_requests"] == (stats["admitted_requests"]
                                         + stats["shed_overload"]
                                         + stats["shed_rate_limited"]
                                         + stats["shed_too_large"])
