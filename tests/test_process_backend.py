"""Process execution backend (ISSUE 5 tentpole): spawn children owning
their sketches under the transport-agnostic runtime contract — drain
conservation + bit-exactness vs a single-shot ingest, SIGKILL crash-resume
through per-shard checkpoints + the shard manifest, worker-failure
propagation to ``Runtime.stop()``, manifest corruption hard-failing
restore, and the graceful signal-drain path (DESIGN.md §Runtime
§Backends)."""
import json
import os
import signal
import time

import numpy as np
import jax
import pytest

from repro.core import kmatrix
from repro.runtime import Runtime, WorkerFailure
from repro.serving import (
    QueryEngine,
    ShardedQueryEngine,
    SketchRegistry,
    attach_shards,
    mix_for_sketch,
    read_shard_manifest,
    sharded_conservation,
    sharded_direct_answers,
    synth_requests,
)
from repro.serving.gates import values_match


def _registry(**kw):
    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


def _single_shot(dataset="cit-HepPh", kind="kmatrix", budget_kb=64, seed=0):
    reg = _registry()
    t = reg.open(dataset, kind, budget_kb, seed=seed)
    sk = t.snapshot.sketch
    ing = jax.jit(kmatrix.ingest)
    for b in t.stream:
        sk = ing(sk, b)
    return t.stream, sk


def _wait(cond, timeout_s=120.0, poll_s=0.01):
    deadline = time.monotonic() + timeout_s
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError("condition not met in time")
        time.sleep(poll_s)


# ----------------------------------------------------------- process drain
def test_process_backend_drain_conserves_and_matches_single_shot():
    """The tentpole gate on the process backend: a pump-fed spawn child
    drains the whole stream, every published epoch lands in the PARENT's
    snapshot buffer, conservation balances, and the final counters are
    bit-identical to a single-shot ingest."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    epochs = []
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=64,
                 poll_s=0.01, backend="process")
    rt.attach(t, on_publish=lambda s: epochs.append(s.epoch))
    rt.start(pumps=False)
    assert rt.wait_ready(300)
    rt.start_pumps()
    assert rt.join_pumps(300)
    rep = rt.stop(drain=True)[t.key.tenant_id]

    assert rep["state"] == "stopped"
    assert rep["unaccounted_edges"] == 0
    assert rep["dropped_edges"] == 0
    assert rep["offered_edges"] == rep["ingested_edges"]
    assert epochs == sorted(epochs) and len(epochs) >= 1
    stream, oracle = _single_shot()
    assert rep["published_edges"] == stream.spec.n_edges
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.conn),
                                  np.asarray(oracle.conn))


def test_process_backend_requires_registry_tenant_and_policy_spec():
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=1)
    t_bare = reg.open("cit-HepPh", "kmatrix", 64, seed=2)
    t_bare.origin = None  # simulate a hand-built tenant
    rt = Runtime(backend="process", reservoir_k=0)
    with pytest.raises(ValueError, match="registry-opened"):
        rt.attach(t_bare)
    from repro.runtime import EveryNBatches
    rt2 = Runtime(backend="process", reservoir_k=0,
                  publish_policy=EveryNBatches(2))
    with pytest.raises(TypeError, match="SPEC string"):
        rt2.attach(t)
    with pytest.raises(ValueError, match="runtime backend"):
        Runtime(backend="fiber")


# ------------------------------------------------- SIGKILL crash + resume
def test_process_sharded_sigkill_resume_conserves_and_serves_exactly(
        tmp_path):
    """Satellite acceptance (mirror of the thread crash test in
    test_sharding.py): SIGKILL one shard's worker PROCESS mid-stream,
    tear the rest down crash-like, restore every shard from its last
    checkpoint via the manifest, drain — per-shard conservation holds and
    the merged state is bit-identical to a never-crashed single sketch,
    with engine == direct on the restored registry."""
    ckpt = str(tmp_path / "ckpt")
    reg_a = _registry()
    st_a = reg_a.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    rt_a = Runtime(queue_capacity=2, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01,
                   backend="process")
    # different throttles drive the shards to different stream offsets
    handles_a = attach_shards(rt_a, st_a, throttle_s=[0.05, 0.12])
    rt_a.start(pumps=False)
    assert rt_a.wait_ready(300)
    rt_a.start_pumps()
    _wait(lambda: all(h.worker.metrics_snapshot()["checkpoints"] >= 1
                      for h in handles_a))
    _wait(lambda: handles_a[0].worker.metrics_snapshot()["ingested_batches"]
          >= 3)
    victim = handles_a[0].worker
    os.kill(victim.process.pid, signal.SIGKILL)
    _wait(lambda: victim.state == "failed", timeout_s=60)
    assert "exitcode" in repr(victim.error)
    rt_a.kill()
    # the kill must be mid-stream for at least one shard
    nb = st_a.stream.num_batches
    manifest = read_shard_manifest(ckpt)
    assert manifest["n_shards"] == 2
    assert manifest["runtime_backend"] == "process"

    reg_b = _registry()
    st_b = reg_b.open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                              n_shards=manifest["n_shards"],
                              shard_seed=manifest["shard_seed"])
    rt_b = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                   checkpoint_dir=ckpt, poll_s=0.01, backend="process")
    handles_b = attach_shards(rt_b, st_b, restore=True)
    restored_offsets = [s.offset for s in st_b.shards]
    assert any(0 < o for o in restored_offsets), \
        "restore must resume from the checkpoints, not from scratch"
    assert any(o < nb for o in restored_offsets), "kill was not mid-stream"
    rt_b.start(pumps=False)
    assert rt_b.wait_ready(300)
    rt_b.start_pumps()
    assert rt_b.join_pumps(300)
    rt_b.stop(drain=True)

    cons = sharded_conservation(handles_b, st_b.stream.spec.n_edges)
    assert all(u == 0 for u in cons["per_shard_unaccounted"]), cons

    stream, oracle = _single_shot()
    merged = st_b.merged_snapshot()
    np.testing.assert_array_equal(np.asarray(merged.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(merged.sketch.conn),
                                  np.asarray(oracle.conn))
    assert merged.n_edges == stream.spec.n_edges

    engine = ShardedQueryEngine(QueryEngine(min_bucket=8))
    snap = st_b.snapshot
    reqs = synth_requests(32, mix_for_sketch("kmatrix"),
                          n_nodes=stream.spec.n_nodes, seed=11,
                          heavy_universe=256, heavy_threshold=5.0)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = sharded_direct_answers(snap, reqs)
    for g, w in zip(got, want):
        assert values_match(g, w)


def test_parent_side_publish_failure_terminates_child():
    """A parent-side adoption failure (e.g. an on_publish callback raising)
    must not leak a live child: the handle goes failed AND the child is
    terminated, and the failure surfaces at stop()."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=7)
    rt = Runtime(queue_capacity=4, publish_policy="every:1", reservoir_k=0,
                 poll_s=0.01, backend="process")

    def bad_callback(snap):
        raise RuntimeError("callback-kaboom")

    h = rt.attach(t, on_publish=bad_callback)
    rt.start()
    _wait(lambda: h.worker.state == "failed", timeout_s=180)
    assert "callback-kaboom" in (h.worker.error_tb or "")
    _wait(lambda: not h.worker.process.is_alive(), timeout_s=30)
    with pytest.raises(WorkerFailure, match="callback-kaboom"):
        rt.stop(drain=True)


# ------------------------------------------------- failure propagation
def test_worker_failure_propagates_to_stop_with_traceback():
    """Satellite: a failed worker must surface at the Runtime.stop() call
    site — original exception AND traceback — not only via health()."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=5)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                 poll_s=0.01)
    handle = rt.attach(t, max_batches=3)

    def explode(item, now):
        raise RuntimeError("boom-at-ingest")

    handle.worker._ingest = explode
    rt.start()
    _wait(lambda: not handle.worker.is_alive())
    with pytest.raises(WorkerFailure) as excinfo:
        rt.stop(drain=True)
    err = excinfo.value
    assert "boom-at-ingest" in str(err)
    assert err.failures[0]["tenant_id"] == t.key.tenant_id
    assert "boom-at-ingest" in (err.failures[0]["traceback"] or "")
    # the accounting report still rides along for the caller
    assert err.report[t.key.tenant_id]["state"] == "failed"
    # and an explicit opt-out returns the report instead of raising
    rep = rt.stop(drain=True, raise_on_failure=False)
    assert rep[t.key.tenant_id]["state"] == "failed"


def test_graceful_signal_drain_flushes_checkpoint(tmp_path):
    """Satellite: SIGTERM on a serving driver drains and flushes a final
    checkpoint before exiting 128+signum (install_graceful_drain)."""
    from repro.checkpoint import store
    from repro.launch.query_serve import install_graceful_drain

    old_term = signal.getsignal(signal.SIGTERM)
    old_int = signal.getsignal(signal.SIGINT)
    try:
        ckpt = str(tmp_path / "ckpt")
        reg = _registry()
        t = reg.open("cit-HepPh", "kmatrix", 64, seed=6)
        rt = Runtime(queue_capacity=4, publish_policy="every:100000",
                     reservoir_k=0, checkpoint_dir=ckpt, poll_s=0.01)
        handle = rt.attach(t, throttle_s=0.01)
        install_graceful_drain(rt)
        rt.start()
        _wait(lambda: handle.worker.metrics.ingested_batches >= 2)
        with pytest.raises(SystemExit) as excinfo:
            os.kill(os.getpid(), signal.SIGTERM)
            # the handler runs on the main thread at the next bytecode
            # boundary; give it one
            time.sleep(5)
        assert excinfo.value.code == 128 + signal.SIGTERM
        # the drain conserved every offered edge (the pump stops early on
        # shutdown — full-stream ingest is NOT the contract here) and the
        # final checkpoint made it to disk for the next --restore
        cons = handle.conservation()
        assert cons["unaccounted_edges"] == 0
        assert t.snapshot.n_edges > 0
        tenant_dir = rt._tenant_dir(ckpt, t)
        assert store.latest_step(tenant_dir) is not None
        meta = store.read_meta(tenant_dir)
        assert meta["extra"]["n_edges"] == t.snapshot.n_edges
    finally:
        signal.signal(signal.SIGTERM, old_term)
        signal.signal(signal.SIGINT, old_int)


# ------------------------------------------------- manifest hardening
def test_truncated_shard_manifest_fails_restore_loudly(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    reg = _registry()
    st = reg.open_sharded("cit-HepPh", "kmatrix", 64, seed=0, n_shards=2)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                 checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01)
    attach_shards(rt, st, max_batches=1)
    rt.start()
    rt.join_pumps(120)
    rt.stop(drain=True)
    manifest_path = os.path.join(ckpt, "shard_manifest.json")
    full = open(manifest_path).read()
    assert json.loads(full)["runtime_backend"] == "thread"

    # torn write: keep only the first half of the JSON
    with open(manifest_path, "w") as f:
        f.write(full[: len(full) // 2])
    with pytest.raises(ValueError, match="truncated or corrupt"):
        read_shard_manifest(ckpt)
    other = _registry().open_sharded("cit-HepPh", "kmatrix", 64, seed=0,
                                     n_shards=2)
    rt2 = Runtime(queue_capacity=4, reservoir_k=0, checkpoint_dir=ckpt,
                  poll_s=0.01)
    with pytest.raises(ValueError, match="truncated or corrupt"):
        attach_shards(rt2, other, restore=True)

    # a manifest missing required keys is just as unverifiable
    with open(manifest_path, "w") as f:
        json.dump({"n_shards": 2}, f)
    with pytest.raises(ValueError, match="missing required keys"):
        read_shard_manifest(ckpt)
