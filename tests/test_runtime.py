"""Background ingest runtime: queues/backpressure, publish policies, worker
lifecycle, conservation under graceful drain, and crash-safe resume
(DESIGN.md §Runtime)."""
import threading
import time

import numpy as np
import jax
import pytest

from repro.core import kmatrix
from repro.runtime import (
    BoundedEdgeQueue,
    EveryNBatches,
    QueueDrainWatermark,
    QueueItem,
    Runtime,
    WallClockInterval,
    make_policy,
)
from repro.serving import QueryEngine, SketchRegistry
from repro.serving import engine as eng
from repro.streams.reservoir import Reservoir


def _item(offset, n=8, n_pad=0, seed=0):
    rng = np.random.default_rng(seed + offset)
    src = rng.integers(0, 100, n + n_pad).astype(np.int32)
    dst = rng.integers(0, 100, n + n_pad).astype(np.int32)
    w = np.concatenate([np.ones(n, np.int32), np.zeros(n_pad, np.int32)])
    return QueueItem.from_arrays(offset, src, dst, w)


def _wait(cond, timeout_s=60.0, poll_s=0.005):
    deadline = time.monotonic() + timeout_s
    while not cond():
        if time.monotonic() >= deadline:
            raise TimeoutError("condition not met in time")
        time.sleep(poll_s)


def _registry(**kw):
    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


def _single_shot(registry_kwargs=None, dataset="cit-HepPh", kind="kmatrix",
                 budget_kb=64, seed=0):
    """Oracle: the whole stream ingested once into one sketch, no runtime."""
    reg = _registry(**(registry_kwargs or {}))
    t = reg.open(dataset, kind, budget_kb, seed=seed)
    sk = t.snapshot.sketch
    ing = jax.jit(kmatrix.ingest)
    for b in t.stream:
        sk = ing(sk, b)
    return t.stream, sk


# ------------------------------------------------------------------ queueing
def test_queue_item_counts_only_nonpadding_edges():
    assert _item(0, n=5, n_pad=3).n_edges == 5


def test_queue_block_policy_blocks_until_consumed():
    q = BoundedEdgeQueue(2, "block")
    assert q.put(_item(0)) and q.put(_item(1))
    assert not q.put(_item(2), timeout=0.05), "full queue must block/timeout"
    got = []
    consumer = threading.Thread(target=lambda: got.append(q.get(timeout=5)))
    consumer.start()
    assert q.put(_item(2), timeout=5), "put must unblock once space frees"
    consumer.join()
    assert got[0].offset == 0, "FIFO"
    assert q.dropped_batches == 0


def test_queue_drop_oldest_accounts_every_drop():
    q = BoundedEdgeQueue(2, "drop_oldest")
    for i in range(5):
        assert q.put(_item(i, n=8))
    assert q.depth() == 2
    assert q.dropped_batches == 3
    assert q.dropped_edges == 3 * 8
    # survivors are the newest, in order
    assert [q.get().offset for _ in range(2)] == [3, 4]
    # conservation at queue level: accepted == consumed + dropped
    assert q.accepted_edges == 5 * 8
    assert q.accepted_edges - q.dropped_edges == 2 * 8


def test_queue_spill_preserves_fifo_and_loses_nothing(tmp_path):
    q = BoundedEdgeQueue(2, "spill", spill_dir=str(tmp_path / "spill"))
    items = [_item(i, n=4) for i in range(7)]
    for it in items:
        assert q.put(it)
    assert q.spilled_batches == 5
    assert q.dropped_batches == 0
    assert q.depth() == 7
    out = [q.get(timeout=1) for _ in range(7)]
    assert [o.offset for o in out] == list(range(7)), "spill must stay FIFO"
    for want, got in zip(items, out):
        np.testing.assert_array_equal(want.src, got.src)
        np.testing.assert_array_equal(want.weight, got.weight)
    assert q.get(timeout=0.01) is None


def test_spill_files_are_wire_item_frames(tmp_path):
    """ISSUE 8: the spill-file format IS the v3 columnar wire frame — one
    ``item_cols`` frame per ``spill_*.kmx`` file, decodable by the wire
    codec directly, with trace ids and padding accounting intact."""
    from repro.net import wire

    q = BoundedEdgeQueue(1, "spill", spill_dir=str(tmp_path / "spill"))
    items = [_item(i, n=4, n_pad=2) for i in range(3)]
    for it in items:
        assert q.put(it)
    files = sorted((tmp_path / "spill").glob("spill_*.kmx"))
    assert len(files) == 2  # capacity 1 ⇒ two items spilled
    spilled = items[1:]
    for path, want in zip(files, spilled):
        msg = wire.decode_message(path.read_bytes(), on_wire=False)
        assert msg[0] == "item" and msg[1] == want.offset
        np.testing.assert_array_equal(msg[2], want.src)
        np.testing.assert_array_equal(msg[3], want.dst)
        np.testing.assert_array_equal(msg[4], want.weight)
        assert msg[5] == want.n_edges  # non-padding count survives
        assert msg[6] == want.trace_id
    # and the queue itself reads them back losslessly (FIFO, accounted)
    out = [q.get(timeout=1) for _ in range(3)]
    assert [o.offset for o in out] == [0, 1, 2]
    assert out[2].n_edges == 4 and out[2].src.shape[0] == 6
    assert q.stats()["spill_pending"] == 0


def test_queue_spill_interleaved_put_get_keeps_order(tmp_path):
    q = BoundedEdgeQueue(1, "spill", spill_dir=str(tmp_path / "spill"))
    seen = []
    for i in range(10):
        q.put(_item(i))
        if i % 2:
            seen.append(q.get(timeout=1).offset)
    while (it := q.get(timeout=0.01)) is not None:
        seen.append(it.offset)
    assert seen == list(range(10))


def test_queue_spill_concurrent_producer_consumer(tmp_path):
    """Producer spilling while a consumer drains concurrently: no lost
    batches, FIFO preserved, no race between slot claim and file write."""
    q = BoundedEdgeQueue(1, "spill", spill_dir=str(tmp_path / "spill"))
    n = 40

    def produce():
        for i in range(n):
            assert q.put(_item(i, n=4))

    thread = threading.Thread(target=produce)
    thread.start()
    got = []
    while len(got) < n:
        it = q.get(timeout=10)
        assert it is not None
        got.append(it.offset)
    thread.join(timeout=10)
    assert got == list(range(n))
    assert q.dropped_batches == 0


def test_queue_close_with_pending_spill_drains_everything(tmp_path):
    """Satellite audit: close() with a non-empty disk FIFO must not strand
    or lose spilled batches — they stay drainable (FIFO, complete) until
    the queue is empty, and depth/spill_pending account for them."""
    q = BoundedEdgeQueue(2, "spill", spill_dir=str(tmp_path / "spill"))
    items = [_item(i, n=4) for i in range(8)]
    for it in items:
        assert q.put(it)
    assert q.stats()["spill_pending"] == 6
    q.close()
    assert not q.put(_item(99)), "closed queue must refuse new work"
    # conservation: every accepted batch is still retrievable, in order
    out = [q.get(timeout=1) for _ in range(8)]
    assert [o.offset for o in out] == list(range(8))
    for want, got in zip(items, out):
        np.testing.assert_array_equal(want.src, got.src)
        np.testing.assert_array_equal(want.weight, got.weight)
    assert q.get(timeout=0.01) is None
    s = q.stats()
    assert s["depth"] == 0 and s["spill_pending"] == 0
    assert s["accepted_edges"] == 8 * 4 and s["dropped_edges"] == 0


def test_queue_fresh_spill_dir_purges_stale_files(tmp_path):
    """Satellite audit: spill files left by a crashed run must never be
    re-ingested (or leak) when a fresh queue reuses the same spill_dir."""
    spill_dir = tmp_path / "spill"
    q1 = BoundedEdgeQueue(1, "spill", spill_dir=str(spill_dir))
    for i in range(5):
        assert q1.put(_item(i, n=4))
    # crash-like: drop q1 undrained; its spill files stay on disk
    assert len(list(spill_dir.glob("spill_*"))) == 4
    (spill_dir / "spill_000000000099.npz.tmp").write_bytes(b"torn write")

    q2 = BoundedEdgeQueue(1, "spill", spill_dir=str(spill_dir))
    assert q2.stale_spills_removed == 5
    assert list(spill_dir.glob("spill_*")) == []
    # the fresh queue serves ONLY its own items, in its own order
    fresh = [_item(100 + i, n=4) for i in range(3)]
    for it in fresh:
        assert q2.put(it)
    got = [q2.get(timeout=1).offset for _ in range(3)]
    assert got == [100, 101, 102]
    assert q2.get(timeout=0.01) is None
    assert q2.stats()["dropped_edges"] == 0


def test_queue_close_unblocks_producer_and_consumer():
    q = BoundedEdgeQueue(1, "block")
    q.put(_item(0))
    results = {}

    def producer():
        results["put"] = q.put(_item(1), timeout=10)

    thread = threading.Thread(target=producer)
    thread.start()
    time.sleep(0.05)
    q.close()
    thread.join(timeout=5)
    assert results["put"] is False
    # closed-but-nonempty still drains, then returns None
    assert q.get(timeout=0.5).offset == 0
    assert q.get(timeout=0.5) is None


def test_queue_rejects_bad_config(tmp_path):
    with pytest.raises(ValueError, match="policy"):
        BoundedEdgeQueue(4, "yolo")
    with pytest.raises(ValueError, match="spill_dir"):
        BoundedEdgeQueue(4, "spill")
    with pytest.raises(ValueError, match="capacity"):
        BoundedEdgeQueue(0, "block")


# ------------------------------------------------------------------ policies
def test_policy_every_n_batches():
    p = EveryNBatches(3)
    assert not p.should_publish(batches_since_publish=2, now=0.0,
                                queue_depth=5)
    assert p.should_publish(batches_since_publish=3, now=0.0, queue_depth=5)


def test_policy_wall_clock_interval_uses_clock_not_batches():
    p = WallClockInterval(10.0)
    # arms on first observation, never publishes with nothing pending
    assert not p.should_publish(batches_since_publish=0, now=0.0,
                                queue_depth=0)
    assert not p.should_publish(batches_since_publish=5, now=0.0,
                                queue_depth=0)
    assert not p.should_publish(batches_since_publish=5, now=9.0,
                                queue_depth=0)
    assert p.should_publish(batches_since_publish=1, now=10.5, queue_depth=0)
    p.note_published(10.5)
    assert not p.should_publish(batches_since_publish=1, now=11.0,
                                queue_depth=0)


def test_policy_drain_watermark_with_overload_backstop():
    p = QueueDrainWatermark(watermark=0, max_batches=4)
    assert not p.should_publish(batches_since_publish=0, now=0.0,
                                queue_depth=0)
    assert not p.should_publish(batches_since_publish=2, now=0.0,
                                queue_depth=3)
    assert p.should_publish(batches_since_publish=2, now=0.0, queue_depth=0)
    # queue never drains under sustained overload: backstop fires
    assert p.should_publish(batches_since_publish=4, now=0.0, queue_depth=9)


def test_make_policy_parses_specs():
    assert isinstance(make_policy("every:7"), EveryNBatches)
    assert make_policy("every:7").n == 7
    assert isinstance(make_policy("interval:0.5"), WallClockInterval)
    assert isinstance(make_policy("drain"), QueueDrainWatermark)
    assert make_policy("drain:2").watermark == 2
    inst = EveryNBatches(2)
    assert make_policy(inst) is inst
    assert isinstance(make_policy(lambda: EveryNBatches(1)), EveryNBatches)
    with pytest.raises(ValueError, match="publish policy"):
        make_policy("sometimes")


# ------------------------------------------------- runtime: conservation
def test_runtime_graceful_stop_conserves_every_edge():
    """Acceptance gate: drain-and-stop leaves zero unaccounted edges and the
    published sketch is bit-identical to a single-shot ingest."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=256,
                 poll_s=0.01)
    rt.attach(t)
    rt.start()
    assert rt.join_pumps(120)
    rep = rt.stop(drain=True)[t.key.tenant_id]

    assert rep["state"] == "stopped"
    assert rep["unaccounted_edges"] == 0
    assert rep["dropped_edges"] == 0
    assert rep["offered_edges"] == rep["ingested_edges"]
    stream, oracle = _single_shot()
    assert rep["published_edges"] == stream.spec.n_edges
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(t.snapshot.sketch.conn),
                                  np.asarray(oracle.conn))


def test_runtime_drop_oldest_conservation_includes_drops():
    """Under drop_oldest, offered == published + dropped — drops are
    accounted, never silent (tiny queue + throttled worker forces drops)."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=1)
    rt = Runtime(queue_capacity=1, backpressure="drop_oldest",
                 publish_policy="every:1", reservoir_k=0, poll_s=0.01)
    handle = rt.attach(t)
    # slow the worker artificially so the pump overruns the queue
    orig_ingest = handle.worker._ingest

    def slow_ingest(item, now):
        time.sleep(0.03)
        orig_ingest(item, now)

    handle.worker._ingest = slow_ingest
    rt.start()
    assert rt.join_pumps(120)
    rep = rt.stop(drain=True)[t.key.tenant_id]
    assert rep["unaccounted_edges"] == 0
    assert rep["offered_edges"] == (rep["ingested_edges"]
                                    + rep["dropped_edges"])
    assert rep["published_edges"] - rep["base_edges"] == rep["ingested_edges"]


def test_runtime_spill_backpressure_loses_nothing(tmp_path):
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=2)
    rt = Runtime(queue_capacity=1, backpressure="spill",
                 spill_dir=str(tmp_path / "spill"), publish_policy="drain",
                 reservoir_k=0, poll_s=0.01)
    rt.attach(t)
    rt.start()
    assert rt.join_pumps(120)
    rep = rt.stop(drain=True)[t.key.tenant_id]
    assert rep["dropped_edges"] == 0
    assert rep["unaccounted_edges"] == 0
    assert rep["published_edges"] == t.stream.spec.n_edges


# ------------------------------------------------- runtime: concurrency
def test_queries_run_against_consistent_epochs_during_ingest():
    """Main-thread engine queries overlap a live worker: epochs observed by
    queries are monotone and every result batch is stamped with ONE epoch."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=3)
    engine = QueryEngine(min_bucket=8)
    reqs = [eng.edge_freq(1, 2), eng.node_out(3), eng.reach(4, 9)]
    engine.execute(t.snapshot, reqs)  # compile off the clock
    rt = Runtime(queue_capacity=2, publish_policy="every:1", reservoir_k=0,
                 poll_s=0.01)
    rt.attach(t, throttle_s=0.01)
    rt.start()
    epochs = []
    while not rt.join_pumps(timeout=0.001):
        res = engine.execute(t.snapshot, reqs)
        assert len({r.epoch for r in res}) == 1, "one batch, one epoch"
        epochs.append(res[0].epoch)
    rt.stop(drain=True)
    assert epochs == sorted(epochs), "epochs must never regress"
    assert len(epochs) > 0


def test_runtime_health_and_metrics_surface_lifecycle():
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=4)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=64,
                 poll_s=0.01)
    rt.attach(t)
    h = rt.health()[t.key.tenant_id]
    assert h["state"] == "created" and not h["alive"]
    rt.start()
    _wait(lambda: rt.health()[t.key.tenant_id]["state"] in
          ("running", "draining", "stopped"))
    rt.join_pumps(120)
    rt.stop(drain=True)
    h = rt.health()[t.key.tenant_id]
    assert h["state"] == "stopped" and h["error"] is None
    m = rt.metrics()[t.key.tenant_id]
    assert m["ingested_batches"] == t.stream.num_batches
    assert m["publishes"] >= 1
    assert m["queue_depth"] == 0
    assert m["edges_per_s_lifetime"] > 0


def test_worker_failure_is_reported_not_swallowed():
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=5)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=0,
                 poll_s=0.01)
    handle = rt.attach(t, max_batches=3)

    def explode(item, now):
        raise RuntimeError("boom")

    handle.worker._ingest = explode
    rt.start()
    _wait(lambda: not handle.worker.is_alive())
    h = rt.health()[t.key.tenant_id]
    assert h["state"] == "failed"
    assert "boom" in h["error"]
    rt.kill()


def test_runtime_online_reservoir_matches_single_pass():
    """The worker-maintained reservoir equals a sequential pass (the queue
    is FIFO and ingest is single-threaded per tenant)."""
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=6)
    rt = Runtime(queue_capacity=4, publish_policy="every:4", reservoir_k=128,
                 poll_s=0.01)
    handle = rt.attach(t)
    rt.start()
    assert rt.join_pumps(120)
    rt.stop(drain=True)
    ref = Reservoir(128, seed=t.key.seed ^ 0xC0FFEE)
    for i in range(t.stream.num_batches):
        ref.offer_batch(*t.stream.batch_numpy(i))
    for got, want in zip(handle.worker.reservoir.sample, ref.sample):
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------- runtime: crash resume
def test_crash_restore_resume_conserves_counter_mass(tmp_path):
    """Satellite acceptance: kill a runtime mid-stream, restore from its
    checkpoint into a fresh registry, resume — total ingested counter mass
    equals a single-shot ingest (no lost or double-counted edges)."""
    ckpt = str(tmp_path / "ckpt")
    reg_a = _registry()
    t_a = reg_a.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt_a = Runtime(queue_capacity=2, publish_policy="every:2",
                   reservoir_k=128, checkpoint_dir=ckpt, checkpoint_every=1,
                   poll_s=0.01)
    handle = rt_a.attach(t_a, throttle_s=0.03)
    rt_a.start()
    # kill strictly mid-stream: some batches ingested, some still to come
    _wait(lambda: handle.worker.metrics.ingested_batches >= 3)
    rt_a.kill()
    assert t_a.offset < t_a.stream.num_batches, "kill was not mid-stream"

    reg_b = _registry()
    t_b = reg_b.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt_b = Runtime(queue_capacity=4, publish_policy="every:2",
                   reservoir_k=128, checkpoint_dir=ckpt, poll_s=0.01)
    handle_b = rt_b.attach(t_b, restore=True)
    assert t_b.offset > 0, "restore must resume mid-stream, not replay all"
    rt_b.start()
    assert rt_b.join_pumps(120)
    rep = rt_b.stop(drain=True)[t_b.key.tenant_id]
    assert rep["unaccounted_edges"] == 0

    stream, oracle = _single_shot()
    # counter-mass equality, cell by cell (stronger than summed mass)
    np.testing.assert_array_equal(np.asarray(t_b.snapshot.sketch.pool),
                                  np.asarray(oracle.pool))
    np.testing.assert_array_equal(np.asarray(t_b.snapshot.sketch.conn),
                                  np.asarray(oracle.conn))
    assert t_b.snapshot.n_edges == stream.spec.n_edges

    # the online reservoir also resumes exactly (rng state checkpointed)
    ref = Reservoir(128, seed=t_b.key.seed ^ 0xC0FFEE)
    for i in range(stream.num_batches):
        ref.offer_batch(*stream.batch_numpy(i))
    for got, want in zip(handle_b.worker.reservoir.sample, ref.sample):
        np.testing.assert_array_equal(got, want)


def test_restored_pending_delta_publishes_on_drain(tmp_path):
    """A checkpoint can hold edges in the (unpublished) delta.  After a
    restore with the stream already exhausted, no new batch ever arrives —
    the drain-time publish must still surface the restored delta."""
    ckpt = str(tmp_path / "ckpt")
    reg_a = _registry()
    t_a = reg_a.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt_a = Runtime(queue_capacity=4, publish_policy="every:100000",
                   reservoir_k=0, checkpoint_dir=ckpt, checkpoint_every=1,
                   poll_s=0.01)
    handle = rt_a.attach(t_a)
    rt_a.start()
    # wait until the LAST batch is both ingested and checkpointed, so the
    # final checkpoint's delta holds the whole stream, published nothing
    _wait(lambda: handle.worker.metrics.checkpoints
          >= t_a.stream.num_batches)
    rt_a.kill()
    assert t_a.snapshot.n_edges == 0, "nothing should be published yet"

    reg_b = _registry()
    t_b = reg_b.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt_b = Runtime(queue_capacity=4, publish_policy="every:100000",
                   reservoir_k=0, checkpoint_dir=ckpt, poll_s=0.01)
    rt_b.attach(t_b, restore=True)
    assert t_b.offset == t_b.stream.num_batches, "stream must be exhausted"
    rt_b.start()
    assert rt_b.join_pumps(60)
    rep = rt_b.stop(drain=True)[t_b.key.tenant_id]
    assert t_b.snapshot.n_edges == t_b.stream.spec.n_edges, \
        "restored delta was dropped instead of published"
    assert rep["unaccounted_edges"] == 0


def test_restore_refuses_foreign_tenant_checkpoint(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt = Runtime(queue_capacity=4, publish_policy="every:2", reservoir_k=64,
                 checkpoint_dir=ckpt, checkpoint_every=1, poll_s=0.01)
    rt.attach(t, max_batches=2)
    rt.start()
    rt.join_pumps(120)
    rt.stop(drain=True)

    from repro.runtime import restore_worker_state
    other = _registry().open("cit-HepPh", "kmatrix", 64, seed=9)
    with pytest.raises(ValueError, match="belongs to tenant"):
        restore_worker_state(
            other, rt._tenant_dir(ckpt, t),
            Reservoir(64, seed=9 ^ 0xC0FFEE))


def test_runtime_attach_is_idempotent_and_post_start_attach_fails():
    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=8)
    rt = Runtime(queue_capacity=4, reservoir_k=0, poll_s=0.01)
    h1 = rt.attach(t, max_batches=1)
    assert rt.attach(t) is h1
    rt.start()
    other = reg.open("cit-HepPh", "gmatrix", 64, seed=8)
    with pytest.raises(RuntimeError, match="before start"):
        rt.attach(other)
    rt.join_pumps(120)
    rt.stop(drain=True)
