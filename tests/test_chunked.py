"""chunked_edge_aggregate: forward/grad equivalence with the unchunked
reference, for several chunk counts and pytree shapes (this custom-VJP
powers nequip/equiformer on web-scale graphs)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.models.gnn.chunked import chunked_edge_aggregate


def _setup(seed, n=16, e=48, d=8):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    ew = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    return h, w, ew, src, dst, n


def _msg(carry, es, ie):
    h_, w_ = carry
    return jnp.tanh(h_[ie["src"]] @ w_) * es["ew"]


@pytest.mark.parametrize("n_chunks", [1, 2, 4, 8])
def test_matches_reference(n_chunks):
    h, w, ew, src, dst, n = _setup(0)

    def chunked(h_, w_, ew_):
        agg = chunked_edge_aggregate(_msg, n, n_chunks, (h_, w_),
                                     {"ew": ew_}, {"src": src}, dst)
        return jnp.sum(agg ** 2)

    def ref(h_, w_, ew_):
        msg = jnp.tanh(h_[src] @ w_) * ew_
        return jnp.sum(jax.ops.segment_sum(msg, dst, num_segments=n) ** 2)

    v1, g1 = jax.value_and_grad(chunked, argnums=(0, 1, 2))(h, w, ew)
    v2, g2 = jax.value_and_grad(ref, argnums=(0, 1, 2))(h, w, ew)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_chunk_count_invariance(seed):
    h, w, ew, src, dst, n = _setup(seed)
    outs = []
    for nc in (1, 4):
        outs.append(np.asarray(chunked_edge_aggregate(
            _msg, n, nc, (h, w), {"ew": ew}, {"src": src}, dst)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)


def test_under_jit_and_second_layer():
    """Composes under jit and stacks (gradient flows through two layers)."""
    h, w, ew, src, dst, n = _setup(3)

    @jax.jit
    def two_layer_loss(h_, w_):
        a1 = chunked_edge_aggregate(_msg, n, 4, (h_, w_), {"ew": ew},
                                    {"src": src}, dst)
        a2 = chunked_edge_aggregate(_msg, n, 2, (a1, w_), {"ew": ew},
                                    {"src": src}, dst)
        return jnp.sum(jnp.abs(a2))

    g = jax.grad(two_layer_loss)(h, w)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0
