"""Hash family invariants: determinism, range, uniformity, independence."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.common.hashing import HashFamily, fastrange, hash_pair_mix, np_hash_into


def test_range_and_determinism():
    fam = HashFamily.create(0, 5)
    x = jnp.arange(10000, dtype=jnp.int32)
    h1 = fam.hash_into(x, 1234)
    h2 = fam.hash_into(x, 1234)
    assert (np.asarray(h1) == np.asarray(h2)).all()
    assert h1.shape == (5, 10000)
    assert int(h1.min()) >= 0 and int(h1.max()) < 1234


@given(w=st.integers(min_value=1, max_value=1 << 20), seed=st.integers(0, 1 << 16))
@settings(max_examples=25, deadline=None)
def test_fastrange_bounds(w, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.integers(0, 1 << 32, size=256, dtype=np.uint32))
    out = np.asarray(fastrange(h, w))
    assert (out >= 0).all() and (out < w).all()


def test_uniformity_chi2():
    """Bucket counts should look uniform (loose 3-sigma bound on chi^2)."""
    fam = HashFamily.create(42, 4)
    w = 256
    x = jnp.arange(1 << 16, dtype=jnp.int32)
    h = np.asarray(fam.hash_into(x, w))
    n = x.shape[0]
    expected = n / w
    for r in range(4):
        counts = np.bincount(h[r], minlength=w)
        chi2 = ((counts - expected) ** 2 / expected).sum()
        # dof = w-1 -> mean ~255, std ~sqrt(2*255)~22.6
        assert chi2 < 255 + 6 * 22.6, f"layer {r} chi2={chi2}"


def test_layers_differ():
    fam = HashFamily.create(7, 6)
    x = jnp.arange(4096, dtype=jnp.int32)
    h = np.asarray(fam.hash_into(x, 512))
    for r in range(6):
        for s in range(r + 1, 6):
            agree = float((h[r] == h[s]).mean())
            assert agree < 0.05, (r, s, agree)


def test_pairwise_collision_rate():
    """2-universal family: P[h(x)==h(y)] ~ 1/w for x != y."""
    w = 128
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.choice(1 << 30, size=2048, replace=False).astype(np.int32))
    fam = HashFamily.create(11, 8)
    h = np.asarray(fam.hash_into(xs, w))  # [8, 2048]
    rate = []
    for r in range(8):
        hh = h[r]
        eq = (hh[:, None] == hh[None, :]).sum() - len(hh)
        rate.append(eq / (len(hh) * (len(hh) - 1)))
    mean_rate = float(np.mean(rate))
    assert abs(mean_rate - 1.0 / w) < 0.3 / w, mean_rate


def test_np_oracle_matches_jax():
    fam = HashFamily.create(5, 3)
    x = np.arange(1000, dtype=np.int32)
    ours = np.asarray(fam.hash_into(jnp.asarray(x), 777))
    oracle = np_hash_into(np.asarray(fam.a), np.asarray(fam.b), x, 777)
    assert (ours == oracle).all()


def test_hash_pair_mix_asymmetric():
    a = jnp.asarray([1, 2, 3], dtype=jnp.int32)
    b = jnp.asarray([3, 2, 1], dtype=jnp.int32)
    assert int(hash_pair_mix(a, b)[0]) != int(hash_pair_mix(b, a)[0])
