"""Stream pipeline: replayability, reservoir statistics, partition planning."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.core import vertex_stats_from_sample
from repro.core.partitioning import (
    plan_partitions,
    plan_partitions_banded,
    good_turing_outlier_share,
)
from repro.streams import Reservoir, SyntheticStream, make_stream, sample_stream
from repro.streams.generators import DATASETS


def test_batch_is_pure_function_of_index():
    s = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
    a = s.batch_numpy(2)
    b = s.batch_numpy(2)
    for x, y in zip(a, b):
        assert (x == y).all()
    # A different stream object with the same seed replays identically (the
    # fault-tolerance contract: restart == seek).
    s2 = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
    for x, y in zip(s.batch_numpy(1), s2.batch_numpy(1)):
        assert (x == y).all()


def test_iter_from_offset_matches_full_iteration():
    s = make_stream("email-EuAll", batch_size=512, seed=1, scale=0.01)
    full = [np.asarray(b.src) for b in s]
    resumed = {i: np.asarray(b.src) for i, b in s.iter_from(3)}
    for i in range(3, s.num_batches):
        assert (full[i] == resumed[i]).all()


def test_edge_counts_and_padding():
    s = make_stream("unicorn-wget", batch_size=1000, seed=0, scale=0.01)
    src, dst, w = s.all_edges_numpy()
    assert len(src) == s.spec.n_edges
    assert (w > 0).all()
    assert src.max() < s.spec.n_nodes and dst.max() < s.spec.n_nodes


def test_power_law_skew():
    """Out-degree distribution must be heavy-tailed (what kMatrix exploits)."""
    s = make_stream("cit-HepPh", batch_size=8192, seed=5, scale=0.2)
    src, _, _ = s.all_edges_numpy()
    counts = np.bincount(src)
    counts = counts[counts > 0]
    top1pct = np.sort(counts)[-max(len(counts) // 100, 1):].sum() / counts.sum()
    assert top1pct > 0.08, f"top-1% vertices carry only {top1pct:.2%} of stream"


def test_reservoir_uniformity():
    res = Reservoir(k=500, seed=0)
    n = 20000
    src = np.arange(n, dtype=np.int32)
    for lo in range(0, n, 1000):
        sl = src[lo : lo + 1000]
        res.offer_batch(sl, sl, np.ones_like(sl))
    smp, _, _ = res.sample
    # mean of a uniform sample over [0, n) should be ~n/2
    assert abs(smp.mean() - n / 2) < n * 0.06
    assert len(np.unique(smp)) == 500


@given(k=st.integers(10, 200), n=st.integers(1, 5000))
@settings(max_examples=15, deadline=None)
def test_reservoir_size_property(k, n):
    res = Reservoir(k=k, seed=1)
    src = np.arange(n, dtype=np.int32)
    res.offer_batch(src, src, np.ones_like(src))
    smp, _, _ = res.sample
    assert len(smp) == min(k, n)


@pytest.mark.parametrize("partitioner", [plan_partitions, plan_partitions_banded])
def test_partition_plan_invariants(partitioner):
    rng = np.random.default_rng(0)
    src = rng.zipf(1.5, 4000).astype(np.int32) % 1000
    dst = rng.integers(0, 1000, 4000).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    plan = partitioner(stats, 256, square=True)
    # every sampled vertex routed exactly once
    routed = np.concatenate([p.vertices for p in plan.partitions])
    assert len(routed) == len(np.unique(routed)) == len(np.asarray(stats.vertex))
    # route table sorted + aligned
    assert (np.diff(plan.route_keys) > 0).all()
    assert len(plan.route_keys) == len(plan.route_part)
    # memory conservation: total area within budget
    area = sum(p.width**2 for p in plan.partitions)
    assert area <= 256 * 256 * 1.001
    assert area >= 256 * 256 * 0.85, "partitioner stranded >15% of the budget"
    # outlier owns no vertices
    assert len(plan.partitions[plan.outlier].vertices) == 0


def test_good_turing_share():
    assert good_turing_outlier_share(np.asarray([1.0] * 100)) >= 0.5
    assert good_turing_outlier_share(np.asarray([50.0] * 100)) <= 0.06


def test_dataset_presets_match_paper():
    assert DATASETS["email-EuAll"].n_nodes == 265_214
    assert DATASETS["email-EuAll"].n_edges == 420_045
    assert DATASETS["cit-HepPh"].n_nodes == 34_546
    assert DATASETS["cit-HepPh"].n_edges == 421_578
    assert DATASETS["unicorn-wget"].n_nodes == 17_778
    assert DATASETS["unicorn-wget"].n_edges == 277_972  # 10% reservoir filter
