"""Stream pipeline: replayability, reservoir statistics, partition planning."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.core import vertex_stats_from_sample
from repro.core.partitioning import (
    plan_partitions,
    plan_partitions_banded,
    good_turing_outlier_share,
)
from repro.streams import Reservoir, SyntheticStream, make_stream, sample_stream
from repro.streams.generators import DATASETS


def test_batch_is_pure_function_of_index():
    s = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
    a = s.batch_numpy(2)
    b = s.batch_numpy(2)
    for x, y in zip(a, b):
        assert (x == y).all()
    # A different stream object with the same seed replays identically (the
    # fault-tolerance contract: restart == seek).
    s2 = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
    for x, y in zip(s.batch_numpy(1), s2.batch_numpy(1)):
        assert (x == y).all()


def test_iter_from_offset_matches_full_iteration():
    s = make_stream("email-EuAll", batch_size=512, seed=1, scale=0.01)
    full = [np.asarray(b.src) for b in s]
    resumed = {i: np.asarray(b.src) for i, b in s.iter_from(3)}
    for i in range(3, s.num_batches):
        assert (full[i] == resumed[i]).all()


def test_edge_counts_and_padding():
    s = make_stream("unicorn-wget", batch_size=1000, seed=0, scale=0.01)
    src, dst, w = s.all_edges_numpy()
    assert len(src) == s.spec.n_edges
    assert (w > 0).all()
    assert src.max() < s.spec.n_nodes and dst.max() < s.spec.n_nodes


def test_power_law_skew():
    """Out-degree distribution must be heavy-tailed (what kMatrix exploits)."""
    s = make_stream("cit-HepPh", batch_size=8192, seed=5, scale=0.2)
    src, _, _ = s.all_edges_numpy()
    counts = np.bincount(src)
    counts = counts[counts > 0]
    top1pct = np.sort(counts)[-max(len(counts) // 100, 1):].sum() / counts.sum()
    assert top1pct > 0.08, f"top-1% vertices carry only {top1pct:.2%} of stream"


def test_reservoir_uniformity():
    res = Reservoir(k=500, seed=0)
    n = 20000
    src = np.arange(n, dtype=np.int32)
    for lo in range(0, n, 1000):
        sl = src[lo : lo + 1000]
        res.offer_batch(sl, sl, np.ones_like(sl))
    smp, _, _ = res.sample
    # mean of a uniform sample over [0, n) should be ~n/2
    assert abs(smp.mean() - n / 2) < n * 0.06
    assert len(np.unique(smp)) == 500


@given(k=st.integers(10, 200), n=st.integers(1, 5000))
@settings(max_examples=15, deadline=None)
def test_reservoir_size_property(k, n):
    res = Reservoir(k=k, seed=1)
    src = np.arange(n, dtype=np.int32)
    res.offer_batch(src, src, np.ones_like(src))
    smp, _, _ = res.sample
    assert len(smp) == min(k, n)


class _LoopReservoir(Reservoir):
    """Reference implementation: the pre-vectorization sequential
    replacement loop.  Must produce the exact same final state from the
    same RNG draws (last accepted write per slot wins)."""

    def offer_batch(self, src, dst, w):
        valid = w > 0
        src, dst, w = src[valid], dst[valid], w[valid]
        n = len(src)
        if n == 0:
            return
        pos = self._seen
        if pos < self.k:
            take = min(self.k - pos, n)
            self._src[pos:pos + take] = src[:take]
            self._dst[pos:pos + take] = dst[:take]
            self._w[pos:pos + take] = w[:take]
            self._seen += take
            src, dst, w = src[take:], dst[take:], w[take:]
            n = len(src)
            if n == 0:
                return
        t = self._seen + np.arange(1, n + 1, dtype=np.float64)
        accept = self._rng.random(n) < (self.k / t)
        slots = self._rng.integers(0, self.k, size=n)
        for i in np.nonzero(accept)[0]:
            s = slots[i]
            self._src[s], self._dst[s], self._w[s] = src[i], dst[i], w[i]
        self._seen += n


@pytest.mark.parametrize("k,batch,seed", [(64, 200, 5), (16, 1000, 0),
                                          (256, 97, 3)])
def test_reservoir_vectorized_matches_sequential_loop(k, batch, seed):
    """The vectorized replacement phase is a pure speedup: bit-identical
    final state to the sequential loop under the same seed (small k forces
    many duplicate-slot collisions, the case where write order matters)."""
    fast, slow = Reservoir(k, seed=seed), _LoopReservoir(k, seed=seed)
    for i in range(25):
        rng = np.random.default_rng(1000 * seed + i)
        src = rng.integers(0, 5000, batch).astype(np.int32)
        dst = rng.integers(0, 5000, batch).astype(np.int32)
        w = (rng.random(batch) > 0.1).astype(np.int32)  # padding mixed in
        fast.offer_batch(src, dst, w)
        slow.offer_batch(src, dst, w)
        assert fast.seen == slow.seen
    for a, b in zip(fast.sample, slow.sample):
        np.testing.assert_array_equal(a, b)


def test_reservoir_state_dict_roundtrip_is_exact():
    """Checkpoint/restore of the sampler (arrays + RNG) must continue the
    exact stream a never-checkpointed sampler would produce — including a
    JSON round trip of the RNG state, as the runtime checkpoint stores it."""
    import json

    a = Reservoir(32, seed=11)
    feed = np.random.default_rng(0).integers(0, 999, (6, 300)).astype(np.int32)
    for row in feed[:3]:
        a.offer_batch(row, row, np.ones_like(row))
    state = a.state_dict()
    state["rng_state"] = json.loads(json.dumps(state["rng_state"]))
    b = Reservoir(32, seed=0)  # wrong seed on purpose: state must win
    b.load_state_dict(state)
    for row in feed[3:]:
        a.offer_batch(row, row, np.ones_like(row))
        b.offer_batch(row, row, np.ones_like(row))
    for x, y in zip(a.sample, b.sample):
        np.testing.assert_array_equal(x, y)
    with pytest.raises(ValueError, match="size mismatch"):
        Reservoir(64, seed=0).load_state_dict(state)


@pytest.mark.parametrize("partitioner", [plan_partitions, plan_partitions_banded])
def test_partition_plan_invariants(partitioner):
    rng = np.random.default_rng(0)
    src = rng.zipf(1.5, 4000).astype(np.int32) % 1000
    dst = rng.integers(0, 1000, 4000).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    plan = partitioner(stats, 256, square=True)
    # every sampled vertex routed exactly once
    routed = np.concatenate([p.vertices for p in plan.partitions])
    assert len(routed) == len(np.unique(routed)) == len(np.asarray(stats.vertex))
    # route table sorted + aligned
    assert (np.diff(plan.route_keys) > 0).all()
    assert len(plan.route_keys) == len(plan.route_part)
    # memory conservation: total area within budget
    area = sum(p.width**2 for p in plan.partitions)
    assert area <= 256 * 256 * 1.001
    assert area >= 256 * 256 * 0.85, "partitioner stranded >15% of the budget"
    # outlier owns no vertices
    assert len(plan.partitions[plan.outlier].vertices) == 0


def test_good_turing_share():
    assert good_turing_outlier_share(np.asarray([1.0] * 100)) >= 0.5
    assert good_turing_outlier_share(np.asarray([50.0] * 100)) <= 0.06


def test_dataset_presets_match_paper():
    assert DATASETS["email-EuAll"].n_nodes == 265_214
    assert DATASETS["email-EuAll"].n_edges == 420_045
    assert DATASETS["cit-HepPh"].n_nodes == 34_546
    assert DATASETS["cit-HepPh"].n_edges == 421_578
    assert DATASETS["unicorn-wget"].n_nodes == 17_778
    assert DATASETS["unicorn-wget"].n_edges == 277_972  # 10% reservoir filter
