"""No-op shims for ``hypothesis`` so tier-1 collects on images without it.

Property tests decorated with the stub ``given`` are skipped (not silently
passed); every non-hypothesis test in the same module still runs.  Import as

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st
"""
from __future__ import annotations

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _AnyStrategy:
    """Stands in for ``hypothesis.strategies``: every attribute is a callable
    returning an inert placeholder (the test body never executes)."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()
strategies = st
