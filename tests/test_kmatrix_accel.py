"""Width-class sketch backend seams: protocol parity with the flat-pool
kMatrix, bit-exact relayout, merge rejection rules, checkpoint round-trips
and backend resolution (ISSUE 3 tentpole coverage)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store
from repro.core import (
    EdgeBatch,
    KMatrix,
    KMatrixAccel,
    queries,
    sketch_backend,
    vertex_stats_from_sample,
)
from repro.core import kmatrix, kmatrix_accel as kma


def _random_stream(seed, n=4096, nodes=2000):
    rng = np.random.default_rng(seed)
    src = rng.zipf(1.3, n).astype(np.int32) % nodes
    dst = rng.integers(0, nodes, n).astype(np.int32)
    w = rng.integers(1, 4, n).astype(np.int32)
    return src, dst, w


def _accel(seed=1, sample_seed=0, depth=3, budget=1 << 16):
    src, dst, w = _random_stream(sample_seed)
    stats = vertex_stats_from_sample(src[:1000], dst[:1000], w[:1000])
    return KMatrixAccel.create(bytes_budget=budget, stats=stats, depth=depth,
                               seed=seed)


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ------------------------------------------------------------ flat parity --
def test_accel_vs_flat_bit_exact_on_randomized_streams():
    """Accel ingest == flat ingest on the SAME quantized layout: counters,
    edge_freq and node_out_freq all bit-identical, for several streams."""
    acc0 = _accel(seed=5)
    flat0 = kma.to_flat_layout(acc0)
    for seed in (1, 2, 3):
        src, dst, w = _random_stream(100 + seed)
        batch = EdgeBatch.from_numpy(src, dst, w)
        # tiny capacity forces a large overflow tail through the scatter path
        acc = kma.ingest(acc0, batch, capacity=128, block_b=128)
        flat = kmatrix.ingest(flat0, batch)
        np.testing.assert_array_equal(
            np.asarray(kma.to_flat_layout(acc).pool), np.asarray(flat.pool))
        q, qd = jnp.asarray(src[:512]), jnp.asarray(dst[:512])
        np.testing.assert_array_equal(
            np.asarray(kma.edge_freq(acc, q, qd)),
            np.asarray(kmatrix.edge_freq(flat, q, qd)))
        np.testing.assert_array_equal(
            np.asarray(kma.node_out_freq(acc, q)),
            np.asarray(kmatrix.node_out_freq(flat, q)))


def test_accel_reachability_matches_flat():
    acc = _accel(seed=2)
    src, dst, w = _random_stream(7, n=1024, nodes=300)
    batch = EdgeBatch.from_numpy(src, dst, w)
    acc = kma.ingest(acc, batch)
    flat = kma.to_flat_layout(acc)
    qs, qd = jnp.asarray(src[:64]), jnp.asarray(dst[::-1][:64])
    np.testing.assert_array_equal(
        np.asarray(queries.closure_layers(acc)),
        np.asarray(queries.closure_layers(flat)))
    np.testing.assert_array_equal(
        np.asarray(queries.reach_cells(acc, qs)),
        np.asarray(queries.reach_cells(flat, qs)))
    closure = queries.build_closure(queries.closure_layers(acc))
    a = queries.reachability_from_closure(
        closure, queries.reach_cells(acc, qs), queries.reach_cells(acc, qd))
    b = queries.reachability_from_closure(
        closure, queries.reach_cells(flat, qs), queries.reach_cells(flat, qd))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------- relayout --
def test_relayout_roundtrip_is_identity():
    """to_class_layout ∘ to_flat_layout == id on every pytree leaf —
    INCLUDING the overflow tally (ISSUE 4 satellite: a relayout round-trip
    or flat-checkpoint migration must not zero a nonzero diagnostic)."""
    acc = _accel(seed=3)
    src, dst, w = _random_stream(11)
    acc = kma.ingest(acc, EdgeBatch.from_numpy(src, dst, w),
                     capacity=128, block_b=128)
    assert int(acc.overflow) > 0, "round-trip must carry a real tally"
    flat = kma.to_flat_layout(acc)
    assert int(flat.overflow) == int(acc.overflow)
    back = kma.to_class_layout(flat)
    assert back.class_widths == acc.class_widths
    assert back.class_counts == acc.class_counts
    assert back.conn_w == acc.conn_w
    assert _leaves_equal(back, acc)
    assert int(back.overflow) == int(acc.overflow)
    # an explicit override still wins (checkpoint-migration escape hatch)
    assert int(kma.to_class_layout(flat, overflow=0).overflow) == 0


def test_flat_overflow_leaf_is_inert_and_additive():
    """The flat KMatrix carries the diagnostic but never writes it: ingest
    leaves it unchanged, empty_like zeroes it, merge sums it."""
    acc = _accel(seed=3)
    src, dst, w = _random_stream(12)
    acc = kma.ingest(acc, EdgeBatch.from_numpy(src, dst, w),
                     capacity=128, block_b=128)
    flat = kma.to_flat_layout(acc)
    tally = int(flat.overflow)
    assert tally > 0
    flat2 = kmatrix.ingest(flat, EdgeBatch.from_numpy(src, dst, w))
    assert int(flat2.overflow) == tally, "flat ingest must not touch it"
    assert int(kmatrix.empty_like(flat).overflow) == 0
    assert int(kmatrix.merge(flat, flat2).overflow) == 2 * tally


def test_dispatch_capacity_sized_from_plan_load():
    """ISSUE 4 satellite: default dispatch capacity comes from the
    partition plan's banded load (max per-partition share, 2x headroom,
    capped at B), not the uniform 2B/P — and capacity is a dispatch-only
    concern: counters are bit-identical under any capacity."""
    src, dst, w = _random_stream(0)
    stats = vertex_stats_from_sample(src[:1000], dst[:1000], w[:1000])
    acc = KMatrixAccel.create(bytes_budget=1 << 16, stats=stats, depth=3,
                              seed=1, partitioner="banded")
    assert acc.load_shares is not None
    assert len(acc.load_shares) == acc.route.n_partitions
    assert 0.99 <= sum(acc.load_shares) <= 1.01
    b = 4096
    cap = kma.dispatch_capacity(acc, b)
    want = int(np.ceil(2.0 * max(acc.load_shares) * b))
    assert cap >= min(want, b) and cap % 128 == 0 and cap <= b + 127
    # relayouted sketches carry no sample: uniform fallback
    relayout = kma.to_class_layout(kma.to_flat_layout(acc))
    assert relayout.load_shares is None
    legacy = kma.dispatch_capacity(relayout, b)
    assert legacy == -(-max(128, (2 * b) // acc.route.n_partitions)
                       // 128) * 128
    # capacity never changes counters, only the MXU/scatter split
    batch = EdgeBatch.from_numpy(*_random_stream(55))
    a = kma.ingest(acc, batch)                    # plan-derived default
    bb = kma.ingest(acc, batch, capacity=legacy)  # legacy uniform
    assert _leaves_equal(a.pools, bb.pools)
    np.testing.assert_array_equal(np.asarray(a.conn), np.asarray(bb.conn))


def test_to_class_layout_rejects_unquantized_plan():
    src, dst, w = _random_stream(0)
    stats = vertex_stats_from_sample(src[:1000], dst[:1000], w[:1000])
    flat = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=3, seed=1,
                          partitioner="banded")
    widths = np.asarray(flat.route.widths)
    if np.all((widths & (widths - 1)) == 0):
        pytest.skip("banded plan happened to be all powers of two")
    with pytest.raises(ValueError, match="powers"):
        kma.to_class_layout(flat)


def test_route_offsets_are_the_flat_invariant():
    """Satellite fix: accel route offsets must be the cumsum-slab layout so
    one route table serves both layouts."""
    acc = _accel(seed=4)
    widths = np.asarray(acc.route.widths).astype(np.int64)
    expect = np.concatenate([[0], np.cumsum(widths**2)[:-1]])
    np.testing.assert_array_equal(np.asarray(acc.route.offsets), expect)


# ------------------------------------------------------------------ merge --
def test_accel_merge_additivity():
    acc = _accel(seed=6)
    s1, d1, w1 = _random_stream(21)
    s2, d2, w2 = _random_stream(22)
    a = kma.ingest(acc, EdgeBatch.from_numpy(s1, d1, w1))
    b = kma.ingest(acc, EdgeBatch.from_numpy(s2, d2, w2))
    both = kma.ingest(a, EdgeBatch.from_numpy(s2, d2, w2))
    merged = kma.merge(a, b)
    assert _leaves_equal(merged.pools, both.pools)
    np.testing.assert_array_equal(np.asarray(merged.conn),
                                  np.asarray(both.conn))
    assert int(merged.overflow) == int(a.overflow) + int(b.overflow)


def test_accel_merge_rejects_mismatched_hash_seeds():
    a = _accel(seed=1, sample_seed=0)
    b = _accel(seed=2, sample_seed=0)  # same plan, different hash family
    with pytest.raises(ValueError, match="hash families"):
        kma.merge(a, b)


def test_accel_merge_rejects_mismatched_partition_plans():
    a = _accel(seed=1, sample_seed=0)
    b = _accel(seed=1, sample_seed=33)  # same seed, different sample/plan
    if a.class_widths != b.class_widths or a.class_counts != b.class_counts:
        with pytest.raises(AssertionError):
            kma.merge(a, b)
    else:
        with pytest.raises(ValueError, match="partition plans"):
            kma.merge(a, b)


def test_accel_empty_like_shares_layout_and_zeroes_counters():
    acc = _accel(seed=8)
    src, dst, w = _random_stream(31)
    acc = kma.ingest(acc, EdgeBatch.from_numpy(src, dst, w),
                     capacity=128, block_b=128)
    empty = kma.empty_like(acc)
    assert all(int(np.asarray(p).sum()) == 0 for p in empty.pools)
    assert int(np.asarray(empty.conn).sum()) == 0
    assert int(empty.overflow) == 0
    # merge(empty, x) == x : the snapshot publish identity
    assert _leaves_equal(kma.merge(empty, acc), acc)


# ------------------------------------------------------------- checkpoint --
def test_accel_checkpoint_roundtrip_bit_exact(tmp_path):
    """Class pools AND overflow accounting survive save/restore bit-exactly
    through the generic npz checkpoint store."""
    acc = _accel(seed=9)
    src, dst, w = _random_stream(41)
    acc = kma.ingest(acc, EdgeBatch.from_numpy(src, dst, w),
                     capacity=128, block_b=128)
    assert int(acc.overflow) > 0  # the round-trip must carry a real tally
    store.save(str(tmp_path), 1, acc, extra={"k": "v"})
    template = kma.empty_like(acc)
    restored, meta = store.restore(str(tmp_path), template)
    assert _leaves_equal(restored, acc)
    assert int(restored.overflow) == int(acc.overflow)


# -------------------------------------------------------------- dispatch --
def test_sketch_backend_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SKETCH_BACKEND", raising=False)
    assert sketch_backend("pallas") == "pallas"
    assert sketch_backend("flat") == "flat"
    assert sketch_backend(None) in ("flat", "pallas")  # platform pick
    monkeypatch.setenv("REPRO_SKETCH_BACKEND", "pallas")
    assert sketch_backend(None) == "pallas"
    with pytest.raises(ValueError, match="sketch backend"):
        sketch_backend("cuda")


def test_registry_serves_accel_backend_exactly(monkeypatch):
    """End-to-end through the production layers: registry builds the accel
    sketch, snapshot buffer ingests/publishes through it, and the engine's
    answers match the direct oracle on the published snapshot."""
    monkeypatch.delenv("REPRO_SKETCH_BACKEND", raising=False)
    from repro.serving import (QueryEngine, SketchRegistry, mix_for_sketch,
                               synth_requests)
    from repro.serving import engine as eng

    reg = SketchRegistry(depth=3, scale=0.02, sketch_backend="pallas")
    tenant = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    assert isinstance(tenant.snapshot.sketch, KMatrixAccel)
    tenant.step(2)
    snap = tenant.publish()
    assert tenant.buffer.overflow_edges >= 0
    engine = QueryEngine()
    reqs = synth_requests(48, mix_for_sketch("kmatrix"),
                          n_nodes=tenant.stream.spec.n_nodes, seed=5,
                          heavy_universe=512, heavy_threshold=10.0)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = eng.direct_answers(snap, reqs)
    for g, w in zip(got, want):
        if isinstance(g, tuple):
            np.testing.assert_array_equal(g[0], w[0])
            np.testing.assert_array_equal(g[1], w[1])
        else:
            assert g == w
