"""FM smoke tests: reduced config, train/serve/retrieval paths, kernel parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.recsys.fm import (
    FMConfig,
    bce_loss,
    forward,
    forward_with_kernel,
    init_params,
    retrieval_scores,
)

CFG = FMConfig(total_vocab=5_000, n_fields=7, embed_dim=10)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


def _ids(key, b, f=CFG.n_fields):
    return jax.random.randint(key, (b, f), 0, 1 << 30)


def test_forward_shapes_and_finite(params):
    logits = jax.jit(lambda p, i: forward(CFG, p, i))(params, _ids(jax.random.PRNGKey(1), 32))
    assert logits.shape == (32,)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_step(params):
    ids = _ids(jax.random.PRNGKey(2), 64)
    labels = jax.random.bernoulli(jax.random.PRNGKey(3), 0.5, (64,)).astype(jnp.float32)
    loss, grads = jax.value_and_grad(lambda p: bce_loss(CFG, p, ids, labels))(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # embedding grads are row-sparse but finite
    assert np.isfinite(np.asarray(grads["emb"])).all()
    # loss near log 2 at init (tiny logits)
    assert abs(float(loss) - np.log(2)) < 0.05


def test_fm_sum_square_identity(params):
    """FM output equals the explicit O(F^2) pairwise sum."""
    ids = _ids(jax.random.PRNGKey(4), 8)
    from repro.models.recsys.fm import _flat_ids

    rows = _flat_ids(CFG, ids)
    v = np.asarray(params["emb"])[np.asarray(rows)]  # (B, F, k)
    explicit = np.zeros(8)
    f = CFG.n_fields
    for i in range(f):
        for j in range(i + 1, f):
            explicit += (v[:, i] * v[:, j]).sum(-1)
    lin = np.asarray(params["lin"])[np.asarray(rows)][..., 0].sum(-1)
    expect = float(params["bias"]) + lin + explicit
    got = np.asarray(forward(CFG, params, ids))
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_retrieval_matches_pairwise_scoring(params):
    """retrieval_scores (GEMV path) == forward() on concatenated fields."""
    q = _ids(jax.random.PRNGKey(5), 1)[0]
    cands = _ids(jax.random.PRNGKey(6), 50)
    scores = np.asarray(retrieval_scores(CFG, params, q, cands))
    assert scores.shape == (50,)
    # independent check for candidate 7: score decomposition
    s7 = float(forward(CFG, params, q[None, :])[0]) + float(
        forward(CFG, params, cands[7:8])[0]
    )
    from repro.models.recsys.fm import _flat_ids

    vq = np.asarray(params["emb"])[np.asarray(_flat_ids(CFG, q[None, :]))].sum(1)[0]
    vc = np.asarray(params["emb"])[np.asarray(_flat_ids(CFG, cands[7:8]))].sum(1)[0]
    np.testing.assert_allclose(scores[7], s7 + vq @ vc, rtol=1e-4)


def test_kernel_path_matches_reference(params):
    ids = _ids(jax.random.PRNGKey(7), 16)
    a = np.asarray(forward(CFG, params, ids))
    b = np.asarray(forward_with_kernel(CFG, params, ids, interpret=True))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_field_vocab_partition():
    sizes = CFG.field_vocabs()
    offs = CFG.field_offsets()
    assert len(sizes) == CFG.n_fields
    assert (sizes >= 4).all()
    # table_rows pads the raw total up to a multiple of 512 (sharding)
    raw = int(offs[-1] + sizes[-1])
    assert raw <= CFG.table_rows < raw + 512
    assert CFG.table_rows % 512 == 0
