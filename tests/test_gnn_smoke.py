"""GNN smoke + property tests: reduced configs, shapes/finiteness, and
rotation-equivariance of the geometric models (the invariant that matters)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.gnn import (
    GraphBatch,
    equiformer_v2,
    gatedgcn,
    graphcast,
    nequip,
    sampler,
    so3,
    synthetic_graph,
)


def _small_graph(seed=0, n=24, e=64, d=12, n_graphs=1, **kw):
    return synthetic_graph(n, e, d, seed=seed, n_graphs=n_graphs, **kw)


def test_gatedgcn_smoke():
    cfg = gatedgcn.GatedGCNConfig(n_layers=3, d_hidden=16, d_out=4)
    g = _small_graph(d=12)
    params = gatedgcn.init_params(cfg, jax.random.PRNGKey(0), d_in=12)
    out = jax.jit(lambda p, g_: gatedgcn.forward(cfg, p, g_))(params, g)
    assert out.shape == (g.n_nodes, 4)
    assert np.isfinite(np.asarray(out)).all()
    # gradient flows
    loss = lambda p: (gatedgcn.forward(cfg, p, g) ** 2).mean()
    grads = jax.grad(loss)(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree_util.tree_leaves(grads))


def test_graphcast_smoke():
    cfg = graphcast.GraphCastConfig(n_layers=2, d_hidden=32, n_vars=13)
    g = _small_graph(d=13)
    params = graphcast.init_params(cfg, jax.random.PRNGKey(1))
    out = jax.jit(lambda p, g_: graphcast.forward(cfg, p, g_))(params, g)
    assert out.shape == (g.n_nodes, 13)
    assert np.isfinite(np.asarray(out)).all()


def test_nequip_smoke_and_forces():
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, l_max=2, edge_chunk=32)
    g = _small_graph(d=5, n_graphs=3, n=10, e=24)
    params = nequip.init_params(cfg, jax.random.PRNGKey(2), d_in=5)
    e, forces = jax.jit(lambda p, g_: nequip.energy_and_forces(cfg, p, g_))(params, g)
    assert e.shape == (3,)
    assert forces.shape == g.positions.shape
    assert np.isfinite(np.asarray(e)).all() and np.isfinite(np.asarray(forces)).all()


def test_equiformer_smoke():
    cfg = equiformer_v2.EquiformerV2Config(
        n_layers=2, d_hidden=16, l_max=3, m_max=2, n_heads=4, edge_chunk=32
    )
    g = _small_graph(d=6, n_graphs=2, n=8, e=20)
    params = equiformer_v2.init_params(cfg, jax.random.PRNGKey(3), d_in=6)
    out = jax.jit(lambda p, g_: equiformer_v2.forward(cfg, p, g_))(params, g)
    assert out.shape == (2,)
    assert np.isfinite(np.asarray(out)).all()


def _rotate_graph(g: GraphBatch, rot: np.ndarray) -> GraphBatch:
    return g.replace(positions=jnp.asarray(np.asarray(g.positions) @ rot.T))


@pytest.mark.parametrize("seed", [0, 1])
def test_nequip_energy_rotation_invariant(seed):
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, l_max=2, edge_chunk=32)
    g = _small_graph(seed=seed, d=5, n=12, e=30)
    params = nequip.init_params(cfg, jax.random.PRNGKey(4), d_in=5)
    rot = so3._rot_z(0.7) @ so3._rot_y(-1.1) @ so3._rot_x(0.3)
    e1 = nequip.energy(cfg, params, g, g.positions)
    g2 = _rotate_graph(g, rot)
    e2 = nequip.energy(cfg, params, g2, g2.positions)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_nequip_forces_rotation_equivariant(seed):
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, l_max=2, edge_chunk=32)
    g = _small_graph(seed=seed, d=5, n=12, e=30)
    params = nequip.init_params(cfg, jax.random.PRNGKey(5), d_in=5)
    rot = so3._rot_y(0.9) @ so3._rot_z(-0.4)
    _, f1 = nequip.energy_and_forces(cfg, params, g)
    g2 = _rotate_graph(g, rot)
    _, f2 = nequip.energy_and_forces(cfg, params, g2)
    np.testing.assert_allclose(
        np.asarray(f1) @ rot.T, np.asarray(f2), rtol=1e-3, atol=1e-4
    )


@pytest.mark.parametrize("seed", [0, 1])
def test_equiformer_energy_rotation_invariant(seed):
    cfg = equiformer_v2.EquiformerV2Config(
        n_layers=2, d_hidden=16, l_max=4, m_max=2, n_heads=4, edge_chunk=64
    )
    g = _small_graph(seed=seed, d=6, n=10, e=24)
    params = equiformer_v2.init_params(cfg, jax.random.PRNGKey(6), d_in=6)
    rot = so3._rot_x(1.2) @ so3._rot_z(0.5)
    e1 = equiformer_v2.forward(cfg, params, g)
    e2 = equiformer_v2.forward(cfg, params, _rotate_graph(g, rot))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4, atol=1e-5)


def test_nequip_translation_invariant():
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, l_max=2, edge_chunk=32)
    g = _small_graph(d=5, n=12, e=30)
    params = nequip.init_params(cfg, jax.random.PRNGKey(7), d_in=5)
    e1 = nequip.energy(cfg, params, g, g.positions)
    e2 = nequip.energy(cfg, params, g, g.positions + jnp.asarray([3.0, -1.0, 2.0]))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5)


def test_edge_chunking_invariance():
    """Results must not depend on the edge_chunk size (pure perf knob)."""
    g = _small_graph(d=5, n=12, e=30)
    params = nequip.init_params(
        nequip.NequIPConfig(n_layers=2, d_hidden=8), jax.random.PRNGKey(8), d_in=5
    )
    outs = []
    for chunk in [8, 30, 64]:
        cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, edge_chunk=chunk)
        outs.append(np.asarray(nequip.energy(cfg, params, g, g.positions)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5)


def test_sampler_budgets_and_locality():
    graph = sampler.random_regular_csr(5000, avg_degree=20, seed=0)
    seeds = np.arange(64, dtype=np.int64)
    nodes, src, dst, mask = sampler.sample_subgraph(graph, seeds, (15, 10), seed=1)
    assert len(nodes) == 64 * (1 + 15 + 150)
    assert len(src) == 64 * (15 + 150)
    # all local ids in range, dst of hop-1 edges are seed slots
    assert src.max() < len(nodes) and dst.max() < len(nodes)
    assert (dst[: 64 * 15] < 64).all()
    # message passing runs on the sampled subgraph
    g = GraphBatch(
        node_feat=jnp.asarray(np.random.default_rng(0).normal(size=(len(nodes), 8)), jnp.float32),
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_feat=jnp.zeros((len(src), 8), jnp.float32),
        positions=jnp.zeros((len(nodes), 3), jnp.float32),
        node_mask=jnp.ones(len(nodes), jnp.float32),
        edge_mask=jnp.asarray(mask),
        graph_id=jnp.zeros(len(nodes), jnp.int32),
        n_graphs=1,
    )
    cfg = gatedgcn.GatedGCNConfig(n_layers=2, d_hidden=16, d_out=4)
    params = gatedgcn.init_params(cfg, jax.random.PRNGKey(0), d_in=8)
    out = gatedgcn.forward(cfg, params, g)
    assert np.isfinite(np.asarray(out)).all()
