"""Distributed sketch (shard_map DP + partition-parallel) on 4 forced host
devices. Runs in a subprocess so the forced device count never leaks into
other tests (jax locks device count at first init)."""
import json
import os
import subprocess
import sys

import pytest

# ~5-10 min of emulated-device shard_map on CPU: by far the slowest tier-1
# module.  CI runs it in its own job; fast local loops use -m "not slow".
pytestmark = pytest.mark.slow

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EdgeBatch, KMatrix, kmatrix, vertex_stats_from_sample
from repro.core.metrics import exact_edge_frequencies, lookup_exact
from repro.distributed.sketch_parallel import (
    build_owner_map,
    make_dp_edge_freq,
    make_dp_ingest,
    make_pp_edge_freq,
    make_pp_ingest,
)
from repro.launch.mesh import use_mesh
from repro.streams import make_stream, sample_stream

assert len(jax.devices()) == 4
mesh = jax.make_mesh((2, 2), ("data", "model"))

stream = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
ssrc, sdst, sw = sample_stream(stream, 2000, seed=5)
stats = vertex_stats_from_sample(ssrc, sdst, sw)
sk0 = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=3, seed=1)

# ---- reference: single-device ingest of the whole stream ----
ref = sk0
ing = jax.jit(kmatrix.ingest)
for b in stream:
    ref = ing(ref, b)
src, dst, w = stream.all_edges_numpy()
fmap = exact_edge_frequencies(src, dst, w)
qs, qd, _ = sample_stream(stream, 256, seed=9)
true = lookup_exact(fmap, qs, qd)
ref_est = np.asarray(kmatrix.edge_freq(ref, jnp.asarray(qs), jnp.asarray(qd)))

results = {}

# ---- data-parallel: replicas over 'data', psum at query ----
with use_mesh(mesh):
    dp_ingest = make_dp_ingest(sk0, mesh)
    dp_query = make_dp_edge_freq(sk0, mesh)
    n_data = mesh.shape["data"]
    pool = jnp.broadcast_to(sk0.pool, (n_data,) + sk0.pool.shape).reshape(
        (n_data * sk0.pool.shape[0],) + sk0.pool.shape[1:])
    # state as stacked replicas: [n_data*d, pool] rows
    pool = jnp.zeros((n_data * sk0.pool.shape[0], sk0.pool.shape[1]), jnp.int32)
    conn = jnp.zeros((n_data * sk0.conn.shape[0],) + sk0.conn.shape[1:], jnp.int32)
    for b in stream:
        pool, conn = dp_ingest(pool, conn, b.src, b.dst, b.weight)
    dp_est = np.asarray(dp_query(pool, conn, jnp.asarray(qs), jnp.asarray(qd)))
results["dp_exact"] = bool((dp_est == ref_est).all())

# ---- partition-parallel: allgather mode (exact) ----
n_rep = mesh.shape["data"] * mesh.shape["model"]
with use_mesh(mesh):
    pp_ingest, owner = make_pp_ingest(sk0, mesh, mode="allgather")
    pp_query = make_pp_edge_freq(sk0, mesh)
    pool = jnp.zeros((n_rep * sk0.pool.shape[0], sk0.pool.shape[1]), jnp.int32)
    conn = jnp.zeros((n_rep * sk0.conn.shape[0],) + sk0.conn.shape[1:], jnp.int32)
    for b in stream:
        pool, conn, dropped = pp_ingest(pool, conn, b.src, b.dst, b.weight)
    ag_est = np.asarray(pp_query(pool, conn, jnp.asarray(qs), jnp.asarray(qd)))
results["pp_allgather_exact"] = bool((ag_est == ref_est).all())

# ---- partition-parallel: a2a mode ----
# cf=4: at this toy scale each model rank handles only a sliver of the
# batch, so buckets are small and the heavy band overflows at cf=2
# (~10% drops); production capacity is sized from the balanced-band load
# (see DESIGN.md §Distribution).
with use_mesh(mesh):
    pp_ingest, owner = make_pp_ingest(sk0, mesh, mode="a2a", capacity_factor=4.0)
    pool = jnp.zeros((n_rep * sk0.pool.shape[0], sk0.pool.shape[1]), jnp.int32)
    conn = jnp.zeros((n_rep * sk0.conn.shape[0],) + sk0.conn.shape[1:], jnp.int32)
    total_dropped = 0
    for b in stream:
        pool, conn, dropped = pp_ingest(pool, conn, b.src, b.dst, b.weight)
        total_dropped += int(dropped)
    a2a_est = np.asarray(pp_query(pool, conn, jnp.asarray(qs), jnp.asarray(qd)))
results["a2a_dropped"] = total_dropped
results["a2a_overcount_ok"] = bool((a2a_est <= ref_est).all())
results["owner_balanced"] = bool(np.bincount(owner, minlength=4).max()
                                 <= len(owner))

print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.slow
def test_distributed_sketch_modes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    results = json.loads(line[0][len("RESULTS:"):])
    assert results["dp_exact"], results
    assert results["pp_allgather_exact"], results
    # a2a estimates can only UNDER-count relative to the exact reference
    # when capacity drops edges; with cf=4 drops should be rare (<2% of
    # the ~21k-edge stream at this 8-device toy scale)
    assert results["a2a_overcount_ok"], results
    assert results["a2a_dropped"] < 450, results
