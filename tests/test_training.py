"""Optimizer, schedules, grad accumulation, checkpoint roundtrip."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.training import (
    AdamWConfig,
    TrainState,
    adamw_init,
    adamw_update,
    cosine_warmup_lr,
    global_norm,
    init_train_state,
    make_train_step,
)
from repro.checkpoint import store


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    loss = jnp.mean((pred - batch["y"]) ** 2)
    return loss, {"mse": loss}


def _toy_state(key=0, din=8, dout=3):
    k = jax.random.PRNGKey(key)
    params = {
        "w": jax.random.normal(k, (din, dout)) * 0.1,
        "b": jnp.zeros((dout,)),
    }
    return params


def test_lr_schedule():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_warmup_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[5] < lrs[10]  # warmup
    assert abs(lrs[10] - 1e-3) < 1e-9  # peak
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)  # min ratio 0.1


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr_peak=0.05, warmup_steps=5, total_steps=300,
                      weight_decay=0.0)
    params = _toy_state()
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(8, 3))
    x = jnp.asarray(rng.normal(size=(256, 8)), jnp.float32)
    y = x @ jnp.asarray(w_true, jnp.float32)
    state = init_train_state(params, cfg)
    step = jax.jit(make_train_step(_quad_loss, cfg))
    for _ in range(300):
        state, metrics = step(state, {"x": x, "y": y})
    assert float(metrics["loss"]) < 1e-2


def test_grad_accum_matches_full_batch():
    """accum_steps microbatching must give the same update (grads linear)."""
    cfg = AdamWConfig(lr_peak=1e-2, warmup_steps=0, total_steps=10,
                      clip_norm=1e9)
    params = _toy_state(1)
    rng = np.random.default_rng(1)
    batch = {
        "x": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(32, 3)), jnp.float32),
    }
    s_full = init_train_state(params, cfg)
    s_acc = init_train_state(params, cfg)
    full_step = jax.jit(make_train_step(_quad_loss, cfg, accum_steps=1))
    acc_step = jax.jit(make_train_step(_quad_loss, cfg, accum_steps=4))
    s_full, m1 = full_step(s_full, batch)
    s_acc, m2 = acc_step(s_acc, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s_full.params),
                    jax.tree_util.tree_leaves(s_acc.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    from repro.training.optimizer import clip_by_global_norm

    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = AdamWConfig()
    state = init_train_state(_toy_state(2), cfg)
    store.save(str(tmp_path), 7, state, extra={"stream_offset": 42})
    template = init_train_state(_toy_state(3), cfg)  # different values
    restored, meta = store.restore(str(tmp_path), template)
    assert meta["step"] == 7
    assert meta["extra"]["stream_offset"] == 42
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_pruning_and_latest(tmp_path):
    state = {"x": jnp.ones(3)}
    for s in [1, 2, 3, 4, 5]:
        store.save(str(tmp_path), s, state, keep=2)
    assert store.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 2


def test_checkpoint_atomicity(tmp_path):
    """A failed save never clobbers the previous checkpoint."""
    state = {"x": jnp.ones(3)}
    store.save(str(tmp_path), 1, state)

    class Boom(Exception):
        pass

    bad_state = {"x": _Unsaveable()}
    with pytest.raises(Exception):
        store.save(str(tmp_path), 2, bad_state)
    restored, meta = store.restore(str(tmp_path), state)
    assert meta["step"] == 1


class _Unsaveable:
    shape = (3,)

    def __array__(self):
        raise RuntimeError("disk full (simulated)")
