"""Type II query surface: reachability (vs networkx oracle), heavy hitters, paths."""
import networkx as nx
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EdgeBatch, KMatrix, MatrixSketch, vertex_stats_from_sample
from repro.core import kmatrix, matrix_sketch
from repro.core import queries


def _graph(seed=0, n_nodes=40, n_edges=80):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    keep = src != dst
    return src[keep], dst[keep]


def test_reachability_no_false_negatives():
    """Sketch reachability may overconnect (collisions) but never misses."""
    src, dst = _graph(0)
    sk = MatrixSketch.create(bytes_budget=1 << 18, depth=4, seed=9)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    g = nx.DiGraph(zip(src.tolist(), dst.tolist()))
    qs, qd, truth = [], [], []
    nodes = sorted(g.nodes())
    for a in nodes[:15]:
        for b in nodes[:15]:
            if a == b:
                continue
            qs.append(a)
            qd.append(b)
            truth.append(nx.has_path(g, a, b))
    est = np.asarray(
        queries.reachability(sk, jnp.asarray(qs, jnp.int32), jnp.asarray(qd, jnp.int32))
    )
    truth = np.asarray(truth)
    assert (est | ~truth).all(), "false negative in sketch reachability"
    # With a huge sketch relative to graph size we expect few false positives.
    fp_rate = float((est & ~truth).mean())
    assert fp_rate < 0.25, fp_rate


def test_kmatrix_reachability_no_false_negatives():
    src, dst = _graph(1)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 18, stats=stats, depth=4, seed=3, conn_frac=0.5)
    sk = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
    g = nx.DiGraph(zip(src.tolist(), dst.tolist()))
    nodes = sorted(g.nodes())[:12]
    qs = np.repeat(nodes, len(nodes)).astype(np.int32)
    qd = np.tile(nodes, len(nodes)).astype(np.int32)
    truth = np.asarray([nx.has_path(g, a, b) for a, b in zip(qs, qd)])
    est = np.asarray(queries.kmatrix_reachability(sk, jnp.asarray(qs), jnp.asarray(qd)))
    assert (est | ~truth).all()


def test_heavy_nodes_sweep_finds_the_heavy_vertex():
    n_nodes = 100
    src = np.concatenate([np.full(500, 7, np.int32), np.arange(50, dtype=np.int32)])
    dst = np.concatenate(
        [np.arange(500, dtype=np.int32) % 90, (np.arange(50, dtype=np.int32) + 1) % 100]
    ).astype(np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 18, depth=4, seed=5)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    ids, freqs = queries.heavy_nodes(
        lambda v: matrix_sketch.node_out_freq(sk, v), n_nodes, threshold=400, chunk=64
    )
    ids = np.asarray(ids)
    found = set(ids[ids >= 0].tolist())
    assert 7 in found
    assert len(found) <= 5  # few false positives at this budget


@pytest.mark.parametrize("kind", ["gmatrix", "kmatrix"])
def test_planted_path_is_always_reachable(kind):
    """Plant an explicit 8-hop chain in noise; every (earlier, later) pair on
    the chain must be reported reachable — one-sided error guarantees it."""
    rng = np.random.default_rng(3)
    noise_s = rng.integers(200, 300, 120).astype(np.int32)
    noise_d = rng.integers(200, 300, 120).astype(np.int32)
    chain = np.arange(9, dtype=np.int32)  # 0 -> 1 -> ... -> 8
    src = np.concatenate([chain[:-1], noise_s])
    dst = np.concatenate([chain[1:], noise_d])
    keep = src != dst
    src, dst = src[keep], dst[keep]

    if kind == "gmatrix":
        sk = MatrixSketch.create(bytes_budget=1 << 16, depth=4, seed=11)
        sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
        reach_fn = queries.reachability
    else:
        stats = vertex_stats_from_sample(src, dst)
        sk = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=4,
                            seed=11, conn_frac=0.5)
        sk = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
        reach_fn = queries.kmatrix_reachability

    qs, qd = [], []
    for i in range(9):
        for j in range(i + 1, 9):
            qs.append(i)
            qd.append(j)
    est = np.asarray(reach_fn(sk, jnp.asarray(qs, jnp.int32),
                              jnp.asarray(qd, jnp.int32)))
    assert est.all(), "planted path reported unreachable (false negative)"


def test_heavy_nodes_padding_contract():
    """Static-shape contract: output length is universe rounded up to chunk,
    misses hold id -1 / freq 0, and every valid id is inside the universe."""
    src = np.concatenate([np.full(300, 5, np.int32),
                          np.arange(20, dtype=np.int32)])
    dst = (np.concatenate([np.arange(300, dtype=np.int32),
                           np.arange(20, dtype=np.int32) + 1]) % 90).astype(
        np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 18, depth=4, seed=2)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    universe, chunk = 90, 64
    ids, freqs = queries.heavy_nodes(
        lambda v: matrix_sketch.node_out_freq(sk, v), universe,
        threshold=250, chunk=chunk)
    ids, freqs = np.asarray(ids), np.asarray(freqs)
    padded = -(-universe // chunk) * chunk
    assert ids.shape == freqs.shape == (padded,)
    miss = ids < 0
    assert (ids[miss] == -1).all()
    assert (freqs[miss] == 0).all()
    valid = ids[~miss]
    assert ((valid >= 0) & (valid < universe)).all()
    assert (freqs[~miss] >= 250).all()
    assert 5 in set(valid.tolist())


def test_path_and_subgraph_weight_vs_exact_ground_truth():
    """At a generous budget (no collisions) both composite estimators equal
    the exact sums; at any budget they stay one-sided (>= exact)."""
    src = np.asarray([0, 1, 2, 3, 0, 2], np.int32)
    dst = np.asarray([1, 2, 3, 4, 2, 4], np.int32)
    w = np.asarray([3, 7, 2, 5, 1, 9], np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 20, depth=4, seed=8)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    fn = lambda s, d: matrix_sketch.edge_freq(sk, s, d)

    # path 0 -> 1 -> 2 -> 3 -> 4: exact 3 + 7 + 2 + 5 = 17
    pw = int(queries.path_weight(fn, jnp.asarray([0, 1, 2, 3, 4], jnp.int32)))
    assert pw == 17

    # subgraph {(0,2), (2,4)}: exact 1 + 9 = 10
    sw = int(queries.subgraph_weight(fn, jnp.asarray([0, 2], jnp.int32),
                                     jnp.asarray([2, 4], jnp.int32)))
    assert sw == 10

    # one-sidedness survives a starved budget
    tiny = MatrixSketch.create(bytes_budget=1 << 8, depth=2, seed=8)
    tiny = matrix_sketch.ingest(tiny, EdgeBatch.from_numpy(src, dst, w))
    tfn = lambda s, d: matrix_sketch.edge_freq(tiny, s, d)
    assert int(queries.path_weight(
        tfn, jnp.asarray([0, 1, 2, 3, 4], jnp.int32))) >= 17
    assert int(queries.subgraph_weight(
        tfn, jnp.asarray([0, 2], jnp.int32),
        jnp.asarray([2, 4], jnp.int32))) >= 10


def test_closure_injection_matches_one_shot_reachability():
    """build_closure + reachability_from_closure == the classic wrappers."""
    src, dst = _graph(4)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=3, seed=6,
                        conn_frac=0.4)
    sk = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
    qs = jnp.asarray(src[:20], jnp.int32)
    qd = jnp.asarray(dst[5:25], jnp.int32)
    one_shot = np.asarray(queries.kmatrix_reachability(sk, qs, qd))
    closure = queries.build_closure(queries.closure_layers(sk))
    injected = np.asarray(queries.reachability_from_closure(
        closure, queries.reach_cells(sk, qs), queries.reach_cells(sk, qd)))
    assert (one_shot == injected).all()


def test_heavy_edges_and_path_weight():
    src = np.asarray([1, 1, 2, 3], np.int32)
    dst = np.asarray([2, 2, 3, 4], np.int32)
    w = np.asarray([5, 5, 2, 1], np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 16, depth=4, seed=6)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    fn = lambda s, d: matrix_sketch.edge_freq(sk, s, d)
    keep, est, _ = queries.heavy_edges(
        fn, jnp.asarray([1, 2, 3], jnp.int32), jnp.asarray([2, 3, 4], jnp.int32), 5
    )
    assert np.asarray(keep).tolist() == [True, False, False]
    pw = queries.path_weight(fn, jnp.asarray([1, 2, 3, 4], jnp.int32))
    assert float(pw) >= 13.0  # 10 + 2 + 1, one-sided
