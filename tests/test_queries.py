"""Type II query surface: reachability (vs networkx oracle), heavy hitters, paths."""
import networkx as nx
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EdgeBatch, KMatrix, MatrixSketch, vertex_stats_from_sample
from repro.core import kmatrix, matrix_sketch
from repro.core import queries


def _graph(seed=0, n_nodes=40, n_edges=80):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    keep = src != dst
    return src[keep], dst[keep]


def test_reachability_no_false_negatives():
    """Sketch reachability may overconnect (collisions) but never misses."""
    src, dst = _graph(0)
    sk = MatrixSketch.create(bytes_budget=1 << 18, depth=4, seed=9)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    g = nx.DiGraph(zip(src.tolist(), dst.tolist()))
    qs, qd, truth = [], [], []
    nodes = sorted(g.nodes())
    for a in nodes[:15]:
        for b in nodes[:15]:
            if a == b:
                continue
            qs.append(a)
            qd.append(b)
            truth.append(nx.has_path(g, a, b))
    est = np.asarray(
        queries.reachability(sk, jnp.asarray(qs, jnp.int32), jnp.asarray(qd, jnp.int32))
    )
    truth = np.asarray(truth)
    assert (est | ~truth).all(), "false negative in sketch reachability"
    # With a huge sketch relative to graph size we expect few false positives.
    fp_rate = float((est & ~truth).mean())
    assert fp_rate < 0.25, fp_rate


def test_kmatrix_reachability_no_false_negatives():
    src, dst = _graph(1)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 18, stats=stats, depth=4, seed=3, conn_frac=0.5)
    sk = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
    g = nx.DiGraph(zip(src.tolist(), dst.tolist()))
    nodes = sorted(g.nodes())[:12]
    qs = np.repeat(nodes, len(nodes)).astype(np.int32)
    qd = np.tile(nodes, len(nodes)).astype(np.int32)
    truth = np.asarray([nx.has_path(g, a, b) for a, b in zip(qs, qd)])
    est = np.asarray(queries.kmatrix_reachability(sk, jnp.asarray(qs), jnp.asarray(qd)))
    assert (est | ~truth).all()


def test_heavy_nodes_sweep_finds_the_heavy_vertex():
    n_nodes = 100
    src = np.concatenate([np.full(500, 7, np.int32), np.arange(50, dtype=np.int32)])
    dst = np.concatenate(
        [np.arange(500, dtype=np.int32) % 90, (np.arange(50, dtype=np.int32) + 1) % 100]
    ).astype(np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 18, depth=4, seed=5)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst))
    ids, freqs = queries.heavy_nodes(
        lambda v: matrix_sketch.node_out_freq(sk, v), n_nodes, threshold=400, chunk=64
    )
    ids = np.asarray(ids)
    found = set(ids[ids >= 0].tolist())
    assert 7 in found
    assert len(found) <= 5  # few false positives at this budget


def test_heavy_edges_and_path_weight():
    src = np.asarray([1, 1, 2, 3], np.int32)
    dst = np.asarray([2, 2, 3, 4], np.int32)
    w = np.asarray([5, 5, 2, 1], np.int32)
    sk = MatrixSketch.create(bytes_budget=1 << 16, depth=4, seed=6)
    sk = matrix_sketch.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    fn = lambda s, d: matrix_sketch.edge_freq(sk, s, d)
    keep, est, _ = queries.heavy_edges(
        fn, jnp.asarray([1, 2, 3], jnp.int32), jnp.asarray([2, 3, 4], jnp.int32), 5
    )
    assert np.asarray(keep).tolist() == [True, False, False]
    pw = queries.path_weight(fn, jnp.asarray([1, 2, 3, 4], jnp.int32))
    assert float(pw) >= 13.0  # 10 + 2 + 1, one-sided
