"""Fault-tolerance: crash/restart bit-exactness, elastic re-sharding,
straggler-tolerant merge semantics. These validate the 1000-node design
contracts on a single host (see DESIGN.md §Fault-tolerance)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.core import EdgeBatch, KMatrix, kmatrix, vertex_stats_from_sample
from repro.streams import make_stream, sample_stream


def _build(depth=3, budget=1 << 14):
    stream = make_stream("cit-HepPh", batch_size=1024, seed=3, scale=0.02)
    ssrc, sdst, sw = sample_stream(stream, 2000, seed=5)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    sk = KMatrix.create(bytes_budget=budget, stats=stats, depth=depth, seed=1)
    return stream, sk


def test_crash_restart_is_bit_exact(tmp_path):
    """Kill mid-stream, restore (sketch, offset), resume -> identical state."""
    stream, sk0 = _build()
    ing = jax.jit(kmatrix.ingest)

    # uninterrupted run
    ref = sk0
    for b in stream:
        ref = ing(ref, b)

    # interrupted run: checkpoint at batch 4, "crash", restore, resume
    sk = sk0
    for i, b in stream.iter_from(0):
        sk = ing(sk, b)
        if i == 3:
            store.save(str(tmp_path), i + 1, sk,
                       extra={"stream_offset": i + 1, "seed": 3})
            break
    del sk  # crash

    restored, meta = store.restore(str(tmp_path), sk0)
    resume_from = meta["extra"]["stream_offset"]
    sk = restored
    for i, b in stream.iter_from(resume_from):
        sk = ing(sk, b)

    np.testing.assert_array_equal(np.asarray(sk.pool), np.asarray(ref.pool))
    np.testing.assert_array_equal(np.asarray(sk.conn), np.asarray(ref.conn))


def test_restore_fills_template_leaves_missing_from_old_checkpoints(tmp_path):
    """A checkpoint written before a (inert) leaf existed must still
    restore into the grown template: the missing leaf falls back to the
    template's freshly-built default and is reported in the metadata —
    e.g. pre-overflow-leaf KMatrix checkpoints migrating forward."""
    stream, sk = _build()
    sk = kmatrix.ingest(sk, stream.batch(0))
    # simulate the old on-disk layout: same sketch minus the overflow leaf
    old_state = {"pool": np.asarray(sk.pool), "conn": np.asarray(sk.conn)}
    store.save(str(tmp_path), 1, old_state)
    template = {"pool": np.zeros_like(sk.pool), "conn": np.zeros_like(sk.conn),
                "overflow": np.zeros((), np.int32)}
    restored, meta = store.restore(str(tmp_path), template)
    np.testing.assert_array_equal(restored["pool"], np.asarray(sk.pool))
    np.testing.assert_array_equal(restored["conn"], np.asarray(sk.conn))
    assert int(restored["overflow"]) == 0
    assert len(meta["filled_from_template"]) == 1
    assert "overflow" in meta["filled_from_template"][0]
    # a complete checkpoint reports nothing filled
    store.save(str(tmp_path), 2, template)
    _, meta2 = store.restore(str(tmp_path), template, step=2)
    assert meta2["filled_from_template"] == []


def test_worker_failure_merge_recovery():
    """Counters are additive: a failed worker's sub-stream can be replayed
    by any other worker and merged — final state identical to no-failure."""
    stream, sk0 = _build()
    ing = jax.jit(kmatrix.ingest)
    n = stream.num_batches

    # 2 workers split batches even/odd; worker B dies after 2 batches.
    worker_a, worker_b = sk0, sk0
    done_b = []
    for i in range(n):
        if i % 2 == 0:
            worker_a = ing(worker_a, stream.batch(i))
        elif len(done_b) < 2:
            worker_b = ing(worker_b, stream.batch(i))
            done_b.append(i)
    # worker C (replacement) replays B's unfinished shard via seekable stream
    worker_c = sk0
    for i in range(n):
        if i % 2 == 1 and i not in done_b:
            worker_c = ing(worker_c, stream.batch(i))

    merged = kmatrix.merge(kmatrix.merge(worker_a, worker_b), worker_c)

    ref = sk0
    for b in stream:
        ref = ing(ref, b)
    np.testing.assert_array_equal(np.asarray(merged.pool), np.asarray(ref.pool))


def test_elastic_rescale_data_parallel():
    """Re-sharding a data-parallel run from 4 'workers' to 2 preserves the
    global sketch exactly (merge is associative + commutative)."""
    stream, sk0 = _build()
    ing = jax.jit(kmatrix.ingest)
    n = stream.num_batches

    def run_workers(k):
        workers = [sk0] * k
        for i in range(n):
            workers[i % k] = ing(workers[i % k], stream.batch(i))
        out = workers[0]
        for w in workers[1:]:
            out = kmatrix.merge(out, w)
        return out

    a = run_workers(4)
    b = run_workers(2)
    np.testing.assert_array_equal(np.asarray(a.pool), np.asarray(b.pool))


def test_straggler_mitigation_out_of_order_merge():
    """Late (straggler) partial results can merge in any order."""
    stream, sk0 = _build()
    ing = jax.jit(kmatrix.ingest)
    shards = []
    for i in range(min(stream.num_batches, 6)):
        shards.append(ing(sk0, stream.batch(i)))
    import itertools

    ref = None
    for perm in list(itertools.permutations(range(len(shards))))[:4]:
        acc = shards[perm[0]]
        for j in perm[1:]:
            acc = kmatrix.merge(acc, shards[j])
        if ref is None:
            ref = acc
        else:
            np.testing.assert_array_equal(np.asarray(acc.pool),
                                          np.asarray(ref.pool))
