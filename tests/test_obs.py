"""Unified telemetry tier (ISSUE 7): mergeable metrics hub, cross-transport
trace spans, and the scrapeable exposition surface (DESIGN.md §Observability).

The load-bearing gates: log-bucketed histograms merge associatively /
commutatively and EXACTLY match a one-shot histogram over the raw samples
(so per-worker distributions sum across threads, pipes and socket frames);
the Prometheus text a server scrapes renders histogram sums equal to the
per-worker histograms merged parent-side; one edge batch's trace chain
closes enqueue -> dispatch -> publish -> adopt across a real socket worker;
and the ``metrics`` frame sits behind the same auth gate as query frames.
"""
import json
import os
import socket
import threading
import time
import types

import numpy as np
import pytest

from repro.obs import (
    Histogram,
    LADDERS,
    MetricsHub,
    MetricsJsonDumper,
    get_hub,
    get_trace_log,
    hist_summary,
    merge_hist_states,
    new_trace_id,
    quantile_from_state,
    render_prometheus,
    reset_hub,
    reset_trace_log,
    set_disabled,
)
from repro.obs.dashboard import parse_prometheus_text
from repro.runtime.metrics import RateEWMA, WorkerMetrics


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    reset_hub()
    reset_trace_log()
    set_disabled(False)
    yield
    set_disabled(False)
    reset_hub()
    reset_trace_log()


def _registry(**kw):
    from repro.serving import SketchRegistry

    kw.setdefault("depth", 3)
    kw.setdefault("batch_size", 1024)
    kw.setdefault("scale", 0.02)
    return SketchRegistry(**kw)


# ------------------------------------------------------------ histograms


def test_histogram_merge_matches_raw_oracle(rng):
    """Per-chunk histograms merged in ANY order/grouping must equal the
    one-shot histogram over all raw samples — counts, sum, min, max."""
    xs = rng.exponential(0.004, 3000)
    chunks = np.array_split(xs, 3)
    hs = []
    for i, chunk in enumerate(chunks):
        h = Histogram(f"h{i}", {})
        h.observe_many(chunk)
        hs.append(h.state())
    oracle = Histogram("all", {})
    oracle.observe_many(xs)
    want = oracle.state()

    left = merge_hist_states(merge_hist_states(hs[0], hs[1]), hs[2])
    right = merge_hist_states(hs[0], merge_hist_states(hs[1], hs[2]))
    flipped = merge_hist_states(hs[2], merge_hist_states(hs[1], hs[0]))
    for merged in (left, right, flipped):
        assert merged["counts"] == want["counts"]
        assert merged["count"] == want["count"] == len(xs)
        assert merged["sum"] == pytest.approx(want["sum"], abs=1e-9)
        assert merged["min"] == want["min"]
        assert merged["max"] == want["max"]
    # associativity/commutativity exactly (integer counts, float adds of
    # the same operands in the same association are compared approx)
    assert left["counts"] == right["counts"] == flipped["counts"]

    # bucket-interpolated quantiles track the raw-sample oracle within a
    # bucket width (the ladder grows by sqrt(2), so <= ~42% relative) and
    # clamp to the observed extremes
    for q in (0.5, 0.9, 0.99):
        est = quantile_from_state(left, q)
        raw = float(np.quantile(xs, q))
        assert raw / 1.5 <= est <= raw * 1.5
        assert want["min"] <= est <= want["max"]


def test_histogram_ladders_and_summary():
    assert len(LADDERS["latency"]) == 54
    assert len(LADDERS["size"]) == 25
    with pytest.raises(ValueError):
        Histogram("bad", {}, ladder="nope")
    a = Histogram("a", {}, ladder="size")
    b = Histogram("b", {})
    with pytest.raises(ValueError, match="ladder"):
        merge_hist_states(a.state(), b.state())

    h = Histogram("s", {})
    h.observe_n(0.25, 7)  # weighted single-bucket update
    s = hist_summary(h.state())
    assert s["count"] == 7
    assert s["mean"] == pytest.approx(0.25)
    assert hist_summary(Histogram("empty", {}).state()) == {"count": 0}


def test_hub_adopt_merges_exactly_and_renders_parseable(rng):
    """Acceptance gate: the scraped exposition's histogram sums equal the
    per-worker histograms merged parent-side — exactly."""
    child_samples = {"w1": rng.exponential(0.01, 400),
                     "w2": rng.exponential(0.002, 700)}
    parent = MetricsHub()
    for name, xs in child_samples.items():
        child = MetricsHub()  # stands in for a remote worker's hub
        child.counter("repro_ingest_edges_total", "edges",
                      tenant="t0").inc(len(xs))
        child.histogram("repro_publish_latency_seconds", "lat",
                        tenant="t0").observe_many(xs)
        parent.adopt(f"worker:{name}", child.state())
    assert sorted(parent.adopted_sources()) == ["worker:w1", "worker:w2"]

    merged = parent.merged_state()
    all_xs = np.concatenate(list(child_samples.values()))
    (hist_state,) = [h for n, _, h in merged["hists"]
                     if n == "repro_publish_latency_seconds"]
    assert hist_state["count"] == len(all_xs)
    assert hist_state["sum"] == pytest.approx(float(all_xs.sum()), abs=1e-9)
    oracle = Histogram("o", {})
    oracle.observe_many(all_xs)
    assert hist_state["counts"] == oracle.state()["counts"]

    samples = parse_prometheus_text(render_prometheus(merged))
    key = ("repro_publish_latency_seconds_sum", (("tenant", "t0"),))
    assert samples[key] == float(hist_state["sum"])  # exact round-trip
    cnt = samples[("repro_publish_latency_seconds_count",
                   (("tenant", "t0"),))]
    assert cnt == len(all_xs)
    edges = samples[("repro_ingest_edges_total", (("tenant", "t0"),))]
    assert edges == sum(len(x) for x in child_samples.values())
    # +Inf bucket must equal _count (cumulative le semantics)
    inf = samples[("repro_publish_latency_seconds_bucket",
                   (("le", "+Inf"), ("tenant", "t0")))]
    assert inf == cnt

    # re-adopting the SAME source replaces, never double-counts
    parent.adopt("worker:w1", parent._adopted["worker:w1"])
    again = parent.merged_state()
    (h2,) = [h for n, _, h in again["hists"]
             if n == "repro_publish_latency_seconds"]
    assert h2["count"] == len(all_xs)


def test_prometheus_parser_is_strict():
    assert parse_prometheus_text("# HELP x y\n# TYPE x counter\nx 1\n") == {
        ("x", ()): 1.0}
    with pytest.raises(ValueError):
        parse_prometheus_text("not a metric line at all")
    with pytest.raises(ValueError):
        parse_prometheus_text('x{bad-label="1"} 2')


def test_set_disabled_is_a_global_kill_switch():
    set_disabled(True)
    hub = get_hub()
    hub.counter("c", "c").inc(5)
    hub.histogram("h", "h").observe(1.0)
    get_trace_log().emit(new_trace_id(), "ingest", "enqueue")
    state = hub.state()
    assert [v for _, _, v in state["counters"]] == [0.0]
    assert get_trace_log().emitted == 0
    set_disabled(False)
    hub.counter("c", "c").inc(5)
    assert [v for _, _, v in hub.state()["counters"]] == [5.0]


# ---------------------------------------------------- runtime satellites


def test_rate_ewma_folds_first_sample_into_next_interval():
    """Satellite: the first update's count must not vanish — it seeds the
    next interval's numerator."""
    r = RateEWMA(halflife_s=5.0)
    r.update(1000, now=100.0)
    assert r.rate == 0.0  # no interval yet — but the count is carried...
    r.update(1000, now=101.0)
    # ...so the first measurable instant rate is 2000/s, not 1000/s
    assert r.rate > RateEWMA(halflife_s=5.0).rate
    two = RateEWMA(halflife_s=5.0)
    two.update(0, now=100.0)
    two.update(1000, now=101.0)
    assert r.rate == pytest.approx(two.rate * 2.0)


def test_worker_metrics_lifetime_wall_is_first_ingest():
    """Satellite: edges_per_s_lifetime must wall at first_ingest_at, not
    started_at — spawn/compile warmup is not ingest time."""
    m = WorkerMetrics(started_at=0.0)
    qs = {"depth": 0, "dropped_batches": 0, "dropped_edges": 0,
          "spilled_batches": 0, "max_depth_seen": 0}
    assert m.snapshot(queue_stats=qs, state="running", epoch=0,
                      now=50.0)["edges_per_s_lifetime"] == 0.0
    m.note_ingest(1000, now=100.0)  # 100s of warmup before this
    m.note_ingest(1000, now=102.0)
    snap = m.snapshot(queue_stats=qs, state="running", epoch=0, now=102.0)
    assert snap["edges_per_s_lifetime"] == pytest.approx(1000.0, rel=0.01)


def test_worker_metrics_bind_hub_mirrors_typed_instruments():
    m = WorkerMetrics(started_at=0.0)
    m.bind_hub("tenantX", backend="thread")
    m.note_ingest(512, now=1.0)
    m.note_ingest(256, now=2.0)
    m.note_publish(0.05, now=2.5)
    state = get_hub().state()
    counters = {(n, tuple(sorted(l.items()))): v
                for n, l, v in state["counters"]}
    labels = (("backend", "thread"), ("tenant", "tenantX"))
    assert counters[("repro_ingest_edges_total", labels)] == 768
    assert counters[("repro_ingest_batches_total", labels)] == 2
    (batch_h,) = [h for n, _, h in state["hists"]
                  if n == "repro_ingest_batch_edges"]
    assert batch_h["count"] == 2 and batch_h["ladder"] == "size"


# ------------------------------------------------------------ trace spans


def test_thread_runtime_closes_ingest_chains_with_edge_parity():
    from repro.runtime import Runtime

    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt = Runtime(publish_policy="drain:0", reservoir_k=0, backend="thread")
    rt.attach(t)
    rt.start(pumps=False)
    rt.wait_ready()
    rt.start_pumps()
    rt.join_pumps()
    rep = rt.stop(drain=True)[t.key.tenant_id]
    assert rep["unaccounted_edges"] == 0

    state = get_hub().merged_state()
    edges = [v for n, _, v in state["counters"]
             if n == "repro_ingest_edges_total"]
    assert sum(edges) == rep["ingested_edges"]

    chains = {}
    for e in get_trace_log().events():
        chains.setdefault(e["trace"], []).append(e["event"])
    closed = [c for c in chains.values()
              if {"enqueue", "dispatch", "publish"} <= set(c)]
    assert closed, f"no closed thread ingest chain in {chains}"


def test_socket_runtime_adopts_worker_hub_and_closes_chains():
    """Tentpole gate over real TCP: the parent's merged hub equals the
    socket child's counters (adopted, never double-counted) and a batch's
    chain closes enqueue -> dispatch -> publish -> adopt across the
    process+socket boundary."""
    from repro.runtime import Runtime

    reg = _registry()
    t = reg.open("cit-HepPh", "kmatrix", 64, seed=0)
    rt = Runtime(publish_policy="drain:0", reservoir_k=0, backend="socket")
    rt.attach(t)
    rt.start(pumps=False)
    rt.wait_ready()
    rt.start_pumps()
    rt.join_pumps()
    rep = rt.stop(drain=True)[t.key.tenant_id]
    assert rep["unaccounted_edges"] == 0

    hub = get_hub()
    assert any(s.startswith("worker:") for s in hub.adopted_sources())
    state = hub.merged_state()
    edges = [v for n, _, v in state["counters"]
             if n == "repro_ingest_edges_total"]
    assert sum(edges) == rep["ingested_edges"]

    chains = {}
    for e in get_trace_log().events():
        chains.setdefault(e["trace"], []).append(e["event"])
    closed = [c for c in chains.values()
              if {"enqueue", "dispatch", "publish", "adopt"} <= set(c)]
    assert closed, f"no closed socket ingest chain in {chains}"


def test_query_server_traces_and_scrape_match_ledger():
    """A query's accept -> plan -> execute -> reply chain closes, and the
    scraped exposition mirrors the admission ledger exactly."""
    from repro.net.query_server import QueryClient, QueryServer

    snap = types.SimpleNamespace(epoch=3, n_edges=10)
    eng = types.SimpleNamespace(execute=lambda s, reqs: [
        types.SimpleNamespace(epoch=s.epoch, value=0.0) for _ in reqs])
    server = QueryServer(eng, lambda: snap).start()
    try:
        client = QueryClient(server.address)
        for _ in range(3):
            assert client.call(["q1", "q2"])["kind"] == "result"
        payload = client.metrics()
        client.close()
    finally:
        server.stop()

    samples = parse_prometheus_text(payload["prometheus"])
    assert samples[("repro_query_served_requests_total", ())] == 6
    assert samples[("repro_query_offered_requests_total", ())] == 6
    (lat,) = [h for n, _, h in payload["state"]["hists"]
              if n == "repro_query_latency_seconds"]
    assert lat["count"] == 6  # one observation per served request

    chains = {}
    for e in get_trace_log().events():
        if e["span"] == "query":
            chains.setdefault(e["trace"], []).append(e["event"])
    assert chains and all(
        c == ["accept", "plan", "execute", "reply"] for c in chains.values())


def test_trace_log_is_bounded_and_dumps_jsonl(tmp_path):
    log = get_trace_log()
    for i in range(5000):
        log.emit(f"t{i}", "ingest", "enqueue", offset=i)
    assert len(log.events()) == 4096  # bounded ring, oldest dropped
    path = tmp_path / "spans.jsonl"
    n = log.dump_jsonl(str(path))
    lines = path.read_text().splitlines()
    assert n == len(lines) == 4096
    rec = json.loads(lines[-1])
    assert rec["event"] == "enqueue" and rec["offset"] == 4999


# ------------------------------------------------------- exposition surface


def test_metrics_frame_requires_auth_on_query_server(monkeypatch):
    """Satellite: the scrape honors --auth-token exactly like query frames
    — telemetry names tenants and throughput, it is not public."""
    from repro.net import wire
    from repro.net.query_server import QueryClient, QueryServer

    monkeypatch.delenv(wire.AUTH_TOKEN_ENV, raising=False)
    snap = types.SimpleNamespace(epoch=1, n_edges=5)
    eng = types.SimpleNamespace(execute=lambda s, reqs: [])
    server = QueryServer(eng, lambda: snap, auth_token="sekrit").start()
    try:
        conn = socket.create_connection(server.address, timeout=10)
        wire.send_message(conn, ("metrics_req",))  # no auth frame
        reply = None
        deadline = time.monotonic() + 30
        while reply is None and time.monotonic() < deadline:
            try:
                reply = wire.recv_message(conn, poll_s=0.2)
            except (ConnectionError, OSError):
                break
        conn.close()
        assert reply is None or reply[0] == "error"

        good = QueryClient(server.address, auth_token="sekrit")
        payload = good.metrics()
        good.close()
        parse_prometheus_text(payload["prometheus"])
    finally:
        server.stop()
    assert server.stats()["auth_failures"] >= 1


def test_metrics_frame_requires_auth_on_worker_server(monkeypatch):
    from repro.net import wire
    from repro.net.ingest_server import WorkerServer

    monkeypatch.delenv(wire.AUTH_TOKEN_ENV, raising=False)
    get_hub().counter("repro_ingest_edges_total", "edges", tenant="x").inc(9)
    server = WorkerServer("127.0.0.1", 0, auth_token="sekrit",
                          hello_timeout_s=10.0)
    host, port = server.address
    thread = threading.Thread(
        target=lambda: server.serve_forever(max_sessions=2), daemon=True)
    thread.start()
    try:
        conn = socket.create_connection((host, port), timeout=10)
        wire.send_message(conn, ("metrics_req",))  # no auth: refused
        deadline = time.monotonic() + 60
        while server.sessions_served < 1 and time.monotonic() < deadline:
            time.sleep(0.02)
        conn.close()
        assert "auth" in server.session_results[0]

        conn2 = socket.create_connection((host, port), timeout=10)
        wire.send_message(conn2, ("auth", "sekrit"))
        wire.send_message(conn2, ("metrics_req",))
        reply = None
        deadline = time.monotonic() + 30
        while reply is None and time.monotonic() < deadline:
            reply = wire.recv_message(conn2, poll_s=0.2)
        conn2.close()
        assert reply is not None and reply[0] == "metrics"
        samples = parse_prometheus_text(reply[1]["prometheus"])
        assert samples[("repro_ingest_edges_total", (("tenant", "x"),))] == 9
        deadline = time.monotonic() + 60
        while server.sessions_served < 2 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert server.session_results[1] == "scraped"
    finally:
        server.stop()
        thread.join(timeout=30)


def test_metrics_json_dumper_and_dashboard_once(tmp_path):
    from repro.obs.dashboard import main as dash_main

    get_hub().counter("repro_ingest_edges_total", "edges",
                      tenant="t").inc(42)
    path = str(tmp_path / "metrics.json")
    dumper = MetricsJsonDumper(path, interval_s=0.05)
    dumper.start()
    time.sleep(0.15)
    dumper.stop()
    assert dumper.writes >= 3
    payload = json.loads((tmp_path / "metrics.json").read_text())
    assert set(payload) == {"prometheus", "state", "ts"}
    assert not os.path.exists(path + ".tmp")  # atomic replace, no litter
    assert dash_main(["--json", path, "--once"]) == 0
    assert dash_main(["--json", str(tmp_path / "absent.json"),
                      "--once"]) == 1


def test_profile_hooks_record_when_enabled(monkeypatch):
    from repro.obs import profile as prof

    monkeypatch.setenv("REPRO_PROFILE", "1")
    prof._reset_for_tests()
    out = prof.profile_call("unit:test", lambda a, b: a + b, 2, 3)
    assert out == 5
    with prof.profile_span("unit:span"):
        pass
    hists = {tuple(sorted(l.items())): h for n, l, h
             in get_hub().state()["hists"] if n == "repro_profile_seconds"}
    assert hists[(("site", "unit:test"),)]["count"] == 1
    assert hists[(("site", "unit:span"),)]["count"] == 1
    monkeypatch.delenv("REPRO_PROFILE")
    prof._reset_for_tests()
    prof.profile_call("unit:off", lambda: None)
    assert not any(tuple(sorted(l.items())) == (("site", "unit:off"),)
                   for n, l, _ in get_hub().state()["hists"]
                   if n == "repro_profile_seconds")


def test_loadgen_reports_carry_merged_histogram_summary(rng):
    """Satellite: LoadReport/NetLoadReport expose p90/p99.9 and a summary
    sourced from the mergeable histograms."""
    from repro.serving.loadgen import LoadReport, _latency_summary_ms

    h = Histogram("l", {})
    xs = rng.exponential(0.005, 1000)
    h.observe_many(xs)
    s = _latency_summary_ms(h.state())
    assert s["count"] == 1000
    assert s["p50"] <= s["p90"] <= s["p99"] <= s["p999"] <= s["max"]
    # the summary rounds to 4 decimals (report hygiene), so compare there
    assert s["mean"] == pytest.approx(float(xs.mean()) * 1e3, abs=1e-3)

    fields = {f.name for f in LoadReport.__dataclass_fields__.values()}
    assert {"p90_ms", "p999_ms", "latency_hist"} <= fields
    rep = LoadReport(n_requests=1, duration_s=1.0, offered_qps=1.0,
                     achieved_qps=1.0, p50_ms=1.0, p90_ms=2.0, p99_ms=3.0,
                     p999_ms=4.0, mean_ms=1.5, max_ms=4.0, n_batches=1,
                     family_counts={}, latency_hist=s)
    parsed = json.loads(rep.to_json())
    assert parsed["latency_hist"]["count"] == 1000
