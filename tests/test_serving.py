"""Serving subsystem: registry, snapshot isolation, engine exactness,
closure caching, merge hardening, load generator."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EdgeBatch, KMatrix, MatrixSketch, vertex_stats_from_sample
from repro.core import countmin, gsketch, kmatrix, matrix_sketch
from repro.serving import (
    OpenLoopLoadGen,
    QueryEngine,
    SketchRegistry,
    SnapshotBuffer,
    TenantKey,
    WorkloadMix,
    synth_requests,
)
from repro.serving import engine as eng
from repro.serving.registry import build_sketch


@pytest.fixture(scope="module")
def registry():
    reg = SketchRegistry(depth=3, batch_size=1024, scale=0.02)
    return reg


@pytest.fixture(scope="module")
def tenant(registry):
    t = registry.open("cit-HepPh", "kmatrix", 64, seed=0)
    t.step(2)
    t.publish()
    return t


def _values_match(a, b):
    if isinstance(a, tuple):
        return np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    return a == b


# ---------------------------------------------------------------- registry
def test_registry_open_is_idempotent(registry, tenant):
    again = registry.open("cit-HepPh", "kmatrix", 64, seed=0)
    assert again is tenant
    assert TenantKey("cit-HepPh", "kmatrix", 64, 0) in registry


def test_registry_multi_tenant_isolated_by_key(registry, tenant):
    other = registry.open("cit-HepPh", "gmatrix", 64, seed=0)
    assert other is not tenant
    assert other.key.tenant_id != tenant.key.tenant_id
    assert len(registry) >= 2


def test_tenant_step_consumes_stream_and_counts_edges(registry):
    t = registry.open("cit-HepPh", "kmatrix", 64, seed=3)
    n = t.step(2)
    snap = t.publish()
    assert n == 2
    assert snap.epoch == 1
    assert snap.n_edges == 2 * t.stream.batch_size  # no padding mid-stream


# ---------------------------------------------------------------- snapshots
def test_snapshot_isolation_under_live_ingest(registry):
    t = registry.open("cit-HepPh", "kmatrix", 64, seed=5)
    t.step(1)
    held = t.publish()
    engine = QueryEngine()
    reqs = [eng.edge_freq(1, 2), eng.node_out(3), eng.reach(4, 9)]
    before = [r.value for r in engine.execute(held, reqs)]

    t.step(2)
    new = t.publish()
    assert new.epoch == held.epoch + 1
    after_held = [r.value for r in engine.execute(held, reqs)]
    assert before == after_held, "held snapshot changed under ingest"


def test_publish_epochs_are_monotonic_and_results_stamped(tenant):
    engine = QueryEngine()
    res = engine.execute(tenant.snapshot, [eng.edge_freq(0, 1)])
    assert res[0].epoch == tenant.snapshot.epoch


def test_delta_buffer_equals_all_at_once_ingest():
    """front ⊕ delta publishing must equal ingesting everything into one
    sketch (counter additivity)."""
    rng = np.random.default_rng(0)
    src = rng.integers(0, 50, 400).astype(np.int32)
    dst = rng.integers(0, 50, 400).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 14, stats=stats, depth=3, seed=1)

    buf = SnapshotBuffer(sk, kmatrix, tenant_id="t")
    for lo in range(0, 400, 100):
        buf.ingest(EdgeBatch.from_numpy(src[lo:lo + 100], dst[lo:lo + 100]))
        buf.publish()
    direct = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
    assert (np.asarray(buf.snapshot.sketch.pool)
            == np.asarray(direct.pool)).all()
    assert (np.asarray(buf.snapshot.sketch.conn)
            == np.asarray(direct.conn)).all()
    assert buf.snapshot.epoch == 4
    assert buf.snapshot.n_edges == 400


def test_adopt_published_delta_folds_exactly_and_gaps_are_stale():
    """Delta publication contract (DESIGN.md §Net): a worker-side buffer
    with ``capture_publish_delta`` stashes exactly the per-epoch batch
    contribution; a parent folding those deltas epoch by epoch lands
    bit-identical to adopting the worker's full fronts — and a delta whose
    base epoch skips the parent's front raises ``StaleDelta`` without
    corrupting the front."""
    from repro.serving.snapshot import StaleDelta

    rng = np.random.default_rng(1)
    src = rng.integers(0, 50, 300).astype(np.int32)
    dst = rng.integers(0, 50, 300).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 14, stats=stats, depth=3, seed=1)

    child = SnapshotBuffer(sk, kmatrix, tenant_id="t")
    child.capture_publish_delta = True
    parent = SnapshotBuffer(sk, kmatrix, tenant_id="t")
    for lo in range(0, 300, 100):
        child.ingest(EdgeBatch.from_numpy(src[lo:lo + 100],
                                          dst[lo:lo + 100]))
        snap = child.publish()
        assert child.last_publish_delta is not None
        parent.adopt_published(None, snap.epoch, snap.n_edges,
                               delta=child.last_publish_delta,
                               base_epoch=snap.epoch - 1)
    direct = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))
    assert (np.asarray(parent.snapshot.sketch.pool)
            == np.asarray(direct.pool)).all()
    assert (np.asarray(parent.snapshot.sketch.conn)
            == np.asarray(direct.conn)).all()
    assert parent.snapshot.epoch == 3
    assert parent.snapshot.n_edges == child.snapshot.n_edges

    # ack gap: a delta based past (or before) the front must refuse to fold
    before = parent.snapshot
    for bad_base in (before.epoch + 1, before.epoch - 1):
        with pytest.raises(StaleDelta, match="full resync"):
            parent.adopt_published(None, bad_base + 1, 999,
                                   delta=child.last_publish_delta,
                                   base_epoch=bad_base)
    assert parent.snapshot is before  # front untouched by the refusal

    # a full adopt (the resync) repairs the stream: counters keep matching
    child.ingest(EdgeBatch.from_numpy(src[:100], dst[:100]))
    resync = child.publish()
    parent.adopt_published(resync.sketch, resync.epoch, resync.n_edges)
    assert (np.asarray(parent.snapshot.sketch.pool)
            == np.asarray(child.snapshot.sketch.pool)).all()


# ---------------------------------------------------------------- engine
@pytest.mark.parametrize("kind", ["kmatrix", "gmatrix"])
def test_engine_matches_direct_for_all_families(registry, kind):
    t = registry.open("cit-HepPh", kind, 64, seed=1)
    t.step(2)
    snap = t.publish()
    n_nodes = t.stream.spec.n_nodes
    mix = WorkloadMix()
    reqs = synth_requests(150, mix, n_nodes=n_nodes, seed=2,
                          heavy_universe=min(n_nodes, 512),
                          heavy_threshold=50.0)
    engine = QueryEngine(min_bucket=16)
    got = [r.value for r in engine.execute(snap, reqs)]
    want = eng.direct_answers(snap, reqs)
    for i, (g, w) in enumerate(zip(got, want)):
        assert _values_match(g, w), (i, reqs[i].family, g, w)


def test_engine_padding_odd_batch_sizes(tenant):
    engine = QueryEngine(min_bucket=4)
    for n in (1, 3, 5, 17):
        reqs = [eng.edge_freq(i, i + 1) for i in range(n)]
        got = [r.value for r in engine.execute(tenant.snapshot, reqs)]
        want = eng.direct_answers(tenant.snapshot, reqs)
        assert got == want


def test_engine_unsupported_family_raises(registry, tenant):
    engine = QueryEngine()
    with pytest.raises(ValueError, match="node_in"):
        engine.execute(tenant.snapshot, [eng.node_in(1)])
    cm = registry.open("cit-HepPh", "countmin", 16, seed=0)
    cm.step(1)
    snap = cm.publish()
    with pytest.raises(ValueError, match="node_out"):
        engine.execute(snap, [eng.node_out(1)])
    # edge-level families still work on countmin
    vals = [r.value for r in engine.execute(
        snap, [eng.edge_freq(1, 2), eng.path_weight([1, 2, 3])])]
    assert vals == eng.direct_answers(snap, [eng.edge_freq(1, 2),
                                             eng.path_weight([1, 2, 3])])


def test_closure_cache_hits_within_epoch_invalidates_across(registry):
    t = registry.open("cit-HepPh", "kmatrix", 64, seed=7)
    t.step(1)
    snap = t.publish()
    engine = QueryEngine()
    reqs = [eng.reach(1, 2), eng.reach(3, 4)]
    engine.execute(snap, reqs)
    assert engine.closures.misses == 1
    engine.execute(snap, reqs)
    assert engine.closures.hits == 1, "same epoch must hit the closure cache"
    t.step(1)
    snap2 = t.publish()
    engine.execute(snap2, reqs)
    assert engine.closures.misses == 2, "new epoch must rebuild the closure"


def test_engine_rejects_unknown_sketch_type():
    with pytest.raises(TypeError):
        eng.sketch_module(object())


# ---------------------------------------------------------------- merges
def test_merge_rejects_mismatched_hash_seeds():
    rng = np.random.default_rng(0)
    src = rng.integers(0, 40, 100).astype(np.int32)
    dst = rng.integers(0, 40, 100).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    for name, mod in [("kmatrix", kmatrix), ("gmatrix", matrix_sketch),
                      ("countmin", countmin), ("gsketch", gsketch)]:
        a, _ = build_sketch(name, 1 << 14, stats, 3, seed=0)
        b, _ = build_sketch(name, 1 << 14, stats, 3, seed=1)
        with pytest.raises(ValueError, match="hash families"):
            mod.merge(a, b)


def test_merge_rejects_mismatched_partition_plans():
    """Same budget/depth/seed but different bootstrap samples: layouts and
    hash families agree, routing does not — merge must refuse."""
    rng = np.random.default_rng(0)
    stats_a = vertex_stats_from_sample(
        rng.integers(0, 100, 200).astype(np.int32),
        rng.integers(0, 100, 200).astype(np.int32))
    stats_b = vertex_stats_from_sample(
        rng.integers(100, 200, 200).astype(np.int32),
        rng.integers(100, 200, 200).astype(np.int32))
    for name, mod in [("kmatrix", kmatrix), ("gsketch", gsketch)]:
        a, _ = build_sketch(name, 1 << 14, stats_a, 3, seed=1)
        b, _ = build_sketch(name, 1 << 14, stats_b, 3, seed=1)
        if a.pool_size != b.pool_size:
            continue  # layouts differ -> already rejected by the assert
        with pytest.raises(ValueError, match="partition plans"):
            mod.merge(a, b)


def test_engine_splits_groups_larger_than_max_bucket(tenant):
    engine = QueryEngine(min_bucket=4, max_bucket=8)
    reqs = [eng.edge_freq(i, i + 1) for i in range(21)]
    got = [r.value for r in engine.execute(tenant.snapshot, reqs)]
    assert got == eng.direct_answers(tenant.snapshot, reqs)
    with pytest.raises(ValueError, match="split the path"):
        engine.execute(tenant.snapshot, [eng.path_weight(range(100))])


def test_anonymous_buffers_do_not_share_closure_cache():
    """Two hand-built buffers at the same epoch must not serve each other's
    cached closures."""
    rng = np.random.default_rng(2)
    src = rng.integers(0, 60, 300).astype(np.int32)
    dst = rng.integers(0, 60, 300).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    sk = KMatrix.create(bytes_budget=1 << 15, stats=stats, depth=3, seed=1,
                        conn_frac=0.5)
    full = SnapshotBuffer(kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst)),
                          kmatrix)
    empty = SnapshotBuffer(sk, kmatrix)
    full.publish()
    empty.publish()
    assert full.snapshot.tenant_id != empty.snapshot.tenant_id
    engine = QueryEngine()
    reqs = [eng.reach(int(s), int(d)) for s, d in zip(src[:30], dst[:30])]
    assert all(r.value for r in engine.execute(full.snapshot, reqs))
    # empty sketch has no edges: nothing (beyond self-loops) is reachable,
    # which a shared cache entry from `full` would contradict
    empty_vals = [r.value for r in engine.execute(empty.snapshot, reqs)]
    want = eng.direct_answers(empty.snapshot, reqs)
    assert empty_vals == want


def test_merge_accepts_same_seed_and_adds_counters():
    sk = MatrixSketch.create(bytes_budget=1 << 14, depth=3, seed=4)
    batch = EdgeBatch.from_numpy(np.asarray([1, 2], np.int32),
                                 np.asarray([2, 3], np.int32))
    a = matrix_sketch.ingest(sk, batch)
    m = matrix_sketch.merge(a, a)
    assert (np.asarray(m.table) == 2 * np.asarray(a.table)).all()


def test_empty_like_zeroes_counters_and_keeps_hashes():
    rng = np.random.default_rng(1)
    src = rng.integers(0, 40, 100).astype(np.int32)
    dst = rng.integers(0, 40, 100).astype(np.int32)
    stats = vertex_stats_from_sample(src, dst)
    sk, mod = build_sketch("kmatrix", 1 << 14, stats, 3, seed=2)
    sk = mod.ingest(sk, EdgeBatch.from_numpy(src, dst))
    z = mod.empty_like(sk)
    assert int(np.asarray(z.pool).sum()) == 0
    assert int(np.asarray(z.conn).sum()) == 0
    assert (np.asarray(z.hashes.a) == np.asarray(sk.hashes.a)).all()
    # merging the zero delta back is the identity
    m = mod.merge(sk, z)
    assert (np.asarray(m.pool) == np.asarray(sk.pool)).all()


# ---------------------------------------------------------------- loadgen
def test_loadgen_open_loop_reports_latency_and_families(tenant):
    engine = QueryEngine(min_bucket=16)
    n_nodes = tenant.stream.spec.n_nodes
    reqs = synth_requests(60, WorkloadMix(), n_nodes=n_nodes, seed=4,
                          heavy_universe=min(n_nodes, 256),
                          heavy_threshold=50.0)
    lg = OpenLoopLoadGen(target_qps=5000.0, batch_max=32)
    ticks = [0]

    def tick():
        ticks[0] += 1

    report = lg.run(engine, lambda: tenant.snapshot, reqs,
                    between_batches=tick)
    assert report.n_requests == 60
    assert report.achieved_qps > 0
    assert report.p99_ms >= report.p50_ms >= 0
    assert sum(report.family_counts.values()) == 60
    assert ticks[0] == report.n_batches
    assert "achieved_qps" in report.to_json()


def test_workload_mix_normalizes_and_validates():
    mix = WorkloadMix(edge_freq=2.0, reach=2.0, node_out=0.0,
                      path_weight=0.0, subgraph_weight=0.0, heavy_nodes=0.0)
    norm = mix.normalized()
    assert norm["edge_freq"] == pytest.approx(0.5)
    reqs = synth_requests(40, mix, n_nodes=100, seed=0)
    fams = {r.family for r in reqs}
    assert fams <= {"edge_freq", "reach"}
