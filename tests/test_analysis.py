"""repro.analysis: trigger + clean fixtures per rule, wire-lock drift,
dynamic lock-order witness.

Every fixture runs through :meth:`Project.from_sources`, which is the
same code path the CI gate takes over the real tree (``from_root`` only
differs in where the text comes from) — so a rule passing here and
failing in CI, or vice versa, cannot be a fixture artifact.
"""
from __future__ import annotations

import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import Project, run_rules
from repro.analysis import donation, locks, pickle_rules, trace_purity, \
    wire_schema
from repro.analysis import witness as witness_mod
from repro.analysis.engine import Finding, split_by_baseline

REPO = Path(__file__).resolve().parents[1]


def msgs(findings, rule=None):
    return [f.message for f in findings if rule is None or f.rule == rule]


# ===================================================== trace-purity rule
CLEAN_JIT = '''
import jax
import jax.numpy as jnp

@jax.jit
def fold(acc, xs):
    return acc + jnp.sum(xs)

def ingest(acc, xs):
    return fold(acc, xs)
'''

DIRTY_JIT = '''
import jax
import time
import threading

_CACHE = {}
_lock = threading.Lock()

def _inner(x):
    _CACHE["t"] = time.monotonic()
    return x

@jax.jit
def step(x):
    with _lock:
        pass
    return _inner(x)
'''


def test_trace_purity_clean():
    p = Project.from_sources({"repro.kernels": CLEAN_JIT})
    assert trace_purity.check(p) == []


def test_trace_purity_flags_clock_lock_and_global():
    p = Project.from_sources({"repro.kernels": DIRTY_JIT})
    got = msgs(trace_purity.check(p))
    assert any("acquires lock `_lock`" in m for m in got)
    # _inner is reached THROUGH the jitted root, not directly decorated
    assert any("time.monotonic" in m for m in got)
    assert any("mutates module-level `_CACHE`" in m for m in got)


def test_trace_purity_flags_hub_touch():
    src = '''
import jax
from repro.obs.hub import get_hub

@jax.jit
def step(x):
    get_hub()
    return x
'''
    p = Project.from_sources({"repro.kernels": src})
    assert any("metrics hub" in m for m in msgs(trace_purity.check(p)))


def test_trace_purity_follows_jit_call_site():
    src = '''
import jax
import time

def kernel(x):
    return time.time()

compiled = jax.jit(kernel)
'''
    p = Project.from_sources({"repro.kernels": src})
    assert any("time.time" in m for m in msgs(trace_purity.check(p)))


# ====================================================== wire-schema rule
WIRE_FIXTURE = '''
import struct

MAGIC = b"KMTX"
WIRE_VERSION = 3
COMPAT_VERSIONS = frozenset({2, WIRE_VERSION})
FRAME_TYPES = {"hello": 1, "item": 3, "stop": 8}
_HEADER = struct.Struct(">4sHHI")

def dispatch(msg):
    kind = msg[0]
    if kind == "hello":
        return 1
    if kind == "item":
        return 2
    if kind == "stop":
        return 3
'''


def lock_for(src: str) -> str:
    schema = wire_schema.extract_schema(
        Project.from_sources({"repro.net.wire": src})
        .get("repro.net.wire").tree)
    return wire_schema.render_lock(schema)


def test_wire_schema_clean_with_matching_lock():
    p = Project.from_sources(
        {"repro.net.wire": WIRE_FIXTURE},
        aux={wire_schema.LOCK_AUX_PATH: lock_for(WIRE_FIXTURE)})
    assert wire_schema.check(p) == []


def test_wire_frame_added_without_version_bump_is_rejected():
    # satellite (b): the committed lock pins version 3's fingerprint; a
    # new frame type with no WIRE_VERSION bump must fail the gate
    edited = WIRE_FIXTURE.replace(
        '"stop": 8}', '"stop": 8, "gossip": 9}').replace(
        'if kind == "stop":', 'if kind in ("stop", "gossip"):')
    p = Project.from_sources(
        {"repro.net.wire": edited},
        aux={wire_schema.LOCK_AUX_PATH: lock_for(WIRE_FIXTURE)})
    got = msgs(wire_schema.check(p))
    assert any("changed without a WIRE_VERSION bump" in m for m in got)


def test_wire_bump_without_lock_regen_is_rejected():
    edited = WIRE_FIXTURE.replace("WIRE_VERSION = 3", "WIRE_VERSION = 4")
    p = Project.from_sources(
        {"repro.net.wire": edited},
        aux={wire_schema.LOCK_AUX_PATH: lock_for(WIRE_FIXTURE)})
    got = msgs(wire_schema.check(p))
    assert any("records version 3" in m and "regenerate" in m for m in got)


def test_wire_struct_layout_change_is_rejected():
    edited = WIRE_FIXTURE.replace('">4sHHI"', '">4sHHQ"')
    p = Project.from_sources(
        {"repro.net.wire": edited},
        aux={wire_schema.LOCK_AUX_PATH: lock_for(WIRE_FIXTURE)})
    assert any("changed without a WIRE_VERSION bump" in m
               for m in msgs(wire_schema.check(p)))


def test_wire_duplicate_ids_and_double_handling():
    dup = WIRE_FIXTURE.replace('"item": 3', '"item": 1')
    double = WIRE_FIXTURE.replace(
        "    if kind == \"stop\":\n        return 3\n",
        "    if kind == \"stop\":\n        return 3\n"
        "    if kind == \"stop\":\n        return 4\n")
    p = Project.from_sources({"repro.net.wire": dup})
    assert any("frame id 1 reused" in m for m in msgs(wire_schema.check(p)))
    p = Project.from_sources({"repro.net.wire": double})
    assert any("handles frame kind 'stop' 2 times" in m
               for m in msgs(wire_schema.check(p)))


def test_wire_unregistered_kind_in_dispatcher():
    edited = WIRE_FIXTURE.replace('if kind == "stop":',
                                  'if kind == "halt":')
    p = Project.from_sources({"repro.net.wire": edited})
    got = msgs(wire_schema.check(p))
    assert any("unregistered frame kind 'halt'" in m for m in got)


def test_committed_lock_matches_live_tree():
    # the repo's own lock file must always match the shipped wire module
    project = Project.from_root(str(REPO))
    assert msgs(wire_schema.check(project)) == []


# ============================================== unpickler-allowlist rule
ALLOW_WIRE = '''
_SAFE_REPRO_CLASSES = {
    "repro.api": frozenset({"Spec"}),
}
'''

ALLOW_TYPES = '''
class Spec:  # wire-type
    pass
'''


def test_allowlist_clean():
    p = Project.from_sources({"repro.net.wire": ALLOW_WIRE,
                              "repro.api": ALLOW_TYPES})
    assert pickle_rules.check_unpickler(p) == []


def test_allowlist_dead_entry_flagged():
    p = Project.from_sources({
        "repro.net.wire": ALLOW_WIRE,
        "repro.api": "class Other:  # wire-type\n    pass\n"})
    got = msgs(pickle_rules.check_unpickler(p))
    assert any("Spec is dead" in m and "gadget" in m for m in got)
    assert any("'Other' is marked" in m and "missing" in m for m in got)


def test_allowlist_unmarked_class_flagged():
    p = Project.from_sources({"repro.net.wire": ALLOW_WIRE,
                              "repro.api": "class Spec:\n    pass\n"})
    assert any("not marked" in m
               for m in msgs(pickle_rules.check_unpickler(p)))


def test_allowlist_missing_dict_flagged():
    p = Project.from_sources({"repro.net.wire": "x = 1\n"})
    assert any("not found" in m
               for m in msgs(pickle_rules.check_unpickler(p)))


def test_real_unpickler_rejects_unlisted_repro_class():
    # runtime counterpart of the static rule: a repro class OUTSIDE
    # _SAFE_REPRO_CLASSES must not materialize from a frame
    import pickle as _pickle

    from repro.net import wire
    from repro.runtime.queueing import QueueItem

    payload = _pickle.dumps(QueueItem(0, b"", b"", b"", 0))
    with pytest.raises(_pickle.UnpicklingError, match="not allowed"):
        wire.restricted_loads(payload)


def test_real_unpickler_accepts_wire_types():
    import pickle as _pickle

    from repro.net import wire
    from repro.serving.engine import Request

    req = Request("edge_freq", src=1, dst=2)
    assert wire.restricted_loads(_pickle.dumps(req)) == req


# ================================================= no-pickle-on-hot-path
def test_hot_module_pickle_flagged():
    src = ('"""Queue.\n\n# analysis: hot-path\n"""\n'
           "import pickle\n\n"
           "def put(x):\n    return pickle.dumps(x)\n")
    p = Project.from_sources({"repro.runtime.queueing": src})
    got = msgs(pickle_rules.check_hot_path(p))
    assert any("imports pickle" in m for m in got)
    assert any("references `pickle.dumps`" in m for m in got)


def test_hot_function_pickle_flagged_others_free():
    src = ("import pickle\n\n"
           "def encode(x):  # hot-path\n"
           "    return pickle.dumps(x)\n\n"
           "def debug_dump(x):\n"
           "    return pickle.dumps(x)\n")
    p = Project.from_sources({"repro.net.wire": src})
    got = msgs(pickle_rules.check_hot_path(p))
    assert got == ["hot-path function 'encode' references `pickle.dumps`"]


def test_hot_module_clean():
    src = ('"""Queue.\n\n# analysis: hot-path\n"""\n'
           "def put(x):\n    return x\n")
    p = Project.from_sources({"repro.runtime.queueing": src})
    assert pickle_rules.check_hot_path(p) == []


# ================================================== lock-discipline rule
GUARDED_CLEAN = '''
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0  # guarded-by: _lock
        self._front = None  # guarded-by(writes): _lock

    def bump(self):
        with self._lock:
            self._n += 1
            self._front = self._n

    def peek(self):
        return self._front
'''

GUARDED_DIRTY = '''
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0  # guarded-by: _lock

    def bump(self):
        self._n += 1

    def read(self):
        return self._n
'''


def test_guarded_by_clean():
    p = Project.from_sources({"repro.box": GUARDED_CLEAN})
    assert locks.check(p) == []


def test_guarded_by_violations():
    p = Project.from_sources({"repro.box": GUARDED_DIRTY})
    got = msgs(locks.check(p))
    assert any("Box.bump writes `self._n`" in m for m in got)
    assert any("Box.read reads `self._n`" in m for m in got)


def test_writes_only_guard_allows_bare_reads():
    src = GUARDED_CLEAN.replace(
        "    def peek(self):\n        return self._front\n",
        "    def peek(self):\n        return self._front\n\n"
        "    def clobber(self):\n        self._front = None\n")
    p = Project.from_sources({"repro.box": src})
    got = msgs(locks.check(p))
    assert got == ["Box.clobber writes `self._front` (guarded-by(writes): "
                   "_lock) without holding `self._lock`"]


def test_requires_lock_helper():
    src = '''
import threading

class Q:
    def __init__(self):
        self._cv = threading.Condition()
        self._items = []  # guarded-by: _cv

    def _depth(self):  # requires-lock: _cv
        return len(self._items)

    def ok(self):
        with self._cv:
            return self._depth()

    def bad(self):
        return self._depth()
'''
    p = Project.from_sources({"repro.q": src})
    got = msgs(locks.check(p))
    assert got == ["Q.bad uses `self._depth` (requires-lock: _cv) "
                   "without holding `self._cv`"]


def test_closure_is_not_treated_as_locked():
    src = '''
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0  # guarded-by: _lock

    def deferred(self):
        with self._lock:
            def cb():
                return self._n
            return cb
'''
    p = Project.from_sources({"repro.box": src})
    assert any("reads `self._n`" in m for m in msgs(locks.check(p)))


def test_static_lock_order_cycle():
    src = '''
import threading

class A:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()

    def fwd(self):
        with self._a_lock:
            with self._b_lock:
                pass

    def rev(self):
        with self._b_lock:
            with self._a_lock:
                pass
'''
    p = Project.from_sources({"repro.ab": src})
    got = msgs(locks.check(p))
    assert any("lock-order cycle" in m for m in got)


def test_static_cycle_through_call_edge():
    src = '''
import threading

class A:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()

    def helper(self):
        with self._a_lock:
            pass

    def fwd(self):
        with self._a_lock:
            with self._b_lock:
                pass

    def rev(self):
        with self._b_lock:
            self.helper()
'''
    p = Project.from_sources({"repro.ab": src})
    assert any("lock-order cycle" in m for m in msgs(locks.check(p)))


def test_static_self_reacquisition():
    src = '''
import threading

class A:
    def __init__(self):
        self._lock = threading.Lock()

    def inner(self):
        with self._lock:
            pass

    def outer(self):
        with self._lock:
            self.inner()
'''
    p = Project.from_sources({"repro.a": src})
    assert any("nested reacquisition" in m for m in msgs(locks.check(p)))


def test_acyclic_order_is_clean():
    src = '''
import threading

class A:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()

    def one(self):
        with self._a_lock:
            with self._b_lock:
                pass

    def two(self):
        with self._a_lock:
            pass
'''
    p = Project.from_sources({"repro.ab": src})
    assert locks.check(p) == []


# ================================================== baseline + CLI gate
def test_baseline_split_is_line_number_free():
    f1 = Finding("r", "repro.m", 10, "problem one")
    f2 = Finding("r", "repro.m", 99, "problem one")  # drifted line
    assert f1.key == f2.key
    new, suppressed, stale = split_by_baseline([f2], {f1.key, "r|x|gone"})
    assert new == [] and suppressed == [f2] and stale == {"r|x|gone"}


def test_gate_clean_on_shipped_tree_and_fails_on_violation(tmp_path):
    # the shipped tree must gate clean with NO baseline (satellite a)
    env_root = str(REPO)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--gate",
         "--root", env_root],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stdout + r.stderr

    # a synthetic violation in a copied tree must flip the exit code
    import shutil

    bad = tmp_path / "src" / "repro" / "net"
    bad.mkdir(parents=True)
    shutil.copy(REPO / "src/repro/net/wire.py", bad / "wire.py")
    shutil.copy(REPO / "src/repro/net/wire_schema.lock",
                bad / "wire_schema.lock")
    (bad / "__init__.py").write_text("")
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    txt = (bad / "wire.py").read_text().replace(
        '"auth": 40,', '"auth": 40,\n    "gossip": 41,')
    (bad / "wire.py").write_text(txt)
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--gate",
         "--root", str(tmp_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert r.returncode == 1
    assert "WIRE_VERSION bump" in r.stdout or "never dispatched" in r.stdout


def test_run_rules_on_real_tree_is_empty():
    project = Project.from_root(str(REPO))
    assert [f.render(project) for f in run_rules(project)] == []


# ====================================================== dynamic witness
def _wlock(w, site):
    return witness_mod.WitnessedLock(witness_mod._REAL_LOCK(), site, w)


def test_witness_records_inversion_across_threads():
    w = witness_mod.LockWitness()
    a = _wlock(w, "repro/x.py:1")
    b = _wlock(w, "repro/x.py:2")

    def fwd():
        with a:
            with b:
                pass

    def rev():
        with b:
            with a:
                pass

    t = threading.Thread(target=fwd)
    t.start()
    t.join()
    assert w.report()["cycles"] == []  # one order alone is fine
    t = threading.Thread(target=rev)
    t.start()
    t.join()
    rep = w.report()
    assert len(rep["cycles"]) == 1
    assert set(rep["cycles"][0]["cycle"]) == {"repro/x.py:1", "repro/x.py:2"}
    assert "fwd" in rep["cycles"][0]["reverse"] \
        or "rev" in rep["cycles"][0]["forward"]
    assert w.render_violations()  # human-readable, non-empty


def test_witness_consistent_order_is_clean():
    w = witness_mod.LockWitness()
    a = _wlock(w, "repro/x.py:1")
    b = _wlock(w, "repro/x.py:2")
    for _ in range(3):
        with a:
            with b:
                pass
    rep = w.report()
    assert rep["cycles"] == [] and rep["edges"] == 1


def test_witness_rlock_reentry_is_not_a_cycle():
    w = witness_mod.LockWitness()
    r = witness_mod.WitnessedRLock(witness_mod._REAL_RLOCK(),
                                   "repro/x.py:9", w)
    with r:
        with r:
            pass
    assert w.report()["cycles"] == []


def test_witness_same_site_pairs_skipped():
    # two instances of one class share an allocation site; instance-level
    # order is invisible at site granularity — documented blind spot
    w = witness_mod.LockWitness()
    a = _wlock(w, "repro/x.py:5")
    b = _wlock(w, "repro/x.py:5")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    rep = w.report()
    assert rep["cycles"] == [] and rep["edges"] == 0


def test_witness_condition_wait_keeps_stack_exact():
    # Condition built on a witnessed RLock: wait() releases through the
    # proxy (no _release_save forwarded), so the waiter's held stack must
    # be empty while it waits and after the cv block — a stale cv entry
    # would fabricate a cv->other edge below and close a false cycle
    # against the notifier's other->cv order.
    w = witness_mod.LockWitness()
    inner = witness_mod.WitnessedRLock(witness_mod._REAL_RLOCK(),
                                       "repro/q.py:1", w)
    cv = threading.Condition(inner)
    other = _wlock(w, "repro/q.py:2")
    ready = threading.Event()
    done = threading.Event()

    def waiter():
        with cv:
            ready.set()  # notifier can't take cv until wait() releases it
            cv.wait(timeout=5.0)
        with other:  # stack must be clean here: no phantom cv->other edge
            done.set()

    t = threading.Thread(target=waiter)
    t.start()
    ready.wait(timeout=5.0)
    with other:
        with cv:  # edge other->cv, the legal order
            cv.notify_all()
    t.join()
    assert done.is_set()
    rep = w.report()
    assert rep["cycles"] == []
    assert ("repro/q.py:1", "repro/q.py:2") not in \
        {tuple(e) for e in w._evidence}


def test_witness_unlocked_publish_guard():
    pytest.importorskip("jax")
    import numpy as np

    from repro.core import kmatrix, vertex_stats_from_sample
    from repro.core.kmatrix import KMatrix
    from repro.serving.snapshot import SnapshotBuffer

    w = witness_mod.LockWitness()
    witness_mod.guard_publishes(w)
    try:
        rng = np.random.default_rng(0)
        src = rng.integers(0, 20, 50).astype(np.int32)
        dst = rng.integers(0, 20, 50).astype(np.int32)
        sk = KMatrix.create(bytes_budget=1 << 12,
                            stats=vertex_stats_from_sample(src, dst),
                            depth=2, seed=1)
        buf = SnapshotBuffer(sk, kmatrix, tenant_id="wtest")
        # a legal publish stores _front under _lock: no violation
        buf.publish()
        legal = len(w.report()["unlocked_publishes"])
        # a raw store outside the lock must be caught
        buf._front = buf.snapshot
        assert len(w.report()["unlocked_publishes"]) == legal + 1
    finally:
        witness_mod._unguard_publishes()


# ================================================= use-after-donate rule
DONATE_CLEAN = '''
import jax

def _raw(s, b):
    return s + b

_step = jax.jit(_raw, donate_argnums=(0,))

def run(s, batches):
    for b in batches:
        s = _step(s, b)
    return s

class Buf:
    def ingest(self, batch):
        self._delta, self._pending = self._kernels.ingest(  # donates: 0
            self._delta, batch, self._pending)

    def peek(self):
        return self._delta  # no donating call in THIS function: clean
'''

DONATE_BAD_ASSIGN = '''
import jax

def _raw(s, b):
    return s + b

_step = jax.jit(_raw, donate_argnums=(0,))

def run(s, b):
    s2 = _step(s, b)
    return s2 + s
'''

DONATE_BAD_MARKER = '''
class Buf:
    def publish(self):
        merged, delta = self._kernels.publish(  # donates: 1
            self._front, self._delta)
        stale = self._delta.table
        self._delta = delta
        return merged, stale
'''


def test_use_after_donate_clean_rebinds():
    p = Project.from_sources({"repro.snap": DONATE_CLEAN})
    assert donation.check(p) == []


def test_use_after_donate_flags_jit_assignment_consumer():
    p = Project.from_sources({"repro.snap": DONATE_BAD_ASSIGN})
    got = msgs(donation.check(p))
    assert len(got) == 1
    assert "reads `s` after it was donated into `_step`" in got[0]


def test_use_after_donate_flags_marked_call_site():
    """The ``# donates: N`` marker alone makes a call consuming — no jit
    assignment in sight (kernels hidden behind a kit attribute)."""
    p = Project.from_sources({"repro.snap": DONATE_BAD_MARKER})
    got = msgs(donation.check(p))
    assert len(got) == 1
    assert "`self._delta`" in got[0]
    # the rebind two lines later clears it: only ONE finding, at the read
    f = donation.check(p)[0]
    assert "stale" in Project.from_sources(
        {"repro.snap": DONATE_BAD_MARKER}).files["repro.snap"].line(f.line)


def test_use_after_donate_store_clears_consumption():
    src = '''
import jax

def _raw(s, b):
    return s + b

_step = jax.jit(_raw, donate_argnums=(0, 1))

def run(s, b, fresh):
    s = _step(s, b)
    b = fresh
    return s + b
'''
    p = Project.from_sources({"repro.snap": src})
    assert donation.check(p) == []


def test_use_after_donate_registered_in_gate():
    assert "use-after-donate" in {name for name, _ in
                                  __import__("repro.analysis.engine",
                                             fromlist=["all_rules"])
                                  .all_rules()}
