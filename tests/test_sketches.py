"""Sketch correctness: exactness regimes, one-sided error, additivity, ARE ordering."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # images without hypothesis: skip, don't die
    from _hypothesis_stub import given, settings, st

from repro.core import (
    CountMin,
    GSketch,
    KMatrix,
    MatrixSketch,
    EdgeBatch,
    vertex_stats_from_sample,
)
from repro.core import countmin, gsketch, kmatrix, matrix_sketch
from repro.core.metrics import (
    average_relative_error,
    exact_edge_frequencies,
    lookup_exact,
)
from repro.streams import make_stream, sample_stream


def _random_edges(rng, n, n_nodes=64):
    src = rng.integers(0, n_nodes, n).astype(np.int32)
    dst = rng.integers(0, n_nodes, n).astype(np.int32)
    w = rng.integers(1, 5, n).astype(np.int32)
    return src, dst, w


def _stats(rng, n_nodes=64):
    src, dst, w = _random_edges(rng, 512, n_nodes)
    return vertex_stats_from_sample(src, dst, w)


def _all_sketches(rng, budget=1 << 16, depth=4):
    stats = _stats(rng)
    return {
        "countmin": (CountMin.create(bytes_budget=budget, depth=depth, seed=1), countmin),
        "gsketch": (
            GSketch.create(bytes_budget=budget, stats=stats, depth=depth, seed=1, min_width=16),
            gsketch,
        ),
        "tcm": (MatrixSketch.create(bytes_budget=budget, depth=depth, seed=1, kind="tcm"), matrix_sketch),
        "gmatrix": (
            MatrixSketch.create(bytes_budget=budget, depth=depth, seed=2, kind="gmatrix"),
            matrix_sketch,
        ),
        "kmatrix": (
            KMatrix.create(bytes_budget=budget, stats=stats, depth=depth, seed=1),
            kmatrix,
        ),
    }


@pytest.mark.parametrize("name", ["countmin", "gsketch", "tcm", "gmatrix", "kmatrix"])
def test_one_sided_overestimate(name):
    """CountMin-family estimates NEVER undercount (core invariant)."""
    rng = np.random.default_rng(0)
    sk, mod = _all_sketches(rng)[name]
    src, dst, w = _random_edges(rng, 2048)
    sk = jax.jit(mod.ingest)(sk, EdgeBatch.from_numpy(src, dst, w))
    fmap = exact_edge_frequencies(src, dst, w)
    true = lookup_exact(fmap, src, dst)
    est = np.asarray(mod.edge_freq(sk, jnp.asarray(src), jnp.asarray(dst)))
    assert (est >= true - 1e-6).all()


@pytest.mark.parametrize("name", ["countmin", "tcm", "gmatrix", "kmatrix"])
def test_exact_when_sparse(name):
    """With far more cells than distinct edges, estimates are exact."""
    rng = np.random.default_rng(1)
    sk, mod = _all_sketches(rng, budget=1 << 20, depth=4)[name]
    src = np.arange(50, dtype=np.int32)
    dst = (np.arange(50, dtype=np.int32) + 7) % 50
    w = np.full(50, 3, np.int32)
    sk = mod.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    est = np.asarray(mod.edge_freq(sk, jnp.asarray(src), jnp.asarray(dst)))
    assert (est == 3).all()


@pytest.mark.parametrize("name", ["countmin", "gsketch", "tcm", "gmatrix", "kmatrix"])
def test_padding_is_noop(name):
    rng = np.random.default_rng(2)
    sk, mod = _all_sketches(rng)[name]
    src, dst, w = _random_edges(rng, 128)
    full = mod.ingest(sk, EdgeBatch.pad_to(src, dst, w, 512))
    tight = mod.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    for a, b in zip(jax.tree_util.tree_leaves(full), jax.tree_util.tree_leaves(tight)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_merge_additivity():
    """sketch(A ++ B) == merge(sketch(A), sketch(B)) — the DP/FT primitive."""
    rng = np.random.default_rng(3)
    stats = _stats(rng)
    base = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=4, seed=5)
    s1, d1, w1 = _random_edges(rng, 256)
    s2, d2, w2 = _random_edges(rng, 256)
    a = kmatrix.ingest(base, EdgeBatch.from_numpy(s1, d1, w1))
    b = kmatrix.ingest(base, EdgeBatch.from_numpy(s2, d2, w2))
    both = kmatrix.ingest(a, EdgeBatch.from_numpy(s2, d2, w2))
    merged = kmatrix.merge(a, b)
    assert (np.asarray(merged.pool) == np.asarray(both.pool)).all()
    assert (np.asarray(merged.conn) == np.asarray(both.conn)).all()


def test_ingest_order_invariance():
    rng = np.random.default_rng(4)
    sk, mod = _all_sketches(rng)["kmatrix"]
    src, dst, w = _random_edges(rng, 512)
    fwd = mod.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
    rev = mod.ingest(sk, EdgeBatch.from_numpy(src[::-1], dst[::-1], w[::-1]))
    assert (np.asarray(fwd.pool) == np.asarray(rev.pool)).all()


@given(seed=st.integers(0, 1000), n=st.integers(1, 300))
@settings(max_examples=20, deadline=None)
def test_property_one_sided_and_additive(seed, n):
    rng = np.random.default_rng(seed)
    stats = _stats(rng)
    sk = KMatrix.create(bytes_budget=1 << 14, stats=stats, depth=3, seed=seed)
    src, dst, w = _random_edges(rng, n)
    cut = n // 2
    a = kmatrix.ingest(sk, EdgeBatch.pad_to(src[:cut], dst[:cut], w[:cut], n))
    ab = kmatrix.ingest(a, EdgeBatch.pad_to(src[cut:], dst[cut:], w[cut:], n))
    fmap = exact_edge_frequencies(src, dst, w)
    true = lookup_exact(fmap, src, dst)
    est = np.asarray(kmatrix.edge_freq(ab, jnp.asarray(src), jnp.asarray(dst)))
    assert (est >= true - 1e-6).all()
    # total pool mass == total ingested weight per layer
    pool_mass = np.asarray(ab.pool).sum(axis=1)
    assert (pool_mass == w.sum()).all()


def test_node_out_freq_matrix_and_kmatrix():
    rng = np.random.default_rng(5)
    sketches = _all_sketches(rng, budget=1 << 18)
    src = np.repeat(np.arange(8, dtype=np.int32), 4)
    dst = np.arange(32, dtype=np.int32) % 13 + 20
    w = np.full(32, 2, np.int32)
    for name in ["tcm", "kmatrix"]:
        sk, mod = sketches[name]
        sk = mod.ingest(sk, EdgeBatch.from_numpy(src, dst, w))
        est = np.asarray(mod.node_out_freq(sk, jnp.arange(8, dtype=jnp.int32)))
        assert (est >= 8 - 1e-6).all(), name  # 4 out-edges x weight 2


def test_kmatrix_beats_global_sketches_on_skewed_stream():
    """The paper's headline claim, as a regression test (fixed seeds)."""
    stream = make_stream("cit-HepPh", batch_size=8192, seed=1, scale=0.25)
    ssrc, sdst, sw = sample_stream(stream, 10000, seed=7)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    budget, depth = 64 * 1024, 5
    tcm = MatrixSketch.create(bytes_budget=budget, depth=depth, seed=3, kind="tcm")
    gm = MatrixSketch.create(bytes_budget=budget, depth=depth, seed=4, kind="gmatrix")
    kn = KMatrix.create(bytes_budget=budget, stats=stats, depth=depth, seed=3)
    ing_m = jax.jit(matrix_sketch.ingest)
    ing_k = jax.jit(kmatrix.ingest)
    for b in stream:
        tcm, gm, kn = ing_m(tcm, b), ing_m(gm, b), ing_k(kn, b)
    src, dst, w = stream.all_edges_numpy()
    fmap = exact_edge_frequencies(src, dst, w)
    qs, qd, _ = sample_stream(stream, 4000, seed=99)
    true = jnp.asarray(lookup_exact(fmap, qs, qd))
    ares = {}
    for name, sk in [("tcm", tcm), ("gmatrix", gm)]:
        est = matrix_sketch.edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd))
        ares[name] = float(average_relative_error(est, true))
    est = kmatrix.edge_freq(kn, jnp.asarray(qs), jnp.asarray(qd))
    ares["kmatrix"] = float(average_relative_error(est, true))
    assert ares["kmatrix"] < ares["tcm"], ares
    assert ares["kmatrix"] < ares["gmatrix"], ares
