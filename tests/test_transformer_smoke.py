"""Per-arch smoke tests: reduced configs, one forward/train/decode step on CPU,
asserting output shapes + finiteness (the assignment's smoke contract)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.lm import LM_CONFIGS, reduced
from repro.models.transformer import (
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    lm_loss,
    prefill,
)
from repro.models.transformer.attention import blockwise_attention

ARCHS = sorted(LM_CONFIGS)


@pytest.fixture(scope="module")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng_key):
    cfg = reduced(LM_CONFIGS[arch])
    params = init_params(cfg, rng_key)
    b, s = 2, 64
    tokens = jax.random.randint(rng_key, (b, s), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        loss, metrics = lm_loss(cfg, p, tokens, labels)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True)
    )(params)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), arch
    # loss should be ~ log(vocab) at init
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_smoke(arch, rng_key):
    cfg = reduced(LM_CONFIGS[arch])
    params = init_params(cfg, rng_key)
    b, s_prompt, s_max = 2, 16, 48
    cache = init_cache(cfg, b, s_max, dtype=jnp.float32)
    tokens = jax.random.randint(rng_key, (b, s_prompt), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t, c: prefill(cfg, p, t, c))(
        params, tokens, cache
    )
    assert logits.shape == (b, 1, cfg.vocab)
    assert int(cache.length) == s_prompt
    step = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        logits, cache = step(params, nxt, cache)
        assert logits.shape == (b, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache.length) == s_prompt + 3


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng_key):
    """Prefill+decode must agree with the training forward pass (same tokens)."""
    cfg = reduced(LM_CONFIGS[arch])
    params = init_params(cfg, rng_key)
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, cfg.vocab)
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    hidden, _ = forward_hidden(cfg, params, tokens, positions)
    from repro.models.transformer.model import logits_from_hidden
    full_logits = logits_from_hidden(cfg, params, hidden)

    cache = init_cache(cfg, b, s + 8, dtype=jnp.float32)
    logits_p, cache = prefill(cfg, params, tokens[:, :-1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, -2]),
        rtol=2e-3, atol=2e-3,
    )
    logits_d, cache = decode_step(cfg, params, tokens[:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


def test_blockwise_attention_vs_naive():
    """Blockwise online-softmax == naive masked attention, global & windowed."""
    key = jax.random.PRNGKey(3)
    b, s, h, kv, dh = 2, 96, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, dh))

    def naive(window):
        g = h // kv
        qr = q.reshape(b, s, kv, g, dh)
        scores = jnp.einsum("bqkgd,btkd->bkgqt", qr, k) / np.sqrt(dh)
        pos = np.arange(s)
        ok = pos[None, :] <= pos[:, None]
        if window:
            ok &= pos[None, :] > pos[:, None] - window
        scores = jnp.where(ok, scores, -1e30)
        p = jax.nn.softmax(scores, -1)
        out = jnp.einsum("bkgqt,btkd->bqkgd", p, v)
        return out.reshape(b, s, h, dh)

    for window in [None, 24]:
        out = blockwise_attention(
            q, k, v, window=window, attn_cap=None, chunk_q=32, chunk_kv=32
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(naive(window)), rtol=2e-4, atol=2e-4
        )


def test_moe_aux_loss_and_balance():
    cfg = reduced(LM_CONFIGS["mixtral-8x7b"])
    params = init_params(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab)
    loss, metrics = lm_loss(cfg, params, tokens, tokens)
    assert float(metrics["aux"]) > 0  # balance loss active per layer


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_analytic_matches(arch, rng_key):
    """config.param_count() (used for roofline MODEL_FLOPS) must match the
    actually-initialized tree."""
    from repro.models.common import count_params

    cfg = reduced(LM_CONFIGS[arch])
    params = init_params(cfg, rng_key)
    assert count_params(params) == cfg.param_count(), arch
