"""Assigned-architecture config fidelity: every number from the assignment
table must appear verbatim, and every (arch x shape) cell must BUILD
(eval_shape only — compilation is the dry-run's job)."""
import numpy as np
import pytest

from repro.configs.lm import LM_CONFIGS
from repro.configs.registry import all_cells, archs
from repro.configs.shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES


def test_gemma2_2b_assignment():
    c = LM_CONFIGS["gemma2-2b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (26, 2304, 8, 4, 9216, 256_000)
    assert c.layer_pattern == ("local", "global")  # alternating
    assert c.attn_softcap and c.final_softcap  # logit softcaps


def test_internlm2_20b_assignment():
    c = LM_CONFIGS["internlm2-20b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (48, 6144, 48, 8, 16384, 92_544)
    assert c.is_pure_global


def test_gemma3_27b_assignment():
    c = LM_CONFIGS["gemma3-27b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (62, 5376, 32, 16, 21504, 262_144)
    # 5:1 local:global
    kinds = c.layer_kinds()
    assert sum(kinds) / len(kinds) == pytest.approx(5 / 6, abs=0.03)


def test_mixtral_assignment():
    c = LM_CONFIGS["mixtral-8x7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (32, 4096, 32, 8, 14336, 32_000)
    assert (c.n_experts, c.top_k) == (8, 2)
    # ~46.7B total / ~12.9B active
    assert abs(c.param_count() / 1e9 - 46.7) < 2.0
    assert abs(c.active_param_count() / 1e9 - 12.9) < 1.0


def test_grok_assignment():
    c = LM_CONFIGS["grok-1-314b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == (64, 6144, 48, 8, 32768, 131_072)
    assert (c.n_experts, c.top_k) == (8, 2)
    assert abs(c.param_count() / 1e9 - 314) < 20


def test_gnn_assignments():
    a = archs()
    gc = a["graphcast"].config
    assert (gc.n_layers, gc.d_hidden, gc.n_vars) == (16, 512, 227)
    gg = a["gatedgcn"].config
    assert (gg.n_layers, gg.d_hidden, gg.aggregator) == (16, 70, "gated")
    eq = a["equiformer-v2"].config
    assert (eq.n_layers, eq.d_hidden, eq.l_max, eq.m_max, eq.n_heads) \
        == (12, 128, 6, 2, 8)
    nq = a["nequip"].config
    assert (nq.n_layers, nq.d_hidden, nq.l_max, nq.n_rbf, nq.cutoff) \
        == (5, 32, 2, 8, 5.0)


def test_fm_assignment():
    c = archs()["fm"].config
    assert (c.n_fields, c.embed_dim, c.interaction) == (39, 10, "fm-2way")


def test_shape_tables_match_assignment():
    assert LM_SHAPES["train_4k"].seq_len == 4096
    assert LM_SHAPES["train_4k"].global_batch == 256
    assert LM_SHAPES["prefill_32k"].global_batch == 32
    assert LM_SHAPES["decode_32k"].global_batch == 128
    assert LM_SHAPES["long_500k"].seq_len == 524_288
    assert GNN_SHAPES["full_graph_sm"].n_nodes == 2_708  # cora
    assert GNN_SHAPES["minibatch_lg"].fanout == (15, 10)
    assert GNN_SHAPES["ogb_products"].n_nodes == 2_449_029
    assert GNN_SHAPES["molecule"].batch_graphs == 128
    assert RECSYS_SHAPES["train_batch"].batch == 65_536
    assert RECSYS_SHAPES["retrieval_cand"].n_candidates == 1_000_000


def test_cell_enumeration():
    cells = all_cells()
    assert len(cells) == 38  # 18 LM (2 long_500k skips) + 16 GNN + 4 recsys
    # skip rules honoured
    assert ("internlm2-20b", "long_500k") not in cells
    assert ("grok-1-314b", "long_500k") not in cells
    assert ("mixtral-8x7b", "long_500k") in cells  # SWA -> sub-quadratic
    assert ("gemma3-27b", "long_500k") in cells


def test_every_arch_selectable():
    assert set(archs()) == {
        "gemma2-2b", "internlm2-20b", "gemma3-27b", "mixtral-8x7b",
        "grok-1-314b", "graphcast", "gatedgcn", "equiformer-v2", "nequip",
        "fm",
    }
