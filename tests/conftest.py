import os

# Smoke tests and benches must see exactly ONE device; only launch/dryrun.py
# force-sets 512 host devices (and it does so before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
