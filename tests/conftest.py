import os
import sys

# Smoke tests and benches must see exactly ONE device; only launch/dryrun.py
# force-sets 512 host devices (and it does so before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Lock-order witness (repro.analysis): must install BEFORE any repro module
# allocates a lock at import/construct time, so conftest import is the one
# safe place to patch the threading factories.
_WITNESS = None
if os.environ.get("REPRO_LOCK_WITNESS") == "1":
    from repro.analysis import witness as _witness_mod

    _WITNESS = _witness_mod.install()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _lock_witness_gate():
    """With REPRO_LOCK_WITNESS=1, fail the run on any lock-order cycle or
    publish-while-unlocked the suite's real concurrency exercised."""
    yield
    if _WITNESS is None:
        return
    rep = _WITNESS.report()
    if rep["cycles"] or rep["unlocked_publishes"]:
        raise AssertionError(
            "lock witness observed violations:\n"
            + _WITNESS.render_violations())
    sys.stderr.write(
        f"\n[lock-witness] clean: {rep['sites']} lock sites, "
        f"{rep['edges']} ordered acquisitions, 0 cycles\n")
