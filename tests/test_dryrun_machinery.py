"""Dry-run harness units: HLO collective parsing, cell registry building,
and one real (small) lower+compile on a subprocess production mesh."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import _result_bytes, parse_collectives


def test_result_bytes_parsing():
    line = ("  %all-gather.1 = bf16[16,4608,128]{2,1,0} "
            "all-gather(%x), replica_groups=...")
    assert _result_bytes(line) == 16 * 4608 * 128 * 2
    line2 = "%ar = f32[128]{0} all-reduce(%y)"
    assert _result_bytes(line2) == 512


def test_parse_collectives_loop_multiplier():
    hlo = """
ENTRY %main {
  %a = f32[100]{0} all-reduce(%x)
}
%while_body.1 {
  %b = bf16[10,10]{1,0} all-gather(%y)
}
"""
    out = parse_collectives(hlo, loop_multiplier=5)
    # all-reduce outside loop: 100*4*2 (ring factor) = 800
    assert out["bytes"]["all-reduce"] == 800
    # all-gather inside while body: 10*10*2 * 5 = 1000
    assert out["bytes"]["all-gather"] == 1000
    assert out["counts"]["all-gather"] == 1


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
rec = run_cell("fm", "serve_p99", multi_pod=False, verbose=False)
print("REC:" + json.dumps({"ok": rec["ok"],
                            "mesh": rec["mesh"],
                            "peak": rec.get("memory", {}).get("peak_bytes")}))
rec2 = run_cell("fm", "serve_p99", multi_pod=True, verbose=False)
print("REC:" + json.dumps({"ok": rec2["ok"], "mesh": rec2["mesh"]}))
"""


@pytest.mark.slow
def test_dryrun_cell_compiles_on_production_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    recs = [json.loads(l[4:]) for l in proc.stdout.splitlines()
            if l.startswith("REC:")]
    assert len(recs) == 2
    assert recs[0]["ok"] and recs[0]["mesh"] == "16x16"
    assert recs[1]["ok"] and recs[1]["mesh"] == "2x16x16"
    assert recs[0]["peak"] > 0
