"""Roofline analysis from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) computes the three terms (seconds/step/device):

    compute    = HLO_FLOPs_adj / peak_FLOPs            (197 TF bf16, v5e)
    memory     = HLO_bytes_adj / HBM_bw                (819 GB/s)
    collective = collective_wire_bytes / ICI_bw        (~50 GB/s/link)

cost_analysis FLOPs/bytes count per-DEVICE program work with while bodies
counted once; records carry loop_multiplier and flops_adjusted. bytes are
adjusted by the same multiplier. The bf16->f32 float-normalization of the
CPU host backend inflates bytes ~<=2x (DESIGN.md §9); we report raw values
and note the corrected interpretation inline.

Usage: PYTHONPATH=src python -m benchmarks.roofline [dryrun_results.jsonl]
       [--csv] [--md]
"""
from __future__ import annotations

import argparse
import json
import sys

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

FAMILY = {
    "gemma2-2b": "lm", "internlm2-20b": "lm", "gemma3-27b": "lm",
    "mixtral-8x7b": "lm", "grok-1-314b": "lm",
    "graphcast": "gnn", "gatedgcn": "gnn", "equiformer-v2": "gnn",
    "nequip": "gnn", "fm": "recsys",
}


def load(path: str) -> list[dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # keep latest
    return list(recs.values())


def terms(rec: dict) -> dict:
    mult = rec.get("loop_multiplier", 1)
    flops = rec.get("flops_adjusted") or rec.get("flops", 0.0) * mult
    nbytes = rec.get("bytes_accessed", 0.0) * mult
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_x = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    n_dev = rec.get("n_devices", 256)
    model_flops = rec.get("model_flops", 0.0) / n_dev  # per device
    useful = model_flops / flops if flops else 0.0
    bound = max(t_c, t_m, t_x)
    frac = t_c / bound if bound else 0.0  # fraction of roofline at bound
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
                useful_flops_ratio=useful, roofline_frac=frac,
                peak_gib=rec.get("memory", {}).get("peak_bytes", 0) / 2**30)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="dryrun_results.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None, help="filter, e.g. 16x16")
    args = ap.parse_args()
    recs = load(args.path)
    recs.sort(key=lambda r: (FAMILY.get(r["arch"], "z"), r["arch"],
                             r["shape"], r["mesh"]))
    sep = "|" if args.md else " "
    hdr = ["arch", "shape", "mesh", "ok", "t_comp(ms)", "t_mem(ms)",
           "t_coll(ms)", "dominant", "useful", "peak GiB"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{hdr[0]:15s} {hdr[1]:14s} {hdr[2]:8s} {hdr[3]:3s} "
              f"{hdr[4]:>10s} {hdr[5]:>10s} {hdr[6]:>10s} {hdr[7]:>10s} "
              f"{hdr[8]:>7s} {hdr[9]:>9s}")
    for r in recs:
        if args.mesh and r["mesh"] != args.mesh:
            continue
        if not r.get("ok"):
            row = [r["arch"], r["shape"], r["mesh"], "NO", "-", "-", "-",
                   r.get("error", "?")[:40], "-", "-"]
        else:
            t = terms(r)
            row = [r["arch"], r["shape"], r["mesh"], "ok",
                   f"{t['t_compute']*1e3:.2f}", f"{t['t_memory']*1e3:.2f}",
                   f"{t['t_collective']*1e3:.2f}", t["dominant"],
                   f"{t['useful_flops_ratio']:.2f}", f"{t['peak_gib']:.1f}"]
        if args.md:
            print("| " + " | ".join(str(x) for x in row) + " |")
        else:
            print(f"{row[0]:15s} {row[1]:14s} {row[2]:8s} {row[3]:3s} "
                  f"{row[4]:>10s} {row[5]:>10s} {row[6]:>10s} {row[7]:>10s} "
                  f"{row[8]:>7s} {row[9]:>9s}")


if __name__ == "__main__":
    main()
