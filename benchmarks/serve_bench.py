"""Serving benchmark: mixed-query workload against a live-ingesting kMatrix.

The BENCH trajectory's serving row.  Measures, in one process:

  * open-loop QPS and p50/p99 latency for a mixed edge-freq / reachability /
    node-aggregate / path / heavy-node workload, while the tenant's ingest
    loop keeps consuming the stream between query batches (publishing a new
    epoch every ``--publish-every`` batches);
  * closure-cache economics: wall time of a reachability batch that must
    rebuild the O(log w) boolean closure (cold) vs one that hits the
    per-(tenant, epoch) cache;
  * exactness: engine answers vs direct ``repro.core.queries`` answers for
    the same snapshot (hard-fails the bench on any mismatch);
  * backend parity (``--sketch-backend pallas`` / REPRO_SKETCH_BACKEND):
    when the tenant runs the width-class accel layout, the warm prefix is
    replayed through the flat-pool backend and both the relayout counters
    and every direct estimate must be bit-identical (hard-fails otherwise).

``--shards K`` serves K hash-band shards: one background runtime worker per
shard, scatter/gather queries through ``ShardedQueryEngine``, and two hard
gates — cross-shard edge conservation (Σ per-shard published + accounted
drops == stream total) and sharded-vs-unsharded exactness (the merge of the
shard sketches must be bit-identical, counters and estimates, to a
single-sketch replay of the same stream).

``--concurrent`` switches ingest to a ``repro.runtime`` background worker:
queries and ingest genuinely overlap, the JSON reports ingest edges/s and
query p50/p99 side by side, the engine-vs-direct gate is re-checked on
EVERY epoch the worker published, and the graceful ``Runtime.stop()`` must
drain with zero unaccounted edges (published + accounted drops == stream
total) — both gates hard-fail the bench.

Emits a single JSON line on stdout (progress goes to stderr):

  PYTHONPATH=src python -m benchmarks.serve_bench --quick [--concurrent]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax

from repro.serving import (
    OpenLoopLoadGen,
    QueryEngine,
    SketchRegistry,
    mix_for_sketch,
    synth_requests,
    warm_bucket_ladder,
)
from repro.serving import engine as eng
from repro.serving.gates import (
    conservation_verdict,
    mismatched_indices,
    replay_exactness,
    replay_sketch,
)

def _log(msg: str) -> None:
    print(msg, file=sys.stderr)


def _time_execute(engine: QueryEngine, snapshot, requests) -> float:
    t0 = time.perf_counter()
    engine.execute(snapshot, requests)
    return time.perf_counter() - t0


def _backend_parity_gate(tenant, requests, accel_answers=None) -> dict | None:
    """Hard gate for the width-class (pallas) sketch backend.

    Call only when the tenant's delta is freshly published (so the front
    snapshot holds exactly stream batches ``[0, tenant.offset)``).  Replays
    that prefix through the flat-pool backend and requires (a) the accel
    sketch to be a bit-exact relayout of the flat one, and (b) every direct
    estimate to be bit-identical between the two layouts.  Returns None for
    non-accel tenants.  ``accel_answers`` lets the caller reuse direct
    answers it already computed for ``requests`` on the accel snapshot (the
    per-request oracle rebuilds closures and is the slow half of the gate).
    """
    from repro.core import KMatrixAccel, kmatrix
    from repro.core import kmatrix_accel as kma
    from repro.serving.snapshot import Snapshot

    snap = tenant.snapshot
    if not isinstance(snap.sketch, KMatrixAccel):
        return None
    flat = replay_sketch(kmatrix, kma.to_flat_layout(kma.empty_like(
        snap.sketch)), tenant.stream, tenant.offset)
    relayout_snap = Snapshot(snap.tenant_id + "/relayout", snap.epoch,
                             kma.to_flat_layout(snap.sketch), snap.kind,
                             snap.n_edges)
    if accel_answers is None:
        # baseline answers MUST come from the accel snapshot itself (not
        # the relayout) — the estimate half of the gate exists to catch
        # accel-side query-path bugs, which a flat-vs-flat compare hides
        accel_answers = eng.direct_answers(snap, requests)
    verdict = replay_exactness(relayout_snap, flat, requests,
                               answers=accel_answers)
    if not verdict["ok"]:
        _log(f"BACKEND PARITY FAILURE: "
             f"counters_equal={verdict['counters_equal']} "
             f"estimates_equal={verdict['estimates_equal']}")
    return {
        "backend_parity_counters": verdict["counters_equal"],
        "backend_parity_estimates": verdict["estimates_equal"],
        "backend_parity_ok": verdict["ok"],
    }


def run_serve_bench(*, dataset: str = "cit-HepPh", sketch: str = "kmatrix",
                    budget_kb: int = 256, depth: int = 5, seed: int = 0,
                    scale: float = 1.0, target_qps: float = 2000.0,
                    n_requests: int = 4000, batch_max: int = 512,
                    publish_every: int = 2, warm_batches: int = 8,
                    sketch_backend: str | None = None) -> dict:
    registry = SketchRegistry(depth=depth, scale=scale,
                              sketch_backend=sketch_backend)
    tenant = registry.open(dataset, sketch, budget_kb, seed=seed)
    engine = QueryEngine()

    # leave at least half the stream unread so serving runs against LIVE
    # ingest (the point of the bench), even at tiny --quick scales
    tenant.step(min(warm_batches, max(1, tenant.stream.num_batches // 2)))
    snap = tenant.publish()
    n_nodes = tenant.stream.spec.n_nodes
    _log(f"tenant {tenant.key.tenant_id}: epoch {snap.epoch}, "
         f"{snap.n_edges} edges ingested, universe {n_nodes}")

    mix = mix_for_sketch(sketch)
    requests = synth_requests(n_requests, mix, n_nodes=n_nodes, seed=seed + 7,
                              heavy_universe=min(n_nodes, 1 << 14),
                              heavy_threshold=100.0)

    # ---- warmup: compile the whole bucket ladder off the clock ------------
    warm = synth_requests(max(batch_max, 256), mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    warm_bucket_ladder(engine, snap, warm)

    # ---- closure cache: cold rebuild vs hit, same snapshot ----------------
    # Two views, medians of 7 reps each: (a) the cache itself — closure
    # build (blocking) vs cache hit; (b) end-to-end reachability batches on
    # a cleared vs warm cache.  (a) is the invariant the cache exists for;
    # (b) shows what a client sees (at small conn widths the cascade is
    # cheap, so (b) compresses toward 1x while (a) stays orders-of-magnitude).
    t_build = t_lookup = t_cold = t_hit = 0.0
    if mix.reach > 0:  # Type I sketches have no closure to cache
        engine.closures.get(snap, None)  # compile the cascade off the clock
        build, lookup = [], []
        for _ in range(7):
            engine.closures.clear()
            t0 = time.perf_counter()
            jax.block_until_ready(engine.closures.get(snap, None))
            build.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            engine.closures.get(snap, None)
            lookup.append(time.perf_counter() - t0)
        t_build = float(np.median(build))
        t_lookup = float(np.median(lookup))

        reach_reqs = [eng.reach(int(a), int(b)) for a, b in zip(
            np.random.default_rng(3).integers(0, n_nodes, 64),
            np.random.default_rng(4).integers(0, n_nodes, 64))]
        engine.execute(snap, reach_reqs)  # compile lookup path off the clock
        cold, hit = [], []
        for _ in range(7):
            engine.closures.clear()
            cold.append(_time_execute(engine, snap, reach_reqs))
            hit.append(_time_execute(engine, snap, reach_reqs))
        t_cold = float(np.median(cold))
        t_hit = float(np.median(hit))
        _log(f"closure build {t_build*1e3:.3f} ms vs cache hit "
             f"{t_lookup*1e3:.4f} ms ({t_build/max(t_lookup, 1e-9):.0f}x); "
             f"reach batch cold {t_cold*1e3:.2f} ms vs warm {t_hit*1e3:.2f} ms")

    # ---- exactness: engine vs direct module-level answers -----------------
    check = requests[:200]
    got = [r.value for r in engine.execute(snap, check)]
    want = eng.direct_answers(snap, check)
    bad = mismatched_indices(got, want)
    matches = not bad
    if bad:
        _log(f"MISMATCH engine vs direct at request indices {bad[:10]}")

    # ---- accel backend: bit-exact vs the flat layout on the same prefix ---
    parity = _backend_parity_gate(tenant, check[:64], accel_answers=want[:64])

    # ---- open-loop mixed workload against the LIVE tenant -----------------
    epoch0 = tenant.epoch
    batches_between = [0]

    def live_ingest() -> None:
        stepped = tenant.step(1)
        batches_between[0] += stepped
        # key off this call's progress, not the cumulative count: once the
        # stream drains, a frozen total would either publish after every
        # served batch (thrashing the closure cache) or never again
        if stepped and batches_between[0] % publish_every == 0:
            tenant.publish()

    loadgen = OpenLoopLoadGen(target_qps=target_qps, batch_max=batch_max)
    report = loadgen.run(engine, lambda: tenant.snapshot, requests,
                         between_batches=live_ingest)
    _log(report.to_json())

    record = {
        "bench": "serve_mixed",
        "dataset": dataset,
        "sketch": sketch,
        "sketch_backend": registry.sketch_backend,
        "budget_kb": budget_kb,
        "depth": depth,
        "offered_qps": report.offered_qps,
        "achieved_qps": round(report.achieved_qps, 1),
        "p50_ms": round(report.p50_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "n_requests": report.n_requests,
        "n_batches": report.n_batches,
        "epochs_published": tenant.epoch - epoch0,
        "ingest_batches_during_serve": batches_between[0],
        "closure_build_ms": round(t_build * 1e3, 4),
        "closure_cache_hit_ms": round(t_lookup * 1e3, 4),
        "closure_cache_speedup": round(t_build / max(t_lookup, 1e-9), 1),
        "reach_batch_cold_ms": round(t_cold * 1e3, 3),
        "reach_batch_warm_ms": round(t_hit * 1e3, 3),
        "engine_matches_direct": bool(matches),
        "overflow_edges": tenant.buffer.overflow_edges,
        **(parity or {}),
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }
    return record


def run_serve_bench_concurrent(*, dataset: str = "cit-HepPh",
                               sketch: str = "kmatrix", budget_kb: int = 256,
                               depth: int = 5, seed: int = 0,
                               scale: float = 1.0,
                               target_qps: float = 2000.0,
                               n_requests: int = 4000, batch_max: int = 512,
                               publish_every: int = 2, warm_batches: int = 8,
                               queue_capacity: int = 64,
                               backpressure: str = "block",
                               publish_policy: str = "",
                               epoch_check_requests: int = 32,
                               sketch_backend: str | None = None,
                               runtime_backend: str = "thread") -> dict:
    """Concurrent regime: loadgen in the main thread, ingest in a
    ``repro.runtime`` worker (thread or process execution backend).  Gates
    (both hard-fail): engine == direct on every published epoch;
    conservation (published + drops == stream total) after a graceful
    drain."""
    from repro.runtime import Runtime

    registry = SketchRegistry(depth=depth, scale=scale,
                              sketch_backend=sketch_backend)
    tenant = registry.open(dataset, sketch, budget_kb, seed=seed)
    engine = QueryEngine()

    tenant.step(min(warm_batches, max(1, tenant.stream.num_batches // 2)))
    snap = tenant.publish()
    n_nodes = tenant.stream.spec.n_nodes
    _log(f"tenant {tenant.key.tenant_id}: warm epoch {snap.epoch}, "
         f"{snap.n_edges} edges ingested, universe {n_nodes}")

    mix = mix_for_sketch(sketch)
    requests = synth_requests(n_requests, mix, n_nodes=n_nodes, seed=seed + 7,
                              heavy_universe=min(n_nodes, 1 << 14),
                              heavy_threshold=100.0)
    warm = synth_requests(max(batch_max, 256), mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    warm_bucket_ladder(engine, snap, warm)

    # every epoch the worker publishes lands here (snapshots are immutable,
    # so holding them costs only references) and is exactness-gated below
    published: list = [snap]
    runtime = Runtime(queue_capacity=queue_capacity,
                      backpressure=backpressure,
                      publish_policy=publish_policy
                      or f"every:{publish_every}",
                      backend=runtime_backend)
    runtime.attach(tenant, on_publish=published.append)
    runtime.start(pumps=False)
    runtime.wait_ready()  # process children build + warm off the clock
    runtime.start_pumps()

    loadgen = OpenLoopLoadGen(target_qps=target_qps, batch_max=batch_max)
    t0 = time.perf_counter()
    report = loadgen.run(engine, lambda: tenant.snapshot, requests)
    serve_wall_s = time.perf_counter() - t0
    mid = runtime.metrics()[tenant.key.tenant_id]
    edges_during_serve = mid["ingested_edges"]
    _log(report.to_json())

    runtime.join_pumps()  # offer the whole stream, then drain-and-stop
    final = runtime.stop(drain=True)[tenant.key.tenant_id]

    # ---- gate 1: engine vs direct on EVERY published epoch ----------------
    check = requests[:epoch_check_requests]
    mismatched_epochs = []
    for s in published:
        got = [r.value for r in engine.execute(s, check)]
        want = eng.direct_answers(s, check)
        if mismatched_indices(got, want):
            mismatched_epochs.append(s.epoch)
    if mismatched_epochs:
        _log(f"MISMATCH engine vs direct at epochs {mismatched_epochs}")

    # ---- gate 2: conservation after graceful drain ------------------------
    cons = conservation_verdict(final["published_edges"],
                                final["dropped_edges"],
                                tenant.stream.spec.n_edges,
                                final["unaccounted_edges"])
    if not cons["conservation_ok"]:
        _log(f"CONSERVATION FAILURE: published {final['published_edges']} "
             f"+ dropped {final['dropped_edges']} != stream "
             f"{cons['stream_total_edges']} "
             f"(unaccounted {final['unaccounted_edges']})")

    return {
        "bench": "serve_concurrent",
        "dataset": dataset,
        "sketch": sketch,
        "sketch_backend": registry.sketch_backend,
        "budget_kb": budget_kb,
        "depth": depth,
        "runtime_backend": runtime.backend.name,
        "backpressure": backpressure,
        "publish_policy": publish_policy or f"every:{publish_every}",
        "offered_qps": report.offered_qps,
        "achieved_qps": round(report.achieved_qps, 1),
        "p50_ms": round(report.p50_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "n_requests": report.n_requests,
        "n_batches": report.n_batches,
        "ingest_edges_during_serve": edges_during_serve,
        "ingest_edges_per_s_during_serve": round(
            edges_during_serve / max(serve_wall_s, 1e-9), 1),
        "ingest_edges_per_s_ewma": mid["edges_per_s_ewma"],
        "epochs_published": len(published) - 1,
        "epochs_checked": len(published),
        "publishes": final["publishes"],
        "mean_publish_latency_ms": final["mean_publish_latency_ms"],
        "max_queue_depth": final["max_queue_depth"],
        "dropped_edges": final["dropped_edges"],
        # accel-backend scatter-fallback volume (0 under the flat backend):
        # capacity regressions surface here instead of as silent slow ingest
        "overflow_edges": final["overflow_edges"],
        "published_edges": final["published_edges"],
        "stream_total_edges": cons["stream_total_edges"],
        "unaccounted_edges": final["unaccounted_edges"],
        "conservation_ok": cons["conservation_ok"],
        "engine_matches_direct": not mismatched_epochs,
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }


def run_serve_bench_sharded(*, dataset: str = "cit-HepPh",
                            sketch: str = "kmatrix", budget_kb: int = 256,
                            depth: int = 5, seed: int = 0,
                            scale: float = 1.0, target_qps: float = 2000.0,
                            n_requests: int = 4000, batch_max: int = 512,
                            publish_every: int = 2, warm_batches: int = 4,
                            n_shards: int = 4, queue_capacity: int = 64,
                            backpressure: str = "block",
                            publish_policy: str = "",
                            epoch_check_requests: int = 64,
                            sketch_backend: str | None = None,
                            runtime_backend: str = "thread",
                            ingest_repeats: int = 1) -> dict:
    """Sharded regime: K runtime ingest workers (one per hash-band shard,
    on the thread OR process execution backend) under live scatter/gather
    query load.  Two hard gates (both fail the bench): cross-shard edge
    conservation (Σ per-shard published + accounted drops == stream total)
    and sharded-vs-unsharded exactness (the merge of the shard sketches
    must be bit-identical — counters and direct estimates — to a
    single-sketch replay of the same stream, which the source-hash-band
    routing guarantees)."""
    from repro.runtime import Runtime
    from repro.serving import (ShardedQueryEngine, attach_shards,
                               measure_sharded_ingest, sharded_conservation,
                               sharded_direct_answers, warm_ingest_shapes)

    registry = SketchRegistry(depth=depth, scale=scale,
                              sketch_backend=sketch_backend)
    tenant = registry.open_sharded(dataset, sketch, budget_kb, seed=seed,
                                   n_shards=n_shards)
    engine = ShardedQueryEngine()
    stream = tenant.stream

    # ---- dedicated ingest throughput: backlog drain, no query load --------
    # a THROWAWAY tenant (fresh registry, same config) so the serve-phase
    # tenant below still owns its whole stream; this is the scaling number
    # BENCH_sharded.json / BENCH_process.json chart against K
    # best-of-N: the quick-scale drain lasts ~150 ms, so a single sample is
    # scheduler noise on a small box; every repeat pays the full spawn/warm
    # cost with a FRESH throwaway tenant and the best drain is the capacity
    # number (identical treatment for every backend, so ratios stay fair)
    dedicated = None
    for _ in range(max(1, ingest_repeats)):
        d = measure_sharded_ingest(
            SketchRegistry(depth=depth, scale=scale,
                           sketch_backend=sketch_backend).open_sharded(
                dataset, sketch, budget_kb, seed=seed, n_shards=n_shards),
            backend=runtime_backend)
        if not d["conserved"]:
            _log(f"DEDICATED INGEST CONSERVATION FAILURE: {d}")
        if dedicated is None or d["edges_per_s"] > dedicated["edges_per_s"]:
            dedicated = d
    _log(f"dedicated ingest drain x{n_shards} (best of {ingest_repeats}): "
         f"{dedicated['edges_per_s']:,.0f} edges/s "
         f"({dedicated['ingested_edges']} edges, {dedicated['wall_s']}s)")
    warm_ingest_shapes(tenant)  # serve-phase shard shapes, off the clock

    tenant.step(min(warm_batches, max(1, stream.num_batches // 2)))
    snap = tenant.publish()
    n_nodes = stream.spec.n_nodes
    _log(f"sharded tenant {tenant.key.tenant_id} x{n_shards}: epochs "
         f"{snap.epochs}, {snap.n_edges} edges warm, universe {n_nodes}")

    mix = mix_for_sketch(sketch)
    requests = synth_requests(n_requests, mix, n_nodes=n_nodes, seed=seed + 7,
                              heavy_universe=min(n_nodes, 1 << 14),
                              heavy_threshold=100.0)
    warm = synth_requests(max(batch_max, 256), mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    warm_bucket_ladder(engine, snap, warm)

    # ---- exactness: scatter/gather engine vs sharded direct oracle --------
    check = requests[:epoch_check_requests]
    got = [r.value for r in engine.execute(snap, check)]
    want = sharded_direct_answers(snap, check)
    bad = mismatched_indices(got, want)
    matches = not bad
    if bad:
        _log(f"MISMATCH sharded engine vs direct at request indices "
             f"{bad[:10]}")

    # ---- serve under live per-shard background ingest ---------------------
    runtime = Runtime(queue_capacity=queue_capacity,
                      backpressure=backpressure,
                      publish_policy=publish_policy
                      or f"every:{publish_every}",
                      coalesce_batches=max(4, n_shards),
                      coalesce_target=stream.batch_size,
                      backend=runtime_backend)
    handles = attach_shards(runtime, tenant)
    runtime.start(pumps=False)
    runtime.wait_ready()  # process children build + warm off the clock
    runtime.start_pumps()
    loadgen = OpenLoopLoadGen(target_qps=target_qps, batch_max=batch_max)
    t0 = time.perf_counter()
    report = loadgen.run(engine, lambda: tenant.snapshot, requests)
    serve_wall_s = time.perf_counter() - t0
    edges_during_serve = sum(m["ingested_edges"]
                             for m in runtime.metrics().values())
    _log(report.to_json())

    runtime.join_pumps()
    t_ingest0 = time.perf_counter()
    runtime.stop(drain=True)
    drain_s = time.perf_counter() - t_ingest0

    # ---- gate 1: cross-shard conservation ---------------------------------
    cons = sharded_conservation(handles, stream.spec.n_edges)
    if not cons["conservation_ok"]:
        _log(f"SHARDED CONSERVATION FAILURE: {cons}")

    # ---- gate 2: merged shards == single-sketch replay, bit-exact ---------
    # Only meaningful with zero drops: under drop_oldest the replay would
    # ingest the accounted drops the shards legitimately never saw, so the
    # mismatch would be the backpressure policy, not a routing break.
    if cons["dropped_edges"] == 0:
        merged = tenant.merged_snapshot()
        replay = replay_sketch(tenant.mod,
                               tenant.mod.empty_like(merged.sketch),
                               stream, stream.num_batches)
        verdict = replay_exactness(merged, replay, check)
        counters_equal = verdict["counters_equal"]
        estimates_equal = verdict["estimates_equal"]
        sharded_exact = verdict["ok"]
        if not sharded_exact:
            _log(f"SHARDED EXACTNESS FAILURE: "
                 f"counters_equal={counters_equal} "
                 f"estimates_equal={estimates_equal}")
    else:
        counters_equal = estimates_equal = sharded_exact = None
        _log(f"sharded exactness gate skipped: {cons['dropped_edges']} "
             "edges dropped under backpressure (accounted by the "
             "conservation gate); a full-stream replay is not comparable")

    total_edges = cons["published_edges"]
    return {
        "bench": "serve_sharded",
        "dataset": dataset,
        "sketch": sketch,
        "sketch_backend": registry.sketch_backend,
        "budget_kb": budget_kb,
        "depth": depth,
        "n_shards": n_shards,
        "runtime_backend": runtime.backend.name,
        "backpressure": backpressure,
        "publish_policy": publish_policy or f"every:{publish_every}",
        "offered_qps": report.offered_qps,
        "achieved_qps": round(report.achieved_qps, 1),
        "p50_ms": round(report.p50_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "n_requests": report.n_requests,
        "ingest_edges_during_serve": edges_during_serve,
        "ingest_edges_per_s_during_serve": round(
            edges_during_serve / max(serve_wall_s, 1e-9), 1),
        # pure concurrent-ingest capacity (backlog drain, no query load) —
        # the honest scaling-vs-K number; the during-serve rate above is
        # dominated by query contention on shared cores
        "ingest_edges_per_s_dedicated": dedicated["edges_per_s"],
        "dedicated_ingest_conserved": dedicated["conserved"],
        "drain_s": round(drain_s, 3),
        "epochs": list(tenant.epochs),
        "published_edges": total_edges,
        "dropped_edges": cons["dropped_edges"],
        "per_shard_published": cons["per_shard_published"],
        "stream_total_edges": cons["stream_total_edges"],
        "conservation_ok": cons["conservation_ok"],
        # None (not False) when drops made the replay incomparable
        "sharded_counters_equal": counters_equal,
        "sharded_estimates_equal": estimates_equal,
        "sharded_exact": sharded_exact,
        "engine_matches_direct": bool(matches),
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cit-HepPh")
    ap.add_argument("--sketch", default="kmatrix")
    ap.add_argument("--budget-kb", type=int, default=256)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--n-requests", type=int, default=4000)
    ap.add_argument("--batch-max", type=int, default=512)
    ap.add_argument("--publish-every", type=int, default=2)
    ap.add_argument("--sketch-backend", default="",
                    choices=["", "flat", "pallas"],
                    help="kmatrix layout (default: $REPRO_SKETCH_BACKEND, "
                         "else platform pick)")
    ap.add_argument("--concurrent", action="store_true",
                    help="background runtime ingest concurrent with queries")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve K hash-band shards (one runtime ingest "
                         "worker per shard, scatter/gather queries); gates "
                         "cross-shard conservation AND merged-vs-unsharded "
                         "bit-exactness")
    ap.add_argument("--backpressure", default="block",
                    choices=["block", "drop_oldest"])
    ap.add_argument("--publish-policy", default="",
                    help="every:N | interval:S | drain[:W]")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--runtime-backend", default="thread",
                    help="execution backend for ingest workers: thread, "
                         "process (spawn children owning their sketches), "
                         "or socket[:HOST:PORT,...] (workers across TCP — "
                         "self-hosted loopback children, or stream_ingest "
                         "--listen hosts); process/socket need "
                         "--concurrent or --shards")
    ap.add_argument("--quick", action="store_true",
                    help="small scale + short run (CI)")
    args = ap.parse_args()
    _valid_backends = ("thread", "process", "socket")
    if args.runtime_backend not in _valid_backends \
            and not args.runtime_backend.startswith("socket:"):
        ap.error(f"--runtime-backend must be one of {_valid_backends} or "
                 f"socket:HOST:PORT[,...], got {args.runtime_backend!r}")
    if args.runtime_backend != "thread" and not (args.concurrent
                                                 or args.shards):
        ap.error(f"--runtime-backend {args.runtime_backend} requires "
                 "--concurrent or --shards (the plain bench has no "
                 "background runtime)")
    if args.quick:
        args.scale = min(args.scale, 0.1)
        args.n_requests = min(args.n_requests, 1000)
        args.qps = min(args.qps, 1000.0)

    if args.shards:
        record = run_serve_bench_sharded(
            dataset=args.dataset, sketch=args.sketch,
            budget_kb=args.budget_kb, depth=args.depth, seed=args.seed,
            scale=args.scale, target_qps=args.qps,
            n_requests=args.n_requests, batch_max=args.batch_max,
            publish_every=args.publish_every, n_shards=args.shards,
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            publish_policy=args.publish_policy,
            sketch_backend=args.sketch_backend or None,
            runtime_backend=args.runtime_backend)
        print(json.dumps(record))
        if not (record["engine_matches_direct"]
                and record["conservation_ok"]
                and record["sharded_exact"] is not False
                and record["dedicated_ingest_conserved"]):
            sys.exit(1)
        return

    if args.concurrent:
        record = run_serve_bench_concurrent(
            dataset=args.dataset, sketch=args.sketch,
            budget_kb=args.budget_kb, depth=args.depth, seed=args.seed,
            scale=args.scale, target_qps=args.qps,
            n_requests=args.n_requests, batch_max=args.batch_max,
            publish_every=args.publish_every,
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            publish_policy=args.publish_policy,
            sketch_backend=args.sketch_backend or None,
            runtime_backend=args.runtime_backend)
        print(json.dumps(record))
        if not (record["engine_matches_direct"]
                and record["conservation_ok"]):
            sys.exit(1)
        return

    record = run_serve_bench(
        dataset=args.dataset, sketch=args.sketch, budget_kb=args.budget_kb,
        depth=args.depth, seed=args.seed, scale=args.scale,
        target_qps=args.qps, n_requests=args.n_requests,
        batch_max=args.batch_max, publish_every=args.publish_every,
        sketch_backend=args.sketch_backend or None)
    print(json.dumps(record))
    if not (record["engine_matches_direct"]
            and record.get("backend_parity_ok", True)):
        sys.exit(1)


if __name__ == "__main__":
    main()
