"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus figure tables to stderr).

  fig6_build_time   paper Fig. 6  — ingest throughput per sketch x dataset
  fig7_are          paper Fig. 7  — ARE vs memory budget (Type II sketches)
  fig8_neq          paper Fig. 8  — number/percent of effective queries
  partitioner_ablation — beyond-paper: greedy (Eq.8) vs banded sqrt-G
  kernel_micro      — Pallas kernels (interpret) vs pure-jnp reference ops
  ingest            — flat-scatter vs width-class accel sketch backend
                      edges/s (emits BENCH_ingest.json, bit-exactness gated)
                      + dispatch-capacity policy: plan-derived vs 2B/P
                      overflow on a skewed stream (strict-improvement gated)
  serve_sharded     — sharded serving at K=1/2/4: per-shard runtime ingest
                      + scatter/gather queries (emits BENCH_sharded.json,
                      conservation + merged-exactness gated)
  serve_process     — thread vs process runtime backends at K=1/2/4
                      (emits BENCH_process.json; same sharded hard gates,
                      process K4/K1 scaling recorded vs cpu_count)
  serve_net         — network transport tier (emits BENCH_net.json):
                      socket vs process ingest edges/s under the same
                      sharded hard gates, TCP query front-end QPS/p50/p99
                      at 1/2/4 connections, and an overload cell gated on
                      nonzero accounted shed with bounded accepted-p99
  obs               — telemetry overhead (emits BENCH_obs.json): metrics-on
                      vs metrics-off ingest edges/s + query p99, gated on
                      metrics-on staying within 5% of metrics-off

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7_are]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    CountMin,
    GSketch,
    KMatrix,
    KMatrixAccel,
    MatrixSketch,
    vertex_stats_from_sample,
)
from repro.core import countmin, gsketch, kmatrix, kmatrix_accel, matrix_sketch
from repro.core.metrics import (
    average_relative_error,
    effective_queries,
    exact_edge_frequencies,
    lookup_exact,
    percent_effective_queries,
)
from repro.streams import make_stream, sample_stream

DATASETS = ["unicorn-wget", "email-EuAll", "cit-HepPh"]


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.3f},{derived}")


def _log(msg: str) -> None:
    print(msg, file=sys.stderr)


def _build_all(budget: int, depth: int, stats, seed=3):
    return {
        "countmin": (CountMin.create(bytes_budget=budget, depth=depth, seed=seed),
                     countmin),
        "gsketch": (GSketch.create(bytes_budget=budget, stats=stats, depth=depth,
                                   seed=seed, min_width=32), gsketch),
        "tcm": (MatrixSketch.create(bytes_budget=budget, depth=depth, seed=seed,
                                    kind="tcm"), matrix_sketch),
        "gmatrix": (MatrixSketch.create(bytes_budget=budget, depth=depth,
                                        seed=seed + 1, kind="gmatrix"),
                    matrix_sketch),
        "kmatrix": (KMatrix.create(bytes_budget=budget, stats=stats, depth=depth,
                                   seed=seed), kmatrix),
        # same sketch, width-class layout: ingest goes through the Pallas MXU
        # kernel (interpret mode off-TPU, so its fig6 column measures the
        # correctness path there, not kernel speed)
        "kmatrix_accel": (KMatrixAccel.create(bytes_budget=budget, stats=stats,
                                              depth=depth, seed=seed),
                          kmatrix_accel),
    }


def _ingest_all(stream, sk, mod):
    ing = jax.jit(mod.ingest)
    t0 = time.time()
    for b in stream:
        sk = ing(sk, b)
    jax.block_until_ready(jax.tree_util.tree_leaves(sk)[0])
    return sk, time.time() - t0


def fig6_build_time(scale: float) -> None:
    """Paper Fig. 6: time to add the entire dataset (1 MB sketches, d=7)."""
    _log("\n== fig6_build_time (1MB, d=7) ==")
    _log(f"{'dataset':14s} {'sketch':13s} {'edges/s':>12s} {'us/edge':>9s}")
    for ds in DATASETS:
        stream = make_stream(ds, batch_size=8192, seed=1, scale=scale)
        ssrc, sdst, sw = sample_stream(stream, int(30_000 * scale) or 1000, seed=7)
        stats = vertex_stats_from_sample(ssrc, sdst, sw)
        for name, (sk, mod) in _build_all(1 << 20, 7, stats).items():
            sk, dt = _ingest_all(stream, sk, mod)
            n = stream.spec.n_edges
            _log(f"{ds:14s} {name:13s} {n/dt:12,.0f} {dt/n*1e6:9.3f}")
            _emit(f"fig6/{ds}/{name}", dt / n * 1e6, f"edges_per_s={n/dt:.0f}")


def _eval_accuracy(stream, states, mods, n_queries, g0_list=(1.0, 10.0)):
    src, dst, w = stream.all_edges_numpy()
    fmap = exact_edge_frequencies(src, dst, w)
    qs, qd, _ = sample_stream(stream, n_queries, seed=99)
    true = jnp.asarray(lookup_exact(fmap, qs, qd))
    out = {}
    for name, sk in states.items():
        est = mods[name].edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd))
        are = float(average_relative_error(est, true))
        neq = {g0: int(effective_queries(est, true, g0)) for g0 in g0_list}
        peq = {g0: float(percent_effective_queries(est, true, g0))
               for g0 in g0_list}
        out[name] = {"are": are, "neq": neq, "peq": peq}
    return out


def fig7_fig8_accuracy(scale: float, quick: bool) -> None:
    """Paper Fig. 7 (ARE) + Fig. 8 (NEQ): accuracy vs memory budget."""
    budgets = [200, 512] if quick else [200, 300, 400, 512]
    n_q = 2_000 if quick else 10_000
    depth = 7
    _log("\n== fig7_are / fig8_neq ==")
    _log(f"{'dataset':14s} {'kb':>4s} {'sketch':9s} {'ARE':>9s} "
         f"{'NEQ@1':>7s} {'PEQ@10':>8s}")
    for ds in DATASETS:
        stream = make_stream(ds, batch_size=8192, seed=1, scale=scale)
        ssrc, sdst, sw = sample_stream(stream, int(30_000 * scale) or 1000, seed=7)
        stats = vertex_stats_from_sample(ssrc, sdst, sw)
        for kb in budgets:
            sketches = _build_all(kb * 1024, depth, stats)
            # paper compares Type II only in Figs 7-8
            type2 = {k: v for k, v in sketches.items()
                     if k in ("tcm", "gmatrix", "kmatrix")}
            states, mods = {}, {}
            for name, (sk, mod) in type2.items():
                sk, dt = _ingest_all(stream, sk, mod)
                states[name], mods[name] = sk, mod
            acc = _eval_accuracy(stream, states, mods, n_q)
            for name, a in acc.items():
                _log(f"{ds:14s} {kb:4d} {name:9s} {a['are']:9.2f} "
                     f"{a['neq'][1.0]:7d} {a['peq'][10.0]:7.1f}%")
                _emit(f"fig7/{ds}/{kb}kb/{name}", 0.0, f"ARE={a['are']:.4f}")
                _emit(f"fig8/{ds}/{kb}kb/{name}", 0.0,
                      f"NEQ_g1={a['neq'][1.0]};PEQ_g10={a['peq'][10.0]:.2f}")


def partitioner_ablation(scale: float) -> None:
    """Beyond-paper: Eq.8 greedy vs banded sqrt-G vs two-term-model auto."""
    _log("\n== partitioner_ablation (256KB, d=5) ==")
    for ds in DATASETS:
        stream = make_stream(ds, batch_size=8192, seed=1, scale=scale)
        ssrc, sdst, sw = sample_stream(stream, int(30_000 * scale) or 1000, seed=7)
        stats = vertex_stats_from_sample(ssrc, sdst, sw)
        states, mods = {}, {}
        for mode in ["greedy", "banded", "auto"]:
            sk = KMatrix.create(bytes_budget=256 * 1024, stats=stats, depth=5,
                                seed=3, partitioner=mode)
            sk, dt = _ingest_all(stream, sk, kmatrix)
            states[mode], mods[mode] = sk, kmatrix
        acc = _eval_accuracy(stream, states, mods, 4000)
        for mode, a in acc.items():
            n_p = states[mode].route.n_partitions
            _log(f"{ds:14s} {mode:7s} ARE={a['are']:.3f} partitions={n_p}")
            _emit(f"ablate_partitioner/{ds}/{mode}", 0.0,
                  f"ARE={a['are']:.4f};partitions={n_p}")


def kernel_micro(quick: bool) -> None:
    """Pallas kernels (interpret mode on CPU) vs jnp reference."""
    from repro.kernels import matrix_ingest, matrix_lookup
    from repro.kernels import ref as kref

    _log("\n== kernel_micro (interpret mode — correctness-path timing only) ==")
    d, p, w, c = 5, 1, 256, 4096
    rng = np.random.default_rng(0)
    pool = jnp.zeros((d, p, w, w), jnp.int32)
    hi = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    hj = jnp.asarray(rng.integers(0, w, (d, p, c)), jnp.int32)
    wt = jnp.ones((p, c), jnp.int32)

    for name, fn in [
        ("pallas_matrix_ingest", lambda: matrix_ingest(pool, hi, hj, wt,
                                                       block_b=256, interpret=True)),
        ("jnp_matrix_ingest_ref", lambda: kref.matrix_ingest_ref(pool, hi, hj, wt)),
        ("pallas_matrix_lookup", lambda: matrix_lookup(pool, hi, hj,
                                                       block_q=256, interpret=True)),
        ("jnp_matrix_lookup_ref", lambda: kref.matrix_lookup_ref(pool, hi, hj)),
    ]:
        fn()  # compile
        n = 3 if quick else 10
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn())
        us = (time.time() - t0) / n * 1e6
        _log(f"{name:24s} {us:12,.0f} us/call")
        _emit(f"kernel/{name}", us, f"edges={c}")


def ingest_backends(scale: float, quick: bool,
                    out_path: str = "BENCH_ingest.json") -> None:
    """flat-scatter vs width-class accel ingest throughput -> BENCH_ingest.json.

    Both backends are interpret-safe (the accel path runs the Pallas kernel
    with interpret=True off-TPU), ingest the SAME stream prefix into the
    SAME quantized layout, and must land bit-identical counters — the bench
    hard-fails otherwise, so the perf trajectory can never quietly trade
    exactness for speed.  The JSON gives fast CI a per-commit edges/s data
    point per backend, plus the donation x dedup fast-path grid
    (``_fastpath_grid``) with its own bit-exactness and 1.5x speedup
    gates.
    """
    import json as _json

    from repro.core import kmatrix_accel as kma

    dataset = "cit-HepPh"
    stream = make_stream(dataset, batch_size=4096, seed=1, scale=scale)
    ssrc, sdst, sw = sample_stream(stream, int(30_000 * scale) or 1000, seed=7)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    capacity = _capacity_policy_compare(stream, stats, quick)
    fastpath = _fastpath_grid(scale, quick)
    n_batches = min(stream.num_batches, 3 if quick else 16)
    edges = sum(int((np.asarray(stream.batch(i).weight) > 0).sum())
                for i in range(n_batches))
    accel = KMatrixAccel.create(bytes_budget=256 * 1024, stats=stats,
                                depth=5, seed=3)
    flat = kma.to_flat_layout(kma.empty_like(accel))  # bit-exact twin layout
    _log(f"\n== ingest ({dataset}, {n_batches} batches, {edges} edges, "
         f"256KB d=5, interpret={jax.default_backend() != 'tpu'}) ==")

    states, backends = {}, {}
    for name, sk, mod in [("flat", flat, kmatrix), ("pallas", accel, kma)]:
        ing = jax.jit(mod.ingest)
        warm = ing(sk, stream.batch(0))  # compile off the clock
        jax.block_until_ready(jax.tree_util.tree_leaves(warm)[0])
        t0 = time.time()
        st = sk
        for i in range(n_batches):
            st = ing(st, stream.batch(i))
        jax.block_until_ready(jax.tree_util.tree_leaves(st)[0])
        dt = time.time() - t0
        states[name] = st
        backends[name] = {"wall_s": round(dt, 4),
                          "edges_per_s": round(edges / max(dt, 1e-9), 1)}
        _log(f"{name:8s} {edges / max(dt, 1e-9):12,.0f} edges/s "
             f"({dt:.3f}s)")
        _emit(f"ingest/{name}", dt / max(edges, 1) * 1e6,
              f"edges_per_s={edges / max(dt, 1e-9):.0f}")

    from repro.serving.gates import layout_counters_equal

    relayout = kma.to_flat_layout(states["pallas"])
    bit_exact = layout_counters_equal(relayout, states["flat"])
    record = {
        "bench": "ingest",
        "dataset": dataset,
        "scale": scale,
        "n_batches": n_batches,
        "edges": edges,
        "depth": 5,
        "budget_kb": 256,
        "interpret": jax.default_backend() != "tpu",
        "overflow_edges": int(states["pallas"].overflow),
        "backends": backends,
        "bit_exact": bit_exact,
        "capacity_policy": capacity,
        "fastpath": fastpath,
    }
    with open(out_path, "w") as f:
        _json.dump(record, f, indent=2)
    _log(f"wrote {out_path}")
    if not bit_exact:
        raise RuntimeError(
            "ingest: accel backend counters diverged from the flat backend "
            "on the same stream — edges/s for wrong counters is meaningless")
    if not capacity["counters_equal"]:
        raise RuntimeError(
            "ingest: capacity policy changed counter state — dispatch "
            "capacity must only move edges between the MXU path and the "
            "exact scatter fallback, never change what is counted")
    if capacity["overflow_plan_capacity"] >= capacity["overflow_2bp_capacity"]:
        raise RuntimeError(
            "ingest: plan-derived dispatch capacity did not reduce the "
            "scatter-fallback volume vs the 2B/P baseline on a skewed "
            f"stream ({capacity['overflow_plan_capacity']} >= "
            f"{capacity['overflow_2bp_capacity']}) — the capacity-policy "
            "fix regressed")
    bad_cells = [k for k, c in fastpath["cells"].items()
                 if not c["bit_exact_vs_baseline"]]
    if bad_cells:
        raise RuntimeError(
            "ingest: fast-path cells diverged from the undonated/undeduped "
            f"baseline: {bad_cells} — donation is an allocation strategy "
            "and pre-aggregation rides on counter linearity; neither may "
            "change a single counter, pending total, or estimate")
    if fastpath["fastpath_speedup"] < 1.5:
        raise RuntimeError(
            "ingest: donate+dedup arm is only "
            f"{fastpath['fastpath_speedup']:.2f}x the baseline edges/s on "
            "the skewed-stream config (same box, same run) — the fast "
            "path regressed below the 1.5x acceptance floor")


def _fastpath_grid(scale: float, quick: bool) -> dict:
    """Ingest fast path A/B (ISSUE 10): donation x dedup, 4 cells.

    Skewed-stream config (email-EuAll, Zipf) where duplicate (src, dst)
    rows are plentiful: each cell drives the SAME pre-built coalesced
    groups through a ``SnapshotBuffer`` — dedup cells pre-aggregate on
    the host first (``preaggregate_edges``), donate cells run the
    donating kernels — and every cell must land counters, n_edges, AND
    estimates bit-identical to the undonated/undeduped baseline
    (counters are linear; donation is an allocation strategy).  The
    caller hard-gates ``fastpath_speedup`` (donate+dedup vs baseline
    edges/s, same box, same run) at 1.5x.
    """
    from repro.runtime.worker import preaggregate_edges
    from repro.serving.gates import layout_counters_equal
    from repro.serving.snapshot import SnapshotBuffer
    from repro.core.types import EdgeBatch

    dataset = "email-EuAll"
    fp_scale = max(scale, 0.3)  # the dedup win needs real skew volume
    group_batches = 8
    stream = make_stream(dataset, batch_size=4096, seed=5, scale=fp_scale)
    ssrc, sdst, sw = sample_stream(stream, 3000, seed=7)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    n_groups = min(stream.num_batches // group_batches, 4 if quick else 10)
    groups, bi = [], 0
    for _ in range(n_groups):
        cols = [stream.batch_numpy(bi + k) for k in range(group_batches)]
        bi += group_batches
        groups.append(tuple(
            np.ascontiguousarray(np.concatenate([c[j] for c in cols]),
                                 np.int32) for j in range(3)))
    raw_edges = sum(int(np.count_nonzero(g[2])) for g in groups)
    unique_rows = sum(preaggregate_edges(*g)[0].shape[0] for g in groups)

    def one_pass(buf, dedup):
        for g in groups:
            if dedup:
                us, ud, uw = preaggregate_edges(*g)
                n = us.shape[0]
                pad = -(-n // 1024) * 1024  # coarse ladder: few jit shapes
                src = np.zeros(pad, np.int32)
                dst = np.zeros(pad, np.int32)
                wt = np.zeros(pad, np.int32)
                src[:n], dst[:n], wt[:n] = us, ud, uw
                buf.ingest(EdgeBatch.from_numpy(src, dst, wt),
                           count=int(np.count_nonzero(g[2])))
            else:
                buf.ingest(EdgeBatch.from_numpy(*g))
        snap = buf.publish()
        jax.block_until_ready(jax.tree_util.tree_leaves(snap.sketch)[0])
        return snap

    def fresh_buffer(donate):
        sk = KMatrix.create(bytes_budget=256 * 1024, stats=stats,
                            depth=5, seed=3)
        return SnapshotBuffer(sk, kmatrix, tenant_id="bench-fastpath",
                              donate=donate)

    probe = np.arange(256, dtype=np.int32)
    probe_dst = ((probe * 31 + 7) % stream.spec.n_nodes).astype(np.int32)
    cells, snaps = {}, {}
    for donate in (False, True):
        for dedup in (False, True):
            one_pass(fresh_buffer(donate), dedup)  # compile off the clock
            best, snap = None, None
            for _ in range(3 if quick else 5):
                buf = fresh_buffer(donate)
                t0 = time.time()
                snap = one_pass(buf, dedup)
                dt = time.time() - t0
                best = dt if best is None else min(best, dt)
            key = f"donate={int(donate)},dedup={int(dedup)}"
            snaps[key] = snap
            cells[key] = {"wall_s": round(best, 4),
                          "edges_per_s": round(raw_edges / best, 1)}
            _log(f"fastpath {key:19s} "
                 f"{raw_edges / best:12,.0f} edges/s ({best:.3f}s)")

    base_key = "donate=0,dedup=0"
    base = snaps[base_key]
    base_est = np.asarray(kmatrix.edge_freq(base.sketch, probe, probe_dst))
    for key, snap in snaps.items():
        ok = (layout_counters_equal(snap.sketch, base.sketch)
              and snap.n_edges == base.n_edges
              and np.array_equal(np.asarray(
                  kmatrix.edge_freq(snap.sketch, probe, probe_dst)),
                  base_est))
        cells[key]["bit_exact_vs_baseline"] = ok
    speedup = cells["donate=1,dedup=1"]["edges_per_s"] / \
        cells[base_key]["edges_per_s"]
    out = {
        "dataset": dataset,
        "scale": fp_scale,
        "group_batches": group_batches,
        "n_groups": n_groups,
        "raw_edges": raw_edges,
        "dedup_ratio": round(raw_edges / max(unique_rows, 1), 4),
        "cells": cells,
        "fastpath_speedup": round(speedup, 4),
    }
    _emit("ingest/fastpath", 0.0,
          f"speedup={speedup:.2f};dedup_ratio={out['dedup_ratio']:.2f}")
    return out


def _capacity_policy_compare(stream, stats, quick: bool) -> dict:
    """Dispatch-capacity policy on a skewed stream: plan-derived (the fix)
    vs the legacy uniform ``2B/P`` baseline.

    Uses the production ``banded`` partitioner (the registry default, P=17)
    where the hot band's load exceeds 2B/P by the skew factor.  Capacity is
    a dispatch concern only, so both runs must land bit-identical counters;
    the plan-derived capacity must STRICTLY cut ``overflow_edges`` (the
    scatter-fallback volume) — both enforced by the caller."""
    from repro.core import kmatrix_accel as kma
    from repro.serving.gates import layout_counters_equal

    accel = KMatrixAccel.create(bytes_budget=256 * 1024, stats=stats,
                                depth=5, seed=3, partitioner="banded")
    b = stream.batch_size
    n_parts = accel.route.n_partitions
    legacy = max(128, (2 * b) // max(n_parts, 1))
    legacy = -(-legacy // 128) * 128
    plan_cap = kma.dispatch_capacity(accel, b)
    n_batches = min(stream.num_batches, 3 if quick else 8)
    st_plan, st_legacy = accel, accel
    for i in range(n_batches):
        batch = stream.batch(i)
        st_plan = kma.ingest(st_plan, batch)  # default: plan-derived
        st_legacy = kma.ingest(st_legacy, batch, capacity=legacy)
    counters_equal = layout_counters_equal(st_plan, st_legacy)
    out = {
        "partitioner": "banded",
        "n_partitions": n_parts,
        "batch_size": b,
        "n_batches": n_batches,
        "capacity_2bp": legacy,
        "capacity_plan": plan_cap,
        "max_load_share": round(max(accel.load_shares), 4),
        "overflow_2bp_capacity": int(st_legacy.overflow),
        "overflow_plan_capacity": int(st_plan.overflow),
        "counters_equal": counters_equal,
    }
    _log(f"capacity policy (banded, P={n_parts}, B={b}): overflow "
         f"{out['overflow_2bp_capacity']} @2B/P={legacy} -> "
         f"{out['overflow_plan_capacity']} @plan={plan_cap}")
    _emit("ingest/capacity_policy", 0.0,
          f"overflow_2bp={out['overflow_2bp_capacity']};"
          f"overflow_plan={out['overflow_plan_capacity']}")
    return out


def serve_mixed(scale: float, quick: bool) -> None:
    """Beyond-paper: online serving QPS/latency (benchmarks/serve_bench.py)."""
    from benchmarks.serve_bench import run_serve_bench

    _log("\n== serve_mixed (live ingest + batched query engine) ==")
    rec = run_serve_bench(scale=scale, n_requests=1000 if quick else 4000,
                          target_qps=1000.0 if quick else 2000.0)
    if not rec["engine_matches_direct"]:
        raise RuntimeError(
            "serve_mixed: engine answers diverged from direct queries — "
            "QPS numbers for wrong answers are meaningless")
    if not rec.get("backend_parity_ok", True):
        raise RuntimeError(
            "serve_mixed: accel sketch backend diverged from the flat "
            "backend on the same stream prefix")
    _emit("serve/qps", 1e6 / max(rec["achieved_qps"], 1e-9),
          f"qps={rec['achieved_qps']};p50_ms={rec['p50_ms']};"
          f"p99_ms={rec['p99_ms']}")
    _emit("serve/closure_cache", rec["closure_build_ms"] * 1e3,
          f"hit_ms={rec['closure_cache_hit_ms']};"
          f"speedup={rec['closure_cache_speedup']}")


def serve_concurrent(scale: float, quick: bool) -> None:
    """Concurrent regime: background runtime ingest under live query load —
    ingest edges/s and query p50/p99 side by side in one record."""
    from benchmarks.serve_bench import run_serve_bench_concurrent

    _log("\n== serve_concurrent (background ingest worker + loadgen) ==")
    rec = run_serve_bench_concurrent(
        scale=scale, n_requests=1000 if quick else 4000,
        target_qps=1000.0 if quick else 2000.0)
    if not rec["engine_matches_direct"]:
        raise RuntimeError(
            "serve_concurrent: engine answers diverged from direct queries "
            "on a published epoch")
    if not rec["conservation_ok"]:
        raise RuntimeError(
            f"serve_concurrent: edge conservation failed "
            f"(unaccounted={rec['unaccounted_edges']})")
    _emit("serve/concurrent_qps", 1e6 / max(rec["achieved_qps"], 1e-9),
          f"qps={rec['achieved_qps']};p50_ms={rec['p50_ms']};"
          f"p99_ms={rec['p99_ms']};"
          f"ingest_eps={rec['ingest_edges_per_s_during_serve']}")
    _emit("serve/concurrent_ingest",
          rec["mean_publish_latency_ms"] * 1e3,
          f"epochs={rec['epochs_published']};"
          f"max_queue_depth={rec['max_queue_depth']};"
          f"dropped={rec['dropped_edges']}")


def serve_sharded(scale: float, quick: bool,
                  out_path: str = "BENCH_sharded.json") -> None:
    """Sharded serving at K=1/2/4 -> BENCH_sharded.json.

    Per K: aggregate ingest edges/s under live query load plus p50/p99, with
    BOTH sharded hard gates enforced (cross-shard conservation; merged
    shards bit-identical to a single-sketch replay).  The JSON gives fast
    CI a per-commit scaling curve for the scatter/gather serving path.
    """
    import json as _json

    from benchmarks.serve_bench import run_serve_bench_sharded

    _log("\n== serve_sharded (per-shard runtime ingest + scatter/gather) ==")
    shards: dict[str, dict] = {}
    for k in (1, 2, 4):
        rec = run_serve_bench_sharded(
            scale=scale, n_requests=600 if quick else 2000,
            target_qps=1000.0 if quick else 2000.0, n_shards=k)
        # serve_process reuses these thread rows when it runs in the same
        # sweep, instead of re-running the identical thread bench
        _SHARDED_THREAD_RECS[(scale, k)] = rec
        if not rec["conservation_ok"]:
            raise RuntimeError(
                f"serve_sharded K={k}: cross-shard conservation failed "
                f"(published {rec['published_edges']} + dropped "
                f"{rec['dropped_edges']} != stream "
                f"{rec['stream_total_edges']})")
        if rec["sharded_exact"] is False:
            raise RuntimeError(
                f"serve_sharded K={k}: merged shard sketches diverged from "
                "the single-sketch replay — the hash-band routing invariant "
                "is broken")
        if not rec["engine_matches_direct"]:
            raise RuntimeError(
                f"serve_sharded K={k}: scatter/gather engine diverged from "
                "the sharded direct oracle")
        shards[str(k)] = {
            "ingest_edges_per_s": rec["ingest_edges_per_s_dedicated"],
            "ingest_edges_per_s_during_serve":
                rec["ingest_edges_per_s_during_serve"],
            "achieved_qps": rec["achieved_qps"],
            "p50_ms": rec["p50_ms"],
            "p99_ms": rec["p99_ms"],
            "per_shard_published": rec["per_shard_published"],
            "conservation_ok": rec["conservation_ok"],
            "sharded_exact": rec["sharded_exact"],
        }
        _log(f"K={k}: {rec['ingest_edges_per_s_dedicated']:,.0f} ingest "
             f"edges/s (dedicated), {rec['achieved_qps']} qps, "
             f"p99 {rec['p99_ms']} ms")
        _emit(f"serve/sharded_k{k}",
              1e6 / max(rec["ingest_edges_per_s_dedicated"], 1e-9),
              f"ingest_eps={rec['ingest_edges_per_s_dedicated']};"
              f"qps={rec['achieved_qps']};p99_ms={rec['p99_ms']}")
    record = {
        "bench": "serve_sharded",
        "dataset": "cit-HepPh",
        "scale": scale,
        "budget_kb": 256,
        "depth": 5,
        # scaling is bounded by available cores: K > cpu_count adds thread
        # overhead without parallelism, so read the curve against this
        "cpu_count": os.cpu_count(),
        "shards": shards,
    }
    with open(out_path, "w") as f:
        _json.dump(record, f, indent=2)
    _log(f"wrote {out_path}")


# thread-backend sharded records from serve_sharded, keyed by (scale, K) —
# lets serve_process skip re-running benches an earlier target in the same
# `benchmarks.run` invocation already produced (CI runs the full sweep)
_SHARDED_THREAD_RECS: dict = {}


def serve_process(scale: float, quick: bool,
                  out_path: str = "BENCH_process.json") -> None:
    """Thread vs process runtime backends at K=1/2/4 -> BENCH_process.json.

    The GIL story in one artifact: the thread backend time-slices K shard
    workers inside one interpreter, the process backend gives each worker
    its own (ISSUE 5 tentpole).  Per (backend, K): dedicated backlog-drain
    ingest edges/s plus query p50/p99 under live ingest, with every sharded
    hard gate enforced (cross-shard conservation, merged-vs-replay
    bit-exactness, engine==direct).  Process K=4 vs K=1 scaling is recorded
    (cpu_count-contextualized) — no gate on absolute numbers: a 2-core CI
    box legitimately plateaus where a 16-core server keeps scaling.
    """
    import json as _json

    from benchmarks.serve_bench import run_serve_bench_sharded

    _log("\n== serve_process (thread vs process runtime backends) ==")
    backends: dict[str, dict] = {}
    for backend in ("thread", "process"):
        rows: dict[str, dict] = {}
        for k in (1, 2, 4):
            rec = (_SHARDED_THREAD_RECS.get((scale, k))
                   if backend == "thread" else None)
            if rec is None:
                # same load as serve_sharded, so reused thread rows and
                # fresh process rows stay apples-to-apples within the one
                # artifact (and standalone --only runs match the sweep)
                rec = run_serve_bench_sharded(
                    scale=scale, n_requests=600 if quick else 2000,
                    target_qps=1000.0 if quick else 2000.0, n_shards=k,
                    runtime_backend=backend)
            if not rec["conservation_ok"]:
                raise RuntimeError(
                    f"serve_process {backend} K={k}: cross-shard "
                    f"conservation failed (published "
                    f"{rec['published_edges']} + dropped "
                    f"{rec['dropped_edges']} != stream "
                    f"{rec['stream_total_edges']})")
            if rec["sharded_exact"] is False:
                raise RuntimeError(
                    f"serve_process {backend} K={k}: merged shard sketches "
                    "diverged from the single-sketch replay")
            if not rec["engine_matches_direct"]:
                raise RuntimeError(
                    f"serve_process {backend} K={k}: scatter/gather engine "
                    "diverged from the sharded direct oracle")
            if not rec["dedicated_ingest_conserved"]:
                raise RuntimeError(
                    f"serve_process {backend} K={k}: dedicated ingest "
                    "drain lost edges")
            rows[str(k)] = {
                "ingest_edges_per_s": rec["ingest_edges_per_s_dedicated"],
                "ingest_edges_per_s_during_serve":
                    rec["ingest_edges_per_s_during_serve"],
                "achieved_qps": rec["achieved_qps"],
                "p50_ms": rec["p50_ms"],
                "p99_ms": rec["p99_ms"],
                "conservation_ok": rec["conservation_ok"],
                "sharded_exact": rec["sharded_exact"],
            }
            _log(f"{backend:8s} K={k}: "
                 f"{rec['ingest_edges_per_s_dedicated']:,.0f} ingest "
                 f"edges/s (dedicated), p99 {rec['p99_ms']} ms")
            _emit(f"serve/{backend}_k{k}",
                  1e6 / max(rec["ingest_edges_per_s_dedicated"], 1e-9),
                  f"ingest_eps={rec['ingest_edges_per_s_dedicated']};"
                  f"qps={rec['achieved_qps']};p99_ms={rec['p99_ms']}")
        backends[backend] = rows
    p1 = backends["process"]["1"]["ingest_edges_per_s"]
    p4 = backends["process"]["4"]["ingest_edges_per_s"]
    record = {
        "bench": "serve_process",
        "dataset": "cit-HepPh",
        "scale": scale,
        "budget_kb": 256,
        "depth": 5,
        # scaling is bounded by available cores: K > cpu_count adds spawn +
        # scheduler overhead without parallelism, so read both curves (and
        # the thread-vs-process gap, which the GIL caps) against this
        "cpu_count": os.cpu_count(),
        "backends": backends,
        "process_k4_over_k1": round(p4 / max(p1, 1e-9), 3),
    }
    with open(out_path, "w") as f:
        _json.dump(record, f, indent=2)
    _log(f"wrote {out_path} (process K4/K1 = "
         f"{record['process_k4_over_k1']}x on {os.cpu_count()} cores)")


def serve_net(scale: float, quick: bool,
              out_path: str = "BENCH_net.json") -> None:
    """Network transport tier -> BENCH_net.json (DESIGN.md §Net).

    Three cells in one artifact:

      * ingest transport — the sharded serving bench on the ``socket``
        runtime backend (TCP self-host loopback workers) next to the
        ``process`` backend (mp pipes), with EVERY sharded hard gate
        enforced for both: cross-shard conservation, merged-vs-replay
        bit-exactness, engine==direct, dedicated-drain conservation.
        Same counters over a socket or a pipe, or the bench dies.
      * front-end — QPS/p50/p99 of the TCP query server at 1/2/4 client
        connections with the OFFERED load held constant (the loadgen's
        arrival clock is global), over one warmed live tenant.
      * overload — tiny ``max_inflight`` against a much higher offered
        rate: admission control must shed (nonzero, accounted — offered ==
        accepted + shed + errors on the client AND offered == admitted +
        shed on the server) while accepted-request p99 stays bounded
        instead of collapsing into queueing.
    """
    import json as _json

    from benchmarks.serve_bench import run_serve_bench_sharded
    from repro.net.query_server import QueryServer
    from repro.obs.hub import get_hub, reset_hub
    from repro.serving import (
        QueryEngine,
        SketchRegistry,
        mix_for_sketch,
        synth_requests,
        warm_bucket_ladder,
    )
    from repro.serving.loadgen import NetLoadGen

    _log("\n== serve_net (socket ingest transport + TCP query front-end) ==")

    def _wire_bytes() -> dict:
        """Parent-LOCAL wire byte counters (repro.net.wire instruments).

        Read ``state()``, never ``merged_state()``: the workers' shipped
        hub states are adopted alongside, and every frame is counted on
        both ends — merging would double the totals."""
        sent: dict[str, int] = {}
        recv: dict[str, int] = {}
        publish_bytes = 0
        for name, labels, value in get_hub().state()["counters"]:
            kind = labels.get("kind", "?")
            if name == "wire_bytes_sent":
                sent[kind] = sent.get(kind, 0) + int(value)
            elif name == "wire_bytes_recv":
                recv[kind] = recv.get(kind, 0) + int(value)
            elif name == "publish_bytes":
                publish_bytes += int(value)
        return {"sent": sent, "recv": recv, "publish_bytes": publish_bytes}

    # ---- cell 1: socket vs process ingest transport, gates on -------------
    transports: dict[str, dict] = {}
    for backend in ("process", "socket"):
        reset_hub()  # per-cell wire accounting (parent-local)
        rec = run_serve_bench_sharded(
            scale=scale, n_requests=400 if quick else 1500,
            target_qps=1000.0 if quick else 2000.0, n_shards=2,
            runtime_backend=backend, ingest_repeats=3)
        if not rec["conservation_ok"]:
            raise RuntimeError(
                f"serve_net {backend} transport: cross-shard conservation "
                f"failed (published {rec['published_edges']} + dropped "
                f"{rec['dropped_edges']} != stream "
                f"{rec['stream_total_edges']})")
        if rec["sharded_exact"] is False:
            raise RuntimeError(
                f"serve_net {backend} transport: merged shard sketches "
                "diverged from the single-sketch replay — the transport "
                "changed what was counted")
        if not rec["engine_matches_direct"]:
            raise RuntimeError(
                f"serve_net {backend} transport: scatter/gather engine "
                "diverged from the sharded direct oracle")
        if not rec["dedicated_ingest_conserved"]:
            raise RuntimeError(
                f"serve_net {backend} transport: dedicated ingest drain "
                "lost edges")
        wire_bytes = _wire_bytes()
        transports[backend] = {
            "ingest_edges_per_s": rec["ingest_edges_per_s_dedicated"],
            "ingest_edges_per_s_during_serve":
                rec["ingest_edges_per_s_during_serve"],
            "achieved_qps": rec["achieved_qps"],
            "p99_ms": rec["p99_ms"],
            "conservation_ok": rec["conservation_ok"],
            "sharded_exact": rec["sharded_exact"],
            "wire_bytes": wire_bytes,
        }
        _log(f"{backend:8s} transport: "
             f"{rec['ingest_edges_per_s_dedicated']:,.0f} ingest edges/s "
             f"(dedicated), p99 {rec['p99_ms']} ms, "
             f"publish_bytes {wire_bytes['publish_bytes']:,}")
        _emit(f"net/ingest_{backend}",
              1e6 / max(rec["ingest_edges_per_s_dedicated"], 1e-9),
              f"ingest_eps={rec['ingest_edges_per_s_dedicated']};"
              f"qps={rec['achieved_qps']};p99_ms={rec['p99_ms']}")

    # ---- cell 1b: delta vs full publish payloads (A/B, gate on) -----------
    # same stream, same every:1 policy, process backend; only the publish
    # encoding differs.  Gates: delta must ship measurably fewer bytes per
    # epoch AND the final adopted sketches must be bit-identical — the
    # sparse delta path is an optimisation, never an approximation.
    import jax as _jax

    from repro.runtime import Runtime
    from repro.runtime.backend import ProcessBackend

    publish_rows: dict[str, dict] = {}
    finals: dict[str, object] = {}
    for mode in ("delta", "full"):
        reset_hub()
        t = SketchRegistry(depth=5, scale=scale).open(
            "cit-HepPh", "kmatrix", 256, seed=0)
        rt = Runtime(publish_policy="every:1", poll_s=0.01,
                     backend=ProcessBackend(publish_mode=mode))
        rt.attach(t)
        rt.start(pumps=False)
        rt.wait_ready()
        rt.start_pumps()
        rt.join_pumps()
        rep = rt.stop(drain=True)[t.key.tenant_id]
        if rep["unaccounted_edges"]:
            raise RuntimeError(
                f"serve_net publish mode={mode}: conservation failed "
                f"({rep['unaccounted_edges']} unaccounted edges)")
        pub_bytes = _wire_bytes()["publish_bytes"]
        epochs = int(rep.get("publishes") or 1)
        publish_rows[mode] = {
            "publish_bytes": pub_bytes,
            "epochs": epochs,
            "publish_bytes_per_epoch": round(pub_bytes / max(epochs, 1)),
        }
        finals[mode] = t.snapshot
        _log(f"publish mode={mode}: {pub_bytes:,} publish bytes over "
             f"{epochs} epochs "
             f"({publish_rows[mode]['publish_bytes_per_epoch']:,}/epoch)")
        _emit(f"net/publish_{mode}",
              publish_rows[mode]["publish_bytes_per_epoch"],
              f"publish_bytes={pub_bytes};epochs={epochs}")
    if not (0 < publish_rows["delta"]["publish_bytes_per_epoch"]
            < publish_rows["full"]["publish_bytes_per_epoch"]):
        raise RuntimeError(
            f"serve_net publish A/B: delta publishes are not smaller than "
            f"full ({publish_rows})")
    d_leaves = _jax.tree_util.tree_leaves(finals["delta"].sketch)
    f_leaves = _jax.tree_util.tree_leaves(finals["full"].sketch)
    if finals["delta"].n_edges != finals["full"].n_edges or not all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(d_leaves, f_leaves)):
        raise RuntimeError(
            "serve_net publish A/B: delta-adopted sketch diverged from "
            "full-adopted sketch — delta publication must be bit-exact")
    _log(f"publish A/B: delta/full bytes-per-epoch = "
         f"{publish_rows['delta']['publish_bytes_per_epoch'] / max(publish_rows['full']['publish_bytes_per_epoch'], 1):.3f}, "
         "final sketches bit-identical")

    # ---- warmed live tenant + engine shared by cells 2 and 3 --------------
    registry = SketchRegistry(depth=5, scale=scale)
    tenant = registry.open("cit-HepPh", "kmatrix", 256, seed=0)
    tenant.step(min(8, max(1, tenant.stream.num_batches // 2)))
    tenant.publish()
    n_nodes = tenant.stream.spec.n_nodes
    engine = QueryEngine()
    mix = mix_for_sketch("kmatrix")
    kw = dict(n_nodes=n_nodes, heavy_universe=min(n_nodes, 1 << 14),
              heavy_threshold=100.0)
    warm_bucket_ladder(engine, tenant.snapshot,
                       synth_requests(256, mix, seed=99, **kw))

    # ---- cell 2: QPS/p50/p99 vs connection count --------------------------
    n_req = 600 if quick else 2400
    qps = 500.0 if quick else 1000.0
    requests = synth_requests(n_req, mix, seed=11, **kw)
    conn_rows: dict[str, dict] = {}
    server = QueryServer(engine, lambda: tenant.snapshot,
                         info={"n_nodes": n_nodes, "kind": "kmatrix",
                               "dataset": "cit-HepPh"}).start()
    try:
        for conns in (1, 2, 4):
            rep = NetLoadGen(target_qps=qps, connections=conns,
                             batch_max=64).run(server.address, requests)
            if rep.errors:
                raise RuntimeError(
                    f"serve_net conns={conns}: {rep.errors} server-side "
                    "errors — QPS for failed answers is meaningless")
            if rep.aborted:
                raise RuntimeError(
                    f"serve_net conns={conns}: {rep.aborted} requests "
                    f"aborted on a dead transport ({rep.transport_error})")
            if rep.accepted != rep.n_requests:
                raise RuntimeError(
                    f"serve_net conns={conns}: {rep.shed} requests shed "
                    "under nominal load (max_inflight=4096) — admission "
                    "control is rejecting work it has room for")
            if rep.last_epoch is None:
                raise RuntimeError(
                    f"serve_net conns={conns}: answers carried no epoch "
                    "stamp — staleness contract broken")
            conn_rows[str(conns)] = {
                "achieved_qps": round(rep.achieved_qps, 1),
                "p50_ms": round(rep.p50_ms, 3),
                "p99_ms": round(rep.p99_ms, 3),
                "n_batches": rep.n_batches,
                "last_epoch": rep.last_epoch,
            }
            _log(f"conns={conns}: {rep.achieved_qps:,.0f} qps, "
                 f"p50 {rep.p50_ms:.2f} ms, p99 {rep.p99_ms:.2f} ms "
                 f"({rep.n_batches} calls)")
            _emit(f"net/conns_{conns}", rep.p50_ms * 1e3,
                  f"qps={rep.achieved_qps:.0f};p50_ms={rep.p50_ms:.3f};"
                  f"p99_ms={rep.p99_ms:.3f}")
    finally:
        server.stop()

    # ---- cell 3: overload — admission control must shed, accounted --------
    over = QueryServer(engine, lambda: tenant.snapshot, max_inflight=64,
                       batch_max=32,
                       info={"n_nodes": n_nodes, "kind": "kmatrix"}).start()
    try:
        over_reqs = synth_requests(800 if quick else 2000, mix, seed=23, **kw)
        rep = NetLoadGen(target_qps=qps * 10, connections=4,
                         batch_max=64).run(over.address, over_reqs)
        stats = over.stats()
    finally:
        over.stop()
    if rep.errors:
        raise RuntimeError(
            f"serve_net overload: {rep.errors} server-side errors — "
            "overload must shed at admission, not fail mid-execution")
    if rep.shed <= 0:
        raise RuntimeError(
            "serve_net overload: offered 10x nominal against "
            "max_inflight=64 and nothing was shed — admission control "
            "is not engaging")
    if rep.aborted:
        raise RuntimeError(
            f"serve_net overload: {rep.aborted} requests aborted on a "
            f"dead transport ({rep.transport_error}) — overload must shed "
            "at admission, not kill connections")
    if rep.accepted + rep.shed != rep.n_requests:
        raise RuntimeError(
            f"serve_net overload: client accounting leak ({rep.accepted} "
            f"accepted + {rep.shed} shed != {rep.n_requests} offered)")
    if stats["offered_requests"] != (stats["admitted_requests"]
                                     + stats["shed_overload"]
                                     + stats["shed_rate_limited"]
                                     + stats["shed_too_large"]):
        raise RuntimeError(
            f"serve_net overload: server admission ledger does not "
            f"balance ({stats})")
    if not np.isfinite(rep.p99_ms) or rep.p99_ms > 30_000:
        raise RuntimeError(
            f"serve_net overload: accepted-request p99 {rep.p99_ms} ms — "
            "shedding exists precisely so accepted work stays bounded")
    if rep.mean_retry_after_ms <= 0:
        raise RuntimeError(
            "serve_net overload: rejections carried no Retry-After hint")
    _log(f"overload: shed {rep.shed}/{rep.n_requests} "
         f"({rep.shed_rate:.1%}), accepted p99 {rep.p99_ms:.2f} ms, "
         f"mean retry-after hint {rep.mean_retry_after_ms:.1f} ms")
    _emit("net/overload", rep.p99_ms * 1e3,
          f"shed_rate={rep.shed_rate:.4f};p99_ms={rep.p99_ms:.3f};"
          f"retry_after_ms={rep.mean_retry_after_ms:.1f}")

    record = {
        "bench": "serve_net",
        "dataset": "cit-HepPh",
        "scale": scale,
        "budget_kb": 256,
        "depth": 5,
        "cpu_count": os.cpu_count(),
        "ingest_transports": transports,
        "socket_over_process": round(
            transports["socket"]["ingest_edges_per_s"]
            / max(transports["process"]["ingest_edges_per_s"], 1e-9), 3),
        "publish_bytes_per_epoch": {
            mode: row["publish_bytes_per_epoch"]
            for mode, row in publish_rows.items()},
        "publish_payload": publish_rows,
        "frontend_offered_qps": qps,
        "frontend_connections": conn_rows,
        "overload": {
            "offered_qps": qps * 10,
            "max_inflight": 64,
            "n_requests": rep.n_requests,
            "accepted": rep.accepted,
            "shed": rep.shed,
            "shed_rate": round(rep.shed_rate, 4),
            "p99_ms": round(rep.p99_ms, 3),
            "mean_retry_after_ms": round(rep.mean_retry_after_ms, 1),
            "server_stats": stats,
        },
    }
    with open(out_path, "w") as f:
        _json.dump(record, f, indent=2)
    _log(f"wrote {out_path} (socket/process ingest = "
         f"{record['socket_over_process']}x)")


def obs_overhead(scale: float, quick: bool,
                 out_path: str = "BENCH_obs.json") -> None:
    """Telemetry overhead -> BENCH_obs.json (DESIGN.md §Observability).

    Two arms over identical work, toggled with ``repro.obs.set_disabled``
    (the global instrument kill-switch): a thread-backend runtime ingest
    drain (edges/s) and an in-process open-loop query run (p99 ms).  Each
    arm takes the best of ``reps`` walls, alternating on/off so drift
    hits both arms equally.  Hard gate: metrics-on ingest throughput must
    stay within 5% of metrics-off — typed instruments are per-batch work
    (two counter incs, two histogram buckets, one span emit against
    ~8k-edge batches), so a bigger gap means someone put telemetry on the
    per-edge path.
    """
    import json as _json

    from repro.obs import reset_hub, reset_trace_log, set_disabled
    from repro.runtime import Runtime
    from repro.serving import (
        QueryEngine,
        SketchRegistry,
        mix_for_sketch,
        synth_requests,
        warm_bucket_ladder,
    )
    from repro.serving.loadgen import OpenLoopLoadGen

    _log("\n== obs (telemetry overhead: metrics on vs off) ==")
    reps = 2 if quick else 3

    def ingest_eps() -> float:
        reset_hub()
        reset_trace_log()
        registry = SketchRegistry(depth=5, scale=scale)
        tenant = registry.open("cit-HepPh", "kmatrix", 256, seed=0)
        runtime = Runtime(publish_policy="drain:0", reservoir_k=0,
                          backend="thread")
        runtime.attach(tenant)
        runtime.start(pumps=False)
        runtime.wait_ready()
        t0 = time.time()
        runtime.start_pumps()
        runtime.join_pumps()
        rep = runtime.stop(drain=True)[tenant.key.tenant_id]
        dt = time.time() - t0
        if rep["unaccounted_edges"]:
            raise RuntimeError("obs bench: ingest drain lost edges")
        return rep["ingested_edges"] / max(dt, 1e-9)

    def query_p99() -> float:
        reset_hub()
        registry = SketchRegistry(depth=5, scale=scale)
        tenant = registry.open("cit-HepPh", "kmatrix", 256, seed=0)
        tenant.step(min(4, max(1, tenant.stream.num_batches // 2)))
        tenant.publish()
        n_nodes = tenant.stream.spec.n_nodes
        engine = QueryEngine()
        mix = mix_for_sketch("kmatrix")
        kw = dict(n_nodes=n_nodes, heavy_universe=min(n_nodes, 1 << 14),
                  heavy_threshold=100.0)
        warm_bucket_ladder(engine, tenant.snapshot,
                           synth_requests(128, mix, seed=99, **kw))
        requests = synth_requests(400 if quick else 1500, mix, seed=11, **kw)
        report = OpenLoopLoadGen(
            target_qps=1000.0 if quick else 2000.0,
            batch_max=256).run(engine, lambda: tenant.snapshot, requests)
        return report.p99_ms

    arms = {"on": {"eps": 0.0, "p99_ms": float("inf")},
            "off": {"eps": 0.0, "p99_ms": float("inf")}}
    try:
        for _ in range(reps):
            for arm in ("off", "on"):  # alternate so drift hits both
                set_disabled(arm == "off")
                arms[arm]["eps"] = max(arms[arm]["eps"], ingest_eps())
                arms[arm]["p99_ms"] = min(arms[arm]["p99_ms"], query_p99())
    finally:
        set_disabled(False)
        reset_hub()
        reset_trace_log()

    ratio = arms["on"]["eps"] / max(arms["off"]["eps"], 1e-9)
    for arm in ("off", "on"):
        _log(f"metrics {arm:3s}: {arms[arm]['eps']:,.0f} ingest edges/s, "
             f"query p99 {arms[arm]['p99_ms']:.2f} ms")
        _emit(f"obs/metrics_{arm}", 1e6 / max(arms[arm]["eps"], 1e-9),
              f"ingest_eps={arms[arm]['eps']:.0f};"
              f"p99_ms={arms[arm]['p99_ms']:.3f}")
    _log(f"metrics-on/off ingest ratio: {ratio:.3f}")
    if ratio < 0.95:
        raise RuntimeError(
            f"obs bench: metrics-on ingest throughput is {ratio:.1%} of "
            "metrics-off (gate: within 5%) — telemetry has leaked onto "
            "the per-edge hot path")

    record = {
        "bench": "obs",
        "dataset": "cit-HepPh",
        "scale": scale,
        "budget_kb": 256,
        "depth": 5,
        "reps": reps,
        "metrics_on": {k: round(v, 3) for k, v in arms["on"].items()},
        "metrics_off": {k: round(v, 3) for k, v in arms["off"].items()},
        "on_over_off_ingest": round(ratio, 4),
        "gate_within": 0.05,
    }
    with open(out_path, "w") as f:
        _json.dump(record, f, indent=2)
    _log(f"wrote {out_path} (on/off ingest = {record['on_over_off_ingest']})")


BENCHES = {
    "fig6_build_time": lambda a: fig6_build_time(a.scale),
    "fig7_are": lambda a: fig7_fig8_accuracy(a.scale, a.quick),
    "partitioner_ablation": lambda a: partitioner_ablation(a.scale),
    "kernel_micro": lambda a: kernel_micro(a.quick),
    "ingest": lambda a: ingest_backends(a.scale, a.quick),
    "serve_mixed": lambda a: serve_mixed(a.scale, a.quick),
    "serve_concurrent": lambda a: serve_concurrent(a.scale, a.quick),
    "serve_sharded": lambda a: serve_sharded(a.scale, a.quick),
    "serve_process": lambda a: serve_process(a.scale, a.quick),
    "serve_net": lambda a: serve_net(a.scale, a.quick),
    "obs": lambda a: obs_overhead(a.scale, a.quick),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", type=float, default=None,
                    help="dataset scale (default: 1.0, 0.1 with --quick)")
    ap.add_argument("--only", choices=sorted(BENCHES))
    args = ap.parse_args()
    if args.scale is None:
        args.scale = 0.1 if args.quick else 1.0
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(args)


if __name__ == "__main__":
    main()
