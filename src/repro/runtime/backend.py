"""Execution backends: where an ingest worker's write path actually runs.

The runtime's worker/queue contract (DESIGN.md §Runtime §Backends) is
transport-agnostic.  Everything the supervisor, publish policies, metrics,
backpressure accounting and crash/restore logic need crosses exactly two
seams:

  inward   the serialized edge-batch stream: ``QueueItem``s pulled from the
           tenant's parent-side ``BoundedEdgeQueue`` (so ALL backpressure
           policies — block / drop-oldest / spill — and their drop/spill
           accounting live in one place regardless of backend);
  outward  epoch-stamped snapshot publication: the full published state
           (sketch pytree leaves + counters + reservoir arrays/RNG + stream
           offset cursor), adopted into the parent's ``SnapshotBuffer`` so
           queries always serve from the parent's address space.

``ThreadBackend`` is the PR 2 behaviour: the worker is an ``IngestWorker``
thread sharing the parent's sketch buffer — publication is a pointer swap.

``ProcessBackend`` runs the same ``IngestWorker`` code in a spawn-safe
``multiprocessing`` child that OWNS its sketch: the child rebuilds the
tenant from its registry-stamped ``TenantOrigin`` (deterministic ⇒
identical layout), loads the parent's buffer state shipped at spawn (warm
prefix or restored checkpoint — restore logic runs once, parent-side),
folds transported batches in its own interpreter (no GIL sharing with K-1
sibling shards or the query path), and ships every published epoch back
over a FIFO result pipe.  Checkpoints are written by the child through the
same ``checkpoint/store`` path a thread worker uses, so thread- and
process-written checkpoints are interchangeable.

Ordering guarantees the parent relies on: the item pipe and the result
pipe are both FIFO, publishes are emitted in epoch order from a single
writer thread, and the terminal ``stopped`` message is sent only after the
child worker joined — so when ``join()`` returns, every published epoch
(including the final drain publish) has been adopted.

``SocketBackend`` (``repro.net.backend``, resolved via ``"socket"``) runs
the exact same worker loop — ``run_ingest_worker`` below — across a TCP
connection instead of a multiprocessing pipe; both transports frame every
message with the shared ``repro.net.wire`` codec, so a version skew or a
torn stream fails loudly instead of as a pickle crash.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import queue as queue_mod
import signal
import sys
import threading
import time

import numpy as np

from repro.net import wire
from repro.obs.hub import get_hub
from repro.obs.trace import get_trace_log
from repro.runtime.metrics import WorkerMetrics
from repro.runtime.queueing import BoundedEdgeQueue, QueueItem
from repro.runtime.worker import (
    CREATED,
    DRAINING,
    FAILED,
    RUNNING,
    STOPPED,
    IngestWorker,
)

_BACKEND_NAMES = ("thread", "process", "socket")


class WorkerFailure(RuntimeError):
    """One or more ingest workers died; carries the original tracebacks.

    Raised by ``Runtime.stop()`` (and drain callers) so failures surface at
    the call site instead of only via ``health()`` polling.  ``failures``
    is a list of ``{"tenant_id", "error", "traceback"}`` dicts; ``report``
    holds the final per-tenant accounting gathered before raising, so a
    caller that catches this still sees the conservation numbers.
    """

    def __init__(self, failures: list, report: dict | None = None) -> None:
        self.failures = failures
        self.report = report
        lines = []
        for f in failures:
            lines.append(f"worker {f['tenant_id']} failed: {f['error']}")
            if f.get("traceback"):
                lines.append(f["traceback"].rstrip())
        super().__init__("\n".join(lines) or "worker failure")


class ExecutionBackend:
    """Factory for worker handles honouring the backend contract.

    A worker handle must expose the surface ``Runtime``/``TenantRuntime``
    program against: ``start / request_stop(drain) / join / is_alive``,
    ``state`` (created/running/draining/stopped/failed), ``error`` +
    ``error_tb``, ``base_edges``, ``ingested_edges``, ``wait_ready``,
    ``health()``, ``metrics_snapshot()``, ``checkpoint()`` and the parent
    ``queue`` it consumes from.
    """

    name: str = ""
    remote: bool = False  # worker's sketch state lives outside this process

    def make_worker(self, tenant, queue: BoundedEdgeQueue, policy, *,
                    reservoir=None, checkpoint_dir: str | None = None,
                    checkpoint_every: int = 0, on_publish=None,
                    poll_s: float = 0.05, coalesce_batches: int = 1,
                    coalesce_target: int = 8192, queue_capacity: int = 64,
                    dedup: bool = False):
        raise NotImplementedError

    def shutdown(self) -> None:
        """Release backend-owned transport resources (listeners, dialers).

        ``Runtime.stop()``/``kill()`` call this BEFORE joining workers so a
        worker wedged in accept/connect (a peer that never dialed back, a
        host that never came up) is cut loose instead of hanging the join.
        Idempotent; the default backends own no transport state.
        """


class ThreadBackend(ExecutionBackend):
    """In-process worker threads over the shared snapshot buffer (PR 2)."""

    name = "thread"
    remote = False

    def make_worker(self, tenant, queue, policy, *, reservoir=None,
                    checkpoint_dir=None, checkpoint_every=0, on_publish=None,
                    poll_s=0.05, coalesce_batches=1, coalesce_target=8192,
                    queue_capacity=64, dedup=False):
        from repro.runtime.policies import make_policy

        return IngestWorker(
            tenant, queue, make_policy(policy), reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            on_publish=on_publish, poll_s=poll_s,
            coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, dedup=dedup)


def resolve_backend(spec) -> ExecutionBackend:
    """``"thread"`` | ``"process"`` | ``"socket[:HOST:PORT,...]"`` | a ready
    ``ExecutionBackend``."""
    if isinstance(spec, ExecutionBackend):
        return spec
    if spec == "thread" or spec is None:
        return ThreadBackend()
    if spec == "process":
        return ProcessBackend()
    if isinstance(spec, str) and (spec == "socket"
                                  or spec.startswith("socket:")):
        # lazy: repro.net.backend imports back into this module
        from repro.net.backend import SocketBackend

        return SocketBackend.from_spec(spec)
    raise ValueError(f"unknown runtime backend {spec!r}; "
                     f"choose from {_BACKEND_NAMES}")


# ----------------------------------------------------------------- process --

@dataclasses.dataclass
class _ChildSpec:  # wire-type
    """Everything a spawn child needs; plain picklable values only."""

    origin: object  # serving.registry.TenantOrigin
    policy: str
    init: dict  # parent buffer state: flat numpy leaves + counters + offset
    reservoir: dict | None  # {"k": int, "state": Reservoir.state_dict()}
    checkpoint_dir: str | None
    checkpoint_every: int
    poll_s: float
    coalesce_batches: int
    coalesce_target: int
    queue_capacity: int
    warm_shapes: bool
    env: dict  # applied before the child imports jax (platform pinning,
    #            thread-pool caps under core oversubscription, ...)
    # "delta" (default): publishes ship only the sketch delta accumulated
    # since the last publish (sparse-encoded; full leaves for the first
    # publish and after any resync request).  "full": every publish ships
    # the whole front — the pre-v3 behaviour, kept for A/B benching.
    publish_mode: str = "delta"
    # exact duplicate-edge pre-aggregation in the child's coalescing path
    # (ISSUE 10); default off so specs pickled by older parents replay
    # unchanged (readers use getattr for the same reason)
    dedup: bool = False


def _tree_leaves_np(tree) -> list:
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _warm_child_shapes(tenant) -> None:
    """Compile the child's ingest bucket ladder (and the publish kernel)
    before the ready handshake, so transport-fed ingest never stalls on XLA.
    Zero-weight batches are counter no-ops; the warm publish bumps the
    epoch, which is harmless (epoch numbers are arbitrary, still monotone).
    """
    from repro.core.types import EdgeBatch

    view = tenant.stream
    granule = getattr(view, "granule", None)
    base = getattr(view, "base", view)
    base_b = getattr(base, "batch_size", None) or 8192
    if granule:  # ShardStreamView ladder; 2x covers coalesced overshoot
        buckets = range(granule, 2 * base_b + granule, granule)
    else:
        buckets = [base_b]
    for bucket in buckets:
        z = np.zeros(bucket, np.int32)
        tenant.buffer.ingest(EdgeBatch.from_numpy(z, z, z))
    tenant.buffer.publish()


def build_child_spec(tenant, policy, *, reservoir=None, checkpoint_dir=None,
                     checkpoint_every=0, poll_s=0.05, coalesce_batches=1,
                     coalesce_target=8192, queue_capacity=64,
                     warm_shapes=True, env=None,
                     publish_mode="delta", dedup=False) -> _ChildSpec:
    """Snapshot everything a remote worker needs into a picklable spec.

    Shared by the process backend (ships it via ``Process`` args) and the
    socket backend (ships it in the ``hello`` frame), so both transports
    rebuild a worker from the exact same state."""
    if not isinstance(policy, str):
        raise TypeError(
            "the process backend needs a publish-policy SPEC string "
            f"(e.g. 'every:4'), not {type(policy).__name__}: the policy "
            "object lives in the child and is rebuilt there")
    origin = getattr(tenant, "origin", None)
    if origin is None:
        raise ValueError(
            "process backend requires a registry-opened tenant (its "
            "TenantOrigin rebuild spec is how the child reproduces the "
            "sketch layout); hand-built tenants can only run on the "
            "thread backend")
    buf = tenant.buffer.state()
    init = {
        "front": _tree_leaves_np(buf["front"]),
        "delta": _tree_leaves_np(buf["delta"]),
        "pending": int(np.asarray(buf["pending"])),
        "epoch": int(buf["epoch"]),
        "n_edges": int(buf["n_edges"]),
        "offset": int(tenant.offset),
    }
    res = None
    if reservoir is not None:
        res = {"k": reservoir.k, "state": reservoir.state_dict()}
    if publish_mode not in ("delta", "full"):
        raise ValueError(
            f"publish_mode must be 'delta' or 'full', got {publish_mode!r}")
    env = dict(env or {})
    # the child must rebuild its buffer with the SAME donation setting as
    # the parent: spec.env lands before the child imports jax, and it also
    # reaches remote socket hosts whose environment the parent's does not
    # (spawn children merely inherit os.environ, which covers the local
    # case but not `stream_ingest --listen` on another box)
    env.setdefault("REPRO_DONATE", "1" if tenant.buffer.donate else "0")
    return _ChildSpec(
        origin=origin, policy=policy, init=init, reservoir=res,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        poll_s=poll_s, coalesce_batches=coalesce_batches,
        coalesce_target=coalesce_target, queue_capacity=queue_capacity,
        warm_shapes=warm_shapes, env=env,
        publish_mode=publish_mode, dedup=bool(dedup))


def run_ingest_worker(spec: _ChildSpec, recv, send) -> str:
    """Transport-neutral body of a remote ingest worker.

    ``recv(timeout_s)`` yields the next decoded message tuple (or ``None``
    on timeout); ``send(msg)`` ships one message tuple back to the parent
    and must be thread-safe (the publish callback fires from the worker
    thread).  Both the process child (``_child_main``) and the socket
    worker server (``repro.net.ingest_server``) drive this loop; message
    kinds are the wire-protocol kinds (``repro.net.wire.FRAME_TYPES``).

    Returns ``"stopped"`` after a graceful stop, ``"failed"`` after a
    terminal ``failed`` message.  Whatever happens, the local ingest
    thread is stopped on the way out — a dead transport can never leave an
    orphan worker folding edges nobody will ever adopt.
    """
    worker = None
    try:
        os.environ.update(spec.env)  # must land before jax initializes
        import jax

        from repro.runtime.policies import make_policy
        from repro.streams.reservoir import Reservoir

        tenant = spec.origin.rebuild()
        # adopt the parent's buffer state (warm prefix / restored checkpoint)
        buf = tenant.buffer.state()
        structure = jax.tree_util.tree_structure(buf["front"])
        tenant.buffer.load_state({
            "front": jax.tree_util.tree_unflatten(structure,
                                                  spec.init["front"]),
            "delta": jax.tree_util.tree_unflatten(structure,
                                                  spec.init["delta"]),
            "pending": spec.init["pending"],
            "epoch": spec.init["epoch"],
            "n_edges": spec.init["n_edges"],
        })
        tenant.offset = int(spec.init["offset"])
        reservoir = None
        if spec.reservoir is not None:
            reservoir = Reservoir(int(spec.reservoir["k"]))
            reservoir.load_state_dict(spec.reservoir["state"])
        publish_delta = getattr(spec, "publish_mode", "delta") == "delta"
        if publish_delta:
            tenant.buffer.capture_publish_delta = True
        # The first publish after (re)build MUST ship full leaves: the warm
        # publish below bumps an epoch the parent never adopts, a restored
        # checkpoint's front predates this session, and a redialed parent
        # opens a fresh session — in every case the parent's front epoch
        # cannot anchor a delta.  A "resync" frame re-arms this.
        force_full = threading.Event()
        force_full.set()
        if spec.warm_shapes:
            _warm_child_shapes(tenant)

        # deliberately small (just enough backlog for coalescing to engage):
        # the PARENT queue is the system's one backpressure point, and a
        # child-side buffer as large as the parent's would double the
        # effective lag bound an operator tuned queue_capacity for
        local_queue = BoundedEdgeQueue(
            min(spec.queue_capacity, max(8, spec.coalesce_batches)))
        worker = IngestWorker(
            tenant, local_queue, make_policy(spec.policy),
            reservoir=reservoir, checkpoint_dir=spec.checkpoint_dir,
            checkpoint_every=spec.checkpoint_every, poll_s=spec.poll_s,
            coalesce_batches=spec.coalesce_batches,
            coalesce_target=spec.coalesce_target,
            dedup=getattr(spec, "dedup", False))

        def ship(snap):  # runs in the worker thread, post-publish
            payload = {
                "epoch": snap.epoch,
                "n_edges": snap.n_edges,
                "next_offset": worker._ingested_offset + 1,
                "reservoir": (reservoir.state_dict()
                              if reservoir is not None else None),
                "metrics": worker.metrics_snapshot(),
                # telemetry rides the beat it already has: cumulative hub
                # state (parent adopts = replace-then-sum) + drained spans
                "obs": {"hub": get_hub().state(),
                        "trace": get_trace_log().drain()},
            }
            delta = (tenant.buffer.last_publish_delta
                     if publish_delta else None)
            if delta is not None and not force_full.is_set():
                # ship only what this epoch folded in; the parent merges it
                # into its front via the same jitted kernel (bit-exact) —
                # counters/reservoir/cursor still ride every publish
                payload["mode"] = "delta"
                payload["base_epoch"] = snap.epoch - 1
                payload["leaves"] = wire.encode_leaves(
                    _tree_leaves_np(delta))
            else:
                force_full.clear()
                payload["mode"] = "full"
                payload["leaves"] = _tree_leaves_np(snap.sketch)
            send(("publish", payload))

        worker.on_publish = ship
        worker.start()
        send(("ready", {"pid": os.getpid(), "offset": tenant.offset,
                        "epoch": tenant.epoch}))

        last_beat = time.monotonic()
        while True:
            if worker.state == FAILED:
                send(("failed", repr(worker.error),
                      worker.error_tb or "", worker.metrics_snapshot()))
                return "failed"
            msg = recv(0.1)
            now = time.monotonic()
            if now - last_beat >= 0.25:
                send(("metrics", worker.metrics_snapshot(),
                      {"hub": get_hub().state(),
                       "trace": get_trace_log().drain()}))
                last_beat = now
            if msg is None:
                continue
            kind = msg[0]
            if kind == "item":
                # v2 frames append trace_id; *rest keeps v1-shaped tuples
                # (e.g. replayed captures) parseable rather than a crash
                _, offset, src, dst, weight, n_edges, *rest = msg
                item = QueueItem(offset, src, dst, weight, n_edges,
                                 trace_id=rest[0] if rest else "")
                while not local_queue.put(item, timeout=0.2):
                    if worker.state == FAILED:
                        break  # surfaced at the top of the loop
            elif kind == "checkpoint":
                try:
                    send(("checkpointed", {"path": worker.checkpoint()}))
                except BaseException as exc:  # keep serving; caller decides
                    send(("checkpointed", {"error": repr(exc)}))
            elif kind == "stop":
                worker.request_stop(drain=bool(msg[1]))
                worker.join()
                if worker.state == FAILED:
                    send(("failed", repr(worker.error),
                          worker.error_tb or "",
                          worker.metrics_snapshot()))
                    return "failed"
                send(("stopped", worker.metrics_snapshot(),
                      {"hub": get_hub().state(),
                       "trace": get_trace_log().drain()}))
                return "stopped"
            elif kind == "ping":
                send(("pong",))
            elif kind == "resync":
                # the parent could not anchor our last delta (ack gap,
                # restart, redial): the NEXT publish ships full leaves —
                # they carry cumulative state, so nothing is lost
                force_full.set()
            else:
                raise ValueError(f"unknown transport message {kind!r}")
    except BaseException as exc:
        import traceback

        try:
            send(("failed", repr(exc), traceback.format_exc(),
                  worker.metrics_snapshot() if worker is not None else None))
        except BaseException:
            pass  # the transport itself is dead; nobody left to tell
        return "failed"
    finally:
        if worker is not None and worker.state in (RUNNING, DRAINING):
            # hard-stop semantics, same as a SIGKILLed process child: the
            # parent re-offers unacknowledged work on restore
            worker.request_stop(drain=False)
            worker.join(timeout=30.0)


def _child_main(spec: _ChildSpec, in_q, out_q) -> None:
    """Entry point of a process-backend worker child (spawn-safe: top-level
    function, rebuilds everything from the picklable spec).  Thin transport
    shim: frames every message with the shared wire codec so the process
    pipe and the socket transport speak byte-identical payloads."""
    # the parent orchestrates graceful drains; a terminal Ctrl-C must not
    # kill children mid-drain before the parent can flush checkpoints
    signal.signal(signal.SIGINT, signal.SIG_IGN)

    def recv(timeout_s: float):
        try:
            raw = in_q.get(timeout=timeout_s)
        except queue_mod.Empty:
            return None
        return wire.decode_message(raw)

    def send(msg) -> None:
        out_q.put(wire.encode_message(msg))

    if run_ingest_worker(spec, recv, send) != "stopped":
        sys.exit(1)


def _absorb_worker_obs(h, obs: dict | None, epoch: int | None = None) -> None:
    """Fold a remote worker's shipped telemetry into the parent: adopt its
    cumulative hub state (replace-then-sum keyed by tenant, so later beats
    supersede earlier ones) and absorb its drained span events.  A span the
    child marked ``publish`` becomes visible parent-side now — close the
    chain with an ``adopt`` event carrying the adopted epoch."""
    if not obs:
        return
    tid = h.tenant.key.tenant_id
    if obs.get("hub"):
        get_hub().adopt(f"worker:{tid}", obs["hub"])
    events = obs.get("trace") or []
    log = get_trace_log()
    log.absorb(events)
    if epoch is not None:
        for ev in events:
            if ev.get("event") == "publish" and ev.get("epoch") == epoch:
                log.emit(ev["trace"], "ingest", "adopt", epoch=epoch,
                         tenant=tid)


def dispatch_parent_message(h, msg) -> None:
    """Parent-side dispatch of one worker→parent message, shared by every
    remote transport (``ProcessWorker`` and ``repro.net``'s
    ``SocketWorker``).  ``h`` is the worker handle; this is where remote
    publishes become parent state via ``SnapshotBuffer.adopt_published``,
    so epoch ordering stays single-sourced no matter the transport."""
    import jax
    import jax.numpy as jnp

    kind = msg[0]
    if kind == "ready":
        h._ready.set()
    elif kind == "metrics":
        h._last_metrics = msg[1]
        if len(msg) > 2:
            _absorb_worker_obs(h, msg[2])
    elif kind == "publish":
        from repro.serving.snapshot import StaleDelta

        payload = msg[1]
        if payload.get("mode") == "delta":
            delta = jax.tree_util.tree_unflatten(
                h._treedef,
                [jnp.asarray(x)
                 for x in wire.decode_leaves(payload["leaves"])])
            try:
                snap = h.tenant.buffer.adopt_published(
                    None, payload["epoch"], payload["n_edges"],
                    delta=delta, base_epoch=payload["base_epoch"])
            except StaleDelta:
                # skip this publish entirely — cursor, metrics and
                # reservoir stay at the last adopted epoch so drop/replay
                # accounting can't run ahead of adopted state; the worker's
                # next publish ships cumulative full leaves and catches the
                # parent up in one step
                h.send_control(("resync",))
                return
        else:
            sketch = jax.tree_util.tree_unflatten(
                h._treedef, [jnp.asarray(x) for x in payload["leaves"]])
            snap = h.tenant.buffer.adopt_published(
                sketch, payload["epoch"], payload["n_edges"])
        h._ingested_offset = payload["next_offset"] - 1
        h.tenant.offset = payload["next_offset"]
        h._last_metrics = payload["metrics"]
        if h.reservoir is not None and payload["reservoir"] is not None:
            h.reservoir.load_state_dict(payload["reservoir"])
        _absorb_worker_obs(h, payload.get("obs"), epoch=payload["epoch"])
        note = getattr(h, "_note_publish_adopted", None)
        if note is not None:  # socket redial bookkeeping (net/backend.py)
            note(int(payload["n_edges"]))
        if h.on_publish is not None:
            h.on_publish(snap)
    elif kind == "checkpointed":
        h._ckpt_result = msg[1]
        h._ckpt_event.set()
    elif kind == "stopped":
        h._last_metrics = msg[1]
        if len(msg) > 2:
            _absorb_worker_obs(h, msg[2])
        h.state = STOPPED
        h._ready.set()
        h._ckpt_event.set()
        h._done.set()
    elif kind == "failed":
        _, err, tb, metrics = msg
        h.error = RuntimeError(err)
        h.error_tb = tb
        if metrics:
            h._last_metrics = metrics
        h.state = FAILED
        h._ready.set()
        h._ckpt_event.set()
        h._done.set()
    elif kind == "pong":
        pass  # liveness ack; receipt alone resets the peer's idle clock
    else:
        raise ValueError(f"unexpected worker→parent message {kind!r}")


class ProcessWorker:
    """Parent-side handle for one ingest worker living in a spawn child.

    Quacks like ``IngestWorker`` for everything the supervisor touches.
    Three parent threads cooperate: the *forwarder* moves ``QueueItem``s
    from the parent's bounded queue into the child's item pipe (held until
    the child's ready handshake so readiness is observable), the *receiver*
    adopts published epochs into the parent ``SnapshotBuffer`` and mirrors
    child metrics/health, and the caller's thread drives lifecycle.
    """

    def __init__(self, tenant, queue: BoundedEdgeQueue, policy, *,
                 reservoir=None, checkpoint_dir=None, checkpoint_every=0,
                 on_publish=None, poll_s=0.05, coalesce_batches=1,
                 coalesce_target=8192, queue_capacity=64,
                 warm_shapes=True, child_env=None, ctx=None,
                 publish_mode="delta", dedup=False) -> None:
        import jax

        self.tenant = tenant
        self.queue = queue
        self.on_publish = on_publish
        # kept live: each publish handoff loads the child's shipped
        # reservoir state back into this object, so parent-side observers
        # see the same online sample a thread worker would expose
        self.reservoir = reservoir
        self.state = CREATED
        self.error: BaseException | None = None
        self.error_tb: str | None = None
        self.base_edges = (tenant.snapshot.n_edges
                          + tenant.buffer.pending_edges)
        self.poll_s = poll_s
        self._treedef = jax.tree_util.tree_structure(tenant.snapshot.sketch)
        spec = build_child_spec(
            tenant, policy, reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            poll_s=poll_s, coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=warm_shapes, env=child_env,
            publish_mode=publish_mode, dedup=dedup)
        ctx = ctx or multiprocessing.get_context("spawn")
        # small transit pipe: backpressure cascades child -> pipe ->
        # parent queue -> pump, so the parent queue's policy stays the
        # single source of drop/spill accounting
        self._in_q = ctx.Queue(maxsize=8)
        self._out_q = ctx.Queue()
        self.process = ctx.Process(
            target=_child_main, args=(spec, self._in_q, self._out_q),
            daemon=True, name=f"ingest-proc-{tenant.key.tenant_id}")
        self._ingested_offset = tenant.offset - 1
        self._last_metrics: dict | None = None
        self._fallback_metrics = WorkerMetrics()
        self._ready = threading.Event()
        self._spawned = threading.Event()
        self._done = threading.Event()
        self._stop_event = threading.Event()
        self._drain = True
        self._hard_stop = False
        self._started = False
        self._ckpt_lock = threading.Lock()
        self._ckpt_event = threading.Event()
        self._ckpt_result: dict | None = None
        self._forwarder: threading.Thread | None = None
        self._receiver: threading.Thread | None = None

    # -------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Non-blocking: spawning happens in a starter thread.

        ``Process.start()`` blocks until the child boots far enough to
        drain the (sketch-sized, pipe-buffer-exceeding) spawn spec, so a
        serial loop over K workers would serialize K child boots; the
        starter thread lets ``Runtime.start()`` launch all children
        concurrently.
        """
        self._started = True
        self.state = RUNNING
        threading.Thread(target=self._spawn_and_attach, daemon=True,
                         name=f"{self.process.name}-spawn").start()

    def _spawn_and_attach(self) -> None:
        try:
            self.process.start()
        except BaseException as exc:
            import traceback

            self.error = exc
            self.error_tb = traceback.format_exc()
            self.state = FAILED
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()
            return
        self._spawned.set()
        if self._hard_stop:  # killed while still booting
            self.process.terminate()
            self.state = STOPPED
            self._done.set()
            return
        self._forwarder = threading.Thread(
            target=self._forward_loop, daemon=True,
            name=f"{self.process.name}-fwd")
        self._receiver = threading.Thread(
            target=self._receive_loop, daemon=True,
            name=f"{self.process.name}-rcv")
        self._receiver.start()
        self._forwarder.start()

    def wait_ready(self, timeout: float = 300.0) -> bool:
        """Block until the child built its tenant (and warmed shapes)."""
        ok = self._ready.wait(timeout)
        if self.state == FAILED:
            raise RuntimeError(
                f"worker process for {self.tenant.key.tenant_id} failed "
                f"during startup: {self.error}\n{self.error_tb or ''}")
        return ok

    def request_stop(self, drain: bool = True) -> None:
        self._drain = drain
        self._stop_event.set()
        if drain:
            if self.state == RUNNING:
                self.state = DRAINING
        else:
            # crash-like hard stop, same contract as IngestWorker: in-queue
            # and in-flight work is abandoned exactly as SIGKILL would
            self._hard_stop = True
            self.queue.close()
            if self._spawned.is_set() and self.process.is_alive():
                self.process.terminate()
            elif not self._spawned.is_set():
                # still booting: the starter thread owns the handoff; mark
                # done so join() doesn't wait on a child we'll never use
                self._done.set()

    def join(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout

        def remaining(default=None):
            if deadline is None:
                return default
            return max(deadline - time.monotonic(), 0.01)

        self._done.wait(timeout=remaining())
        if self._spawned.is_set():
            self.process.join(timeout=remaining(60.0))

    def is_alive(self) -> bool:
        if not self._started:
            return False
        if not self._spawned.is_set():
            return not self._done.is_set()  # still booting (or spawn failed)
        return self.process.is_alive() or not self._done.is_set()

    # -------------------------------------------------------------- transport
    def _forward_loop(self) -> None:
        while not self._ready.wait(timeout=0.1):
            if self._done.is_set() or self._hard_stop:
                return
        while True:
            if self._done.is_set() or self._hard_stop:
                return
            item = self.queue.get(timeout=self.poll_s)
            if item is None:
                if (self._stop_event.is_set() and self._drain
                        and self.queue.depth() == 0):
                    break
                continue
            # columnar fast path: raw buffer views, no pickle (v3 frames)
            msg = wire.encode_item_frame(item)
            placed = False
            while not placed:
                try:
                    self._in_q.put(msg, timeout=0.2)
                    placed = True
                except queue_mod.Full:
                    if self._done.is_set() or self._hard_stop:
                        return
        # parent queue drained: hand the child its graceful-stop sentinel
        # (retry while the transit pipe is full — the child is still
        # working through the backlog; give up only on terminal states,
        # which the receiver surfaces)
        while not (self._done.is_set() or self._hard_stop):
            try:
                self._in_q.put(wire.encode_message(("stop", True)),
                               timeout=0.5)
                return
            except queue_mod.Full:
                continue

    def _receive_loop(self) -> None:
        while True:
            try:
                msg = self._out_q.get(timeout=0.2)
            except queue_mod.Empty:
                if not self.process.is_alive():
                    # the pipe may still hold messages the child flushed
                    # before dying — adopt them before declaring death
                    while True:
                        try:
                            tail = self._out_q.get(timeout=0.2)
                        except (queue_mod.Empty, EOFError, OSError):
                            break
                        if not self._handle_guarded(tail):
                            return
                        if self._done.is_set():
                            return
                    self._finalize_death()
                    return
                continue
            except (EOFError, OSError):
                self._finalize_death()
                return
            if not self._handle_guarded(msg):
                return
            if self._done.is_set():
                return

    def _handle_guarded(self, raw) -> bool:
        """Decode and dispatch one framed child message; on a parent-side
        failure (an on_publish callback raising, a torn/mismatched frame
        surfacing as ``WireError``) mark the handle failed, take the child
        down with us (it knows nothing and would keep ingesting until its
        result pipe wedged), and finalize — the receiver must NEVER die
        without setting ``_done``, or ``join()`` would hang for its full
        timeout with the failure swallowed.
        Returns False when the receiver should exit."""
        try:
            dispatch_parent_message(self, wire.decode_message(raw))
            return True
        except BaseException as exc:
            import traceback

            self.error = exc
            self.error_tb = traceback.format_exc()
            self.state = FAILED
            if self.process.is_alive():
                self.process.terminate()
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()
            return False

    def _finalize_death(self) -> None:
        """The child exited without a terminal message."""
        if self._done.is_set():
            return
        if self._hard_stop:
            self.state = STOPPED
        else:
            code = self.process.exitcode
            self.error = RuntimeError(
                f"worker process for {self.tenant.key.tenant_id} exited "
                f"unexpectedly (exitcode={code})")
            self.error_tb = None
            self.state = FAILED
        self._ready.set()
        self._ckpt_event.set()
        self._done.set()

    def send_control(self, msg) -> None:
        """Ship a parent→child control frame out-of-band of the item stream
        (used by the adopt path to request a full-leaves resync)."""
        self._in_q.put(wire.encode_message(msg), timeout=60.0)

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self, timeout: float = 300.0) -> str:
        """Ask the child for a synchronous checkpoint; returns its path."""
        with self._ckpt_lock:
            if self._done.is_set() or not self._spawned.is_set() \
                    or not self.process.is_alive():
                raise RuntimeError(
                    f"worker process for {self.tenant.key.tenant_id} is not "
                    "running; cannot checkpoint")
            self._ckpt_event.clear()
            self._ckpt_result = None
            self._in_q.put(wire.encode_message(("checkpoint",)), timeout=60.0)
            if not self._ckpt_event.wait(timeout):
                raise TimeoutError("child did not acknowledge checkpoint")
            res = self._ckpt_result
        if res is None:  # terminal state raced the request
            raise RuntimeError(
                f"worker process for {self.tenant.key.tenant_id} stopped "
                f"before checkpointing (state={self.state})")
        if "error" in res:
            raise RuntimeError(f"child checkpoint failed: {res['error']}")
        return res["path"]

    # ---------------------------------------------------------------- reports
    @property
    def ingested_edges(self) -> int:
        return int((self._last_metrics or {}).get("ingested_edges", 0))

    def health(self) -> dict:
        return {
            "state": self.state,
            "alive": self.is_alive(),
            "error": repr(self.error) if self.error else None,
            "epoch": self.tenant.epoch,
            "ingested_offset": self._ingested_offset,
            "queue_depth": self.queue.depth(),
            "pid": self.process.pid if self._spawned.is_set() else None,
        }

    def metrics_snapshot(self) -> dict:
        qstats = self.queue.stats()
        if self._last_metrics is None:
            m = self._fallback_metrics.snapshot(
                queue_stats=qstats, state=self.state,
                epoch=self.tenant.epoch)
            child_depth = 0
        else:
            m = dict(self._last_metrics)
            child_depth = int(m.get("queue_depth", 0))
        # queue accounting is parent-authoritative (drops/spills happen in
        # the parent queue only); depth adds batches already in the child
        m["state"] = self.state
        m["epoch"] = self.tenant.epoch
        m["queue_depth"] = qstats["depth"] + child_depth
        m["ingest_lag_batches"] = m["queue_depth"]
        m["dropped_batches"] = qstats["dropped_batches"]
        m["dropped_edges"] = qstats["dropped_edges"]
        m["spilled_batches"] = qstats["spilled_batches"]
        m["max_queue_depth"] = qstats["max_depth_seen"]
        m["pid"] = self.process.pid if self._spawned.is_set() else None
        return m


class ProcessBackend(ExecutionBackend):
    """Spawn-safe multiprocessing children owning their sketches."""

    name = "process"
    remote = True

    def __init__(self, *, warm_shapes: bool = True,
                 child_env: dict | None = None,
                 mp_context: str = "spawn",
                 publish_mode: str = "delta") -> None:
        # spawn, never fork: the parent holds a live XLA runtime and worker
        # threads; forking either is undefined behaviour
        self._ctx = multiprocessing.get_context(mp_context)
        self.warm_shapes = warm_shapes
        # applied in each child BEFORE jax initializes: pin children off a
        # shared accelerator (JAX_PLATFORMS=cpu on a TPU host) or cap their
        # XLA host thread pools under core oversubscription
        self.child_env = dict(child_env or {})
        # "delta" ships per-epoch sketch deltas (sparse-encoded); "full"
        # ships whole fronts — kept selectable for the A/B bench column
        self.publish_mode = publish_mode

    def make_worker(self, tenant, queue, policy, *, reservoir=None,
                    checkpoint_dir=None, checkpoint_every=0, on_publish=None,
                    poll_s=0.05, coalesce_batches=1, coalesce_target=8192,
                    queue_capacity=64, dedup=False):
        return ProcessWorker(
            tenant, queue, policy, reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            on_publish=on_publish, poll_s=poll_s,
            coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=self.warm_shapes, child_env=self.child_env,
            ctx=self._ctx, publish_mode=self.publish_mode, dedup=dedup)
