"""Per-tenant background ingest worker (DESIGN.md §Runtime).

# analysis: hot-path — the per-batch ingest loop; the no-pickle-hot-path
# rule keeps serialization out of this module (checkpoints go through
# repro.checkpoint.store, never inline pickle).

One ``IngestWorker`` thread owns one tenant's write path end to end: it
pulls ``QueueItem``s from the tenant's bounded queue, folds them into the
registry's delta sketch (``SnapshotBuffer.ingest``), feeds the tenant's
online reservoir sample, publishes epochs when its ``PublishPolicy`` says
so, and writes crash-safe checkpoints through ``repro.checkpoint.store``.

Single-writer discipline: everything the worker mutates (delta buffer,
stream offset, reservoir, metrics) is touched by this thread only, EXCEPT
checkpoint capture, which any thread may request — ``_state_lock`` makes
the (buffer state, ingested offset, reservoir) triple mutually consistent
for that one reader.  Queries never take any of these locks: they read the
published snapshot reference, which is immutable.

Worker lifecycle::

    CREATED --start()--> RUNNING --request_stop(drain=True)--> DRAINING
        RUNNING/DRAINING --queue empty--> STOPPED   (final publish + ckpt)
        RUNNING --request_stop(drain=False)--> STOPPED  (crash-like: no
                final publish, no final checkpoint — restore must replay)
        any ----unhandled exception----> FAILED     (error kept for health())
"""
from __future__ import annotations

import threading
import time
import traceback

import numpy as np

from repro.checkpoint import store
from repro.core.types import EdgeBatch
from repro.obs.profile import profile_span
from repro.obs.trace import get_trace_log
from repro.runtime.metrics import WorkerMetrics
from repro.runtime.policies import PublishPolicy
from repro.runtime.queueing import BoundedEdgeQueue, QueueItem
from repro.streams.reservoir import Reservoir

CREATED = "created"
RUNNING = "running"
DRAINING = "draining"
STOPPED = "stopped"
FAILED = "failed"


class IngestWorker(threading.Thread):
    def __init__(self, tenant, queue: BoundedEdgeQueue,
                 policy: PublishPolicy, *,
                 reservoir: Reservoir | None = None,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 0,
                 on_publish=None,
                 poll_s: float = 0.05,
                 coalesce_batches: int = 1,
                 coalesce_target: int = 8192) -> None:
        super().__init__(name=f"ingest-{tenant.key.tenant_id}", daemon=True)
        self.tenant = tenant
        self.queue = queue
        self.policy = policy
        self.reservoir = reservoir
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.on_publish = on_publish
        self.poll_s = poll_s
        # Ingest coalescing: under backlog, fold up to ``coalesce_batches``
        # queued items (or ~``coalesce_target`` edges) into ONE device
        # dispatch.  The per-dispatch fixed cost (pool copy + driver) is
        # independent of batch size, so many small batches — the sharded
        # regime, where each shard sees ~B/K edges per stream batch — pay
        # it K-fold; coalescing restores dispatch-count parity with the
        # unsharded path.  1 (the default) preserves item-at-a-time
        # behaviour exactly.
        self.coalesce_batches = max(1, coalesce_batches)
        self.coalesce_target = coalesce_target
        # Dispatch-size byte cap: 3 int32 output columns ⇒ 12 bytes/edge.
        # A deep backlog (spill drain, drop_oldest churn) must not build an
        # unbounded coalesced batch; an item that would push the group past
        # the cap is HELD and leads the next group instead.
        self._coalesce_byte_cap = 12 * max(1, coalesce_target)
        self._held: QueueItem | None = None
        self.metrics = WorkerMetrics()
        self.metrics.bind_hub(tenant.key.tenant_id)
        self._trace = get_trace_log()
        # trace IDs ingested since the last publish; the publish event
        # closes them all with the epoch they became visible in (bounded:
        # a pathological publish policy must not grow this without limit)
        self._pending_traces: list[str] = []
        self.state = CREATED
        self.error: BaseException | None = None
        self.error_tb: str | None = None  # formatted traceback, for callers
        #                                   in other processes/threads that
        #                                   cannot reach error.__traceback__
        self._stop_event = threading.Event()
        self._drain = True
        self._state_lock = threading.Lock()
        self._ingested_offset = tenant.offset - 1  # last batch folded in
        self._batches_since_checkpoint = 0
        # conservation baseline: edges already in the tenant (published +
        # pending delta) before this worker touched it
        self.base_edges = (tenant.snapshot.n_edges
                          + tenant.buffer.pending_edges)

    # -------------------------------------------------------------- lifecycle
    def request_stop(self, drain: bool = True) -> None:
        """Ask the worker to exit.  ``drain=True`` consumes the queue, takes
        a final publish (and checkpoint, if configured), then stops.
        ``drain=False`` is a crash-like hard stop: in-queue and in-delta
        work is abandoned exactly as a SIGKILL would abandon it."""
        self._drain = drain
        self._stop_event.set()
        if not drain:
            self.queue.close()

    def run(self) -> None:  # thread body
        self.state = RUNNING
        self.metrics.note_started(time.monotonic())
        try:
            while True:
                item = self._held
                if item is not None:
                    self._held = None  # byte-cap holdover leads this group
                else:
                    item = self.queue.get(timeout=self.poll_s)
                now = time.monotonic()
                if item is None:
                    if self._stop_event.is_set():
                        if not self._drain or self.queue.depth() == 0:
                            break
                        self.state = DRAINING
                        continue
                    # idle tick: wall-clock policies may still want to
                    # surface a lingering delta as a fresh epoch
                    if self._should_publish(now):
                        self._publish()
                    continue
                if self._stop_event.is_set() and not self._drain:
                    break  # hard stop: abandon the item, like a crash would
                if self._stop_event.is_set():
                    self.state = DRAINING
                items = [item]
                total = item.src.shape[0]
                while (len(items) < self.coalesce_batches
                       and total < self.coalesce_target):
                    nxt = self.queue.get(timeout=0)  # opportunistic, no wait
                    if nxt is None:
                        break
                    if 12 * (total + nxt.src.shape[0]) \
                            > self._coalesce_byte_cap:
                        self._held = nxt  # caps the dispatch; never dropped
                        break
                    items.append(nxt)
                    total += nxt.src.shape[0]
                if len(items) == 1:
                    self._ingest(item, now)
                else:
                    self._ingest_coalesced(items, now)
                if self._should_publish(time.monotonic()):
                    self._publish()
                if (self.checkpoint_dir and self.checkpoint_every
                        and self._batches_since_checkpoint
                        >= self.checkpoint_every):
                    self.checkpoint()
            if self._drain:
                # graceful exit: surface everything ingested, then persist.
                # Gate on the buffer's actual pending count, not just this
                # run's batch counter: a restored checkpoint can carry a
                # non-empty delta even when no new batch arrived (stream
                # already exhausted), and it must still reach an epoch.
                if (self.metrics.pending_batches()
                        or self.tenant.buffer.pending_edges):
                    self._publish()
                if self.checkpoint_dir:
                    self.checkpoint()
            self.state = STOPPED
        except BaseException as exc:
            # don't re-raise: a dying thread would only reach
            # threading.excepthook; the supervisor reads state/error instead
            # (and Runtime.stop() re-raises it to drain callers)
            self.error = exc
            self.error_tb = "".join(traceback.format_exception(
                type(exc), exc, exc.__traceback__))
            self.state = FAILED

    # ----------------------------------------------------------------- ingest
    def _note_dispatch(self, item: QueueItem) -> None:
        if not item.trace_id:
            return
        self._trace.emit(item.trace_id, "ingest", "dispatch",
                         offset=item.offset, n_edges=item.n_edges,
                         tenant=self.tenant.key.tenant_id)
        if len(self._pending_traces) < 256:
            self._pending_traces.append(item.trace_id)

    def _ingest(self, item: QueueItem, now: float) -> None:
        batch = EdgeBatch.from_numpy(item.src, item.dst, item.weight)
        self._note_dispatch(item)
        with self._state_lock:
            with profile_span("ingest"):
                self.tenant.buffer.ingest(batch)
            if self.reservoir is not None:
                self.reservoir.offer_batch(item.src, item.dst, item.weight)
            if item.offset >= 0:
                # externally submitted batches carry offset -1: they are not
                # part of the seekable stream, so they must not move the
                # stream cursor (checkpoint replay would double-count)
                self._ingested_offset = item.offset
                self.tenant.offset = item.offset + 1
        self.metrics.note_ingest(item.n_edges, now)
        self._batches_since_checkpoint += 1

    def _ingest_coalesced(self, items: list[QueueItem], now: float) -> None:
        """Fold several queued items into ONE buffer ingest dispatch.

        Exactness is unaffected: sketch deltas are additive and order-free,
        the reservoir still sees items in FIFO order, and the whole group
        lands in the delta atomically under the state lock, so the offset
        cursor can jump straight to the newest seekable batch (FIFO ⇒ the
        last item is the newest) without ever describing a state the
        counters do not hold.  Padded to a coarse ladder
        (``coalesce_target/4`` granule) so coalesced shapes stay few.
        """
        n = sum(it.src.shape[0] for it in items)
        granule = max(256, self.coalesce_target // 4)
        bucket = max(granule, -(-n // granule) * granule)
        # one pre-sized int32 buffer per column, filled by slicing: the
        # old concatenate → pad → cast chain copied every column three
        # times; here the slice assignment does the cast AND the copy,
        # and the zero tail IS the weight-0 padding pad_to produced
        src = np.zeros(bucket, np.int32)
        dst = np.zeros(bucket, np.int32)
        weight = np.zeros(bucket, np.int32)
        pos = 0
        for it in items:
            end = pos + it.src.shape[0]
            src[pos:end] = it.src
            dst[pos:end] = it.dst
            weight[pos:end] = it.weight
            pos = end
        batch = EdgeBatch.from_numpy(src, dst, weight)
        for it in items:
            self._note_dispatch(it)
        with self._state_lock:
            with profile_span("ingest"):
                self.tenant.buffer.ingest(batch)
            if self.reservoir is not None:
                for it in items:
                    self.reservoir.offer_batch(it.src, it.dst, it.weight)
            offsets = [it.offset for it in items if it.offset >= 0]
            if offsets:
                self._ingested_offset = offsets[-1]
                self.tenant.offset = offsets[-1] + 1
        for it in items:
            self.metrics.note_ingest(it.n_edges, now)
        self._batches_since_checkpoint += len(items)

    def _should_publish(self, now: float) -> bool:
        return self.policy.should_publish(
            batches_since_publish=self.metrics.pending_batches(),
            now=now, queue_depth=self.queue.depth())

    def _publish(self):
        t0 = time.monotonic()
        snap = self.tenant.publish()
        now = time.monotonic()
        self.metrics.note_publish(now - t0, now)
        self.policy.note_published(now)
        for tid in self._pending_traces:
            self._trace.emit(tid, "ingest", "publish", epoch=snap.epoch,
                             tenant=self.tenant.key.tenant_id)
        self._pending_traces.clear()
        if self.on_publish is not None:
            self.on_publish(snap)
        return snap

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self) -> str:
        """Write a crash-safe checkpoint of the tenant's full ingest state.

        Callable from any thread.  Captures (front, delta, pending,
        reservoir, next stream offset) as ONE consistent cut under
        ``_state_lock`` — JAX arrays are immutable, so serialization happens
        outside the lock; the reservoir is copied out inside it.
        """
        if not self.checkpoint_dir:
            raise ValueError("worker has no checkpoint_dir configured")
        with self._state_lock:
            buf = self.tenant.buffer.state()
            next_offset = self._ingested_offset + 1
            res = (self.reservoir.state_dict()
                   if self.reservoir is not None else None)
        state = {"front": buf["front"], "delta": buf["delta"],
                 "pending": buf["pending"]}
        extra = {
            "tenant_id": self.tenant.key.tenant_id,
            "epoch": buf["epoch"],
            "n_edges": buf["n_edges"],
            "next_offset": next_offset,
        }
        if res is not None:
            state["reservoir"] = {"src": res["src"], "dst": res["dst"],
                                  "w": res["w"]}
            extra["reservoir"] = {"k": res["k"], "seen": res["seen"],
                                  "rng_state": res["rng_state"]}
        path = store.save(self.checkpoint_dir, next_offset, state, extra=extra)
        self._batches_since_checkpoint = 0
        self.metrics.note_checkpoint(time.monotonic())
        return path

    # ---------------------------------------------------------------- reports
    @property
    def ingested_edges(self) -> int:
        """Backend-neutral accessor (runtime/backend.py contract): total
        non-padding edges this worker has folded into the delta."""
        return self.metrics.total_edges()

    def wait_ready(self, timeout: float = 0.0) -> bool:
        """Backend-neutral readiness barrier: a thread worker shares the
        parent's address space and compiled kernels, so it is ready the
        moment it exists.  (The process backend overrides this with a real
        wait on the child's ready handshake.)"""
        return True

    def health(self) -> dict:
        return {
            "state": self.state,
            "alive": self.is_alive(),
            "error": repr(self.error) if self.error else None,
            "epoch": self.tenant.epoch,
            "ingested_offset": self._ingested_offset,
            "queue_depth": self.queue.depth(),
        }

    def metrics_snapshot(self) -> dict:
        return self.metrics.snapshot(
            queue_stats=self.queue.stats(),
            state=self.state,
            epoch=self.tenant.epoch,
            overflow_edges=getattr(self.tenant.buffer, "overflow_edges", 0))


def restore_worker_state(tenant, checkpoint_dir: str,
                         reservoir: Reservoir | None = None,
                         step: int | None = None) -> dict:
    """Load the latest (or ``step``) checkpoint back into a *fresh* tenant.

    The tenant must come from an identically-configured registry (same key,
    depth, batch size, scale): the checkpoint stores counter state, not
    layout, and ``store.restore`` asserts shape agreement leaf by leaf.
    Returns the checkpoint metadata; after this call a worker/pump pair
    resumes from ``tenant.offset`` and reproduces a never-crashed run
    bit-exactly (streams are seekable, counters additive).
    """
    # identity check BEFORE touching arrays: a foreign tenant's checkpoint
    # must fail loudly on identity, not incidentally on layout shapes
    probe = store.read_meta(checkpoint_dir, step=step)["extra"]
    if probe.get("tenant_id") != tenant.key.tenant_id:
        raise ValueError(
            f"checkpoint belongs to tenant {probe.get('tenant_id')!r}, "
            f"not {tenant.key.tenant_id!r}")
    buf = tenant.buffer.state()
    template = {"front": buf["front"], "delta": buf["delta"],
                "pending": buf["pending"]}
    if reservoir is not None:
        template["reservoir"] = {"src": reservoir._src, "dst": reservoir._dst,
                                 "w": reservoir._w}
    state, meta = store.restore(checkpoint_dir, template, step=step)
    extra = meta["extra"]
    tenant.buffer.load_state({
        "front": state["front"], "delta": state["delta"],
        "pending": state["pending"], "epoch": extra["epoch"],
        "n_edges": extra["n_edges"],
    })
    tenant.offset = int(extra["next_offset"])
    if reservoir is not None:
        if "reservoir" not in state:
            raise ValueError("checkpoint has no reservoir state")
        res_extra = extra["reservoir"]
        reservoir.load_state_dict({
            "k": res_extra["k"], "seen": res_extra["seen"],
            "rng_state": res_extra["rng_state"],
            "src": state["reservoir"]["src"],
            "dst": state["reservoir"]["dst"],
            "w": state["reservoir"]["w"],
        })
    return meta
