"""Per-tenant background ingest worker (DESIGN.md §Runtime).

# analysis: hot-path — the per-batch ingest loop; the no-pickle-hot-path
# rule keeps serialization out of this module (checkpoints go through
# repro.checkpoint.store, never inline pickle).

One ``IngestWorker`` thread owns one tenant's write path end to end: it
pulls ``QueueItem``s from the tenant's bounded queue, folds them into the
registry's delta sketch (``SnapshotBuffer.ingest``), feeds the tenant's
online reservoir sample, publishes epochs when its ``PublishPolicy`` says
so, and writes crash-safe checkpoints through ``repro.checkpoint.store``.

Single-writer discipline: everything the worker mutates (delta buffer,
stream offset, reservoir, metrics) is touched by this thread only, EXCEPT
checkpoint capture, which any thread may request — ``_state_lock`` makes
the (buffer state, ingested offset, reservoir) triple mutually consistent
for that one reader.  Queries never take any of these locks: they read the
published snapshot reference, which is immutable.

Worker lifecycle::

    CREATED --start()--> RUNNING --request_stop(drain=True)--> DRAINING
        RUNNING/DRAINING --queue empty--> STOPPED   (final publish + ckpt)
        RUNNING --request_stop(drain=False)--> STOPPED  (crash-like: no
                final publish, no final checkpoint — restore must replay)
        any ----unhandled exception----> FAILED     (error kept for health())
"""
from __future__ import annotations

import threading
import time
import traceback

import jax
import numpy as np

from repro.checkpoint import store
from repro.core.types import EdgeBatch
from repro.obs.profile import profile_span
from repro.obs.trace import get_trace_log
from repro.runtime.metrics import WorkerMetrics
from repro.runtime.policies import PublishPolicy
from repro.runtime.queueing import BoundedEdgeQueue, QueueItem
from repro.streams.reservoir import Reservoir

CREATED = "created"
RUNNING = "running"
DRAINING = "draining"
STOPPED = "stopped"
FAILED = "failed"


def _item_nbytes(item: QueueItem) -> int:
    """Host bytes one queued item contributes to a coalesced dispatch,
    from the ACTUAL column dtypes (v3 columnar frames carry dtype tags, so
    externally submitted wide-weight columns really can arrive as int64;
    the old hardcoded 12 B/edge under-counted them ~2x)."""
    return item.src.shape[0] * (item.src.dtype.itemsize
                                + item.dst.dtype.itemsize
                                + item.weight.dtype.itemsize)


def preaggregate_edges(src: np.ndarray, dst: np.ndarray,
                       weight: np.ndarray):
    """Exact (src, dst) duplicate-edge pre-aggregation for linear sketches.

    Returns ``(usrc, udst, uweight)`` int32 arrays with one row per
    distinct (src, dst) pair, weights summed, zero-sum rows dropped.

    Bit-exactness argument (gated by the BENCH_ingest A/B cells): sketch
    counters are linear — every update is ``cell += weight`` — and int32
    addition modulo 2^32 is commutative and associative, so scattering one
    summed row is bit-identical to scattering each duplicate in turn.  The
    group sum runs in int64 and truncates back to int32, which equals the
    sequential wrap-add chain mod 2^32.  Negative weights (turnstile
    deletions) ride along unchanged; weight-0 rows are padding by the
    EdgeBatch contract and are dropped (adding zero is a no-op), including
    groups whose weights cancel to exactly zero.
    """
    s = np.ascontiguousarray(src, np.int32)
    d = np.ascontiguousarray(dst, np.int32)
    w = np.ascontiguousarray(weight, np.int32)
    live = w != 0
    if not live.all():
        s, d, w = s[live], d[live], w[live]
    if s.size == 0:
        z = np.zeros(0, np.int32)
        return z, z, z
    # pack (src, dst) into one uint64 key: sort once, group once
    key = (s.view(np.uint32).astype(np.uint64) << np.uint64(32)) \
        | d.view(np.uint32).astype(np.uint64)
    order = np.argsort(key, kind="stable")
    ks = key[order]
    ws = w[order].astype(np.int64)
    starts = np.flatnonzero(np.concatenate(([True], ks[1:] != ks[:-1])))
    sums = np.add.reduceat(ws, starts)
    uw = sums.astype(np.int32)  # int64 -> int32 truncation == wrap-add chain
    keep = uw != 0
    uk = ks[starts][keep]
    usrc = (uk >> np.uint64(32)).astype(np.uint32).view(np.int32)
    udst = uk.astype(np.uint32).view(np.int32)
    return usrc, udst, uw[keep]


class IngestWorker(threading.Thread):
    def __init__(self, tenant, queue: BoundedEdgeQueue,
                 policy: PublishPolicy, *,
                 reservoir: Reservoir | None = None,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 0,
                 on_publish=None,
                 poll_s: float = 0.05,
                 coalesce_batches: int = 1,
                 coalesce_target: int = 8192,
                 dedup: bool = False) -> None:
        super().__init__(name=f"ingest-{tenant.key.tenant_id}", daemon=True)
        self.tenant = tenant
        self.queue = queue
        self.policy = policy
        self.reservoir = reservoir
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.on_publish = on_publish
        self.poll_s = poll_s
        # Ingest coalescing: under backlog, fold up to ``coalesce_batches``
        # queued items (or ~``coalesce_target`` edges) into ONE device
        # dispatch.  The per-dispatch fixed cost (pool copy + driver) is
        # independent of batch size, so many small batches — the sharded
        # regime, where each shard sees ~B/K edges per stream batch — pay
        # it K-fold; coalescing restores dispatch-count parity with the
        # unsharded path.  1 (the default) preserves item-at-a-time
        # behaviour exactly.
        self.coalesce_batches = max(1, coalesce_batches)
        self.coalesce_target = coalesce_target
        # Exact duplicate-edge pre-aggregation (ISSUE 10): sort/unique each
        # group on (src, dst) and sum weights before dispatch.  Bit-exact by
        # counter linearity (see preaggregate_edges); on skewed streams it
        # collapses heavy-hitter repeats into single scatter rows.  The
        # pending ledger then takes the HOST count of raw weight>0 updates
        # (QueueItem.n_edges, precomputed at enqueue) because the deduped
        # device batch no longer carries one row per stream update.
        self.dedup = bool(dedup)
        # Dispatch-size byte cap, expressed as coalesce_target edges at the
        # canonical 3×int32 = 12 B/edge layout.  Group accounting uses each
        # item's ACTUAL column dtypes (_item_nbytes) — wide-weight streams
        # hit the cap proportionally earlier instead of blowing the
        # dispatch sizing.  A deep backlog (spill drain, drop_oldest churn)
        # must not build an unbounded coalesced batch; an item that would
        # push the group past the cap is HELD and leads the next group.
        self._coalesce_byte_cap = 12 * max(1, coalesce_target)
        self._held: QueueItem | None = None
        # Pipelined dispatch (ISSUE 10): two ping-pong host staging buffer
        # sets.  EdgeBatch.from_numpy is zero-copy on CPU — the device
        # batch ALIASES the staging memory — so a slot may only be refilled
        # once the dispatch that read it has finished executing.  Each
        # slot's fence is the buffer's dispatch_token captured right after
        # the dispatch; blocking on the PREVIOUS use of a slot (one and two
        # dispatches back) lets the worker coalesce group N+1 on the host
        # while the device still scatters group N.
        self._stage: list = [None, None]
        self._stage_fence: list = [None, None]
        self._stage_idx = 0
        self.metrics = WorkerMetrics()
        self.metrics.bind_hub(tenant.key.tenant_id)
        self._trace = get_trace_log()
        # trace IDs ingested since the last publish; the publish event
        # closes them all with the epoch they became visible in (bounded:
        # a pathological publish policy must not grow this without limit)
        self._pending_traces: list[str] = []
        self.state = CREATED
        self.error: BaseException | None = None
        self.error_tb: str | None = None  # formatted traceback, for callers
        #                                   in other processes/threads that
        #                                   cannot reach error.__traceback__
        self._stop_event = threading.Event()
        self._drain = True
        self._state_lock = threading.Lock()
        self._ingested_offset = tenant.offset - 1  # last batch folded in
        self._batches_since_checkpoint = 0
        # conservation baseline: edges already in the tenant (published +
        # pending delta) before this worker touched it
        self.base_edges = (tenant.snapshot.n_edges
                          + tenant.buffer.pending_edges)

    # -------------------------------------------------------------- lifecycle
    def request_stop(self, drain: bool = True) -> None:
        """Ask the worker to exit.  ``drain=True`` consumes the queue, takes
        a final publish (and checkpoint, if configured), then stops.
        ``drain=False`` is a crash-like hard stop: in-queue and in-delta
        work is abandoned exactly as a SIGKILL would abandon it."""
        self._drain = drain
        self._stop_event.set()
        if not drain:
            self.queue.close()

    def run(self) -> None:  # thread body
        self.state = RUNNING
        self.metrics.note_started(time.monotonic())
        try:
            while True:
                item = self._held
                if item is not None:
                    self._held = None  # byte-cap holdover leads this group
                else:
                    item = self.queue.get(timeout=self.poll_s)
                now = time.monotonic()
                if item is None:
                    if self._stop_event.is_set():
                        if not self._drain or self.queue.depth() == 0:
                            break
                        self.state = DRAINING
                        continue
                    # idle tick: wall-clock policies may still want to
                    # surface a lingering delta as a fresh epoch
                    if self._should_publish(now):
                        self._publish()
                    continue
                if self._stop_event.is_set() and not self._drain:
                    break  # hard stop: abandon the item, like a crash would
                if self._stop_event.is_set():
                    self.state = DRAINING
                items = [item]
                total = item.src.shape[0]
                group_bytes = _item_nbytes(item)
                while (len(items) < self.coalesce_batches
                       and total < self.coalesce_target):
                    nxt = self.queue.get(timeout=0)  # opportunistic, no wait
                    if nxt is None:
                        break
                    if group_bytes + _item_nbytes(nxt) \
                            > self._coalesce_byte_cap:
                        self._held = nxt  # caps the dispatch; never dropped
                        break
                    items.append(nxt)
                    total += nxt.src.shape[0]
                    group_bytes += _item_nbytes(nxt)
                if len(items) == 1 and not self.dedup:
                    self._ingest(item, now)
                else:
                    self._ingest_coalesced(items, now)
                if self._should_publish(time.monotonic()):
                    self._publish()
                if (self.checkpoint_dir and self.checkpoint_every
                        and self._batches_since_checkpoint
                        >= self.checkpoint_every):
                    self.checkpoint()
            if self._drain:
                # graceful exit: surface everything ingested, then persist.
                # Gate on the buffer's actual pending count, not just this
                # run's batch counter: a restored checkpoint can carry a
                # non-empty delta even when no new batch arrived (stream
                # already exhausted), and it must still reach an epoch.
                if (self.metrics.pending_batches()
                        or self.tenant.buffer.pending_edges):
                    self._publish()
                if self.checkpoint_dir:
                    self.checkpoint()
            self.state = STOPPED
        except BaseException as exc:
            # don't re-raise: a dying thread would only reach
            # threading.excepthook; the supervisor reads state/error instead
            # (and Runtime.stop() re-raises it to drain callers)
            self.error = exc
            self.error_tb = "".join(traceback.format_exception(
                type(exc), exc, exc.__traceback__))
            self.state = FAILED

    # ----------------------------------------------------------------- ingest
    def _note_dispatch(self, item: QueueItem) -> None:
        if not item.trace_id:
            return
        self._trace.emit(item.trace_id, "ingest", "dispatch",
                         offset=item.offset, n_edges=item.n_edges,
                         tenant=self.tenant.key.tenant_id)
        if len(self._pending_traces) < 256:
            self._pending_traces.append(item.trace_id)

    def _ingest(self, item: QueueItem, now: float) -> None:
        batch = EdgeBatch.from_numpy(item.src, item.dst, item.weight)
        self._note_dispatch(item)
        with self._state_lock:
            with profile_span("ingest"):
                self.tenant.buffer.ingest(batch)
            if self.reservoir is not None:
                self.reservoir.offer_batch(item.src, item.dst, item.weight)
            if item.offset >= 0:
                # externally submitted batches carry offset -1: they are not
                # part of the seekable stream, so they must not move the
                # stream cursor (checkpoint replay would double-count)
                self._ingested_offset = item.offset
                self.tenant.offset = item.offset + 1
        self.metrics.note_ingest(item.n_edges, now)
        self._batches_since_checkpoint += 1

    def _claim_stage(self, bucket: int):
        """Borrow a host staging column set of ≥ ``bucket`` rows (ping-pong).

        The device batch built over a staging set ALIASES its memory
        (zero-copy ``jnp.asarray`` on CPU), so a slot is only safe to
        refill after the dispatch that read it finished executing — the
        fence captured by ``_fence_stage``.  Alternating two slots lets
        group N+1 coalesce on the host while the device scatters group N;
        the block here only bites when the device falls a full two
        dispatches behind the host.
        """
        slot = self._stage_idx
        self._stage_idx ^= 1
        fence = self._stage_fence[slot]
        if fence is not None:
            jax.block_until_ready(fence)
            self._stage_fence[slot] = None
        bufs = self._stage[slot]
        if bufs is None or bufs[0].shape[0] < bucket:
            bufs = (np.zeros(bucket, np.int32), np.zeros(bucket, np.int32),
                    np.zeros(bucket, np.int32))
            self._stage[slot] = bufs
        return slot, bufs

    def _fence_stage(self, slot: int) -> None:
        token = getattr(self.tenant.buffer, "dispatch_token", None)
        if token is not None:
            self._stage_fence[slot] = token()
        else:
            # no completion fence available: never reuse this staging set
            self._stage[slot] = None

    def _ingest_coalesced(self, items: list[QueueItem], now: float) -> None:
        """Fold several queued items into ONE buffer ingest dispatch.

        Exactness is unaffected: sketch deltas are additive and order-free,
        the reservoir still sees items in FIFO order (raw, pre-dedup), and
        the whole group lands in the delta atomically under the state lock,
        so the offset cursor can jump straight to the newest seekable batch
        (FIFO ⇒ the last item is the newest) without ever describing a
        state the counters do not hold.  Padded to a coarse ladder
        (``coalesce_target/4`` granule) so coalesced shapes stay few.

        With ``dedup`` on, the group is pre-aggregated on (src, dst) first
        (bit-exact — see ``preaggregate_edges``) and the pending ledger
        takes the host-side raw weight>0 count instead of the device count.
        """
        n_raw = sum(it.src.shape[0] for it in items)
        count = None
        if self.dedup:
            if len(items) == 1:
                rs, rd, rw = items[0].src, items[0].dst, items[0].weight
            else:
                rs = np.concatenate([np.asarray(it.src) for it in items])
                rd = np.concatenate([np.asarray(it.dst) for it in items])
                rw = np.concatenate([np.asarray(it.weight) for it in items])
            raw_live = int(np.count_nonzero(np.asarray(rw)))
            usrc, udst, uw = preaggregate_edges(rs, rd, rw)
            n = usrc.shape[0]
            count = sum(it.n_edges for it in items)
        else:
            n = n_raw
        granule = max(256, self.coalesce_target // 4)
        bucket = max(granule, -(-n // granule) * granule)
        # pre-sized int32 staging per column, filled by slicing: the slice
        # assignment does the cast AND the copy, and the zero tail IS the
        # weight-0 padding pad_to produced
        slot, (src, dst, weight) = self._claim_stage(bucket)
        if self.dedup:
            src[:n] = usrc
            dst[:n] = udst
            weight[:n] = uw
        else:
            pos = 0
            for it in items:
                end = pos + it.src.shape[0]
                src[pos:end] = it.src
                dst[pos:end] = it.dst
                weight[pos:end] = it.weight
                pos = end
        src[n:bucket] = 0
        dst[n:bucket] = 0
        weight[n:bucket] = 0
        batch = EdgeBatch.from_numpy(src[:bucket], dst[:bucket],
                                     weight[:bucket])
        for it in items:
            self._note_dispatch(it)
        with self._state_lock:
            with profile_span("ingest"):
                if count is None:
                    self.tenant.buffer.ingest(batch)
                else:
                    self.tenant.buffer.ingest(batch, count=count)
            self._fence_stage(slot)
            if self.reservoir is not None:
                for it in items:
                    self.reservoir.offer_batch(it.src, it.dst, it.weight)
            offsets = [it.offset for it in items if it.offset >= 0]
            if offsets:
                self._ingested_offset = offsets[-1]
                self.tenant.offset = offsets[-1] + 1
        for it in items:
            self.metrics.note_ingest(it.n_edges, now)
        if self.dedup:
            self.metrics.note_dedup(raw_live, n)
        self._batches_since_checkpoint += len(items)

    def _should_publish(self, now: float) -> bool:
        return self.policy.should_publish(
            batches_since_publish=self.metrics.pending_batches(),
            now=now, queue_depth=self.queue.depth())

    def _publish(self):
        t0 = time.monotonic()
        snap = self.tenant.publish()
        now = time.monotonic()
        self.metrics.note_publish(now - t0, now)
        self.policy.note_published(now)
        for tid in self._pending_traces:
            self._trace.emit(tid, "ingest", "publish", epoch=snap.epoch,
                             tenant=self.tenant.key.tenant_id)
        self._pending_traces.clear()
        if self.on_publish is not None:
            self.on_publish(snap)
        return snap

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self) -> str:
        """Write a crash-safe checkpoint of the tenant's full ingest state.

        Callable from any thread.  Captures (front, delta, pending,
        reservoir, next stream offset) as ONE consistent cut under
        ``_state_lock`` — JAX arrays are immutable, so serialization happens
        outside the lock; the reservoir is copied out inside it.
        """
        if not self.checkpoint_dir:
            raise ValueError("worker has no checkpoint_dir configured")
        with self._state_lock:
            buf = self.tenant.buffer.state()
            next_offset = self._ingested_offset + 1
            res = (self.reservoir.state_dict()
                   if self.reservoir is not None else None)
        state = {"front": buf["front"], "delta": buf["delta"],
                 "pending": buf["pending"]}
        extra = {
            "tenant_id": self.tenant.key.tenant_id,
            "epoch": buf["epoch"],
            "n_edges": buf["n_edges"],
            "next_offset": next_offset,
        }
        if res is not None:
            state["reservoir"] = {"src": res["src"], "dst": res["dst"],
                                  "w": res["w"]}
            extra["reservoir"] = {"k": res["k"], "seen": res["seen"],
                                  "rng_state": res["rng_state"]}
        path = store.save(self.checkpoint_dir, next_offset, state, extra=extra)
        self._batches_since_checkpoint = 0
        self.metrics.note_checkpoint(time.monotonic())
        return path

    # ---------------------------------------------------------------- reports
    @property
    def ingested_edges(self) -> int:
        """Backend-neutral accessor (runtime/backend.py contract): total
        non-padding edges this worker has folded into the delta."""
        return self.metrics.total_edges()

    def wait_ready(self, timeout: float = 0.0) -> bool:
        """Backend-neutral readiness barrier: a thread worker shares the
        parent's address space and compiled kernels, so it is ready the
        moment it exists.  (The process backend overrides this with a real
        wait on the child's ready handshake.)"""
        return True

    def health(self) -> dict:
        return {
            "state": self.state,
            "alive": self.is_alive(),
            "error": repr(self.error) if self.error else None,
            "epoch": self.tenant.epoch,
            "ingested_offset": self._ingested_offset,
            "queue_depth": self.queue.depth(),
        }

    def metrics_snapshot(self) -> dict:
        return self.metrics.snapshot(
            queue_stats=self.queue.stats(),
            state=self.state,
            epoch=self.tenant.epoch,
            overflow_edges=getattr(self.tenant.buffer, "overflow_edges", 0))


def restore_worker_state(tenant, checkpoint_dir: str,
                         reservoir: Reservoir | None = None,
                         step: int | None = None) -> dict:
    """Load the latest (or ``step``) checkpoint back into a *fresh* tenant.

    The tenant must come from an identically-configured registry (same key,
    depth, batch size, scale): the checkpoint stores counter state, not
    layout, and ``store.restore`` asserts shape agreement leaf by leaf.
    Returns the checkpoint metadata; after this call a worker/pump pair
    resumes from ``tenant.offset`` and reproduces a never-crashed run
    bit-exactly (streams are seekable, counters additive).
    """
    # identity check BEFORE touching arrays: a foreign tenant's checkpoint
    # must fail loudly on identity, not incidentally on layout shapes
    probe = store.read_meta(checkpoint_dir, step=step)["extra"]
    if probe.get("tenant_id") != tenant.key.tenant_id:
        raise ValueError(
            f"checkpoint belongs to tenant {probe.get('tenant_id')!r}, "
            f"not {tenant.key.tenant_id!r}")
    buf = tenant.buffer.state()
    template = {"front": buf["front"], "delta": buf["delta"],
                "pending": buf["pending"]}
    if reservoir is not None:
        template["reservoir"] = {"src": reservoir._src, "dst": reservoir._dst,
                                 "w": reservoir._w}
    state, meta = store.restore(checkpoint_dir, template, step=step)
    extra = meta["extra"]
    tenant.buffer.load_state({
        "front": state["front"], "delta": state["delta"],
        "pending": state["pending"], "epoch": extra["epoch"],
        "n_edges": extra["n_edges"],
    })
    tenant.offset = int(extra["next_offset"])
    if reservoir is not None:
        if "reservoir" not in state:
            raise ValueError("checkpoint has no reservoir state")
        res_extra = extra["reservoir"]
        reservoir.load_state_dict({
            "k": res_extra["k"], "seen": res_extra["seen"],
            "rng_state": res_extra["rng_state"],
            "src": state["reservoir"]["src"],
            "dst": state["reservoir"]["dst"],
            "w": state["reservoir"]["w"],
        })
    return meta
