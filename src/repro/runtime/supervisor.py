"""Runtime supervisor: background ingest behind the serving engine.

``Runtime`` owns the concurrency story that `launch/query_serve.py` and
`benchmarks/serve_bench.py --concurrent` build on: per tenant, a
``StreamPump`` thread reads the seekable stream and feeds a
``BoundedEdgeQueue`` (explicit backpressure), a worker built by the
configured **execution backend** (``backend="thread"`` — the classic
``IngestWorker`` thread — or ``"process"`` — a spawn child owning its
sketch, see ``runtime/backend.py``) folds batches into the delta sketch
and publishes epochs, and the supervisor provides lifecycle (start /
health / graceful drain-and-stop / crash-like kill), live metrics,
conservation accounting, and crash-safe checkpoint/restore — all written
once against the backend interface.  Query threads are *not* managed here
— they just read ``tenant.snapshot``, which is always a consistent
immutable epoch in THIS process regardless of where ingest runs.

Conservation contract (tested; the serve bench gates on it): for every
tenant, ``offered == ingested + dropped`` and after a graceful stop
``published - base == ingested`` — no edge is lost or double-counted,
and drops (only under the ``drop_oldest`` policy) are explicit numbers,
never silence.
"""
from __future__ import annotations

import os
import threading
import time

from repro.obs.hub import get_hub
from repro.obs.trace import get_trace_log, new_trace_id
from repro.runtime.backend import WorkerFailure, resolve_backend
from repro.runtime.queueing import BLOCK, SPILL, BoundedEdgeQueue, QueueItem
from repro.runtime.worker import FAILED, restore_worker_state
from repro.streams.reservoir import Reservoir


class StreamPump(threading.Thread):
    """Producer thread: seekable stream -> bounded queue, FIFO, accounted."""

    def __init__(self, stream, queue: BoundedEdgeQueue, *,
                 start_offset: int = 0, max_batches: int | None = None,
                 throttle_s: float = 0.0) -> None:
        super().__init__(name="stream-pump", daemon=True)
        self.stream = stream
        self.queue = queue
        self.start_offset = start_offset
        self.max_batches = max_batches
        self.throttle_s = throttle_s
        self.offered_batches = 0
        self.offered_edges = 0
        self.done = False  # reached end of stream (or max_batches) cleanly
        self._stop_event = threading.Event()

    def request_stop(self) -> None:
        self._stop_event.set()

    def run(self) -> None:
        i = self.start_offset
        end = self.stream.num_batches
        if self.max_batches is not None:
            end = min(end, self.start_offset + self.max_batches)
        trace = get_trace_log()
        while i < end and not self._stop_event.is_set():
            src, dst, w = self.stream.batch_numpy(i)
            item = QueueItem.from_arrays(i, src, dst, w,
                                         trace_id=new_trace_id())
            while not self._stop_event.is_set():
                if self.queue.put(item, timeout=0.2):
                    self.offered_batches += 1
                    self.offered_edges += item.n_edges
                    trace.emit(item.trace_id, "ingest", "enqueue",
                               offset=i, n_edges=item.n_edges)
                    break
                if self.queue.closed:
                    return  # killed under us; offered stays = accepted
            else:
                return
            i += 1
            if self.throttle_s:
                time.sleep(self.throttle_s)
        self.done = i >= end


class TenantRuntime:
    """Handle bundling one tenant's pump + queue + backend worker."""

    def __init__(self, tenant, queue: BoundedEdgeQueue, worker,
                 pump: StreamPump | None) -> None:
        self.tenant = tenant
        self.queue = queue
        self.worker = worker
        self.pump = pump
        self._external_edges = 0

    @property
    def tenant_id(self) -> str:
        return self.tenant.key.tenant_id

    def submit(self, src, dst, weight, timeout: float | None = None) -> bool:
        """Enqueue an external (non-pump) batch; offsets are synthetic (-1)
        so checkpoint replay does not apply to externally-submitted edges."""
        item = QueueItem.from_arrays(-1, src, dst, weight,
                                     trace_id=new_trace_id())
        ok = self.queue.put(item, timeout=timeout)
        if ok:
            self._external_edges += item.n_edges
            get_trace_log().emit(item.trace_id, "ingest", "enqueue",
                                 offset=-1, n_edges=item.n_edges,
                                 tenant=self.tenant_id)
        return ok

    def conservation(self) -> dict:
        """Edge-mass accounting: offered vs ingested vs dropped vs published."""
        qstats = self.queue.stats()
        offered = qstats["accepted_edges"]
        ingested = self.worker.ingested_edges  # backend-neutral accessor
        dropped = qstats["dropped_edges"]
        published = self.tenant.snapshot.n_edges
        base = self.worker.base_edges
        return {
            "offered_edges": offered,
            "ingested_edges": ingested,
            "dropped_edges": dropped,
            "in_queue_edges": offered - ingested - dropped,
            "published_edges": published,
            "base_edges": base,
            # zero after a graceful drain-and-stop: every offered edge is
            # either published or an accounted drop
            "unaccounted_edges": offered - dropped - (published - base),
        }


class Runtime:
    """Supervisor for background ingest workers over a sketch registry."""

    def __init__(self, *, queue_capacity: int = 64, backpressure: str = BLOCK,
                 publish_policy: str = "every:4", reservoir_k: int = 4096,
                 checkpoint_dir: str | None = None, checkpoint_every: int = 0,
                 spill_dir: str | None = None, poll_s: float = 0.02,
                 coalesce_batches: int = 1,
                 coalesce_target: int = 8192,
                 dedup: bool = False,
                 backend: str = "thread") -> None:
        # execution backend: where workers run ("thread" | "process" |
        # "socket[:HOST:PORT,...]" | an ExecutionBackend instance) —
        # everything below is written against the runtime/backend.py
        # contract, not a concrete worker class
        self.backend = resolve_backend(backend)
        self.queue_capacity = queue_capacity
        self.backpressure = backpressure
        self.publish_policy = publish_policy
        self.reservoir_k = reservoir_k
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.spill_dir = spill_dir
        self.poll_s = poll_s
        # ingest coalescing under backlog (see IngestWorker); 1 = off
        self.coalesce_batches = coalesce_batches
        self.coalesce_target = coalesce_target
        # exact duplicate-edge pre-aggregation before dispatch (bit-exact
        # by counter linearity — see worker.preaggregate_edges); off by
        # default so existing ingest behaviour is unchanged
        self.dedup = bool(dedup)
        self._handles: dict[str, TenantRuntime] = {}
        self._started = False
        self._lock = threading.Lock()
        self._hub_collector = None

    # --------------------------------------------------------------- telemetry
    def _collect_hub(self) -> None:
        """Hub collector (runs on every scrape/state): refresh per-tenant
        gauges from the authoritative snapshot dicts.  Remote workers'
        hub states are adopted as their beats arrive (see
        ``backend._absorb_worker_obs``), not here."""
        hub = get_hub()
        backend = self.backend.name
        for h in self.handles():
            try:
                snap = h.worker.metrics_snapshot()
            except Exception:
                continue
            labels = {"tenant": h.tenant_id, "backend": backend}
            hub.gauge("repro_queue_depth",
                      "batches waiting in the bounded ingest queue",
                      **labels).set(snap.get("queue_depth") or 0)
            hub.gauge("repro_epoch", "published snapshot epoch",
                      **labels).set(snap.get("epoch") or 0)
            hub.gauge("repro_ingest_edges_per_s",
                      "recent ingest rate (EWMA)",
                      **labels).set(snap.get("edges_per_s_ewma") or 0.0)
            hub.counter("repro_queue_dropped_edges_total",
                        "edges dropped by backpressure", **labels
                        ).set(snap.get("dropped_edges") or 0)

    # ------------------------------------------------------------ composition
    def _tenant_dir(self, base: str | None, tenant) -> str | None:
        if base is None:
            return None
        # tenant ids contain '/'; flatten for one directory per tenant
        return os.path.join(base, tenant.key.tenant_id.replace("/", "_"))

    def attach(self, tenant, *, pump: bool = True,
               max_batches: int | None = None, throttle_s: float = 0.0,
               publish_policy: str | None = None,
               restore: bool = False, on_publish=None) -> TenantRuntime:
        """Register a tenant: build its queue, worker and (optionally) pump.

        ``restore=True`` loads the latest checkpoint for this tenant from
        ``checkpoint_dir`` before the worker is built, so the pump resumes
        from the checkpointed stream offset (crash recovery).
        """
        with self._lock:
            if self._started:
                raise RuntimeError("attach() before start()")
            if tenant.key.tenant_id in self._handles:
                return self._handles[tenant.key.tenant_id]
        ckpt_dir = self._tenant_dir(self.checkpoint_dir, tenant)
        reservoir = (Reservoir(self.reservoir_k,
                               seed=tenant.key.seed ^ 0xC0FFEE)
                     if self.reservoir_k else None)
        if restore:
            if not ckpt_dir:
                raise ValueError("restore=True requires checkpoint_dir")
            # restore runs ONCE, here in the parent, for every backend: the
            # thread worker shares this state directly; a process worker
            # receives it (buffer + reservoir + offset) in its spawn spec
            restore_worker_state(tenant, ckpt_dir, reservoir)
        spill_dir = None
        if self.backpressure == SPILL:
            if not self.spill_dir:
                raise ValueError("spill backpressure requires spill_dir")
            spill_dir = self._tenant_dir(self.spill_dir, tenant)
        queue = BoundedEdgeQueue(self.queue_capacity, self.backpressure,
                                 spill_dir=spill_dir)
        worker = self.backend.make_worker(
            tenant, queue, publish_policy or self.publish_policy,
            reservoir=reservoir, checkpoint_dir=ckpt_dir,
            checkpoint_every=self.checkpoint_every, on_publish=on_publish,
            poll_s=self.poll_s, coalesce_batches=self.coalesce_batches,
            coalesce_target=self.coalesce_target,
            queue_capacity=self.queue_capacity, dedup=self.dedup)
        pump_thread = (StreamPump(tenant.stream, queue,
                                  start_offset=tenant.offset,
                                  max_batches=max_batches,
                                  throttle_s=throttle_s)
                       if pump else None)
        handle = TenantRuntime(tenant, queue, worker, pump_thread)
        with self._lock:
            # re-check under the lock (mirrors SketchRegistry.open): a
            # racing attach of the same tenant must not orphan a handle
            # whose worker would never be started
            existing = self._handles.get(tenant.key.tenant_id)
            if existing is not None:
                return existing
            self._handles[tenant.key.tenant_id] = handle
        return handle

    def handles(self) -> list[TenantRuntime]:
        with self._lock:
            return list(self._handles.values())

    # -------------------------------------------------------------- lifecycle
    def start(self, pumps: bool = True) -> None:
        """Start every worker (and, by default, every pump).

        ``pumps=False`` is the staged start: workers come up first (process
        children spawn and warm in parallel), the caller can
        ``wait_ready()``, then ``start_pumps()`` — benchmarks use this to
        keep child startup off the ingest clock.
        """
        with self._lock:
            if self._started:
                return
            self._started = True
        if self._hub_collector is None:
            self._hub_collector = self._collect_hub
            get_hub().add_collector(self._hub_collector)
        for h in self.handles():
            h.worker.start()
        if pumps:
            self.start_pumps()

    def start_pumps(self) -> None:
        for h in self.handles():
            if h.pump is not None and h.pump.ident is None:  # not yet started
                h.pump.start()

    def wait_ready(self, timeout: float = 300.0) -> bool:
        """Block until every worker is ready to ingest (thread workers are
        born ready; process workers finish their child-side build/warm)."""
        deadline = time.monotonic() + timeout
        return all(
            h.worker.wait_ready(timeout=max(deadline - time.monotonic(),
                                            0.01))
            for h in self.handles())

    def join_pumps(self, timeout: float = 300.0) -> bool:
        """Wait until every pump has offered its whole stream."""
        deadline = time.monotonic() + timeout
        for h in self.handles():
            if h.pump is not None:
                h.pump.join(timeout=max(deadline - time.monotonic(), 0.01))
        return all(h.pump is None or h.pump.done for h in self.handles())

    def stop(self, drain: bool = True, timeout: float = 300.0,
             raise_on_failure: bool = True) -> dict:
        """Stop everything; with ``drain`` the queues are consumed to empty,
        a final epoch is published and a final checkpoint written.  Returns
        the final per-tenant report (metrics + conservation).

        If any worker is in the ``failed`` state after the join, raises
        ``WorkerFailure`` carrying each original exception + traceback (the
        report rides along on the exception) — a dead worker must surface
        at the drain call site, not only via ``health()`` polling.  Pass
        ``raise_on_failure=False`` to get the report unconditionally.
        """
        for h in self.handles():
            if h.pump is not None:
                h.pump.request_stop()
        deadline = time.monotonic() + timeout
        for h in self.handles():
            if h.pump is not None and h.pump.is_alive():
                h.pump.join(timeout=max(deadline - time.monotonic(), 0.01))
        for h in self.handles():
            h.worker.request_stop(drain=drain)
        # cut transport-level waits loose (close listeners / cancel dials)
        # BEFORE joining: a socket worker whose peer never connected must
        # fail fast here, not ride out the join timeout
        self.backend.shutdown()
        for h in self.handles():
            if h.worker.is_alive():
                h.worker.join(timeout=max(deadline - time.monotonic(), 0.01))
            h.queue.close()
        if self._hub_collector is not None:
            # final refresh, then detach: a stopped runtime must not keep
            # running collector callbacks on later scrapes
            self._collect_hub()
            get_hub().remove_collector(self._hub_collector)
            self._hub_collector = None
        report = self.report()
        if raise_on_failure:
            failures = [
                {"tenant_id": h.tenant_id,
                 "error": repr(h.worker.error) if h.worker.error else
                 f"worker state {h.worker.state!r}",
                 "traceback": getattr(h.worker, "error_tb", None)}
                for h in self.handles() if h.worker.state == FAILED
            ]
            if failures:
                raise WorkerFailure(failures, report)
        return report

    def kill(self) -> None:
        """Crash-like termination: close queues, abandon in-flight work.

        Pending deltas and queued batches are lost exactly as they would be
        in a process kill; a later ``attach(restore=True)`` replays from the
        last checkpoint (see tests/test_runtime.py conservation-on-resume)."""
        if self._hub_collector is not None:
            get_hub().remove_collector(self._hub_collector)
            self._hub_collector = None
        for h in self.handles():
            if h.pump is not None:
                h.pump.request_stop()
            h.worker.request_stop(drain=False)
        self.backend.shutdown()
        for h in self.handles():
            if h.pump is not None and h.pump.is_alive():
                h.pump.join(timeout=10.0)
            if h.worker.is_alive():
                h.worker.join(timeout=10.0)

    # ---------------------------------------------------------------- reports
    def health(self) -> dict:
        out = {}
        for h in self.handles():
            w = h.worker.health()
            w["pump_alive"] = bool(h.pump is not None and h.pump.is_alive())
            w["pump_done"] = bool(h.pump is None or h.pump.done)
            out[h.tenant_id] = w
        return out

    def metrics(self) -> dict:
        return {h.tenant_id: h.worker.metrics_snapshot()
                for h in self.handles()}

    def report(self) -> dict:
        """Final per-tenant accounting: metrics + conservation + health."""
        out = {}
        for h in self.handles():
            out[h.tenant_id] = {
                **h.worker.metrics_snapshot(),
                **h.conservation(),
                "pump_done": bool(h.pump is None or h.pump.done),
            }
        return out

    def checkpoint_all(self) -> list[str]:
        """Synchronously checkpoint every tenant (callable while running)."""
        return [h.worker.checkpoint() for h in self.handles()]
