"""Bounded edge-batch queues with explicit backpressure (DESIGN.md §Runtime).

# analysis: hot-path — every queued batch flows through here; the
# no-pickle-hot-path rule keeps serialization out of this module.

The queue is the contract between a stream producer (``StreamPump`` or an
external ``Runtime.submit`` caller) and a tenant's ``IngestWorker``.  It is
*bounded* on purpose: an unbounded queue turns a slow ingest path into
unbounded memory growth and hides overload.  When full, one of three
policies applies:

  block        the producer waits (lossless; producer-paced — the default)
  drop_oldest  the oldest queued batch is evicted and *accounted* (bounded
               staleness under overload; never silent — ``dropped_edges``
               feeds the runtime's conservation report)
  spill        overflow batches go to an on-disk FIFO and are read back in
               order as the consumer catches up (lossless and non-blocking,
               at the price of disk I/O — which happens outside the queue
               lock, so producer and consumer never serialize on the disk)

Items are host-side numpy triples, not device arrays: they are cheap to
drop, cheap to spill, and the worker converts to an ``EdgeBatch`` only at
ingest time.  FIFO order is preserved by every policy (for spill, once an
overflow batch is on disk all younger puts spill too until the disk FIFO
drains — in-memory items are always older than spilled ones).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque

import numpy as np

BLOCK = "block"
DROP_OLDEST = "drop_oldest"
SPILL = "spill"
BACKPRESSURE_POLICIES = (BLOCK, DROP_OLDEST, SPILL)


@dataclasses.dataclass
class QueueItem:
    """One stream batch in flight: seekable offset + host-side arrays."""

    offset: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    n_edges: int  # non-padding updates (weight > 0), precomputed once
    # span ID minted at enqueue (repro.obs.trace); rides queues, spills,
    # and v2 wire `item` frames so the batch's enqueue -> dispatch ->
    # publish -> adopt chain is reconstructable on any backend
    trace_id: str = ""

    @staticmethod
    def from_arrays(offset: int, src: np.ndarray, dst: np.ndarray,
                    weight: np.ndarray, trace_id: str = "") -> "QueueItem":
        return QueueItem(offset, src, dst, weight,
                         n_edges=int(np.count_nonzero(weight > 0)),
                         trace_id=trace_id)


class BoundedEdgeQueue:
    """Thread-safe bounded FIFO of ``QueueItem`` with a backpressure policy."""

    def __init__(self, capacity: int, policy: str = BLOCK,
                 spill_dir: str | None = None) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in BACKPRESSURE_POLICIES:
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             f"choose from {BACKPRESSURE_POLICIES}")
        if policy == SPILL and not spill_dir:
            raise ValueError("spill policy requires spill_dir")
        self.capacity = capacity
        self.policy = policy
        self.spill_dir = spill_dir
        self.stale_spills_removed = 0
        if policy == SPILL:
            os.makedirs(spill_dir, exist_ok=True)
            # A fresh queue reusing a crashed run's spill_dir must never
            # confuse that run's leftovers with its own slots: slot indices
            # restart at 0, so a stale file could sit at a path this queue
            # is about to reserve.  The slot-ready events make reads safe
            # within one queue lifetime, but stale files are dead weight at
            # best and a hazard if the numbering scheme ever changes —
            # purge them (and any torn .tmp writes) up front, accounted.
            for name in os.listdir(spill_dir):
                if name.startswith("spill_"):
                    os.remove(os.path.join(spill_dir, name))
                    self.stale_spills_removed += 1
        self._items: deque[QueueItem] = deque()  # guarded-by: _cv
        self._cv = threading.Condition()
        self._closed = False  # guarded-by(writes): _cv
        # disk FIFO indices: slots [_spill_head, _spill_tail) are reserved;
        # _spill_ready[i] is set once slot i's file is actually on disk
        # (reservation happens under the lock, file I/O outside it)
        self._spill_head = 0  # guarded-by: _cv
        self._spill_tail = 0  # guarded-by: _cv
        self._spill_ready: dict[int, threading.Event] = {}
        self.accepted_batches = 0  # guarded-by: _cv
        self.accepted_edges = 0  # guarded-by: _cv
        self.dropped_batches = 0  # guarded-by: _cv
        self.dropped_edges = 0  # guarded-by: _cv
        self.spilled_batches = 0  # guarded-by: _cv
        self.max_depth_seen = 0  # guarded-by: _cv

    # ------------------------------------------------------------------ spill
    def _spill_path(self, idx: int) -> str:
        # .kmx: one v3 columnar item frame (repro.net.wire), verbatim — the
        # spill FIFO and the transports share a single codec, so a spilled
        # batch costs one buffer concat down and one frombuffer view up
        return os.path.join(self.spill_dir, f"spill_{idx:012d}.kmx")

    def _spill_write(self, idx: int, item: QueueItem) -> None:
        """File I/O for reserved slot ``idx`` — called OUTSIDE the lock.

        tmp + rename so a producer crash mid-write leaves a recognizable
        ``.tmp`` orphan (purged by the next queue on this dir), never a
        torn file at the slot's final path.
        """
        from repro.net import wire

        path = self._spill_path(idx)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(wire.encode_item_frame(item, on_wire=False))
        os.replace(tmp, path)

    def _spill_read(self, idx: int) -> QueueItem:
        """File I/O for claimed slot ``idx`` — called OUTSIDE the lock."""
        from repro.net import wire

        path = self._spill_path(idx)
        with open(path, "rb") as f:
            data = f.read()
        # zero-copy: the decoded columns are views over `data`, which the
        # QueueItem keeps alive; a torn/garbled file raises WireError loud
        _, offset, src, dst, weight, n_edges, trace_id = wire.decode_message(
            data, on_wire=False)
        item = QueueItem(offset, src, dst, weight, n_edges,
                         trace_id=trace_id)
        os.remove(path)
        return item

    @property
    def _spill_pending(self) -> int:  # requires-lock: _cv
        return self._spill_tail - self._spill_head

    # -------------------------------------------------------------- interface
    @property
    def closed(self) -> bool:
        return self._closed

    def depth(self) -> int:
        """Batches waiting (in memory + spilled) — the worker's ingest lag."""
        with self._cv:
            return len(self._items) + self._spill_pending

    def put(self, item: QueueItem, timeout: float | None = None) -> bool:
        """Enqueue under the backpressure policy.

        Returns True iff the item was accepted (queued or spilled).  ``block``
        may return False on timeout or close; the other policies always
        accept unless the queue is closed.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        spill_idx = None
        spill_done = None
        with self._cv:
            if self.policy == BLOCK:
                while (not self._closed and len(self._items) >= self.capacity):
                    remaining = None
                    if deadline is not None:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            return False
                    self._cv.wait(timeout=remaining if remaining is not None
                                  else 0.1)
                if self._closed:
                    return False
                self._items.append(item)
            elif self.policy == DROP_OLDEST:
                if self._closed:
                    return False
                if len(self._items) >= self.capacity:
                    victim = self._items.popleft()
                    self.dropped_batches += 1
                    self.dropped_edges += victim.n_edges
                self._items.append(item)
            else:  # SPILL
                if self._closed:
                    return False
                if len(self._items) >= self.capacity or self._spill_pending:
                    # reserve a slot only; the np.savez happens outside the
                    # lock so the consumer keeps dequeuing during disk I/O
                    spill_idx = self._spill_tail
                    self._spill_tail += 1
                    # keep a local ref: a fast consumer may claim the slot
                    # (popping the dict entry) before the write finishes
                    spill_done = threading.Event()
                    self._spill_ready[spill_idx] = spill_done
                    self.spilled_batches += 1
                else:
                    self._items.append(item)
            self.accepted_batches += 1
            self.accepted_edges += item.n_edges
            self.max_depth_seen = max(self.max_depth_seen,
                                      len(self._items) + self._spill_pending)
            self._cv.notify_all()
        if spill_idx is not None:
            self._spill_write(spill_idx, item)
            spill_done.set()
        return True

    def get(self, timeout: float | None = None) -> QueueItem | None:
        """Dequeue the oldest item; None on timeout or when closed and empty."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._items and not self._spill_pending:
                if self._closed:
                    return None
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                self._cv.wait(timeout=remaining if remaining is not None
                              else 0.1)
            if self._items:
                item = self._items.popleft()
                self._cv.notify_all()
                return item
            # claim the oldest spill slot under the lock; read it outside
            # (FIFO holds: in-memory items are always older than spilled
            # ones, and puts keep spilling while any slot is outstanding)
            idx = self._spill_head
            self._spill_head += 1
            ready = self._spill_ready.pop(idx)
            self._cv.notify_all()
        if not ready.wait(timeout=60.0):  # producer died mid-write
            raise RuntimeError(f"spill slot {idx} was reserved but never "
                               "written (producer failed mid-spill)")
        return self._spill_read(idx)

    def close(self) -> None:
        """Wake every blocked producer/consumer; further puts are refused.

        Closing does NOT discard queued work: in-memory items and pending
        spilled batches stay drainable through ``get()`` until the queue is
        empty (only then does ``get`` return None), so a drain-after-close
        conserves every accepted edge — the disk FIFO is part of the queue,
        not a side channel.  Anything left undrained remains visible in
        ``stats()`` (``depth`` / ``spill_pending``), never silently lost.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def stats(self) -> dict:
        with self._cv:
            return {
                "depth": len(self._items) + self._spill_pending,
                "accepted_batches": self.accepted_batches,
                "accepted_edges": self.accepted_edges,
                "dropped_batches": self.dropped_batches,
                "dropped_edges": self.dropped_edges,
                "spilled_batches": self.spilled_batches,
                "spill_pending": self._spill_pending,
                "stale_spills_removed": self.stale_spills_removed,
                "max_depth_seen": self.max_depth_seen,
            }
