"""Pluggable snapshot-publish policies for ingest workers.

A worker folds queue batches into its tenant's delta sketch; *when* the
delta is folded into the published snapshot (a new epoch) is a policy
decision with a real trade-off: frequent publishes minimize staleness but
thrash every per-(tenant, epoch) cache downstream (notably the engine's
closure cache); rare publishes serve stale counters.  Three policies:

  every:N      publish after N ingested batches (throughput-paced; the
               cooperative serving loop's behaviour, now per worker)
  interval:S   publish at most every S wall-clock seconds (staleness-paced;
               publishes happen on idle ticks too, so a quiet stream still
               surfaces its last batches)
  drain[:W]    publish when the queue depth falls to the watermark W
               (default 0) — epochs align with bursts, so a backlogged
               worker does one big fold instead of many small ones.  A
               ``max_batches`` backstop bounds staleness under sustained
               overload where the queue never drains.

Policies are tiny stateful objects owned by ONE worker thread each; the
worker consults ``should_publish`` after every ingested batch and on idle
ticks, and calls ``note_published`` after each publish.
"""
from __future__ import annotations

import time
from typing import Callable


class PublishPolicy:
    """Base class; subclasses decide when a worker publishes an epoch."""

    def note_published(self, now: float) -> None:
        """Called by the worker right after every publish."""

    def should_publish(self, *, batches_since_publish: int, now: float,
                       queue_depth: int) -> bool:
        raise NotImplementedError


class EveryNBatches(PublishPolicy):
    def __init__(self, n: int) -> None:
        if n < 1:
            raise ValueError(f"every:N requires N >= 1, got {n}")
        self.n = n

    def should_publish(self, *, batches_since_publish: int, now: float,
                       queue_depth: int) -> bool:
        return batches_since_publish >= self.n


class WallClockInterval(PublishPolicy):
    def __init__(self, seconds: float,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if seconds <= 0:
            raise ValueError(f"interval:S requires S > 0, got {seconds}")
        self.seconds = seconds
        self._clock = clock
        self._last: float | None = None

    def note_published(self, now: float) -> None:
        self._last = now

    def should_publish(self, *, batches_since_publish: int, now: float,
                       queue_depth: int) -> bool:
        if batches_since_publish == 0:
            return False  # nothing pending; an empty publish is pure churn
        if self._last is None:
            self._last = now  # arm on first observation
            return False
        return (now - self._last) >= self.seconds


class QueueDrainWatermark(PublishPolicy):
    def __init__(self, watermark: int = 0, max_batches: int = 64) -> None:
        if watermark < 0:
            raise ValueError(f"drain:W requires W >= 0, got {watermark}")
        if max_batches < 1:
            raise ValueError(f"max_batches must be >= 1, got {max_batches}")
        self.watermark = watermark
        self.max_batches = max_batches

    def should_publish(self, *, batches_since_publish: int, now: float,
                       queue_depth: int) -> bool:
        if batches_since_publish == 0:
            return False
        return (queue_depth <= self.watermark
                or batches_since_publish >= self.max_batches)


def make_policy(spec: "str | PublishPolicy | Callable[[], PublishPolicy]"
                ) -> PublishPolicy:
    """Parse a policy spec: ``"every:4"``, ``"interval:0.5"``, ``"drain"``,
    ``"drain:2"``; also accepts a ready instance or a zero-arg factory."""
    if isinstance(spec, PublishPolicy):
        return spec
    if callable(spec):
        policy = spec()
        if not isinstance(policy, PublishPolicy):
            raise TypeError(f"policy factory returned {type(policy).__name__}")
        return policy
    name, _, arg = spec.partition(":")
    if name == "every":
        return EveryNBatches(int(arg or 4))
    if name == "interval":
        return WallClockInterval(float(arg))
    if name == "drain":
        return QueueDrainWatermark(int(arg or 0))
    raise ValueError(f"unknown publish policy spec {spec!r} "
                     "(expected every:N | interval:S | drain[:W])")
