"""Live per-worker ingest metrics (DESIGN.md §Runtime).

One ``WorkerMetrics`` per ingest worker, written by that worker's thread
and read by anyone via ``snapshot()`` or the locked accessors.  The old
contract — "single-writer; plain attribute stores are atomic under the
GIL" — was true per *store* but not per *snapshot*: a reader could see
``publishes`` from after a publish and ``publish_latency_sum_s`` from
before it, i.e. torn multi-field reads (flagged by the lock-discipline
rule in ``repro.analysis``).  All counter mutation and every multi-field
read now happens under ``_lock``; hub instrument mirroring stays outside
it (instruments carry their own locks — nesting would add lock-order
edges for no benefit).

The rates use an exponentially-weighted moving average so a dashboard
polling ``Runtime.metrics()`` sees the *recent* ingest rate, not a
lifetime mean diluted by warmup.
"""
from __future__ import annotations

import dataclasses
import threading
import time


class RateEWMA:
    """Exponentially-weighted event rate (events/s) with a time half-life."""

    def __init__(self, halflife_s: float = 5.0) -> None:
        self.halflife_s = halflife_s
        self._rate = 0.0
        self._last: float | None = None
        self._carry = 0.0

    def update(self, n: float, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        if self._last is None:
            # First sample defines the interval start; its count can't be
            # turned into a rate yet, so carry it into the next interval
            # instead of dropping it (which understated early rates).
            self._last = now
            self._carry = n
            return
        dt = max(now - self._last, 1e-9)
        inst = (n + self._carry) / dt
        self._carry = 0.0
        alpha = 1.0 - 0.5 ** (dt / self.halflife_s)
        self._rate += alpha * (inst - self._rate)
        self._last = now

    @property
    def rate(self) -> float:
        return self._rate


@dataclasses.dataclass
class WorkerMetrics:
    """Locked counters for one ingest worker (one writer, many readers)."""

    started_at: float = 0.0  # guarded-by: _lock
    # monotonic timestamps of the first/last real ingest dispatch: the honest
    # wall for throughput numbers (excludes spawn/compile warmup before the
    # first batch).  CLOCK_MONOTONIC is system-wide on Linux, so these are
    # comparable across the process boundary (runtime/backend.py relies on
    # that to time multi-process drains from per-worker metrics alone).
    first_ingest_at: float = 0.0  # guarded-by: _lock
    last_ingest_at: float = 0.0  # guarded-by: _lock
    ingested_batches: int = 0  # guarded-by: _lock
    ingested_edges: int = 0  # guarded-by: _lock
    batches_since_publish: int = 0  # guarded-by: _lock
    publishes: int = 0  # guarded-by: _lock
    last_publish_at: float = 0.0  # guarded-by: _lock
    last_publish_latency_s: float = 0.0  # guarded-by: _lock
    publish_latency_sum_s: float = 0.0  # guarded-by: _lock
    checkpoints: int = 0  # guarded-by: _lock
    last_checkpoint_at: float = 0.0  # guarded-by: _lock
    # duplicate-edge pre-aggregation (worker dedup path): raw weight!=0 rows
    # seen vs unique (src, dst) rows actually dispatched — their ratio is
    # the scatter-row compression the fast path wins on skewed streams
    dedup_raw_rows: int = 0  # guarded-by: _lock
    dedup_unique_rows: int = 0  # guarded-by: _lock

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self.edge_rate = RateEWMA()
        self._hub_edges = None
        self._hub_batches = None
        self._hub_batch_hist = None
        self._hub_publishes = None
        self._hub_publish_hist = None
        self._hub_dedup_raw = None
        self._hub_dedup_unique = None

    def bind_hub(self, tenant_id: str, backend: str = "") -> None:
        """Mirror this worker's counters into typed hub instruments
        (repro.obs), labeled by tenant/backend.  In remote workers the hub
        is child-local; its state reaches the parent via metrics beats."""
        from repro.obs.hub import get_hub
        hub = get_hub()
        labels = {"tenant": tenant_id}
        if backend:
            labels["backend"] = backend
        self._hub_edges = hub.counter(
            "repro_ingest_edges_total", "edges ingested", **labels)
        self._hub_batches = hub.counter(
            "repro_ingest_batches_total", "batches ingested", **labels)
        self._hub_batch_hist = hub.histogram(
            "repro_ingest_batch_edges", "edges per ingested batch",
            ladder="size", **labels)
        self._hub_publishes = hub.counter(
            "repro_publish_total", "snapshot publishes", **labels)
        self._hub_publish_hist = hub.histogram(
            "repro_publish_latency_seconds", "publish latency", **labels)
        self._hub_dedup_raw = hub.counter(
            "repro_ingest_dedup_raw_rows_total",
            "raw weight!=0 rows entering pre-aggregation", **labels)
        self._hub_dedup_unique = hub.counter(
            "repro_ingest_dedup_unique_rows_total",
            "unique (src,dst) rows dispatched after pre-aggregation",
            **labels)

    def note_started(self, now: float) -> None:
        with self._lock:
            self.started_at = now

    def note_ingest(self, n_edges: int, now: float) -> None:
        with self._lock:
            if not self.first_ingest_at:
                self.first_ingest_at = now
            self.last_ingest_at = now
            self.ingested_batches += 1
            self.ingested_edges += n_edges
            self.batches_since_publish += 1
            self.edge_rate.update(n_edges, now)
        # hub instruments lock themselves; mirrored outside _lock so the
        # static lock-order graph gains no metrics->hub edge
        if self._hub_edges is not None:
            self._hub_edges.inc(n_edges)
            self._hub_batches.inc()
            self._hub_batch_hist.observe(n_edges)

    def note_publish(self, latency_s: float, now: float) -> None:
        with self._lock:
            self.publishes += 1
            self.batches_since_publish = 0
            self.last_publish_at = now
            self.last_publish_latency_s = latency_s
            self.publish_latency_sum_s += latency_s
        if self._hub_publishes is not None:
            self._hub_publishes.inc()
            self._hub_publish_hist.observe(latency_s)

    def note_dedup(self, raw_rows: int, unique_rows: int) -> None:
        with self._lock:
            self.dedup_raw_rows += raw_rows
            self.dedup_unique_rows += unique_rows
        if self._hub_dedup_raw is not None:
            self._hub_dedup_raw.inc(raw_rows)
            self._hub_dedup_unique.inc(unique_rows)

    def note_checkpoint(self, now: float) -> None:
        with self._lock:
            self.checkpoints += 1
            self.last_checkpoint_at = now

    def pending_batches(self) -> int:
        """Batches ingested since the last publish (consistent read)."""
        with self._lock:
            return self.batches_since_publish

    def total_edges(self) -> int:
        with self._lock:
            return self.ingested_edges

    def snapshot(self, *, queue_stats: dict, state: str, epoch: int,
                 overflow_edges: int = 0, now: float | None = None) -> dict:
        """One JSON-able metrics view; ``queue_stats`` from the worker's queue.

        Taken under ``_lock`` so derived values (mean latency, lifetime
        rate) divide counters from the same instant — the reason this
        class grew a lock at all."""
        now = time.monotonic() if now is None else now
        with self._lock:
            # Lifetime throughput walls at the FIRST INGEST, not worker
            # start: billing spawn/compile warmup understated the rate and
            # contradicted the bench wall in runtime/backend.py (which uses
            # first_ingest_at).
            elapsed = max(now - self.first_ingest_at, 1e-9) \
                if self.first_ingest_at else 0.0
            return {
                "state": state,
                "epoch": epoch,
                "epoch_age_s": round(now - self.last_publish_at, 4)
                if self.last_publish_at else None,
                "ingested_batches": self.ingested_batches,
                "ingested_edges": self.ingested_edges,
                "first_ingest_at": self.first_ingest_at,
                "last_ingest_at": self.last_ingest_at,
                "batches_since_publish": self.batches_since_publish,
                "edges_per_s_ewma": round(self.edge_rate.rate, 1),
                "edges_per_s_lifetime": round(
                    self.ingested_edges / elapsed, 1)
                if elapsed else 0.0,
                "publishes": self.publishes,
                "last_publish_at": self.last_publish_at,
                "last_publish_latency_ms": round(
                    self.last_publish_latency_s * 1e3, 3),
                "mean_publish_latency_ms": round(
                    self.publish_latency_sum_s / self.publishes * 1e3, 3)
                if self.publishes else 0.0,
                "checkpoints": self.checkpoints,
                # pre-aggregation compression: raw/unique ≥ 1 once the
                # dedup path is on; 0/0 (ratio None) when it is off
                "dedup_raw_rows": self.dedup_raw_rows,
                "dedup_unique_rows": self.dedup_unique_rows,
                "dedup_ratio": round(
                    self.dedup_raw_rows / self.dedup_unique_rows, 4)
                if self.dedup_unique_rows else None,
                # accel-backend scatter-fallback volume (0 on the flat
                # backend): a rising rate means per-partition dispatch
                # capacity is being outgrown and ingest is silently paying
                # scatter cost
                "overflow_edges": overflow_edges,
                "queue_depth": queue_stats["depth"],
                "ingest_lag_batches": queue_stats["depth"],
                "dropped_batches": queue_stats["dropped_batches"],
                "dropped_edges": queue_stats["dropped_edges"],
                "spilled_batches": queue_stats["spilled_batches"],
                "max_queue_depth": queue_stats["max_depth_seen"],
            }
