"""repro.runtime — background ingest behind the serving engine.

Turns the PR 1 registry into a genuinely concurrent system (DESIGN.md
§Runtime): per-tenant ``IngestWorker`` threads pull stream batches from
bounded queues with explicit backpressure (block / drop-oldest / spill,
all drops accounted), fold them into the registry's delta sketch, and
publish epochs under a pluggable ``PublishPolicy``; the ``Runtime``
supervisor owns worker lifecycle (start, health, graceful drain-and-stop,
crash-like kill), the per-tenant online reservoir sample, crash-safe
checkpointing through ``repro.checkpoint.store``, and live metrics (queue
depth, ingest lag, edges/s, publish latency, epoch age).

Since PR 5 the worker's execution venue is an **execution backend**
(``runtime/backend.py``): ``backend="thread"`` keeps the classic in-process
worker threads; ``backend="process"`` runs each worker in a spawn-safe
multiprocessing child that owns its sketch and ships epoch-stamped
snapshot publications back into the parent's ``SnapshotBuffer`` — K-shard
ingest then scales past the GIL.

Entry points: ``launch/query_serve.py --background-ingest
[--runtime-backend process]`` and ``benchmarks/serve_bench.py
--concurrent`` / ``--shards K``.
"""
from repro.runtime.backend import (
    ExecutionBackend,
    ProcessBackend,
    ProcessWorker,
    ThreadBackend,
    WorkerFailure,
    resolve_backend,
)
from repro.runtime.metrics import RateEWMA, WorkerMetrics
from repro.runtime.policies import (
    EveryNBatches,
    PublishPolicy,
    QueueDrainWatermark,
    WallClockInterval,
    make_policy,
)
from repro.runtime.queueing import (
    BACKPRESSURE_POLICIES,
    BLOCK,
    DROP_OLDEST,
    SPILL,
    BoundedEdgeQueue,
    QueueItem,
)
from repro.runtime.supervisor import Runtime, StreamPump, TenantRuntime
from repro.runtime.worker import (
    CREATED,
    DRAINING,
    FAILED,
    RUNNING,
    STOPPED,
    IngestWorker,
    restore_worker_state,
)

__all__ = [
    "ExecutionBackend",
    "ProcessBackend",
    "ProcessWorker",
    "ThreadBackend",
    "WorkerFailure",
    "resolve_backend",
    "RateEWMA",
    "WorkerMetrics",
    "EveryNBatches",
    "PublishPolicy",
    "QueueDrainWatermark",
    "WallClockInterval",
    "make_policy",
    "BACKPRESSURE_POLICIES",
    "BLOCK",
    "DROP_OLDEST",
    "SPILL",
    "BoundedEdgeQueue",
    "QueueItem",
    "Runtime",
    "StreamPump",
    "TenantRuntime",
    "IngestWorker",
    "restore_worker_state",
    "CREATED",
    "RUNNING",
    "DRAINING",
    "STOPPED",
    "FAILED",
]
