"""Pallas TPU kernel: one boolean transitive-closure squaring step.

Reachability on matrix sketches (queries.py) is log2(w) squarings of a
boolean adjacency: R <- min(R @ R, 1).  This kernel is a classic tiled
matmul with a clamp epilogue; ops.py drives the outer squaring loop (each
step is one pallas_call — the data dependency between steps is global, so
steps cannot fuse).

Grid (M/TM, N/TN, K/TK), K innermost; the accumulator tile is f32 in VMEM
and the clamp runs on the final K step only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _closure_step_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = jnp.minimum(acc_ref[...], 1.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def reach_step(reach: jax.Array, *, block: int = 256, interpret: bool = True) -> jax.Array:
    """One squaring step R <- min(R @ R, 1). reach: f32[w, w], w % block == 0."""
    w = reach.shape[-1]
    assert w % block == 0, (w, block)
    n_k = w // block
    grid = (w // block, w // block, n_k)
    return pl.pallas_call(
        functools.partial(_closure_step_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((w, w), reach.dtype),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=interpret,
    )(reach, reach)
