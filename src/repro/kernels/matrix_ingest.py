"""Pallas TPU kernel: batched sketch ingest via one-hot MXU accumulation.

Hardware adaptation (DESIGN.md §TPU-adaptation): the GPU-native formulation
of sketch ingest is an atomic scatter-add — one random HBM write per (edge,
layer).  TPUs have no atomics and serialize XLA scatters, so we *reformulate
counting as matrix multiplication*: for an edge tile with row-slots ``hi``,
column-slots ``hj`` and weights ``wt``,

    increment = U^T @ (V * wt[:, None]),   U = onehot(hi), V = onehot(hj)

adds exactly ``wt[e]`` at cell ``(hi[e], hj[e])`` for every edge ``e`` in the
tile — a (w x TB) @ (TB x w) contraction that runs on the 128x128 systolic
MXU at full clip instead of a serialized scatter pipeline.  f32 accumulation
of 0/1-weighted products is exact for counts < 2^24; the result is cast and
added into the resident int32 tile.

Layout: ``pool`` is [d, P, w, w] — d hash layers, P partitions (P=1 recovers
plain TCM/gMatrix; P>1 is the kMatrix width-class layout).  Grid is
(d, P, C/TB) with the edge-tile axis innermost: each (layer, partition) out
block stays resident in VMEM while every edge tile streams through it.

VMEM budget @ defaults (w<=512, TB=256): pool tile 512*512*4 = 1 MiB,
U/V f32 tiles 2 * 256*512*4 = 1 MiB, well under the ~16 MiB/core budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ingest_kernel(hi_ref, hj_ref, wt_ref, pool_ref, out_ref):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = pool_ref[...]

    w = out_ref.shape[-1]
    tb = hi_ref.shape[-1]
    hi = hi_ref[0, 0, :]  # (TB,)
    hj = hj_ref[0, 0, :]
    wt = wt_ref[0, :].astype(jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (tb, w), 1)
    u = (hi[:, None] == iota).astype(jnp.float32)
    v = (hj[:, None] == iota).astype(jnp.float32) * wt[:, None]
    inc = jax.lax.dot_general(
        u, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (w, w) = U^T @ V
    out_ref[0, 0] += inc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def matrix_ingest(
    pool: jax.Array,
    hi: jax.Array,
    hj: jax.Array,
    wt: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """pool[r,p,hi[r,p,c],hj[r,p,c]] += wt[p,c] for all (r,p,c). See ref.py.

    Shapes: pool int32[d,P,w,w], hi/hj int32[d,P,C], wt int32[P,C].
    C must be a multiple of ``block_b`` (ops.py pads with wt=0 slots).
    """
    d, p, w, _ = pool.shape
    c = hi.shape[-1]
    assert c % block_b == 0, (c, block_b)
    grid = (d, p, c // block_b)
    return pl.pallas_call(
        _ingest_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_b), lambda r, q, b: (r, q, b)),
            pl.BlockSpec((1, 1, block_b), lambda r, q, b: (r, q, b)),
            pl.BlockSpec((1, block_b), lambda r, q, b: (q, b)),
            pl.BlockSpec((1, 1, w, w), lambda r, q, b: (r, q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w, w), lambda r, q, b: (r, q, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
    )(hi, hj, wt, pool)
