"""Pallas TPU kernel: batched sketch point-queries (gather + min over layers).

Same one-hot MXU trick as matrix_ingest, inverted: the addressed cell value
for query q is  (U @ M) ⊙ V  row-summed, i.e.

    val[q] = sum_j ( sum_i U[q,i] * M[i,j] ) * V[q,j] = M[hi[q], hj[q]]

Grid is (P, C/TQ, d) with the *layer* axis innermost so the min-accumulator
tile stays resident while layers stream through the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _lookup_kernel(hi_ref, hj_ref, pool_ref, out_ref):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INT32_MAX)

    w = pool_ref.shape[-1]
    tq = hi_ref.shape[-1]
    hi = hi_ref[0, 0, :]
    hj = hj_ref[0, 0, :]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tq, w), 1)
    u = (hi[:, None] == iota).astype(jnp.float32)
    v = (hj[:, None] == iota).astype(jnp.float32)
    m = pool_ref[0, 0].astype(jnp.float32)  # (w, w)
    uv = jax.lax.dot(u, m, preferred_element_type=jnp.float32)  # (TQ, w)
    vals = jnp.sum(uv * v, axis=-1).astype(out_ref.dtype)  # (TQ,)
    out_ref[0, :] = jnp.minimum(out_ref[0, :], vals)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def matrix_lookup(
    pool: jax.Array,
    hi: jax.Array,
    hj: jax.Array,
    *,
    block_q: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """min_r pool[r, p, hi[r,p,c], hj[r,p,c]] -> int32[P, C]. See ref.py."""
    d, p, w, _ = pool.shape
    c = hi.shape[-1]
    assert c % block_q == 0, (c, block_q)
    grid = (p, c // block_q, d)
    return pl.pallas_call(
        _lookup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q), lambda q, b, r: (r, q, b)),
            pl.BlockSpec((1, 1, block_q), lambda q, b, r: (r, q, b)),
            pl.BlockSpec((1, 1, w, w), lambda q, b, r: (r, q, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda q, b, r: (q, b)),
        out_shape=jax.ShapeDtypeStruct((p, c), pool.dtype),
        interpret=interpret,
    )(hi, hj, pool)
