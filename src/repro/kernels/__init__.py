"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three pieces (see EXAMPLE.md):
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     — jit'd wrappers binding kernels to sketch/model state
  ref.py     — pure-jnp oracles defining exact semantics

Kernels:
  matrix_ingest  — sketch ingest as one-hot MXU matmul accumulation
  matrix_lookup  — batched point queries (gather+min) via MXU
  reach_closure  — tiled boolean matmul squaring (reachability)
  embedding_bag  — scalar-prefetch row-gather + segment reduce (recsys)
"""
from repro.kernels.matrix_ingest import matrix_ingest
from repro.kernels.matrix_lookup import matrix_lookup
from repro.kernels.reach_closure import reach_step
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels import ops, ref

__all__ = [
    "matrix_ingest",
    "matrix_lookup",
    "reach_step",
    "embedding_bag",
    "ops",
    "ref",
]
