"""Pure-jnp oracles for every Pallas kernel in this package.

Each function defines the *exact* semantics its kernel must reproduce
(tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle, with the
kernel run in interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matrix_ingest_ref(pool: jax.Array, hi: jax.Array, hj: jax.Array,
                      wt: jax.Array) -> jax.Array:
    """Scatter-add edge counts into per-partition count matrices.

    pool: int32[d, P, w, w]   hi/hj: int32[d, P, C]   wt: int32[P, C]
    For every layer r, partition p, slot c:
        pool[r, p, hi[r,p,c], hj[r,p,c]] += wt[p, c]
    (wt == 0 marks padding / unused capacity slots.)
    """
    d, p, w, _ = pool.shape
    rows = jnp.arange(d, dtype=jnp.int32)[:, None, None]
    parts = jnp.arange(p, dtype=jnp.int32)[None, :, None]
    return pool.at[rows, parts, hi, hj].add(
        jnp.broadcast_to(wt[None], hi.shape).astype(pool.dtype)
    )


def matrix_lookup_ref(pool: jax.Array, hi: jax.Array, hj: jax.Array) -> jax.Array:
    """Point queries: min over layers of the addressed cells.

    pool: int32[d, P, w, w]   hi/hj: int32[d, P, C]  ->  int32[P, C]
    """
    d, p, w, _ = pool.shape
    rows = jnp.arange(d, dtype=jnp.int32)[:, None, None]
    parts = jnp.arange(p, dtype=jnp.int32)[None, :, None]
    return jnp.min(pool[rows, parts, hi, hj], axis=0)


def reach_step_ref(reach: jax.Array) -> jax.Array:
    """One boolean-closure squaring step: R <- min(R @ R, 1), R: f32[w, w]."""
    return jnp.minimum(
        jax.lax.dot(reach, reach, preferred_element_type=jnp.float32), 1.0
    )


def reach_closure_ref(adj: jax.Array, n_steps: int) -> jax.Array:
    """Reflexive-transitive closure via ``n_steps`` squarings. adj: f32[w,w]."""
    w = adj.shape[-1]
    reach = jnp.minimum(adj + jnp.eye(w, dtype=adj.dtype), 1.0)
    for _ in range(n_steps):
        reach = reach_step_ref(reach)
    return reach


def embedding_bag_ref(table: jax.Array, idx: jax.Array,
                      weights: jax.Array | None = None) -> jax.Array:
    """Fixed-arity embedding bag: out[b] = sum_f w[b,f] * table[idx[b,f]].

    table: f32[V, D]   idx: int32[B, F]   weights: f32[B, F] or None
    """
    rows = table[idx]  # [B, F, D]
    if weights is not None:
        rows = rows * weights[..., None]
    return rows.sum(axis=1)
