"""Pallas TPU kernel: fixed-arity EmbeddingBag (gather rows + reduce).

JAX has no native EmbeddingBag; the recsys substrate builds it from
``jnp.take`` + reduce.  On TPU the hot path is the HBM row gather — this
kernel uses a *scalar-prefetch* grid so each (bag, field) step's BlockSpec
index_map addresses table row ``idx[b, f]`` directly: Pallas double-buffers
the row DMAs (HBM -> VMEM) against the running bag accumulation, which is
exactly how production TPU embedding layers (and the row-gather half of
FBGEMM's TBE) are structured.

Grid (B, F), field axis innermost: out tile (1, D) stays resident per bag;
each step streams one table row through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, wt_ref, row_ref, out_ref, *, weighted: bool):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    row = row_ref[...]
    if weighted:
        row = row * wt_ref[0, f]
    out_ref[...] += row


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(
    table: jax.Array,
    idx: jax.Array,
    weights: jax.Array | None = None,
    *,
    interpret: bool = True,
) -> jax.Array:
    """out[b] = sum_f weights[b,f] * table[idx[b,f]].

    table: f32[V, D] (D lane-aligned for TPU), idx: int32[B, F],
    weights: f32[B, F] or None. Returns f32[B, D]. See ref.py oracle.
    """
    b, f = idx.shape
    v, d = table.shape
    weighted = weights is not None
    if weights is None:
        weights = jnp.ones((b, f), dtype=table.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, f),
        in_specs=[
            pl.BlockSpec((1, f), lambda i, j, idx_ref: (i, 0)),
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (idx_ref[i * f + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, weighted=weighted),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), weights, table)
