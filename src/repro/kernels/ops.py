"""jit'd public wrappers around the Pallas kernels.

Two consumption levels:

  * Global matrix sketches (TCM/gMatrix): drop-in accelerated ingest/lookup
    (`accel_matrix_ingest` / `accel_matrix_edge_freq`) on the (d, w, w)
    table — P=1 instances of the kernels.

  * kMatrix: the TPU-native `KMatrixAccel` state. Partition widths are
    quantized to power-of-two *width classes* so the pool rectangularizes
    into one (d, P_c, w_c, w_c) array per class — every block static, no
    scalar-prefetch offsets, and ingest batches become per-class MXU
    matmuls.  Edges are bucketed to (partition, slot) rectangles with a
    capacity factor; a sketch must count EVERY edge, so capacity overflow
    falls back to an exact in-jit scatter (never drops, unlike MoE).

On this CPU container every kernel runs with interpret=True (same dataflow,
Python-executed kernel body); on real TPUs pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.hashing import fastrange
from repro.core.kmatrix_accel import KMatrixAccel, dispatch_capacity
from repro.core.kmatrix_accel import edge_freq as kmatrix_accel_edge_freq  # noqa: F401 (kernel-level re-export)
from repro.core.matrix_sketch import MatrixSketch
from repro.core.types import EdgeBatch
from repro.kernels.matrix_ingest import matrix_ingest
from repro.kernels.matrix_lookup import matrix_lookup
from repro.kernels.reach_closure import reach_step
from repro.kernels.embedding_bag import embedding_bag  # re-export

_INTERPRET = jax.default_backend() != "tpu"


def _pad_edges(x: jax.Array, block: int, fill=0) -> jax.Array:
    b = x.shape[-1]
    pad = (-b) % block
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=fill)


# --------------------------------------------------------------------------
# Global (d, w, w) matrix sketches: P = 1
# --------------------------------------------------------------------------

def accel_matrix_ingest(sk: MatrixSketch, batch: EdgeBatch,
                        *, block_b: int = 256) -> MatrixSketch:
    hi = fastrange(sk.hashes.mix(batch.src), sk.w)  # [d, B]
    hj = fastrange(sk.hashes.mix(batch.dst), sk.w)
    hi = _pad_edges(hi, block_b)[:, None, :]  # [d, 1, C]
    hj = _pad_edges(hj, block_b)[:, None, :]
    wt = _pad_edges(batch.weight, block_b)[None, :]  # [1, C]
    table = matrix_ingest(
        sk.table[:, None], hi, hj, wt, block_b=block_b, interpret=_INTERPRET
    )[:, 0]
    return sk.replace(table=table)


def accel_matrix_edge_freq(sk: MatrixSketch, src: jax.Array, dst: jax.Array,
                           *, block_q: int = 256) -> jax.Array:
    hi = _pad_edges(fastrange(sk.hashes.mix(src), sk.w), block_q)[:, None, :]
    hj = _pad_edges(fastrange(sk.hashes.mix(dst), sk.w), block_q)[:, None, :]
    est = matrix_lookup(sk.table[:, None], hi, hj, block_q=block_q,
                        interpret=_INTERPRET)
    return est[0, : src.shape[-1]]


def accel_reach_closure(table: jax.Array, *, block: int = 128,
                        n_steps: int | None = None) -> jax.Array:
    """Boolean closure of every layer of int32[d, w, w] -> bool[d, w, w]."""
    d, w, _ = table.shape
    pad = (-w) % block
    adj = (table > 0).astype(jnp.float32)
    adj = jnp.pad(adj, ((0, 0), (0, pad), (0, pad)))
    wp = w + pad
    eye = jnp.eye(wp, dtype=jnp.float32)
    reach = jnp.minimum(adj + eye[None], 1.0)
    steps = n_steps if n_steps is not None else max(1, (w - 1).bit_length())
    step = functools.partial(reach_step, block=block, interpret=_INTERPRET)
    for _ in range(steps):
        reach = jax.vmap(step)(reach)
    return reach[:, :w, :w] > 0.5


# --------------------------------------------------------------------------
# kMatrix width-class layout
# --------------------------------------------------------------------------
#
# The ``KMatrixAccel`` state and its pure-jnp query/merge/relayout surface
# live in ``repro.core.kmatrix_accel`` (the sketch-protocol module the
# serving/runtime layers consume).  This file owns only the Pallas-backed
# ingest dispatch; the names below are re-exported for kernel-level callers.


def _dispatch(sk: KMatrixAccel, batch: EdgeBatch, capacity: int):
    """Bucket edges into per-partition rectangles (P, C) + overflow mask.

    Returns (slot, part, in_capacity): slot[e] is the edge's rank within its
    partition (stable), computed with one argsort — the TPU-friendly
    alternative to atomic counters.
    """
    p = sk.route.lookup(batch.src)  # [B]
    p = jnp.where(batch.weight > 0, p, jnp.int32(sk.route.n_partitions))  # park padding
    order = jnp.argsort(p)  # stable
    p_sorted = p[order]
    # rank within each partition = position - first position of that partition
    b = p.shape[0]
    pos = jnp.arange(b, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones(1, bool), p_sorted[1:] != p_sorted[:-1]])
    start_pos = jnp.where(is_start, pos, 0)
    start_of_group = jax.lax.associative_scan(jnp.maximum, start_pos)
    rank_sorted = pos - start_of_group
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    in_cap = (rank < capacity) & (batch.weight > 0)
    return p, rank, in_cap


def kmatrix_accel_ingest(sk: KMatrixAccel, batch: EdgeBatch,
                         *, capacity: int | None = None,
                         block_b: int = 128) -> KMatrixAccel:
    """Exact batched ingest: per-class Pallas matmul ingest for edges within
    capacity, in-jit scatter fallback for the overflow tail (no drops)."""
    b = batch.size
    if capacity is None:
        # sized from the partition plan's banded load (hottest partition's
        # expected share of the batch), NOT a uniform 2B/P — on skewed
        # streams the hot partition's load exceeds 2B/P by the skew factor
        # and every excess edge would pay the scatter fallback
        capacity = dispatch_capacity(sk, b, block_b)
    capacity = -(-capacity // block_b) * block_b

    p, rank, in_cap = _dispatch(sk, batch, capacity)
    d = sk.depth
    mix_src = sk.hashes.mix(batch.src)  # [d, B] uint32
    mix_dst = sk.hashes.mix(batch.dst)

    pools = list(sk.pools)
    for c, (w_c, p_c) in enumerate(zip(sk.class_widths, sk.class_counts)):
        if p_c == 0:
            continue
        sel = in_cap & (sk.part_class[p] == c)
        q = jnp.where(sel, sk.part_index[p], 0)
        # Park unselected edges at slot == capacity: out of bounds, dropped.
        # (Parking *in bounds* would let a parked .set(0) race a real edge.)
        slot = jnp.where(sel, rank, capacity)
        hi = fastrange(mix_src, w_c)  # [d, B]
        hj = fastrange(mix_dst, w_c)
        # Scatter edges into the (P_c, C) rectangle (weight 0 elsewhere).
        hi_r = jnp.zeros((d, p_c, capacity), jnp.int32).at[:, q, slot].set(
            jnp.where(sel[None], hi, 0), mode="drop")
        hj_r = jnp.zeros((d, p_c, capacity), jnp.int32).at[:, q, slot].set(
            jnp.where(sel[None], hj, 0), mode="drop")
        wt_r = jnp.zeros((p_c, capacity), jnp.int32).at[q, slot].add(
            jnp.where(sel, batch.weight, 0), mode="drop")
        pools[c] = matrix_ingest(pools[c], hi_r, hj_r, wt_r,
                                 block_b=block_b, interpret=_INTERPRET)

    # Overflow tail: exact scatter (rare; only when a partition exceeds cap).
    # The tally is surfaced as sk.overflow so capacity regressions show up
    # in runtime metrics instead of silently eating scatter-fallback cost.
    over = (~in_cap) & (batch.weight > 0)
    overflow = sk.overflow + jnp.sum(over.astype(sk.overflow.dtype))
    w_p = sk.part_width[p]
    hi_o = fastrange(mix_src, w_p)
    hj_o = fastrange(mix_dst, w_p)
    wts_o = jnp.where(over, batch.weight, 0)
    cls_o = sk.part_class[p]
    idx_o = sk.part_index[p]
    for c, (w_c, p_c) in enumerate(zip(sk.class_widths, sk.class_counts)):
        if p_c == 0:
            continue
        sel = over & (cls_o == c)
        rows = jnp.arange(d, dtype=jnp.int32)[:, None]
        pools[c] = pools[c].at[
            rows, jnp.where(sel, idx_o, 0)[None], hi_o, hj_o
        ].add(jnp.where(sel, wts_o, 0)[None], mode="drop")

    if sk.conn_w > 0:
        ci = fastrange(mix_src, sk.conn_w)
        cj = fastrange(mix_dst, sk.conn_w)
        rows = jnp.arange(d, dtype=jnp.int32)[:, None]
        conn = sk.conn.at[rows, ci, cj].add(batch.weight[None])
    else:
        conn = sk.conn
    return sk.replace(pools=tuple(pools), conn=conn, overflow=overflow)
