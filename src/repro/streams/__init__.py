from repro.streams.generators import (
    DATASETS,
    FileStream,
    StreamSpec,
    SyntheticStream,
    make_stream,
)
from repro.streams.reservoir import Reservoir, sample_stream

__all__ = [
    "DATASETS",
    "FileStream",
    "StreamSpec",
    "SyntheticStream",
    "make_stream",
    "Reservoir",
    "sample_stream",
]
