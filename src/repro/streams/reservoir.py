"""Reservoir sampling over edge streams (paper §V-A: 30k-edge init sample).

Vectorized Algorithm R: a whole batch is processed with one RNG draw per
element; deterministic given (seed, stream order).  Used to (a) bootstrap
the kMatrix/gSketch partitioners, (b) draw query workloads for the
benchmark suite, and (c) maintain the per-tenant *online* sample inside
``repro.runtime`` ingest workers — which is why the sampler exposes
``state_dict``/``load_state_dict`` (checkpoint/restore must reproduce the
exact sample a single uninterrupted pass would have produced).
"""
from __future__ import annotations

import numpy as np


class Reservoir:
    def __init__(self, k: int, seed: int = 0):
        self.k = k
        self._rng = np.random.default_rng(np.random.Philox(key=seed ^ 0x5EED))
        self._src = np.zeros(k, np.int32)
        self._dst = np.zeros(k, np.int32)
        self._w = np.zeros(k, np.int32)
        self._seen = 0

    def offer_batch(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> None:
        valid = w > 0
        src, dst, w = src[valid], dst[valid], w[valid]
        n = len(src)
        if n == 0:
            return
        pos = self._seen
        # Fill phase.
        if pos < self.k:
            take = min(self.k - pos, n)
            self._src[pos : pos + take] = src[:take]
            self._dst[pos : pos + take] = dst[:take]
            self._w[pos : pos + take] = w[:take]
            self._seen += take
            src, dst, w = src[take:], dst[take:], w[take:]
            n = len(src)
            if n == 0:
                return
        # Replacement phase: item t (1-based) replaces a random slot w.p. k/t.
        # Vectorized with the same draws (and therefore the same final state)
        # as the sequential loop: accepted items land in slot order, so the
        # LAST accepted item targeting a slot wins.  np.unique on the
        # reversed slot array yields each slot's last occurrence; duplicate
        # fancy-index assignment order is unspecified in numpy, so we must
        # not rely on it.
        t = self._seen + np.arange(1, n + 1, dtype=np.float64)
        accept = self._rng.random(n) < (self.k / t)
        slots = self._rng.integers(0, self.k, size=n)
        idx = np.nonzero(accept)[0]
        if idx.size:
            accepted_slots = slots[idx]
            uniq, last_rev = np.unique(accepted_slots[::-1], return_index=True)
            winners = idx[idx.size - 1 - last_rev]
            self._src[uniq] = src[winners]
            self._dst[uniq] = dst[winners]
            self._w[uniq] = w[winners]
        self._seen += n

    @property
    def seen(self) -> int:
        """Total non-padding edges offered so far."""
        return self._seen

    @property
    def sample(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = min(self._seen, self.k)
        return self._src[:n].copy(), self._dst[:n].copy(), self._w[:n].copy()

    # ---------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """Copy-out of the full sampler state (arrays + RNG bit-generator).

        ``arrays`` are plain numpy (checkpointable as pytree leaves);
        ``rng_state`` is JSON-able (uint64 arrays flattened to int lists).
        """
        return {
            "k": self.k,
            "seen": int(self._seen),
            "src": self._src.copy(),
            "dst": self._dst.copy(),
            "w": self._w.copy(),
            "rng_state": _rng_state_to_jsonable(self._rng.bit_generator.state),
        }

    def load_state_dict(self, state: dict) -> None:
        if int(state["k"]) != self.k:
            raise ValueError(
                f"reservoir size mismatch: checkpoint k={state['k']}, "
                f"this sampler k={self.k}")
        self._seen = int(state["seen"])
        self._src[:] = np.asarray(state["src"], np.int32)
        self._dst[:] = np.asarray(state["dst"], np.int32)
        self._w[:] = np.asarray(state["w"], np.int32)
        self._rng.bit_generator.state = _rng_state_from_jsonable(
            state["rng_state"])


def _rng_state_to_jsonable(state):
    if isinstance(state, dict):
        return {k: _rng_state_to_jsonable(v) for k, v in state.items()}
    if isinstance(state, np.ndarray):
        return {"__ndarray__": state.tolist(), "dtype": str(state.dtype)}
    if isinstance(state, np.integer):
        return int(state)
    return state


def _rng_state_from_jsonable(state):
    if isinstance(state, dict):
        if "__ndarray__" in state:
            return np.asarray(state["__ndarray__"], dtype=state["dtype"])
        return {k: _rng_state_from_jsonable(v) for k, v in state.items()}
    return state


def sample_stream(stream, k: int, seed: int = 0,
                  max_batches: int | None = None):
    """One-pass reservoir sample of ``k`` edges from a stream object."""
    res = Reservoir(k, seed)
    n = stream.num_batches if max_batches is None else min(max_batches, stream.num_batches)
    for i in range(n):
        res.offer_batch(*stream.batch_numpy(i))
    return res.sample
