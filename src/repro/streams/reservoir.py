"""Reservoir sampling over edge streams (paper §V-A: 30k-edge init sample).

Vectorized Algorithm R: a whole batch is processed with one RNG draw per
element; deterministic given (seed, stream order).  Used to (a) bootstrap
the kMatrix/gSketch partitioners and (b) draw query workloads for the
benchmark suite, both exactly as in the paper.
"""
from __future__ import annotations

import numpy as np


class Reservoir:
    def __init__(self, k: int, seed: int = 0):
        self.k = k
        self._rng = np.random.default_rng(np.random.Philox(key=seed ^ 0x5EED))
        self._src = np.zeros(k, np.int32)
        self._dst = np.zeros(k, np.int32)
        self._w = np.zeros(k, np.int32)
        self._seen = 0

    def offer_batch(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray) -> None:
        valid = w > 0
        src, dst, w = src[valid], dst[valid], w[valid]
        n = len(src)
        if n == 0:
            return
        pos = self._seen
        # Fill phase.
        if pos < self.k:
            take = min(self.k - pos, n)
            self._src[pos : pos + take] = src[:take]
            self._dst[pos : pos + take] = dst[:take]
            self._w[pos : pos + take] = w[:take]
            self._seen += take
            src, dst, w = src[take:], dst[take:], w[take:]
            n = len(src)
            if n == 0:
                return
        # Replacement phase: item t (1-based) replaces a random slot w.p. k/t.
        t = self._seen + np.arange(1, n + 1, dtype=np.float64)
        accept = self._rng.random(n) < (self.k / t)
        slots = self._rng.integers(0, self.k, size=n)
        for i in np.nonzero(accept)[0]:
            s = slots[i]
            self._src[s], self._dst[s], self._w[s] = src[i], dst[i], w[i]
        self._seen += n

    @property
    def sample(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = min(self._seen, self.k)
        return self._src[:n].copy(), self._dst[:n].copy(), self._w[:n].copy()


def sample_stream(stream, k: int, seed: int = 0,
                  max_batches: int | None = None):
    """One-pass reservoir sample of ``k`` edges from a stream object."""
    res = Reservoir(k, seed)
    n = stream.num_batches if max_batches is None else min(max_batches, stream.num_batches)
    for i in range(n):
        res.offer_batch(*stream.batch_numpy(i))
    return res.sample
