"""Deterministic, seekable synthetic graph-stream generators.

The paper benchmarks on unicorn-wget, email-EuAll and cit-HepPh; those files
are not available offline, so we generate *statistically matched* streams:
same node/edge counts, and power-law out/in-degree with per-dataset skew (the
property the kMatrix partitioner exploits).  Real edge-list files are
supported through ``FileStream`` when present on disk.

Replayability contract (used by checkpoint/restart): batch ``i`` of a stream
is a pure function of ``(seed, i)`` — we key a Philox generator with the
batch index, so seeking to any offset is O(1).  A restarted worker resumes
from the recorded batch offset and reproduces the identical stream.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import numpy as np

from repro.core.types import EdgeBatch


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Static description of an edge stream."""

    name: str
    n_nodes: int
    n_edges: int
    alpha_src: float  # Zipf skew of source endpoint choice
    alpha_dst: float
    self_loops: bool = False


# Paper §V-B datasets, statistically matched (node/edge counts from the text).
UNICORN_WGET = StreamSpec("unicorn-wget", 17_778, 277_972, 1.2, 1.1)
EMAIL_EUALL = StreamSpec("email-EuAll", 265_214, 420_045, 1.35, 1.25)
CIT_HEPPH = StreamSpec("cit-HepPh", 34_546, 421_578, 1.05, 1.3)
DATASETS = {s.name: s for s in (UNICORN_WGET, EMAIL_EUALL, CIT_HEPPH)}


def _zipf_cdf(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    weights = ranks ** (-alpha)
    cdf = np.cumsum(weights)
    return cdf / cdf[-1]


class SyntheticStream:
    """Power-law edge stream; batch i is a pure function of (seed, i)."""

    def __init__(self, spec: StreamSpec, *, batch_size: int = 8192, seed: int = 0):
        self.spec = spec
        self.batch_size = batch_size
        self.seed = seed
        self._cdf_src = _zipf_cdf(spec.n_nodes, spec.alpha_src)
        self._cdf_dst = _zipf_cdf(spec.n_nodes, spec.alpha_dst)
        # Node identities are a seeded permutation so that "rank 1" is not
        # always vertex 0 (adversarial for sequential-id hash families).
        perm_rng = np.random.default_rng(np.random.Philox(key=seed))
        self._perm_src = perm_rng.permutation(spec.n_nodes).astype(np.int32)
        self._perm_dst = perm_rng.permutation(spec.n_nodes).astype(np.int32)

    @property
    def num_batches(self) -> int:
        return -(-self.spec.n_edges // self.batch_size)

    def batch_numpy(self, i: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight) for batch ``i``; final batch zero-padded."""
        if not (0 <= i < self.num_batches):
            raise IndexError(i)
        lo = i * self.batch_size
        n = min(self.batch_size, self.spec.n_edges - lo)
        rng = np.random.default_rng(np.random.Philox(key=(self.seed << 20) + i + 1))
        u = rng.random((2, n))
        src = self._perm_src[np.searchsorted(self._cdf_src, u[0])]
        dst = self._perm_dst[np.searchsorted(self._cdf_dst, u[1])]
        if not self.spec.self_loops:
            collide = src == dst
            dst = np.where(collide, (dst + 1) % self.spec.n_nodes, dst)
        weight = np.ones(n, np.int32)
        if n < self.batch_size:
            pad = self.batch_size - n
            src = np.concatenate([src, np.zeros(pad, np.int32)])
            dst = np.concatenate([dst, np.zeros(pad, np.int32)])
            weight = np.concatenate([weight, np.zeros(pad, np.int32)])
        return src.astype(np.int32), dst.astype(np.int32), weight

    def batch(self, i: int) -> EdgeBatch:
        return EdgeBatch.from_numpy(*self.batch_numpy(i))

    def __iter__(self) -> Iterator[EdgeBatch]:
        for i in range(self.num_batches):
            yield self.batch(i)

    def iter_from(self, offset: int) -> Iterator[tuple[int, EdgeBatch]]:
        """Resume iteration from a checkpointed batch offset."""
        for i in range(offset, self.num_batches):
            yield i, self.batch(i)

    def all_edges_numpy(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialize the full stream host-side (test oracles only)."""
        parts = [self.batch_numpy(i) for i in range(self.num_batches)]
        src = np.concatenate([p[0] for p in parts])
        dst = np.concatenate([p[1] for p in parts])
        w = np.concatenate([p[2] for p in parts])
        keep = w > 0
        return src[keep], dst[keep], w[keep]


class FileStream:
    """Edge-list file stream ('src dst' per line, '#' comments). Loaded once
    host-side; batching/replay semantics identical to SyntheticStream."""

    def __init__(self, path: str, *, batch_size: int = 8192, name: str | None = None):
        edges = np.loadtxt(path, dtype=np.int64, comments="#")
        if edges.ndim == 1:
            edges = edges[None, :]
        self._src = edges[:, 0].astype(np.int32)
        self._dst = edges[:, 1].astype(np.int32)
        self.batch_size = batch_size
        n_nodes = int(max(self._src.max(initial=0), self._dst.max(initial=0)) + 1)
        self.spec = StreamSpec(
            name or os.path.basename(path), n_nodes, len(self._src), 0.0, 0.0
        )

    @property
    def num_batches(self) -> int:
        return -(-self.spec.n_edges // self.batch_size)

    def batch_numpy(self, i: int):
        lo = i * self.batch_size
        hi = min(lo + self.batch_size, self.spec.n_edges)
        n = hi - lo
        src, dst = self._src[lo:hi], self._dst[lo:hi]
        weight = np.ones(n, np.int32)
        if n < self.batch_size:
            pad = self.batch_size - n
            src = np.concatenate([src, np.zeros(pad, np.int32)])
            dst = np.concatenate([dst, np.zeros(pad, np.int32)])
            weight = np.concatenate([weight, np.zeros(pad, np.int32)])
        return src.astype(np.int32), dst.astype(np.int32), weight

    def batch(self, i: int) -> EdgeBatch:
        return EdgeBatch.from_numpy(*self.batch_numpy(i))

    def __iter__(self):
        for i in range(self.num_batches):
            yield self.batch(i)

    def iter_from(self, offset: int):
        for i in range(offset, self.num_batches):
            yield i, self.batch(i)

    def all_edges_numpy(self):
        return self._src, self._dst, np.ones(len(self._src), np.int32)


def make_stream(name: str, *, batch_size: int = 8192, seed: int = 0,
                scale: float = 1.0):
    """Stream factory. ``scale`` < 1 shrinks a dataset preset (CI-friendly)."""
    spec = DATASETS[name]
    if scale != 1.0:
        spec = dataclasses.replace(
            spec,
            n_nodes=max(int(spec.n_nodes * scale), 16),
            n_edges=max(int(spec.n_edges * scale), 64),
        )
    return SyntheticStream(spec, batch_size=batch_size, seed=seed)
