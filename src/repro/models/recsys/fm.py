"""Factorization Machine [Rendle, ICDM'10] — the assigned recsys arch.

Config: 39 sparse fields, embed_dim 10, 2-way FM interactions via the
O(n*k) sum-square identity:

    sum_{i<j} <v_i, v_j> x_i x_j = 0.5 * ( (sum_i v_i)^2 - sum_i v_i^2 )

The hot path is the embedding LOOKUP over huge tables.  JAX has no native
EmbeddingBag; ours is jnp.take + reduce (and the Pallas scalar-prefetch
kernel in repro.kernels.embedding_bag for the TPU row-gather).  Tables are
sharded over the model axis by ROW (hash-partitioned vocab), the classic
recsys table-parallel layout.

Vocab: per-field sizes follow a Criteo-like power-law (few huge id fields,
many small categoricals), hashed into a single fused table with per-field
offsets — one gather for all 39 fields.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.common import normal_init


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_fields: int = 39
    embed_dim: int = 10
    total_vocab: int = 10_000_000  # fused table rows (Criteo-scale)
    interaction: str = "fm-2way"

    def field_vocabs(self) -> np.ndarray:
        """Per-field vocab sizes, power-law distributed, summing ~total."""
        ranks = np.arange(1, self.n_fields + 1, dtype=np.float64)
        w = ranks**-1.2
        sizes = np.maximum((w / w.sum() * self.total_vocab).astype(np.int64), 4)
        return sizes

    def field_offsets(self) -> np.ndarray:
        sizes = self.field_vocabs()
        return np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)

    @property
    def table_rows(self) -> int:
        # padded to a multiple of 512 so the row dim shards on any mesh axis
        raw = int(self.field_vocabs().sum())
        return -(-raw // 512) * 512


def init_params(cfg: FMConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    rows = cfg.table_rows
    return {
        # 2nd-order factor table + 1st-order weight table (fused rows)
        "emb": normal_init(k1, (rows, cfg.embed_dim), 0.01),
        "lin": normal_init(k2, (rows, 1), 0.01),
        "bias": jnp.zeros((), jnp.float32),
    }


def _flat_ids(cfg: FMConfig, ids: jax.Array) -> jax.Array:
    """Per-field ids -> fused table rows. ids: int32[B, F]."""
    offs = jnp.asarray(cfg.field_offsets(), jnp.int32)
    sizes = jnp.asarray(cfg.field_vocabs(), jnp.int32)
    return offs[None, :] + jnp.remainder(ids, sizes[None, :])


def forward(cfg: FMConfig, params: dict, ids: jax.Array) -> jax.Array:
    """Logits [B] for a batch of multi-field categorical rows int32[B, F]."""
    rows = _flat_ids(cfg, ids)
    v = params["emb"][rows]  # (B, F, k)  <- THE hot gather
    lin = params["lin"][rows][..., 0]  # (B, F)
    sum_v = v.sum(axis=1)  # (B, k)
    sum_sq = (v * v).sum(axis=1)  # (B, k)
    pairwise = 0.5 * (sum_v * sum_v - sum_sq).sum(axis=-1)  # (B,)
    return params["bias"] + lin.sum(axis=-1) + pairwise


def bce_loss(cfg: FMConfig, params: dict, ids: jax.Array,
             labels: jax.Array) -> jax.Array:
    logits = forward(cfg, params, ids)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_scores(cfg: FMConfig, params: dict, query_ids: jax.Array,
                     cand_ids: jax.Array) -> jax.Array:
    """Score ONE query against N candidate items without a python loop.

    query_ids: int32[Fq] user-side fields; cand_ids: int32[N, Fc] item-side
    fields. FM decomposes: score(u, c) = fm(u) + fm(c) + <sum_v(u), sum_v(c)>
    so candidate scoring is one batched matvec over precomputed candidate
    aggregates — this is what makes 1M-candidate retrieval a single GEMV.
    """
    q = forward(cfg, params, query_ids[None, :])  # (1,)
    c = forward(cfg, params, cand_ids)  # (N,)
    vq = params["emb"][_flat_ids(cfg, query_ids[None, :])].sum(axis=1)  # (1, k)
    vc = params["emb"][_flat_ids(cfg, cand_ids)].sum(axis=1)  # (N, k)
    cross = (vc @ vq[0]).astype(jnp.float32)  # (N,)
    return q + c + cross


def forward_with_kernel(cfg: FMConfig, params: dict, ids: jax.Array,
                        *, interpret: bool = True) -> jax.Array:
    """Same as forward() but the gather+reduce runs through the Pallas
    embedding_bag kernel (sum_v directly; squares via a second bag)."""
    from repro.kernels.embedding_bag import embedding_bag

    rows = _flat_ids(cfg, ids)
    k = cfg.embed_dim
    pad = (-k) % 128  # lane alignment for the TPU kernel
    emb = jnp.pad(params["emb"], ((0, 0), (0, pad)))
    sum_v = embedding_bag(emb, rows, interpret=interpret)[:, :k]
    sum_sq = embedding_bag(emb * emb, rows, interpret=interpret)[:, :k]
    lin = embedding_bag(
        jnp.pad(params["lin"], ((0, 0), (0, 127))), rows, interpret=interpret
    )[:, 0]
    pairwise = 0.5 * (sum_v * sum_v - sum_sq).sum(axis=-1)
    return params["bias"] + lin + pairwise
