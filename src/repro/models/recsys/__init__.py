from repro.models.recsys import fm

__all__ = ["fm"]
