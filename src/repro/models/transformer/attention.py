"""GQA attention: RoPE, logit softcap, sliding-window/global, QK-norm.

Memory discipline: a 32k-token prefill cannot materialize (S, S) scores
(256 GB at gemma3 scale), so training/prefill attention is *blockwise*:
an outer lax.scan over query chunks with an online-softmax inner loop over
KV chunks (the FlashAttention recurrence, expressed in pure JAX so XLA/Mosaic
fuses it; a Pallas port is a further perf step, see EXPERIMENTS.md §Perf).

  * global layers: inner fori over KV chunks; a scalar lax.cond skips chunks
    that lie entirely in the causal future (real compute skip, not a mask).
  * local (sliding-window) layers: each query chunk dynamic-slices a
    (window + chunk_q) KV slab — compute is O(S * window), which is what
    makes the gemma-2/3 and mixtral long-context shapes sub-quadratic.

Decode (q_len == 1) attends to the full cache in one fused einsum chain —
O(S) and bandwidth-bound by design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import rms_norm, softcap

NEG_INF = -1e30


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta**exponent)  # (d_head/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _scores(q, k, scale, cap):
    """q: (B, Tq, KV, G, Dh), k: (B, Tk, KV, Dh) -> (B, KV, G, Tq, Tk)."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k, preferred_element_type=jnp.float32)
    return softcap(s * scale, cap)


def _mask(q_pos, k_pos, window):
    """(Tq, Tk) additive mask: causal, plus sliding window when window>0."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None and window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def blockwise_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, KV, Dh)
    v: jax.Array,  # (B, S, KV, Dh)
    *,
    window: int | None,  # None -> global
    attn_cap: float | None,
    chunk_q: int,
    chunk_kv: int,
) -> jax.Array:
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / np.sqrt(dh)
    chunk_q = min(chunk_q, s)
    nq = -(-s // chunk_q)
    sq_pad = nq * chunk_q
    qp = jnp.pad(q, ((0, 0), (0, sq_pad - s), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, chunk_q, kv, g, dh)

    if window is not None and window > 0:
        # ---- sliding window: one static KV slab per query chunk ----------
        slab = window + chunk_q
        kpad = jnp.pad(k, ((0, 0), (slab, sq_pad - s), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (slab, sq_pad - s), (0, 0), (0, 0)))

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def q_chunk(i):
            # remat per q-chunk: the layer-level checkpoint recomputes the
            # layer forward, but WITHIN that recomputation the backward
            # would otherwise hold every chunk's (Tq, window+Tq) score
            # tensor at once (~10 GiB/layer at gemma2 train_4k). Chunk-level
            # remat caps residuals at one chunk (§Perf iteration 1).
            q_i = qp[:, i]  # (B, Tq, KV, G, Dh)
            start = i * chunk_q  # first q position in chunk
            # Slab covers original positions [start - window, start + Tq - 1];
            # position x lives at index x + slab in the padded arrays, so the
            # slice starts at (start - window) + slab == start + chunk_q.
            k_i = jax.lax.dynamic_slice_in_dim(kpad, start + chunk_q, slab, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(vpad, start + chunk_q, slab, axis=1)
            s_i = _scores(q_i, k_i, scale, attn_cap)
            q_pos = start + jnp.arange(chunk_q)
            k_pos = start - window + jnp.arange(slab)  # true positions of slab
            valid = (k_pos >= 0) & (k_pos < s)
            s_i = s_i + _mask(q_pos, k_pos, window) + jnp.where(valid, 0.0, NEG_INF)
            p = jax.nn.softmax(s_i, axis=-1)
            return jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v_i)

        out = jax.lax.map(q_chunk, jnp.arange(nq))  # (nq, B, Tq, KV, G, Dh)
        out = jnp.moveaxis(out, 0, 1).reshape(b, sq_pad, h, dh)
        return out[:, :s]

    # ---- global causal: online softmax over KV chunks --------------------
    chunk_kv = min(chunk_kv, s)
    nk = -(-s // chunk_kv)
    sk_pad = nk * chunk_kv
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - s), (0, 0), (0, 0)))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_chunk(i):
        q_i = qp[:, i]
        q_pos = i * chunk_q + jnp.arange(chunk_q)

        def kv_step(j, carry):
            m, l, acc = carry

            @functools.partial(jax.checkpoint, prevent_cse=False)
            def visit(carry):
                m, l, acc = carry
                k_j = jax.lax.dynamic_slice_in_dim(kp, j * chunk_kv, chunk_kv, 1)
                v_j = jax.lax.dynamic_slice_in_dim(vp, j * chunk_kv, chunk_kv, 1)
                s_ij = _scores(q_i, k_j, scale, attn_cap)
                k_pos = j * chunk_kv + jnp.arange(chunk_kv)
                s_ij = s_ij + _mask(q_pos, k_pos, None) + jnp.where(
                    k_pos < s, 0.0, NEG_INF
                )
                m_new = jnp.maximum(m, s_ij.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s_ij - m_new[..., None])
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bkgqt,btkd->bkgqd", p, v_j.astype(jnp.float32)
                )
                return m_new, l_new, acc_new

            # Real skip for chunks fully in the causal future.
            first_q = i * chunk_q
            return jax.lax.cond(j * chunk_kv <= first_q + chunk_q - 1, visit,
                                lambda c: c, carry)

        m0 = jnp.full((b, kv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kv, g, chunk_q, dh), jnp.float32)
        m, l, acc = jax.lax.fori_loop(0, nk, kv_step, (m0, l0, a0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # (B, Tq, KV, G, Dh)

    out = jax.lax.map(q_chunk, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_pad, kv * g, dh).astype(q.dtype)
    return out[:, :s]


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, S, KV, Dh)
    v_cache: jax.Array,  # (B, S, KV, Dh)
    cache_len: jax.Array,  # scalar int32: number of valid cache positions
    *,
    window: int | None,
    attn_cap: float | None,
) -> jax.Array:
    b, s, kvh, dh = k_cache.shape
    h = q.shape[2]
    g = h // kvh
    scale = 1.0 / np.sqrt(dh)
    qr = q.reshape(b, 1, kvh, g, dh)
    scores = _scores(qr, k_cache, scale, attn_cap)[..., 0, :]  # (B, KV, G, S)
    pos = jnp.arange(s)
    ok = pos[None, None, None, :] < cache_len
    if window is not None and window > 0:
        ok &= pos[None, None, None, :] > cache_len - 1 - window
    scores = jnp.where(ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


def qk_rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """Per-head RMS norm on q/k (Gemma-3 replaces softcapping with this)."""
    return rms_norm(x, gamma, eps)
