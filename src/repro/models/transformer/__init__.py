from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.model import (
    KVCache,
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    lm_loss,
    logits_from_hidden,
    prefill,
)

__all__ = [
    "TransformerConfig",
    "KVCache",
    "decode_step",
    "forward_hidden",
    "init_cache",
    "init_params",
    "lm_loss",
    "logits_from_hidden",
    "prefill",
]
