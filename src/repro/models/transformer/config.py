"""Transformer family configuration covering all five assigned LM archs."""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # Attention pattern: cycled over layers, e.g. ("local","global") for
    # Gemma-2 alternation, ("local",)*5+("global",) for Gemma-3 5:1.
    layer_pattern: tuple[str, ...] = ("global",)
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # MoE dispatch groups: set to the data-shard count so routing argsorts
    # stay shard-local (see moe.moe_ffn_grouped). 1 = single global group.
    moe_groups: int = 1
    # misc
    act: str = "gelu"
    gated_mlp: bool = True  # GeGLU/SwiGLU when True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embed: bool = True
    embed_scale: bool = False  # Gemma multiplies embeddings by sqrt(d_model)
    post_norms: bool = False  # Gemma-2/3 post-attn/post-ffn RMSNorms
    dtype: str = "bfloat16"
    remat: bool = True
    # Megatron-style sequence parallelism: the scan carry (and thus the
    # per-layer saved residual stack) is sharded (batch over these DP axes,
    # seq over "model") instead of model-replicated — 16x less HBM for
    # saved activations at the cost of per-layer gather collectives.
    # None = off (CPU tests); e.g. ("data",) or ("pod", "data").
    seq_parallel: tuple | None = None
    # ZeRO-3 gather-at-use for FFN/expert weights (stored sharded over all
    # axes, constrained to model-only at the einsum). On for all dry-run
    # cells; off in CPU tests (no mesh context).
    zero3_gather: bool = False
    # hillclimb knobs (see EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    ce_chunk: int = 512

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> tuple[bool, ...]:
        """is_local flag per layer."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] == "local" for i in range(self.n_layers))

    @property
    def is_pure_global(self) -> bool:
        return all(not x for x in self.layer_kinds())

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
            + self.n_heads * self.d_head * d
        if self.is_moe:
            per_expert = (3 if self.gated_mlp else 2) * d * f
            ffn = self.n_experts * per_expert + d * self.n_experts  # + router
        else:
            ffn = (3 if self.gated_mlp else 2) * d * f
        norms = d * (4 if self.post_norms else 2)
        if self.qk_norm:
            norms += 2 * self.d_head
        layer = attn + ffn + norms
        embed = v * d * (1 if self.tie_embed else 2)
        return self.n_layers * layer + embed + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.gated_mlp else 2) * d * f
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return self.param_count() - inactive
