"""Top-k MoE FFN with sort-based (scatter) dispatch.

One-hot dispatch einsums (GShard/T5X style) materialize a (T, E, C) tensor —
hundreds of MB at our shapes — so we dispatch the way MegaBlocks/modern
systems do: flatten (token, k) assignments, argsort by expert, compute each
assignment's rank within its expert (one associative scan), and scatter rows
into a (E, C, D) buffer.  Over-capacity assignments are dropped with their
combine weight renormalized (standard training-time semantics; capacity
factor 1.25 * top_k keeps drops <1% at balanced load).

Sharding intent (see launch/shardings.py): tokens are data-parallel, expert
weight matrices are sharded over the model axis on d_ff (tensor-parallel
experts — for E=8 experts on 16-way model meshes, TP-inside-expert beats
expert-parallel all-to-all; the EP variant is evaluated in EXPERIMENTS.md).
An auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ACTIVATIONS


def moe_ffn_grouped(
    x: jax.Array,  # (T, D) flattened tokens
    router_w: jax.Array,
    w_in: jax.Array,
    w_out: jax.Array,
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    gated: bool,
    groups: int = 1,
    group_axes: tuple | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Dispatch per token GROUP (vmap), groups aligned to data shards.

    A global argsort over the sharded token dim would make SPMD gather all
    tokens every layer (measured: mixtral train_4k at 247 GiB/device).
    With ``groups == number of data shards`` each group's sort/scatter is
    shard-local; expert einsums broadcast weights across groups.
    """
    t, d = x.shape
    if t % groups != 0:  # e.g. batch-1 decode: fall back to one group
        groups = 1
    xg = x.reshape(groups, t // groups, d)

    mesh = None
    if group_axes is not None:
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is None or mesh.empty or "model" not in mesh.axis_names:
                mesh = None
        except Exception:
            mesh = None

    if mesh is None or groups == 1:
        # CPU/tests or single-group (batch-1 decode): plain vmap
        def one_group(xi):
            return moe_ffn(xi, router_w, w_in, w_out, top_k=top_k,
                           capacity_factor=capacity_factor, act=act,
                           gated=gated)

        yg, aux = jax.vmap(one_group)(xg)
        return yg.reshape(t, d), aux.mean()

    # ---- distributed: explicit shard_map ----------------------------------
    # vmap + SPMD replicated every group on every device (measured 20x
    # FLOPs / 220 GiB on mixtral train). shard_map pins one group per
    # data-rank; expert weights arrive ZeRO-sharded over every axis and are
    # all-gathered over the DP axes to model-only sharding at use.
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(group_axes)
    all_axes = tuple(mesh.axis_names)

    def local(xg_l, rw, w_in_l, w_out_l):
        # xg_l: (1, Tg, D); w slices: F sharded over every axis
        w_in_g = jax.lax.all_gather(w_in_l, dp, axis=2, tiled=True)
        w_out_g = jax.lax.all_gather(w_out_l, dp, axis=1, tiled=True)
        y, aux = moe_ffn(xg_l[0], rw, w_in_g, w_out_g, top_k=top_k,
                         capacity_factor=capacity_factor, act=act,
                         gated=gated)
        # out contributions are partial over the model-sharded F dim
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")
        return y[None], aux[None]

    yg, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(None, None, all_axes), P(None, all_axes, None)),
        out_specs=(P(dp, None, None), P(dp)),
    )(xg, router_w, w_in, w_out)
    return yg.reshape(t, d), aux.mean()


def moe_ffn(
    x: jax.Array,  # (T, D) flattened tokens
    router_w: jax.Array,  # (D, E)
    w_in: jax.Array,  # (E, D, F) — gate+up fused when gated: (E, D, 2F)
    w_out: jax.Array,  # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    gated: bool,
) -> tuple[jax.Array, jax.Array]:
    t, d = x.shape
    e = router_w.shape[-1]
    f = w_out.shape[1]
    cap = int(t * top_k * capacity_factor / e)
    cap = max(cap, top_k)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (t * top_k)
    aux_loss = e * jnp.sum(me * ce)

    # ---- sort-based dispatch ---------------------------------------------
    flat_e = expert_ids.reshape(-1)  # (T*K,)
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)  # stable
    e_sorted = flat_e[order]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones(1, bool), e_sorted[1:] != e_sorted[:-1]])
    start_of_group = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, pos, 0)
    )
    rank_sorted = pos - start_of_group
    rank = jnp.zeros(n, jnp.int32).at[order].set(rank_sorted)  # (T*K,)
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)  # cap == out-of-bounds -> dropped

    token_of = jnp.arange(n, dtype=jnp.int32) // top_k
    buf = jnp.zeros((e, cap, d), x.dtype).at[flat_e, slot].set(
        x[token_of], mode="drop"
    )

    # ---- expert computation ----------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if gated:
        g, u = jnp.split(h, 2, axis=-1)
        h = ACTIVATIONS[act](g) * u
    else:
        h = ACTIVATIONS[act](h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)  # (E, C, D)

    # ---- combine ----------------------------------------------------------
    rows = out_buf[flat_e, jnp.minimum(slot, cap - 1)]  # (T*K, D)
    w_flat = jnp.where(keep, gate_vals.reshape(-1), 0.0)
    y = jnp.zeros((t, d), jnp.float32).at[token_of].add(
        rows.astype(jnp.float32) * w_flat[:, None]
    )
    return y.astype(x.dtype), aux_loss
