"""Transformer model: init / train forward / prefill / decode.

Layer stacking: parameters are stacked (n_periods, period, ...) and the
forward pass is a single ``lax.scan`` over *pattern periods* (gemma-2's
local/global alternation has period 2, gemma-3's 5:1 has period 6, uniform
archs period 1).  The period is unrolled in Python inside the scan body, so
each layer kind is statically specialized (no dead branches, no per-layer
cond) while HLO size stays O(period), keeping 62-layer compiles cheap.

Loss: cross-entropy is computed in sequence chunks with the vocab dimension
model-sharded (Megatron-style vocab-parallel CE); full (B, S, V) logits are
never materialized (gemma3 would need 33 GB/device otherwise).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (
    ACTIVATIONS,
    dense_init,
    normal_init,
    rms_norm,
    softcap,
    stacked_layer_init,
)
from repro.models.transformer.attention import (
    apply_rope,
    blockwise_attention,
    decode_attention,
    qk_rms_norm,
)
from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.moe import moe_ffn


def _period(cfg: TransformerConfig) -> int:
    return len(cfg.layer_pattern)


def _dtype(cfg: TransformerConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- params --

def init_layer(cfg: TransformerConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    d, h, kv, dh, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff
    dt = _dtype(cfg)
    p = {
        "ln_attn": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], d, h * dh, dt),
        "wk": dense_init(ks[1], d, kv * dh, dt),
        "wv": dense_init(ks[2], d, kv * dh, dt),
        "wo": dense_init(ks[3], h * dh, d, dt),
        "ln_mlp": jnp.zeros((d,), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dt)
        p["k_norm"] = jnp.zeros((dh,), dt)
    if cfg.post_norms:
        p["ln_post_attn"] = jnp.zeros((d,), dt)
        p["ln_post_mlp"] = jnp.zeros((d,), dt)
    fin = 2 * f if cfg.gated_mlp else f
    if cfg.is_moe:
        p["router"] = dense_init(ks[4], d, cfg.n_experts, jnp.float32)
        p["w_in"] = jax.vmap(lambda k_: dense_init(k_, d, fin, dt))(
            jax.random.split(ks[5], cfg.n_experts)
        )
        p["w_out"] = jax.vmap(lambda k_: dense_init(k_, f, d, dt))(
            jax.random.split(ks[6], cfg.n_experts)
        )
    else:
        p["w_in"] = dense_init(ks[5], d, fin, dt)
        p["w_out"] = dense_init(ks[6], f, d, dt)
    return p


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    k_embed, k_layers, k_head, k_tail = jax.random.split(key, 4)
    per = _period(cfg)
    n_per = cfg.n_layers // per
    rem = cfg.n_layers - n_per * per  # tail layers when period doesn't divide
    dt = _dtype(cfg)

    def init_period(k_):
        return [init_layer(cfg, kk) for kk in jax.random.split(k_, per)]

    layers = stacked_layer_init(init_period, k_layers, n_per)
    params = {
        # 1/sqrt(d) keeps tied-head logits ~unit-scale at init; the Gemma
        # embed_scale (sqrt(d) on the input side) restores unit embeddings.
        "embed": normal_init(k_embed, (cfg.vocab, cfg.d_model),
                             cfg.d_model**-0.5, dt),
        "layers": layers,  # list of per dicts, leaves (n_per, ...)
        "tail": [init_layer(cfg, kk) for kk in jax.random.split(k_tail, rem)]
        if rem else [],
        "ln_final": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embed:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    return params


# --------------------------------------------------------------- forward --

def _cache_write(cache: jax.Array, new: jax.Array, offset: jax.Array) -> jax.Array:
    """Write ``new`` (B, s, KV, Dh) into cache (B, S, KV, Dh) at ``offset``
    along S, as a shard-friendly one-hot select (no dynamic-update-slice)."""
    s_new = new.shape[1]
    s_max = cache.shape[1]
    pos = jnp.arange(s_max, dtype=jnp.int32)
    in_window = (pos >= offset) & (pos < offset + s_new)
    if s_new == 1:
        # decode: plain broadcast — fuses into the select, no gather temp
        placed = jnp.broadcast_to(new.astype(cache.dtype), cache.shape)
    else:
        # prefill: roll new into place via clipped gather, masked below
        idx = jnp.clip(pos - offset, 0, s_new - 1)
        placed = jnp.take(new.astype(cache.dtype), idx, axis=1)
    return jnp.where(in_window[None, :, None, None], placed, cache)


def _attn_block(cfg: TransformerConfig, p: dict, x, positions, is_local: bool,
                cache=None, cache_len=None):
    """Returns (out, (k, v)) — k/v returned for prefill cache collection."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    y = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q = jnp.einsum("bsd,dh->bsh", y, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,dh->bsh", y, p["wk"]).reshape(b, s, kv, dh)
    v = jnp.einsum("bsd,dh->bsh", y, p["wv"]).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = qk_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = qk_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if is_local else None

    if cache is None:
        # Training: no cache.
        out = blockwise_attention(
            q, k, v, window=window, attn_cap=cfg.attn_softcap,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        )
    elif s > 1:
        # Prefill: blockwise attention over the prompt, then write the cache.
        # Cache writes are ONE-HOT selects, not dynamic_update_slice: the S
        # dim may be sharded (long-context serving) and an elementwise
        # select keeps SPMD from all-gathering the cache.
        k_cache, v_cache = cache
        out = blockwise_attention(
            q, k, v, window=window, attn_cap=cfg.attn_softcap,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        )
        k, v = _cache_write(k_cache, k, cache_len), _cache_write(v_cache, v, cache_len)
    else:
        # Decode: one token against the full cache.
        k_cache, v_cache = cache
        k_cache = _cache_write(k_cache, k, cache_len)
        v_cache = _cache_write(v_cache, v, cache_len)
        out = decode_attention(
            q, k_cache, v_cache, cache_len + s,
            window=window, attn_cap=cfg.attn_softcap,
        )
        k, v = k_cache, v_cache

    out = out.reshape(b, s, h * dh)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if cfg.post_norms:
        out = rms_norm(out, p["ln_post_attn"], cfg.norm_eps)
    return out, (k, v)


def _gather_weight(cfg: TransformerConfig, w: jax.Array, f_dim: int):
    """ZeRO-3 gather-at-use: FFN weights are STORED sharded over every mesh
    axis (launch/shardings.py) but must be model-only-sharded at the einsum
    — if d_ff stays data-sharded while activations are data-sharded on
    batch, SPMD reshards the (huge) activations instead of the (small)
    weights (mixtral train measured 175 GiB/device). Only active in
    distributed mode (zero3_gather set by the dry-run cell builder)."""
    if not cfg.zero3_gather:
        return w
    from jax.sharding import PartitionSpec as P

    spec = [None] * w.ndim
    spec[f_dim] = "model"
    return jax.lax.with_sharding_constraint(w, P(*spec))


def _ffn_block(cfg: TransformerConfig, p: dict, x):
    """Returns (out, aux_loss)."""
    b, s, d = x.shape
    y = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        from repro.models.transformer.moe import moe_ffn_grouped

        # NOTE: no _gather_weight here — the grouped shard_map declares the
        # all-axes (ZeRO) layout in its in_specs and all-gathers over the DP
        # axes itself; constraining first would just double the resharding.
        out, aux = moe_ffn_grouped(
            y.reshape(b * s, d), p["router"], p["w_in"], p["w_out"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.act, gated=cfg.gated_mlp,
            groups=cfg.moe_groups, group_axes=cfg.seq_parallel,
        )
        out = out.reshape(b, s, d)
    else:
        w_in = _gather_weight(cfg, p["w_in"], 1)  # (D, F*)
        w_out = _gather_weight(cfg, p["w_out"], 0)  # (F, D)
        h = jnp.einsum("bsd,df->bsf", y, w_in)
        if cfg.gated_mlp:
            g, u = jnp.split(h, 2, axis=-1)
            h = ACTIVATIONS[cfg.act](g) * u
        else:
            h = ACTIVATIONS[cfg.act](h)
        out = jnp.einsum("bsf,fd->bsd", h, w_out)
        aux = jnp.zeros((), jnp.float32)
    if cfg.post_norms:
        out = rms_norm(out, p["ln_post_mlp"], cfg.norm_eps)
    return out, aux


def _layer(cfg, p, x, positions, is_local, cache=None, cache_len=None):
    attn_out, new_cache = _attn_block(cfg, p, x, positions, is_local, cache, cache_len)
    x = x + attn_out
    ffn_out, aux = _ffn_block(cfg, p, x)
    return x + ffn_out, aux, new_cache


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sharded_embed_lookup(meta, embed, tokens):
    return embed[tokens]


def _sel_fwd(meta, embed, tokens):
    return embed[tokens], tokens


def _sel_bwd(meta, tokens, g):
    """Vocab-parallel embedding gradient (Megatron style).

    A plain ``zeros.at[tokens].add(g)`` makes SPMD materialize the FULL
    (V, D) f32 cotangent before any sharding constraint applies (gemma3:
    6 x 5.25 GiB measured). Instead each model shard scatters only its own
    vocab row range locally under shard_map, then psums over the
    data-parallel axes — peak is (V/n_model, D) per device."""
    vocab, d_model, dtype_str, dp = meta
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names or "model" not in mesh.axis_names:
            mesh = None
    except Exception:
        mesh = None
    if mesh is None or dp is None:
        d_embed = jnp.zeros((vocab, d_model), g.dtype).at[tokens].add(g)
        return d_embed.astype(jnp.dtype(dtype_str)), None

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape["model"]
    rows = vocab // n_model
    dp_t = tuple(dp)

    def local(tok, g_loc):
        my = jax.lax.axis_index("model")
        idx = tok - my * rows
        valid = (idx >= 0) & (idx < rows)
        idx = jnp.where(valid, idx, rows)  # out of bounds -> dropped
        d_loc = jnp.zeros((rows, d_model), g_loc.dtype).at[idx].add(
            jnp.where(valid[..., None], g_loc, 0.0), mode="drop")
        for ax in dp_t:
            d_loc = jax.lax.psum(d_loc, ax)
        return d_loc

    d_embed = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp_t, None), P(dp_t, None, None)),
        out_specs=P("model", None),
    )(tokens, g)
    return d_embed.astype(jnp.dtype(dtype_str)), None


_sharded_embed_lookup.defvjp(_sel_fwd, _sel_bwd)


def embed_tokens(cfg: TransformerConfig, params, tokens):
    if cfg.zero3_gather:  # distributed mode: sharded-cotangent lookup
        x = _sharded_embed_lookup(
            (cfg.vocab, cfg.d_model, cfg.dtype, cfg.seq_parallel),
            params["embed"], tokens)
    else:
        x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def _seq_shard(cfg: TransformerConfig, x):
    """Sequence-parallel annotation for the residual stream (see config)."""
    if cfg.seq_parallel is None:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(tuple(cfg.seq_parallel), "model", None)
    )


def forward_hidden(cfg: TransformerConfig, params, tokens, positions):
    """Token ids -> final hidden states (B, S, D); scan over periods."""
    x = embed_tokens(cfg, params, tokens)
    kinds = cfg.layer_kinds()
    per = _period(cfg)

    def body(carry, period_params):
        # Remat is PER LAYER, not per period: a period-level checkpoint
        # keeps all ``per`` layers' residuals live during the body backward
        # (gemma3's 5:1 pattern -> 6x residual concurrency, measured +25
        # GiB). Per-layer checkpoints bound it to one layer while the scan
        # still saves only one carry per period.
        x, aux = carry
        x = _seq_shard(cfg, x)
        for j in range(per):
            def layer_fn(x_, p_, _j=j):
                out, a_, _ = _layer(cfg, p_, x_, positions, kinds[_j])
                return out, a_

            if cfg.remat:
                layer_fn = jax.checkpoint(layer_fn, prevent_cse=False)
            x, a = layer_fn(x, period_params[j])
            aux = aux + a
        return (_seq_shard(cfg, x), aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    n_scanned = (cfg.n_layers // per) * per
    for j, p_tail in enumerate(params["tail"]):
        # tail layers get the same remat treatment as the scanned stack —
        # unrematted they each pin full attention residuals (§Perf it. 7)
        def tail_fn(x_, p_):
            out, a_, _ = _layer(cfg, p_, x_, positions, kinds[n_scanned + j])
            return out, a_

        if cfg.remat:
            tail_fn = jax.checkpoint(tail_fn, prevent_cse=False)
        x, a = tail_fn(x, p_tail)
        aux = aux + a
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    return x, aux


def logits_from_hidden(cfg: TransformerConfig, params, hidden):
    w = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden, w)
    return softcap(logits, cfg.final_softcap)


def chunked_ce_loss(cfg: TransformerConfig, params, hidden, labels,
                    mask=None):
    """Vocab-parallel chunked cross entropy; never materializes (B,S,V)."""
    b, s, d = hidden.shape
    chunk = min(cfg.ce_chunk, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, pad)))
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = jnp.pad(mask, ((0, 0), (0, pad)))
    w = params["embed"].T if cfg.tie_embed else params["lm_head"]

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(i, acc):
        # remat: without this the loss scan's backward would hold every
        # chunk's (B, chunk, V/model) f32 logits (~4 GiB at gemma scale).
        h_c = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, 1)
        l_c = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        m_c = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        logits = softcap(
            jnp.einsum("bsd,dv->bsv", h_c, w).astype(jnp.float32),
            cfg.final_softcap,
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - true) * m_c)

    total = jax.lax.fori_loop(0, n, chunk_loss, jnp.zeros((), jnp.float32))
    return total / jnp.maximum(mask.sum(), 1.0)


def lm_loss(cfg: TransformerConfig, params, tokens, labels,
            aux_weight: float = 0.01):
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    hidden, aux = forward_hidden(cfg, params, tokens, positions)
    ce = chunked_ce_loss(cfg, params, hidden, labels)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------- serving --

class KVCache(NamedTuple):
    k: jax.Array  # (n_per, per, B, S_max, KV, Dh)
    v: jax.Array
    k_tail: jax.Array  # (rem, B, S_max, KV, Dh) — possibly rem == 0
    v_tail: jax.Array
    length: jax.Array  # scalar int32


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    per = _period(cfg)
    n_per = cfg.n_layers // per
    rem = cfg.n_layers - n_per * per
    shape = (n_per, per, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    tail_shape = (rem, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros(tail_shape, dtype), jnp.zeros(tail_shape, dtype),
                   jnp.zeros((), jnp.int32))


def prefill(cfg: TransformerConfig, params, tokens, cache: KVCache):
    """Run the prompt through the model, filling the cache; returns
    (next-token logits, cache)."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    x = embed_tokens(cfg, params, tokens)
    kinds = cfg.layer_kinds()
    per = _period(cfg)

    def body(x, scanned):
        period_params, k_cache, v_cache = scanned
        new_ks, new_vs = [], []
        for j in range(per):
            cache_j = (k_cache[j], v_cache[j])
            x_new, _, (k_j, v_j) = _layer(
                cfg, period_params[j], x, positions, kinds[j],
                cache=cache_j, cache_len=jnp.zeros((), jnp.int32))
            x = x_new
            new_ks.append(k_j)
            new_vs.append(v_j)
        return x, (jnp.stack(new_ks), jnp.stack(new_vs))

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    n_scanned = (cfg.n_layers // per) * per
    tail_ks, tail_vs = [], []
    for j, p_tail in enumerate(params["tail"]):
        x, _, (k_j, v_j) = _layer(
            cfg, p_tail, x, positions, kinds[n_scanned + j],
            cache=(cache.k_tail[j], cache.v_tail[j]),
            cache_len=jnp.zeros((), jnp.int32))
        tail_ks.append(k_j)
        tail_vs.append(v_j)
    k_tail = jnp.stack(tail_ks) if tail_ks else cache.k_tail
    v_tail = jnp.stack(tail_vs) if tail_vs else cache.v_tail
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1:])
    return logits, KVCache(ks, vs, k_tail, v_tail, jnp.asarray(s, jnp.int32))


def decode_step(cfg: TransformerConfig, params, tokens, cache: KVCache):
    """One decode step: tokens (B, 1) -> (logits, updated cache)."""
    positions = jnp.full((tokens.shape[0], 1), cache.length, jnp.int32)
    x = embed_tokens(cfg, params, tokens)
    kinds = cfg.layer_kinds()
    per = _period(cfg)

    def body(x, scanned):
        period_params, k_cache, v_cache = scanned
        new_ks, new_vs = [], []
        for j in range(per):
            x, _, (k_j, v_j) = _layer(
                cfg, period_params[j], x, positions, kinds[j],
                cache=(k_cache[j], v_cache[j]), cache_len=cache.length)
            new_ks.append(k_j)
            new_vs.append(v_j)
        return x, (jnp.stack(new_ks), jnp.stack(new_vs))

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    n_scanned = (cfg.n_layers // per) * per
    tail_ks, tail_vs = [], []
    for j, p_tail in enumerate(params["tail"]):
        x, _, (k_j, v_j) = _layer(
            cfg, p_tail, x, positions, kinds[n_scanned + j],
            cache=(cache.k_tail[j], cache.v_tail[j]), cache_len=cache.length)
        tail_ks.append(k_j)
        tail_vs.append(v_j)
    k_tail = jnp.stack(tail_ks) if tail_ks else cache.k_tail
    v_tail = jnp.stack(tail_vs) if tail_vs else cache.v_tail
    x = rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x)
    return logits, KVCache(ks, vs, k_tail, v_tail, cache.length + 1)
