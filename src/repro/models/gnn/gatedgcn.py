"""GatedGCN [arXiv:2003.00982 benchmarking / 1711.07553] — edge-gated MPNN.

Layer (Bresson & Laurent):
    e'_ij = E1 e_ij + E2 h_i + E3 h_j                       (edge update)
    eta_ij = sigma(e'_ij) / (sum_{j'} sigma(e'_ij') + eps)  (gates)
    h'_i  = A h_i + sum_j eta_ij ⊙ (B h_j)                  (node update)
with BN->ReLU->residual on both streams (we use LayerNorm — batch-size-free
and the standard modern substitution).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, layer_norm
from repro.models.gnn.graph import GraphBatch, scatter_sum


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_out: int = 16
    aggregator: str = "gated"
    remat: bool = False


def init_layer(cfg: GatedGCNConfig, key) -> dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, 5)
    return {
        "A": dense_init(ks[0], d, d),
        "B": dense_init(ks[1], d, d),
        "E1": dense_init(ks[2], d, d),
        "E2": dense_init(ks[3], d, d),
        "E3": dense_init(ks[4], d, d),
        "ln_h_g": jnp.ones((d,)),
        "ln_h_b": jnp.zeros((d,)),
        "ln_e_g": jnp.ones((d,)),
        "ln_e_b": jnp.zeros((d,)),
    }


def init_params(cfg: GatedGCNConfig, key, d_in: int, d_edge_in: int = 8) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(
        jax.random.split(k3, cfg.n_layers)
    )
    return {
        "embed_h": dense_init(k1, d_in, cfg.d_hidden),
        "embed_e": dense_init(k2, d_edge_in, cfg.d_hidden),
        "layers": layers,
        "head": dense_init(k4, cfg.d_hidden, cfg.d_out),
    }


def _layer(cfg: GatedGCNConfig, p: dict, h, e, g: GraphBatch):
    hi = h[g.edge_src]
    hj = h[g.edge_dst]
    e_new = e @ p["E1"] + hi @ p["E2"] + hj @ p["E3"]
    gate = jax.nn.sigmoid(e_new) * g.edge_mask[:, None]
    denom = scatter_sum(gate, g.edge_dst, g.n_nodes) + 1e-6
    msg = scatter_sum(gate * (hi @ p["B"]), g.edge_dst, g.n_nodes)
    h_new = h @ p["A"] + msg / denom
    h = h + jax.nn.relu(layer_norm(h_new, p["ln_h_g"], p["ln_h_b"]))
    e = e + jax.nn.relu(layer_norm(e_new, p["ln_e_g"], p["ln_e_b"]))
    return h, e


def forward(cfg: GatedGCNConfig, params: dict, g: GraphBatch) -> jax.Array:
    """Node-level outputs [N, d_out]."""
    h = g.node_feat @ params["embed_h"]
    e = g.edge_feat @ params["embed_e"]

    def body(carry, lp):
        h, e = carry
        h, e = _layer(cfg, lp, h, e, g)
        return (h, e), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return h @ params["head"]
