"""NequIP [arXiv:2101.03164] — E(3)-equivariant interatomic potential.

Assigned config: 5 layers, 32 channels, l_max=2, 8 Bessel RBFs, cutoff 5 A.

Features are (N, C_irr, d) with C_irr = (l_max+1)^2 SH-indexed components and
d channels.  An interaction layer computes, per edge (j -> i):

    m_ij[l3] = sum_paths  R_path(|r|) * G_{l1 l2 l3} ( h_j[l1] (x) Y_{l2}(r^) )

with learned radial MLPs R on a Bessel basis under a smooth polynomial
cutoff, followed by per-l self-interactions and gated nonlinearities
(scalars: silu; l>0: sigmoid gates from scalar channels — the NequIP gate).

Energy = sum_atoms MLP(h[l=0]); forces = -dE/dpositions via jax.grad (tested
for rotation equivariance end-to-end).  Parity subtleties of full E(3)
(improper reflections) are not tracked separately — see DESIGN.md
§Arch-adaptation.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.graph import GraphBatch
from repro.models.gnn.so3 import gaunt_tensor, n_comps, real_sph_harm


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    edge_chunk: int = 65_536
    remat: bool = False


@functools.lru_cache(maxsize=None)
def _paths(l_max: int) -> tuple:
    """All (l1, l2, l3) with non-vanishing Gaunt coupling, l* <= l_max."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                g = gaunt_tensor(l1, l2, l3)
                if np.abs(g).max() > 1e-10:
                    out.append((l1, l2, l3))
    return tuple(out)


def _sl(l: int) -> slice:
    return slice(l * l, (l + 1) * (l + 1))


def bessel_rbf(r: jax.Array, n: int, cutoff: float) -> jax.Array:
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-6)
    k = jnp.arange(1, n + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(k * jnp.pi * r[..., None] / cutoff) / r[..., None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5  # p=2 poly cutoff
    return basis * env[..., None]


def init_params(cfg: NequIPConfig, key, d_in: int) -> dict:
    d = cfg.d_hidden
    paths = _paths(cfg.l_max)
    n_l = cfg.l_max + 1

    def layer_init(k):
        ks = jax.random.split(k, 6)
        return {
            # radial MLP -> one weight per (path, channel)
            "rad_w1": dense_init(ks[0], cfg.n_rbf, 64),
            "rad_w2": dense_init(ks[1], 64, len(paths) * d),
            # per-l self interactions (channel mixing)
            "self_w": jax.vmap(lambda kk: dense_init(kk, d, d))(
                jax.random.split(ks[2], n_l)
            ),
            "msg_w": jax.vmap(lambda kk: dense_init(kk, d, d))(
                jax.random.split(ks[3], n_l)
            ),
            # gates for l > 0 from scalar channels
            "gate_w": dense_init(ks[4], d, (n_l - 1) * d),
        }

    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": dense_init(k1, d_in, d),
        "layers": jax.vmap(layer_init)(jax.random.split(k2, cfg.n_layers)),
        "head_w1": dense_init(k3, d, d),
        "head_w2": jnp.zeros((d, 1)),
    }


def _interaction(cfg: NequIPConfig, p: dict, h, g: GraphBatch, y_edge, rbf):
    """One message-passing layer. h: (N, C, d).

    Edge messages stream through ``chunked_edge_aggregate`` (custom VJP —
    see chunked.py): radial MLP, Gaunt couplings and gathers all live
    inside the chunk function, so nothing E-sized beyond the (E, C_sh) SH
    values and (E, n_rbf) basis ever materializes, in EITHER direction.
    """
    from repro.models.gnn.chunked import chunked_edge_aggregate

    paths = _paths(cfg.l_max)
    d = cfg.d_hidden
    n_edges = g.n_edges
    n_chunks = max(n_edges // cfg.edge_chunk, 1)
    chunk = -(-n_edges // n_chunks)
    pad = n_chunks * chunk - n_edges
    src = jnp.pad(g.edge_src, (0, pad))
    dst = jnp.pad(g.edge_dst, (0, pad))
    mask = jnp.pad(g.edge_mask, (0, pad))
    y_pad = jnp.pad(y_edge, ((0, pad), (0, 0)))
    rbf_pad = jnp.pad(rbf, ((0, pad), (0, 0)))

    def msg_fn(carry, es, ie):
        h_, w1, w2 = carry
        rad = jax.nn.silu(es["rbf"] @ w1) @ w2
        rad = rad.reshape(rad.shape[0], len(paths), d)
        h_src = h_[ie["src"]]  # (chunk, C, d)
        msg = jnp.zeros((rad.shape[0], n_comps(cfg.l_max), d), h_.dtype)
        for pi, (l1, l2, l3) in enumerate(paths):
            gt = jnp.asarray(gaunt_tensor(l1, l2, l3), h_.dtype)
            contrib = jnp.einsum(
                "abc,ead,eb,ed->ecd",
                gt, h_src[:, _sl(l1), :], es["y"][:, _sl(l2)], rad[:, pi, :],
            )
            msg = msg.at[:, _sl(l3), :].add(contrib)
        return msg * es["mask"][:, None, None]

    agg = chunked_edge_aggregate(
        msg_fn, g.n_nodes, n_chunks,
        (h, p["rad_w1"], p["rad_w2"]),
        {"y": y_pad, "rbf": rbf_pad, "mask": mask},
        {"src": src},
        dst,
    )

    # self-interaction + message mix per l, then gated nonlinearity
    h_new = jnp.zeros_like(h)
    for l in range(cfg.l_max + 1):
        mixed = h[:, _sl(l), :] @ p["self_w"][l] + agg[:, _sl(l), :] @ p["msg_w"][l]
        h_new = h_new.at[:, _sl(l), :].set(mixed)
    scalars = h_new[:, 0, :]
    gates = jax.nn.sigmoid(scalars @ p["gate_w"]).reshape(
        -1, cfg.l_max, cfg.d_hidden
    )
    out = h_new.at[:, 0, :].set(jax.nn.silu(scalars))
    for l in range(1, cfg.l_max + 1):
        out = out.at[:, _sl(l), :].multiply(gates[:, l - 1 : l, :])
    return h + out  # residual


def energy(cfg: NequIPConfig, params: dict, g: GraphBatch,
           positions: jax.Array) -> jax.Array:
    """Total energy per graph: (n_graphs,). Differentiable in positions."""
    vec = positions[g.edge_src] - positions[g.edge_dst]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    vhat = vec / jnp.maximum(dist[:, None], 1e-9)
    y_edge = real_sph_harm(vhat, cfg.l_max, xp=jnp)  # (E, C)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)

    h0 = g.node_feat @ params["embed"]  # (N, d) scalars
    h = jnp.zeros((g.n_nodes, n_comps(cfg.l_max), cfg.d_hidden), h0.dtype)
    h = h.at[:, 0, :].set(h0)

    def body(h, lp):
        return _interaction(cfg, lp, h, g, y_edge, rbf), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["layers"])

    e_atom = jax.nn.silu(h[:, 0, :] @ params["head_w1"]) @ params["head_w2"]
    e_atom = e_atom[:, 0] * g.node_mask
    return jax.ops.segment_sum(e_atom, g.graph_id, num_segments=g.n_graphs)


def energy_and_forces(cfg: NequIPConfig, params: dict, g: GraphBatch):
    def total_e(pos):
        return energy(cfg, params, g, pos).sum()

    e = energy(cfg, params, g, g.positions)
    forces = -jax.grad(total_e)(g.positions)
    return e, forces
