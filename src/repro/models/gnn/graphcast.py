"""GraphCast-style encode-process-decode mesh GNN [arXiv:2212.12794].

The published model runs on a lat/lon grid + icosahedral multimesh; the
assignment pairs it with *generic* graph shapes, so we adapt (DESIGN.md
§Hardware/shape adaptation): given any (n_nodes, n_edges) graph,
  * grid nodes  = the given nodes (n_vars=227 features each),
  * mesh nodes  = every ``mesh_ratio``-th node (multimesh stand-in whose
    edge set is the given edge set contracted onto mesh nodes; refinement
    level 6 sets mesh_ratio = 4),
  * grid2mesh / mesh2grid edges = each grid node <-> its mesh anchor.
All three stages are InteractionNetwork blocks (edge MLP + node MLP with
residuals, sum aggregation), d_hidden=512, 16 processor layers — the
published processor config.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, layer_norm
from repro.models.gnn.graph import GraphBatch, scatter_sum


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16  # processor depth
    d_hidden: int = 512
    n_vars: int = 227
    mesh_ratio: int = 4  # grid nodes per mesh node (refinement-6 stand-in)
    remat: bool = False
    # latent dtype: bf16 halves the (E, d_hidden) edge-latent carries that
    # dominate memory on the 61.8M-edge full-batch shape; params stay f32.
    latent_dtype: str = "float32"


def _mlp_init(key, d_in, d_hidden, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, d_in, d_hidden),
        "b1": jnp.zeros((d_hidden,)),
        "w2": dense_init(k2, d_hidden, d_out),
        "b2": jnp.zeros((d_out,)),
        "ln_g": jnp.ones((d_out,)),
        "ln_b": jnp.zeros((d_out,)),
    }


def _mlp(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["w1"].astype(dt) + p["b1"].astype(dt))
    h = h @ p["w2"].astype(dt) + p["b2"].astype(dt)
    return layer_norm(h, p["ln_g"], p["ln_b"])


def _interaction_init(key, d):
    k1, k2 = jax.random.split(key)
    return {
        "edge": _mlp_init(k1, 3 * d, d, d),  # [e, h_src, h_dst]
        "node": _mlp_init(k2, 2 * d, d, d),  # [h, agg]
    }


def _interaction(p, h_src_nodes, h_dst_nodes, e, src, dst, n_dst, edge_mask):
    m = edge_mask[:, None].astype(e.dtype)  # keep latent dtype (scan carry!)
    ein = jnp.concatenate([e, h_src_nodes[src], h_dst_nodes[dst]], axis=-1)
    e_new = e + _mlp(p["edge"], ein) * m
    agg = scatter_sum(e_new * m, dst, n_dst)
    h_new = h_dst_nodes + _mlp(p["node"], jnp.concatenate([h_dst_nodes, agg], -1))
    return h_new, e_new


def init_params(cfg: GraphCastConfig, key) -> dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, 8)
    return {
        "embed_grid": _mlp_init(ks[0], cfg.n_vars, d, d),
        "embed_mesh": _mlp_init(ks[1], cfg.n_vars, d, d),
        "embed_edge": _mlp_init(ks[2], 4, d, d),  # [dist feats]
        "g2m": _interaction_init(ks[3], d),
        "processor": jax.vmap(lambda k: _interaction_init(k, d))(
            jax.random.split(ks[4], cfg.n_layers)
        ),
        "m2g": _interaction_init(ks[5], d),
        "head": _mlp_init(ks[6], d, d, cfg.n_vars),
    }


def _mesh_topology(cfg: GraphCastConfig, g: GraphBatch):
    """Deterministic mesh derivation from a generic graph (see module doc)."""
    n_mesh = max(g.n_nodes // cfg.mesh_ratio, 1)
    anchor = (jnp.arange(g.n_nodes, dtype=jnp.int32) // cfg.mesh_ratio) % n_mesh
    mesh_src = (g.edge_src // cfg.mesh_ratio) % n_mesh
    mesh_dst = (g.edge_dst // cfg.mesh_ratio) % n_mesh
    return n_mesh, anchor, mesh_src, mesh_dst


def forward(cfg: GraphCastConfig, params: dict, g: GraphBatch) -> jax.Array:
    """Next-state prediction for every grid node: [N, n_vars]."""
    n_mesh, anchor, mesh_src, mesh_dst = _mesh_topology(cfg, g)
    d = cfg.d_hidden
    lat = jnp.dtype(cfg.latent_dtype)

    h_grid = _mlp(params["embed_grid"], g.node_feat.astype(lat))
    # mesh initial state: mean of anchored grid nodes (cheap pre-encoder)
    cnt = jnp.maximum(
        jax.ops.segment_sum(g.node_mask, anchor, num_segments=n_mesh), 1.0
    )
    mesh_feat = (
        jax.ops.segment_sum(g.node_feat * g.node_mask[:, None], anchor, n_mesh)
        / cnt[:, None]
    )
    h_mesh = _mlp(params["embed_mesh"], mesh_feat.astype(lat))

    # grid2mesh: one edge per grid node to its anchor.
    g2m_e = jnp.zeros((g.n_nodes, d), lat)
    h_mesh, _ = _interaction(
        params["g2m"], h_grid, h_mesh, g2m_e,
        jnp.arange(g.n_nodes, dtype=jnp.int32), anchor, n_mesh, g.node_mask,
    )

    # processor on the contracted mesh graph
    e_mesh = jnp.zeros((g.n_edges, d), lat)

    def body(carry, lp):
        h_mesh, e_mesh = carry
        h_mesh, e_mesh = _interaction(
            lp, h_mesh, h_mesh, e_mesh, mesh_src, mesh_dst, n_mesh, g.edge_mask
        )
        return (h_mesh, e_mesh), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (h_mesh, _), _ = jax.lax.scan(body, (h_mesh, e_mesh), params["processor"])

    # mesh2grid
    m2g_e = jnp.zeros((g.n_nodes, d), lat)
    h_grid, _ = _interaction(
        params["m2g"], h_mesh, h_grid, m2g_e,
        anchor, jnp.arange(g.n_nodes, dtype=jnp.int32), g.n_nodes, g.node_mask,
    )
    return _mlp(params["head"], h_grid).astype(jnp.float32)
