"""Host-side fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

A *real* sampler over a CSR adjacency: seed nodes -> fanout-15 -> fanout-10,
with replacement-free sampling per node (falling back to with-replacement
when degree < fanout, matching DGL semantics).  Output is a padded, static-
shape subgraph (local node ids) ready for the device step; node budget is
batch_nodes * (1 + f1 + f1*f2) exactly as the dry-run input specs assume.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # int64[N+1]
    indices: np.ndarray  # int32[nnz]
    n_nodes: int

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")  # CSR over incoming edges
        s, d = src[order], dst[order]
        counts = np.bincount(d, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return CSRGraph(indptr=indptr, indices=s.astype(np.int32), n_nodes=n_nodes)


def random_regular_csr(n_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    """Synthetic CSR stand-in for the full minibatch_lg graph (the 114M-edge
    Reddit-scale edge list never materializes on device; only sampled
    subgraphs do)."""
    rng = np.random.default_rng(seed)
    indptr = np.arange(n_nodes + 1, dtype=np.int64) * avg_degree
    indices = rng.integers(0, n_nodes, n_nodes * avg_degree, dtype=np.int64)
    return CSRGraph(indptr=indptr, indices=indices.astype(np.int32), n_nodes=n_nodes)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple[int, ...],
    *,
    seed: int = 0,
):
    """Multi-hop fanout sampling.

    Returns (nodes, edge_src_local, edge_dst_local, edge_mask) with padded
    static shapes: n_nodes = sum of layer budgets, n_edges = sum of
    per-layer edge budgets. Local ids index into ``nodes``.
    """
    rng = np.random.default_rng(seed)
    layer_nodes = [np.asarray(seeds, dtype=np.int64)]
    edges_src: list[np.ndarray] = []
    edges_dst: list[np.ndarray] = []

    frontier = layer_nodes[0]
    for f in fanout:
        deg = graph.indptr[frontier + 1] - graph.indptr[frontier]
        # sample f neighbours per frontier node (with replacement if needed)
        offsets = (rng.random((len(frontier), f)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
        neigh = graph.indices[
            (graph.indptr[frontier][:, None] + offsets).clip(0, len(graph.indices) - 1)
        ]
        edges_src.append(neigh.reshape(-1))
        edges_dst.append(np.repeat(frontier, f))
        layer_nodes.append(neigh.reshape(-1).astype(np.int64))
        frontier = layer_nodes[-1]

    all_nodes = np.concatenate(layer_nodes)
    # Local ids = positions in the duplicate-preserving concat list (static
    # budget; deduplication would make shapes data-dependent). Edges flow
    # sampled-neighbour slot (layer li+1) -> frontier parent slot (layer li).
    src_local = []
    dst_local = []
    cursor = len(layer_nodes[0])
    dst_cursor = 0
    for li, f in enumerate(fanout):
        n_front = len(layer_nodes[li])
        src_local.append(np.arange(cursor, cursor + n_front * f, dtype=np.int32))
        dst_local.append(np.repeat(np.arange(dst_cursor, dst_cursor + n_front,
                                             dtype=np.int32), f))
        dst_cursor = cursor
        cursor += n_front * f

    return (
        all_nodes.astype(np.int64),  # global ids per local slot (for features)
        np.concatenate(src_local),
        np.concatenate(dst_local),
        np.ones(sum(len(s) for s in src_local), np.float32),
    )
