"""Graph batch representation + the message-passing primitive.

JAX sparse is BCOO-only, so message passing is built on explicit edge-index
scatter: ``gather source features -> edge function -> segment_sum to dst``.
``segment_sum``/``segment_max`` ARE the system's SpMM (taxonomy §GNN); all
four GNN archs reduce to this primitive plus their per-edge kernels.

Graphs are padded to static shapes: ``edge_mask``/``node_mask`` mark real
entries (padding edges point at node 0 with mask 0 — segment ops weight
them out).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.struct import pytree_dataclass, static_field


@pytree_dataclass
class GraphBatch:
    """A (possibly padded) graph or batch of graphs.

    For batched small graphs (molecule shape) the graphs are concatenated
    and ``graph_id`` routes nodes to per-graph readouts.
    """

    node_feat: jax.Array  # f32[N, F] (or one-hot atom types)
    edge_src: jax.Array  # int32[E]
    edge_dst: jax.Array  # int32[E]
    edge_feat: jax.Array  # f32[E, Fe] (zeros when unused)
    positions: jax.Array  # f32[N, 3] (zeros for non-geometric graphs)
    node_mask: jax.Array  # f32[N]
    edge_mask: jax.Array  # f32[E]
    graph_id: jax.Array  # int32[N] (zeros for single graphs)
    n_graphs: int = static_field(default=1)  # static: segment count at trace

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def scatter_sum(edge_vals: jax.Array, dst: jax.Array, n_nodes: int) -> jax.Array:
    """Sum edge messages into destination nodes: the SpMM primitive."""
    return jax.ops.segment_sum(edge_vals, dst, num_segments=n_nodes)


def scatter_mean(edge_vals, dst, n_nodes, edge_mask=None):
    w = jnp.ones(edge_vals.shape[0]) if edge_mask is None else edge_mask
    tot = jax.ops.segment_sum(edge_vals * w[:, None], dst, num_segments=n_nodes)
    cnt = jax.ops.segment_sum(w, dst, num_segments=n_nodes)
    return tot / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(edge_vals, dst, n_nodes):
    return jax.ops.segment_max(edge_vals, dst, num_segments=n_nodes)


def gather(node_vals: jax.Array, idx: jax.Array) -> jax.Array:
    return node_vals[idx]


def edge_softmax(scores: jax.Array, dst: jax.Array, n_nodes: int,
                 edge_mask: jax.Array | None = None) -> jax.Array:
    """Softmax over incoming edges per destination node. scores: [E, H]."""
    if edge_mask is not None:
        scores = jnp.where(edge_mask[:, None] > 0, scores, -1e30)
    mx = jax.ops.segment_max(scores, dst, num_segments=n_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(scores - mx[dst])
    if edge_mask is not None:
        ex = ex * edge_mask[:, None]
    den = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / jnp.maximum(den[dst], 1e-9)


def graph_readout(node_vals: jax.Array, graph_id: jax.Array, n_graphs: int,
                  node_mask: jax.Array) -> jax.Array:
    """Mean-pool nodes per graph -> [G, F]."""
    tot = jax.ops.segment_sum(node_vals * node_mask[:, None], graph_id,
                              num_segments=n_graphs)
    cnt = jax.ops.segment_sum(node_mask, graph_id, num_segments=n_graphs)
    return tot / jnp.maximum(cnt, 1.0)[:, None]


# ------------------------------------------------------------ generators --

def synthetic_graph(n_nodes: int, n_edges: int, d_feat: int, *, seed: int = 0,
                    n_graphs: int = 1, geometric: bool = False) -> GraphBatch:
    """Deterministic random graph batch matching an assigned GNN shape.

    For ``n_graphs > 1`` (molecule shape) nodes/edges are split evenly.
    Geometric graphs get random 3D positions in a box; edges then connect
    nearest neighbours (simple, deterministic)."""
    rng = np.random.default_rng(seed)
    per_g_nodes = n_nodes
    total_nodes = n_nodes * n_graphs
    total_edges = n_edges * n_graphs
    graph_id = np.repeat(np.arange(n_graphs, dtype=np.int32), per_g_nodes)

    src = np.empty(total_edges, np.int32)
    dst = np.empty(total_edges, np.int32)
    for g in range(n_graphs):
        lo = g * n_edges
        base = g * per_g_nodes
        src[lo : lo + n_edges] = base + rng.integers(0, per_g_nodes, n_edges)
        dst[lo : lo + n_edges] = base + rng.integers(0, per_g_nodes, n_edges)

    positions = rng.normal(size=(total_nodes, 3)).astype(np.float32) * 2.0
    feat = rng.normal(size=(total_nodes, d_feat)).astype(np.float32)
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_feat=jnp.zeros((total_edges, 8), jnp.float32),
        positions=jnp.asarray(positions),
        node_mask=jnp.ones(total_nodes, jnp.float32),
        edge_mask=jnp.ones(total_edges, jnp.float32),
        graph_id=jnp.asarray(graph_id),
        n_graphs=n_graphs,
    )
