"""EquiformerV2 [arXiv:2306.12059] — equivariant graph attention via eSCN.

Assigned config: 12 layers, 128 channels, l_max=6, m_max=2, 8 heads.

The eSCN trick: a full SO(3) tensor-product convolution at l_max=6 costs
O(l_max^6); rotating each edge's features into a frame where the edge is the
z-axis makes the convolution *block-diagonal in m* and truncatable to
|m| <= m_max, reducing it to a handful of dense per-m linear maps (SO(2)
convolutions), O(l_max^3).  The per-edge rotation itself is two analytic
z-rotations + two static J-matrix multiplies (so3.py) — this is the
TPU-friendly reformulation: everything is dense einsums over static index
sets; no per-edge Wigner-d evaluation, no scatter inside the hot loop.

Edge flow per layer (attention):
    gather src/dst features -> rotate to edge frame -> truncate to m<=m_max
    -> SO(2) linear (separate W per m, complex-pair structure for m>0)
    -> attention logits from the m=0 (invariant) block -> edge softmax
    -> value messages * alpha -> un-truncate -> rotate back -> segment_sum.

Memory: edges are processed in static chunks (two passes: logits, then
messages) so the (E, C, d) tensors never materialize for web-scale graphs.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.graph import GraphBatch, edge_softmax
from repro.models.gnn.so3 import m_array, n_comps, rotate_to_edge_frame


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    edge_chunk: int = 32_768
    remat: bool = False


@functools.lru_cache(maxsize=None)
def _m_indices(l_max: int, m_max: int):
    """Component slots per |m| <= m_max: (idx_m0, [(idx_+m, idx_-m)] m=1..)."""
    ms = m_array(l_max)
    ls = np.concatenate([[l] * (2 * l + 1) for l in range(l_max + 1)])
    idx0 = np.nonzero(ms == 0)[0]
    pairs = []
    for m in range(1, m_max + 1):
        plus = np.nonzero(ms == m)[0]
        minus = np.nonzero(ms == -m)[0]
        assert len(plus) == len(minus)
        pairs.append((plus, minus))
    return idx0, pairs


def _so2_sizes(cfg) -> list[int]:
    idx0, pairs = _m_indices(cfg.l_max, cfg.m_max)
    return [len(idx0)] + [len(p) for p, _ in pairs]


def init_layer(cfg: EquiformerV2Config, key) -> dict:
    d = cfg.d_hidden
    sizes = _so2_sizes(cfg)
    ks = jax.random.split(key, 8 + 2 * len(sizes))
    p = {
        "alpha_w1": dense_init(ks[0], 2 * sizes[0] * d, d),
        "alpha_w2": dense_init(ks[1], d, cfg.n_heads),
        "ffn_w1": dense_init(ks[2], d, 2 * d),
        "ffn_w2": dense_init(ks[3], 2 * d, d),
        "ffn_gate": dense_init(ks[4], d, (cfg.l_max) * d),
        "out_w": dense_init(ks[5], d, d),
    }
    # SO(2) conv weights: m=0 real; m>0 complex pairs. Input is the CONCAT of
    # rotated src+dst features (2d channels) -> d channels.
    for mi, n_l in enumerate(sizes):
        d_in, d_out = n_l * 2 * d, n_l * d
        if mi == 0:
            p[f"so2_m0"] = dense_init(ks[6], d_in, d_out)
        else:
            p[f"so2_m{mi}_r"] = dense_init(ks[6 + 2 * mi], d_in, d_out)
            p[f"so2_m{mi}_i"] = dense_init(ks[7 + 2 * mi], d_in, d_out)
    return p


def init_params(cfg: EquiformerV2Config, key, d_in: int) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": dense_init(k1, d_in, cfg.d_hidden),
        "layers": jax.vmap(lambda k: init_layer(cfg, k))(
            jax.random.split(k2, cfg.n_layers)
        ),
        "head_w1": dense_init(k3, cfg.d_hidden, cfg.d_hidden),
        "head_w2": jnp.zeros((cfg.d_hidden, 1)),
    }


def _so2_conv(cfg, p, x):
    """SO(2) convolution on edge-frame features x: (E, C, 2d) -> (E, C, d)."""
    e = x.shape[0]
    d = cfg.d_hidden
    idx0, pairs = _m_indices(cfg.l_max, cfg.m_max)
    out = jnp.zeros((e, n_comps(cfg.l_max), d), x.dtype)
    x0 = x[:, idx0, :].reshape(e, -1)
    out = out.at[:, idx0, :].set((x0 @ p["so2_m0"]).reshape(e, len(idx0), d))
    for mi, (plus, minus) in enumerate(pairs, start=1):
        xp_ = x[:, plus, :].reshape(e, -1)
        xm_ = x[:, minus, :].reshape(e, -1)
        wr, wi = p[f"so2_m{mi}_r"], p[f"so2_m{mi}_i"]
        yp = (xp_ @ wr - xm_ @ wi).reshape(e, len(plus), d)
        ym = (xp_ @ wi + xm_ @ wr).reshape(e, len(plus), d)
        out = out.at[:, plus, :].set(yp)
        out = out.at[:, minus, :].set(ym)
    return out


def _equiv_layer_norm(h):
    """Normalize per-l subspace norms (equivariant)."""
    # h: (N, C, d); norm over (comps of each l, channel-wise RMS)
    sq = jnp.mean(h * h, axis=(1,), keepdims=True)  # (N, 1, d) — l-mixed RMS
    return h * jax.lax.rsqrt(sq + 1e-6)


def _attention_layer(cfg, p, h, g: GraphBatch, inv_sqrt_deg):
    from repro.models.gnn.chunked import chunked_edge_aggregate

    n_edges = g.n_edges
    d = cfg.d_hidden
    idx0, _ = _m_indices(cfg.l_max, cfg.m_max)
    vec = g.positions[g.edge_src] - g.positions[g.edge_dst]

    n_chunks = max(n_edges // cfg.edge_chunk, 1)
    chunk = -(-n_edges // n_chunks)
    pad = n_chunks * chunk - n_edges

    src = jnp.pad(g.edge_src, (0, pad))
    dst = jnp.pad(g.edge_dst, (0, pad))
    vec_p = jnp.pad(vec, ((0, pad), (0, 0)))

    def rotate_mix(h_, so2_p, s, t, v):
        """Shared first half: rotated + SO(2)-mixed features for a chunk."""
        x = jnp.concatenate([h_[s], h_[t]], axis=-1)  # (chunk, C, 2d)
        x = jnp.swapaxes(x, 1, 2)  # comps last for the so3 helper
        x = rotate_to_edge_frame(x, v[:, None, :], l_max=cfg.l_max)
        x = jnp.swapaxes(x, 1, 2)
        return _so2_conv(cfg, so2_p, x)  # (chunk, C, d)

    so2_keys = [k for k in p if k.startswith("so2_")]
    so2_p = {k: p[k] for k in so2_keys}

    # ---- pass 1: attention logits (invariant m=0 block) -------------------
    # lax.map with a checkpointed body: ys cotangents stream per chunk and
    # the rotate/mix recomputes in backward (no per-chunk residual stacks).
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def logits_chunk(i):
        s = jax.lax.dynamic_slice_in_dim(src, i * chunk, chunk)
        t = jax.lax.dynamic_slice_in_dim(dst, i * chunk, chunk)
        v = jax.lax.dynamic_slice_in_dim(vec_p, i * chunk, chunk)
        mixed = rotate_mix(h, so2_p, s, t, v)
        inv = mixed[:, idx0, :].reshape(chunk, -1)
        z = jax.nn.silu(jnp.concatenate([inv, inv], axis=-1) @ p["alpha_w1"])
        return z @ p["alpha_w2"]  # (chunk, H)

    logits = jax.lax.map(logits_chunk, jnp.arange(n_chunks))
    logits = logits.reshape(-1, cfg.n_heads)[:n_edges]
    alpha = edge_softmax(logits, g.edge_dst, g.n_nodes, g.edge_mask)  # (E, H)
    alpha_p = jnp.pad(alpha, ((0, pad), (0, 0)))

    # ---- pass 2: weighted messages via the linear-aggregate custom VJP ----
    def msg_fn(carry, es, ie):
        h_, so2_ = carry
        mixed = rotate_mix(h_, so2_, ie["src"], ie["dst"], es["vec"])
        val = mixed.reshape(mixed.shape[0], -1, cfg.n_heads, d // cfg.n_heads)
        val = val * es["alpha"][:, None, :, None]
        val = val.reshape(val.shape[0], n_comps(cfg.l_max), d)
        val = jnp.swapaxes(val, 1, 2)
        val = rotate_to_edge_frame(val, es["vec"][:, None, :],
                                   l_max=cfg.l_max, inverse=True)
        return jnp.swapaxes(val, 1, 2)

    agg = chunked_edge_aggregate(
        msg_fn, g.n_nodes, n_chunks,
        (h, so2_p),
        {"vec": vec_p, "alpha": alpha_p},
        {"src": src, "dst": dst},
        dst,
    )
    agg = agg * inv_sqrt_deg[:, None, None]

    h = h + jnp.einsum("ncd,df->ncf", agg, p["out_w"])
    h = _equiv_layer_norm(h)

    # ---- pointwise equivariant FFN ----------------------------------------
    scalars = h[:, 0, :]
    z = jax.nn.silu(scalars @ p["ffn_w1"]) @ p["ffn_w2"]
    gates = jax.nn.sigmoid(scalars @ p["ffn_gate"]).reshape(
        -1, cfg.l_max, cfg.d_hidden
    )
    out = h.at[:, 0, :].add(z)
    for l in range(1, cfg.l_max + 1):
        sl = slice(l * l, (l + 1) * (l + 1))
        out = out.at[:, sl, :].multiply(gates[:, l - 1 : l, :])
    return out


def forward(cfg: EquiformerV2Config, params: dict, g: GraphBatch) -> jax.Array:
    """Per-graph energies (n_graphs,) — the OC20-style readout."""
    deg = jax.ops.segment_sum(g.edge_mask, g.edge_dst, num_segments=g.n_nodes)
    inv_sqrt_deg = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
    h0 = g.node_feat @ params["embed"]
    h = jnp.zeros((g.n_nodes, n_comps(cfg.l_max), cfg.d_hidden), h0.dtype)
    h = h.at[:, 0, :].set(h0)

    def body(h, lp):
        return _attention_layer(cfg, lp, h, g, inv_sqrt_deg), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["layers"])
    e_atom = jax.nn.silu(h[:, 0, :] @ params["head_w1"]) @ params["head_w2"]
    e_atom = e_atom[:, 0] * g.node_mask
    return jax.ops.segment_sum(e_atom, g.graph_id, num_segments=g.n_graphs)
