"""SO(3) machinery for the equivariant GNNs (NequIP, EquiformerV2/eSCN).

Design choice (DESIGN.md §TPU-adaptation): every static tensor that depends
on representation-theoretic conventions (Wigner J matrices, Gaunt/CG
couplings) is computed *numerically at build time* from the real spherical
harmonics themselves — J matrices are least-squares fits of D(R) from
Y(Rv) = D Y(v) sample systems, and couplings are exact Gauss-Legendre x
Fourier quadratures of triple products.  This removes every sign/phase
convention footgun; correctness reduces to the SH evaluator, which is unit
tested against first principles (and equivariance is property-tested end to
end).

Runtime (jax, per edge) uses the classic zyz factorization
    D(R_align) = J^{-1} . Z(-beta) . J . Z(-alpha)        (applied right-to-left)
where Z(theta) is the analytic block rotation mixing (m, -m) pairs and J is
the static change-of-axis matrix — two cheap elementwise ops and two tiny
block-diag matmuls instead of a per-edge Wigner-d evaluation.

Component ordering: irrep l occupies slots [l^2, (l+1)^2), m from -l to +l.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp


def n_comps(l_max: int) -> int:
    return (l_max + 1) ** 2


def m_array(l_max: int) -> np.ndarray:
    """Signed m per component slot."""
    out = []
    for l in range(l_max + 1):
        out.extend(range(-l, l + 1))
    return np.asarray(out, dtype=np.int64)


def flip_index(l_max: int) -> np.ndarray:
    """Index permutation mapping slot (l, m) -> (l, -m)."""
    idx = []
    for l in range(l_max + 1):
        base = l * l
        idx.extend(base + (l - m) for m in range(-l, l + 1))
    return np.asarray(idx, dtype=np.int64)


# ------------------------------------------------------ real SH evaluator --

def _double_factorial(n: int) -> float:
    out = 1.0
    while n > 1:
        out *= n
        n -= 2
    return out


def real_sph_harm(vecs, l_max: int, xp=jnp):
    """Real spherical harmonics of unit vectors.

    vecs: (..., 3) -> (..., (l_max+1)^2).  Pole-safe: uses the scaled
    Legendre polynomials Q_l^m = P_l^m / sin^m(theta) (polynomial in z) and
    the Chebyshev-style recurrences A_m = Re((x+iy)^m), B_m = Im((x+iy)^m).
    Works for numpy (build time) and jnp (runtime) via ``xp``.
    """
    x, y, z = vecs[..., 0], vecs[..., 1], vecs[..., 2]
    # Q_l^m table via recursion (python loops over static l, m)
    # No Condon-Shortley phase (standard *real* SH convention: Y_1 ~ (y,z,x)).
    q = {}
    for m in range(l_max + 1):
        q[(m, m)] = _double_factorial(2 * m - 1) * xp.ones_like(z)
        if m + 1 <= l_max:
            q[(m + 1, m)] = z * (2 * m + 1) * q[(m, m)]
        for l in range(m + 2, l_max + 1):
            q[(l, m)] = ((2 * l - 1) * z * q[(l - 1, m)]
                         - (l + m - 1) * q[(l - 2, m)]) / (l - m)
    # azimuthal parts: A_m = Re((x+iy)^m), B_m = Im((x+iy)^m)
    import math

    a = [xp.ones_like(z)]
    b = [xp.zeros_like(z)]
    for m in range(1, l_max + 1):
        a_new = a[m - 1] * x - b[m - 1] * y
        b_new = a[m - 1] * y + b[m - 1] * x
        a.append(a_new)
        b.append(b_new)
    comps = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = np.sqrt(
                (2 * l + 1)
                / (4 * np.pi)
                * float(math.factorial(l - am))
                / float(math.factorial(l + am))
            )
            if m == 0:
                comps.append(norm * q[(l, 0)])
            elif m > 0:
                comps.append(np.sqrt(2.0) * norm * q[(l, m)] * a[m])
            else:
                comps.append(np.sqrt(2.0) * norm * q[(l, am)] * b[am])
    return xp.stack(comps, axis=-1)


# --------------------------------------------- build-time fitted matrices --

def _fibonacci_sphere(n: int) -> np.ndarray:
    i = np.arange(n, dtype=np.float64) + 0.5
    phi = np.arccos(1 - 2 * i / n)
    golden = np.pi * (1 + np.sqrt(5.0))
    theta = golden * i
    return np.stack(
        [np.sin(phi) * np.cos(theta), np.sin(phi) * np.sin(theta), np.cos(phi)], -1
    )


def fit_rotation_rep(l: int, rot: np.ndarray) -> np.ndarray:
    """Least-squares fit of D^l(R) from Y(R v) = D Y(v); residual asserted."""
    vecs = _fibonacci_sphere(max(8 * (2 * l + 1), 64))
    y = real_sph_harm(vecs, l, xp=np)[..., l * l : (l + 1) * (l + 1)]
    y_rot = real_sph_harm(vecs @ rot.T, l, xp=np)[..., l * l : (l + 1) * (l + 1)]
    d, res, *_ = np.linalg.lstsq(y, y_rot, rcond=None)
    d = d.T  # we solved Y D^T = Y_rot
    err = np.abs(y_rot - y @ d.T).max()
    assert err < 1e-8, (l, err)
    return d


def _rot_x(t):
    c, s = np.cos(t), np.sin(t)
    return np.asarray([[1, 0, 0], [0, c, -s], [0, s, c]])


def _rot_y(t):
    c, s = np.cos(t), np.sin(t)
    return np.asarray([[c, 0, s], [0, 1, 0], [-s, 0, c]])


def _rot_z(t):
    c, s = np.cos(t), np.sin(t)
    return np.asarray([[c, -s, 0], [s, c, 0], [0, 0, 1]])


# R_J: maps z->y (rotation by -pi/2 about x); conjugation turns Rz into Ry.
_R_J = _rot_x(-np.pi / 2)


@functools.lru_cache(maxsize=None)
def j_matrix_big(l_max: int) -> np.ndarray:
    """Block-diag J = D(R_J) over l = 0..l_max, shape (C, C)."""
    c = n_comps(l_max)
    out = np.zeros((c, c))
    for l in range(l_max + 1):
        out[l * l : (l + 1) ** 2, l * l : (l + 1) ** 2] = fit_rotation_rep(l, _R_J)
    return out


def _zrot_apply(x, theta, m_arr, flip_idx):
    """Apply D(Rz(theta)) to features x: (..., C) with per-... theta.

    out_i = cos(m_i t) x_i - sin(m_i t) x_flip(i)   (verified in tests)
    """
    ang = theta[..., None] * m_arr
    return jnp.cos(ang) * x - jnp.sin(ang) * x[..., flip_idx]


@functools.partial(jax.jit, static_argnames=("l_max", "inverse"))
def rotate_to_edge_frame(x: jax.Array, edge_vec: jax.Array, *, l_max: int,
                         inverse: bool = False) -> jax.Array:
    """Rotate SH-indexed features into (or back from) the edge-aligned frame.

    x: (E, C, ...) features with C = (l_max+1)^2 as axis 1 — we require the
    component axis LAST here: x (..., C); edge_vec (..., 3) unnormalized.
    In the aligned frame the edge direction is the z-axis.
    """
    v = edge_vec / jnp.maximum(
        jnp.linalg.norm(edge_vec, axis=-1, keepdims=True), 1e-9
    )
    alpha = jnp.arctan2(v[..., 1], v[..., 0])
    beta = jnp.arccos(jnp.clip(v[..., 2], -1.0, 1.0))
    m_arr = jnp.asarray(m_array(l_max), jnp.float32)
    flip = jnp.asarray(flip_index(l_max))
    jmat = jnp.asarray(j_matrix_big(l_max), x.dtype)

    # Matrix-vector on trailing axis: (J x)_d   = einsum('...c,dc->...d')
    #                                  (J^T x)_d = einsum('...c,cd->...d')
    if not inverse:
        # D_align = D_J . Z(-beta) . D_J^{-1} . Z(-alpha)  (right-to-left)
        x = _zrot_apply(x, -alpha, m_arr, flip)
        x = jnp.einsum("...c,cd->...d", x, jmat)  # D_J^{-1} x (orthogonal)
        x = _zrot_apply(x, -beta, m_arr, flip)
        x = jnp.einsum("...c,dc->...d", x, jmat)  # D_J x
        return x
    else:
        # D_align^{-1} = Z(alpha) . D_J . Z(beta) . D_J^{-1}
        x = jnp.einsum("...c,cd->...d", x, jmat)
        x = _zrot_apply(x, beta, m_arr, flip)
        x = jnp.einsum("...c,dc->...d", x, jmat)
        x = _zrot_apply(x, alpha, m_arr, flip)
        return x


# ----------------------------------------------------------- couplings ----

@functools.lru_cache(maxsize=None)
def gaunt_tensor(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real Gaunt coefficients  G[m1, m2, m3] = ∮ Y_{l1 m1} Y_{l2 m2} Y_{l3 m3}.

    Exact product quadrature: Gauss-Legendre in cos(theta) (degree l1+l2+l3
    polynomial) x uniform trapezoid in phi (band-limited Fourier).  The
    resulting coupling map (x (x) y)_{m3} = sum G x_{m1} y_{m2} is SO(3)-
    equivariant and proportional to the real CG coefficients per (l1,l2,l3).
    """
    deg = l1 + l2 + l3
    n_t = deg + 2
    n_p = 2 * deg + 3
    nodes, weights = np.polynomial.legendre.leggauss(n_t)
    phis = 2 * np.pi * np.arange(n_p) / n_p
    ct, ph = np.meshgrid(nodes, phis, indexing="ij")
    st = np.sqrt(1 - ct**2)
    vecs = np.stack([st * np.cos(ph), st * np.sin(ph), ct], -1).reshape(-1, 3)
    w = np.broadcast_to(weights[:, None], (n_t, n_p)).reshape(-1) * (
        2 * np.pi / n_p
    )
    y = real_sph_harm(vecs, max(l1, l2, l3), xp=np)
    y1 = y[:, l1 * l1 : (l1 + 1) ** 2]
    y2 = y[:, l2 * l2 : (l2 + 1) ** 2]
    y3 = y[:, l3 * l3 : (l3 + 1) ** 2]
    return np.einsum("n,na,nb,nc->abc", w, y1, y2, y3)
