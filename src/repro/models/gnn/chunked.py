"""Memory-bounded edge aggregation for web-scale graphs (custom VJP).

Message aggregation is LINEAR in per-chunk contributions:

    agg = sum_i segment_sum(msg(carry, edge_slice_i), dst_i)

so its backward needs NO per-chunk residuals and NO carried accumulator
cotangents: d_carry = sum_i vjp_i(d_agg), with each chunk's vjp recomputed
on the fly. Plain lax.scan differentiation misses this — it saves every
chunk's message tensors (equiformer-v2 x ogb_products measured 5.5 TB of
saved residuals), and checkpointing the body instead saves n_chunks copies
of the accumulator carry. This helper makes both directions stream through
chunks at O(chunk) extra memory — the same structure production GNN /
flash-attention backwards use.

Contract:
  * ``carry_args`` and ``edge_args`` hold ONLY inexact (float) leaves;
    integer per-edge data (source ids, masks) goes in ``int_edge_args``.
  * per-edge leaves have leading dim E, divisible by ``n_chunks``
    (callers pad with masked slots).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp


def _slice_tree(tree: Any, start, size: int) -> Any:
    return jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, start, size, axis=0), tree
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def chunked_edge_aggregate(
    msg_fn: Callable,  # (carry_args, edge_slice, int_slice) -> msg [chunk, ...]
    n_nodes: int,
    n_chunks: int,
    carry_args: Any,  # float pytree (node features, layer params, ...)
    edge_args: Any,  # float per-edge pytree, leading dim E
    int_edge_args: Any,  # int per-edge pytree (src ids, ...), leading dim E
    dst: jax.Array,  # int32[E] destination ids
) -> jax.Array:
    return _forward(msg_fn, n_nodes, n_chunks, carry_args, edge_args,
                    int_edge_args, dst)


def _forward(msg_fn, n_nodes, n_chunks, carry_args, edge_args, int_edge_args,
             dst):
    e = dst.shape[0]
    chunk = e // n_chunks
    assert chunk * n_chunks == e, (e, n_chunks)
    probe = jax.eval_shape(
        msg_fn, carry_args, _slice_tree(edge_args, 0, chunk),
        _slice_tree(int_edge_args, 0, chunk),
    )
    acc0 = jnp.zeros((n_nodes,) + probe.shape[1:], probe.dtype)

    def body(i, acc):
        es = _slice_tree(edge_args, i * chunk, chunk)
        ie = _slice_tree(int_edge_args, i * chunk, chunk)
        d_i = jax.lax.dynamic_slice_in_dim(dst, i * chunk, chunk)
        msg = msg_fn(carry_args, es, ie)
        return acc + jax.ops.segment_sum(msg, d_i, num_segments=n_nodes)

    return jax.lax.fori_loop(0, n_chunks, body, acc0)


def _fwd(msg_fn, n_nodes, n_chunks, carry_args, edge_args, int_edge_args, dst):
    out = _forward(msg_fn, n_nodes, n_chunks, carry_args, edge_args,
                   int_edge_args, dst)
    return out, (carry_args, edge_args, int_edge_args, dst)


def _bwd(msg_fn, n_nodes, n_chunks, res, g):
    carry_args, edge_args, int_edge_args, dst = res
    e = dst.shape[0]
    chunk = e // n_chunks

    d_carry0 = jax.tree.map(jnp.zeros_like, carry_args)
    d_edge0 = jax.tree.map(jnp.zeros_like, edge_args)

    def body(i, acc):
        d_carry, d_edge = acc
        start = i * chunk
        es = _slice_tree(edge_args, start, chunk)
        ie = _slice_tree(int_edge_args, start, chunk)
        d_i = jax.lax.dynamic_slice_in_dim(dst, start, chunk)

        def f(c, e_):
            return jax.ops.segment_sum(msg_fn(c, e_, ie), d_i,
                                       num_segments=n_nodes)

        _, vjp = jax.vjp(f, carry_args, es)
        dc_i, de_i = vjp(g)
        d_carry = jax.tree.map(jnp.add, d_carry, dc_i)
        d_edge = jax.tree.map(
            lambda full, u: jax.lax.dynamic_update_slice_in_dim(
                full, u.astype(full.dtype), start, axis=0),
            d_edge, de_i)
        return d_carry, d_edge

    d_carry, d_edge = jax.lax.fori_loop(0, n_chunks, body, (d_carry0, d_edge0))
    # int inputs take no gradient: None is the float0 stand-in custom_vjp
    # accepts for integer-dtype primals.
    d_int = jax.tree.map(lambda _: None, int_edge_args)
    return d_carry, d_edge, d_int, None


chunked_edge_aggregate.defvjp(_fwd, _bwd)
