from repro.models.gnn.graph import GraphBatch, synthetic_graph
from repro.models.gnn import gatedgcn, graphcast, nequip, equiformer_v2, so3, sampler

__all__ = [
    "GraphBatch",
    "synthetic_graph",
    "gatedgcn",
    "graphcast",
    "nequip",
    "equiformer_v2",
    "so3",
    "sampler",
]
