"""Shared model-layer primitives (no flax; params are plain dict pytrees)."""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = dict  # nested dict of jax.Array leaves


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, stddev, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(stddev, dtype)


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    """LeCun-normal fan-in init."""
    return normal_init(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
             zero_centered: bool = True) -> jax.Array:
    """RMSNorm; ``zero_centered`` follows the Gemma (1 + gamma) convention."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + gamma) if zero_centered else gamma
    return (x * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


ACTIVATIONS: dict[str, Callable] = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def stacked_layer_init(init_one: Callable[[jax.Array], Params], key: jax.Array,
                       n_layers: int) -> Params:
    """Initialize per-layer params and stack leaves to (L, ...) for lax.scan."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def count_params(params: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
