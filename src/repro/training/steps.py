"""Train/serve step builders for every architecture family.

Each builder returns a pure ``step(state, batch) -> (state, metrics)`` (or
``serve(params, inputs) -> outputs``) suitable for jit/pjit; the dry-run
lowers exactly these functions against ShapeDtypeStruct inputs.

Microbatch gradient accumulation (``accum_steps``) runs as a lax.scan over
microbatches — the standard memory/throughput trade — and is exercised by
tests for exact equivalence with full-batch gradients (linearity of grads).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(
    loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
    opt_cfg: AdamWConfig,
    *,
    accum_steps: int = 1,
) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics dict)."""

    def step(state: TrainState, batch: Any) -> tuple[TrainState, dict]:
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb
                )
                return (
                    loss_acc + l / accum_steps,
                    jax.tree.map(lambda a, b: a + b / accum_steps, grads_acc, g),
                ), m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (loss, grads), metrics = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro
            )
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        params, opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params
        )
        metrics = dict(metrics or {})
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return step


def init_train_state(params: Any, opt_cfg: AdamWConfig) -> TrainState:
    return TrainState(params=params, opt=adamw_init(opt_cfg, params))


# ------------------------------------------------------- family loss fns --

def lm_loss_fn(cfg):
    from repro.models.transformer.model import lm_loss

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch["tokens"], batch["labels"])

    return loss_fn


def gnn_node_class_loss_fn(cfg, forward, n_classes: int):
    def loss_fn(params, batch):
        g, labels = batch["graph"], batch["labels"]
        logits = forward(cfg, params, g)[..., :n_classes]
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.sum((lse - true) * g.node_mask) / jnp.maximum(
            g.node_mask.sum(), 1.0
        )
        return loss, {"ce": loss}

    return loss_fn


def gnn_regression_loss_fn(cfg, forward):
    def loss_fn(params, batch):
        g, target = batch["graph"], batch["target"]
        pred = forward(cfg, params, g)
        loss = jnp.mean((pred - target) ** 2)
        return loss, {"mse": loss}

    return loss_fn


def energy_loss_fn(cfg, energy_fn, *, force_weight: float = 0.0):
    """Molecular potential loss; optional force matching (grad-of-grad)."""

    def loss_fn(params, batch):
        g, e_target = batch["graph"], batch["energy"]
        if force_weight > 0:
            e, forces = energy_fn(cfg, params, g)
            f_loss = jnp.mean(jnp.sum((forces - batch["forces"]) ** 2, -1))
        else:
            from repro.models.gnn import nequip  # noqa

            e = energy_fn(cfg, params, g)
            if isinstance(e, tuple):
                e = e[0]
            f_loss = 0.0
        e_loss = jnp.mean((e - e_target) ** 2)
        loss = e_loss + force_weight * f_loss
        return loss, {"e_mse": e_loss}

    return loss_fn


def fm_loss_fn(cfg):
    from repro.models.recsys.fm import bce_loss

    def loss_fn(params, batch):
        loss = bce_loss(cfg, params, batch["ids"], batch["labels"])
        return loss, {"bce": loss}

    return loss_fn
