from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_warmup_lr,
    global_norm,
)
from repro.training.steps import TrainState, init_train_state, make_train_step

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_warmup_lr",
    "global_norm",
    "TrainState",
    "init_train_state",
    "make_train_step",
]
