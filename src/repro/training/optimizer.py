"""AdamW + schedules + global-norm clipping (hand-rolled; optax is not a
dependency).  State is a plain pytree so checkpointing/sharding Just Work:
moments inherit the parameter sharding under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any  # pytree like params
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # bf16 halves optimizer HBM (grok-scale)


def cosine_warmup_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (1-D and 0-D leaves)."""
    return True  # refined per-leaf by ndim below


def adamw_init(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
) -> tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_warmup_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices/embeddings, not norms/biases
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
