"""kMatrix — the paper's contribution (§IV).

A gMatrix whose counter space is *partitioned* using a sample of the stream:
the greedy E'-minimizing partitioner (``repro.core.partitioning``, paper
Eq. 8) assigns each sampled vertex to a localized ``w_i x w_i`` sketch; the
per-layer slabs are concatenated into one flat pool so that ingest stays a
single fused hash + scatter-add regardless of how heterogeneous the
partition widths are.

Layout (per layer r):

    pool[r] = [ slab_0 | slab_1 | ... | slab_{P-1} ]      slab_p has w_p^2 cells
    edge (i, j) with p = partition(i):
        cell = offset_p + h_r(i) % w_p * w_p + h_r(j) % w_p
    (actually fastrange, not mod — see repro.common.hashing)

This is the *flat* backend of the kMatrix sketch.  The same cells also
exist in a TPU-native width-class arrangement (``repro.core.kmatrix_accel``,
selected via ``sketch_backend()``); the two layouts are bit-exact
permutations of each other (DESIGN.md §Width-class-backend).

Design note (documented in DESIGN.md): the paper asserts kMatrix answers
every gMatrix query but does not specify how *connectivity* queries work
once the node hash space is partitioned (a path can hop between partitions,
and slots of different partitions are not mutually resolvable). We therefore
reserve a small global connectivity matrix (``conn_frac`` of the budget,
default 10%) that ingests every edge under a global hash — frequency queries
use the partitioned pool (the paper's accuracy win), reachability uses the
global matrix. Setting ``conn_frac=0`` recovers a frequency-only kMatrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.hashing import HashFamily, families_match, fastrange
from repro.common.struct import pytree_dataclass, static_field
from repro.core.partitioning import PartitionPlan, plan_for
from repro.core.routing import RouteTable, route_table_from_plan, routes_match
from repro.core.types import EdgeBatch, VertexStats

# Alias-safe under buffer donation (serving/snapshot.py): ingest / merge /
# empty_like never retain a reference to an input leaf, so the sketch may
# sit in a donate_argnums position and XLA can scatter into the pool/conn
# buffers in place.  empty_like reuses the hash and route leaves by
# reference — donating callers must deep-copy first
# (SnapshotBuffer._private_copy does).
DONATION_SAFE = True


@pytree_dataclass
class KMatrix:
    pool: jax.Array  # int32[d, pool_size]
    conn: jax.Array  # int32[d, cw, cw] global connectivity sketch (cw may be 0)
    # scatter-fallback tally carried over from the width-class backend
    # (``core.kmatrix_accel``).  The flat scatter path never overflows, so
    # ingest leaves it untouched; it exists so a relayout / checkpoint
    # migration round-trip (accel -> flat -> accel) preserves the diagnostic
    # instead of silently zeroing it.  merge sums it (same as accel).
    overflow: jax.Array  # int32[]
    hashes: HashFamily
    route: RouteTable
    pool_size: int = static_field()
    conn_w: int = static_field()

    @property
    def depth(self) -> int:
        return self.pool.shape[0]

    @property
    def num_counters(self) -> int:
        return self.pool.size + self.conn.size

    @staticmethod
    def create(
        *,
        bytes_budget: int,
        stats: VertexStats,
        depth: int = 7,
        seed: int = 0,
        max_partitions: int = 64,
        min_width: int = 8,
        outlier_frac: float | None = None,
        conn_frac: float = 0.1,
        partitioner: str = "auto",
        n_bands: int = 16,
    ) -> "KMatrix":
        counters = bytes_budget // 4
        per_layer = max(counters // depth, 4)
        conn_w = int(np.sqrt(per_layer * conn_frac)) if conn_frac > 0 else 0
        freq_budget = per_layer - conn_w * conn_w
        total_width = max(int(np.sqrt(freq_budget)), 2)
        plan = plan_for(
            partitioner,
            stats,
            total_width,
            square=True,
            min_width=min_width,
            outlier_frac=outlier_frac,
            max_partitions=max_partitions,
            n_bands=n_bands,
        )
        route, pool_size = route_table_from_plan(plan, square=True)
        return KMatrix(
            pool=jnp.zeros((depth, pool_size), dtype=jnp.int32),
            conn=jnp.zeros((depth, conn_w, conn_w), dtype=jnp.int32),
            overflow=jnp.zeros((), jnp.int32),
            hashes=HashFamily.create(seed, depth),
            route=route,
            pool_size=pool_size,
            conn_w=conn_w,
        )


def edge_cells(sk: KMatrix, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Flat pool index of edge (src, dst) in every layer -> int32[d, *S]."""
    p = sk.route.lookup(src)
    w = sk.route.widths[p]  # [*S]
    off = sk.route.offsets[p]
    hi = fastrange(sk.hashes.mix(src), w)  # [d, *S]
    hj = fastrange(sk.hashes.mix(dst), w)
    return off[None] + hi * w[None] + hj


def conn_cells(sk: KMatrix, v: jax.Array) -> jax.Array:
    """Per-layer slot of vertex ``v`` in the global connectivity matrix."""
    return fastrange(sk.hashes.mix(v), sk.conn_w)


def ingest(sk: KMatrix, batch: EdgeBatch) -> KMatrix:
    idx = edge_cells(sk, batch.src, batch.dst)  # [d, B]
    rows = jnp.arange(sk.depth, dtype=jnp.int32)[:, None]
    wts = batch.weight[None, :].astype(sk.pool.dtype)
    pool = sk.pool.at[rows, idx].add(wts)
    if sk.conn_w > 0:
        ci = fastrange(sk.hashes.mix(batch.src), sk.conn_w)
        cj = fastrange(sk.hashes.mix(batch.dst), sk.conn_w)
        conn = sk.conn.at[rows, ci, cj].add(wts)
    else:
        conn = sk.conn
    return sk.replace(pool=pool, conn=conn)


def edge_freq(sk: KMatrix, src: jax.Array, dst: jax.Array) -> jax.Array:
    idx = edge_cells(sk, src, dst)
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape((sk.depth,) + (1,) * src.ndim)
    return jnp.min(sk.pool[rows, idx], axis=0)


def node_out_freq(sk: KMatrix, v: jax.Array) -> jax.Array:
    """Row-sum of v's row inside its partition slab, min over layers.

    Heterogeneous widths are handled with a masked gather over the max
    partition width (static), so the op stays dense/batched.
    """
    p = sk.route.lookup(v)
    w = sk.route.widths[p]  # [*S]
    off = sk.route.offsets[p]
    hi = fastrange(sk.hashes.mix(v), w)  # [d, *S]
    wmax = sk.route.max_width
    cols = jnp.arange(wmax, dtype=jnp.int32)  # [wmax]
    # idx[d, *S, wmax]
    idx = off[None, ..., None] + hi[..., None] * w[None, ..., None] + cols
    mask = cols < w[None, ..., None]
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape(
        (sk.depth,) + (1,) * v.ndim + (1,)
    )
    vals = jnp.where(mask, sk.pool[rows, idx], 0)
    return jnp.min(jnp.sum(vals, axis=-1), axis=0)


def empty_like(sk: KMatrix) -> KMatrix:
    """A zero-counter sketch sharing ``sk``'s layout, routing and hashes.

    Snapshot hook (DESIGN.md §Serving): the serving double-buffer ingests
    into an ``empty_like`` delta and folds it into the published sketch with
    ``merge`` at epoch publish.
    """
    return sk.replace(pool=jnp.zeros_like(sk.pool), conn=jnp.zeros_like(sk.conn),
                      overflow=jnp.zeros_like(sk.overflow))


def merge(a: KMatrix, b: KMatrix) -> KMatrix:
    """Counter-additivity: the sketch of a union stream is the elementwise sum.

    This is the primitive behind data-parallel ingest (each data shard
    sketches its sub-stream; query-time psum), fault-tolerant re-joins and
    serving snapshot publishes.  Both operands must share layout AND hash
    seeds — layouts can coincide across seeds, so we check the hash-family
    parameters explicitly (outside jit) rather than trusting shapes.
    """
    assert a.pool_size == b.pool_size and a.conn_w == b.conn_w
    if families_match(a.hashes, b.hashes) is False:
        raise ValueError(
            "merge: operands use different hash families (built with "
            "different seeds); merging them silently corrupts estimates")
    if routes_match(a.route, b.route) is False:
        raise ValueError(
            "merge: operands use different partition plans (built from "
            "different samples); edges route to different slabs, so summing "
            "the pools silently corrupts estimates")
    return a.replace(pool=a.pool + b.pool, conn=a.conn + b.conn,
                     overflow=a.overflow + b.overflow)
