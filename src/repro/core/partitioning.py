"""gSketch-style sketch partitioning, generalized for kMatrix (paper §IV-A).

Given per-vertex sample statistics (estimated out-frequency ``f_v(m)`` and
out-degree ``deg(m)``), the expected relative error of a partition ``S`` with
width ``w`` follows paper Eq. (5):

    E(S, w) = (1/w) * [ sum_m deg(m)^2 * F(S) / f_v(m)  -  sum_m deg(m) ]
    F(S)    = sum_{m in S} f_v(m)

and the split criterion Eq. (8) reduces (for an equal split) to minimizing

    E'(S1, S2) = G(S1) + G(S2),
    G(S) = F(S) * sum_{m in S} deg(m)^2 / f_v(m)

The classical gSketch heuristic sorts vertices by average edge frequency
``f_v(m)/deg(m)`` (so each side stays frequency-uniform) and sweeps the cut
point; prefix sums make each sweep O(n).  We recurse greedily: always split
the leaf with the largest predicted error reduction, stopping at
``max_partitions`` / ``min_width`` / non-positive gain.

Width bookkeeping differs between the 1-D (gSketch: CountMin rows, memory
``d*w``) and 2-D (kMatrix: w x w matrices, memory ``d*w^2``) cases; splits
conserve *memory*, so the 2-D child width is ``w/sqrt(2)``, not ``w/2``.
This is host-side numpy — it runs once at sketch build time from the sample
(paper: 30k reservoir-sampled edges) and produces static Python ints, so
every downstream jit specializes on the final layout.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.types import VertexStats


@dataclasses.dataclass(frozen=True)
class Partition:
    """One leaf of the partition tree."""

    vertices: np.ndarray  # int32[k] vertex ids routed here
    width: int  # hash range of the localized sketch
    expected_error: float  # E(S, w) from Eq. (5)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Full output of the partitioner.

    ``route_keys``/``route_part`` give the sorted vertex -> partition map for
    sampled vertices; ``outlier`` is the partition index for unseen vertices.
    """

    partitions: tuple[Partition, ...]
    route_keys: np.ndarray  # int32[n] sorted
    route_part: np.ndarray  # int32[n]
    outlier: int

    @property
    def widths(self) -> tuple[int, ...]:
        return tuple(p.width for p in self.partitions)


def _partition_error(freq: np.ndarray, deg: np.ndarray, width: int) -> float:
    """Paper Eq. (5) for a vertex group with sketch width ``width``."""
    if len(freq) == 0 or width <= 0:
        return 0.0
    big_f = float(freq.sum())
    term = float((deg * deg / np.maximum(freq, 1e-9)).sum())
    return (big_f * term - float(deg.sum())) / float(width)


def _best_split(freq: np.ndarray, deg: np.ndarray):
    """Sweep the sorted-by-avg-frequency cut minimizing G(S1)+G(S2).

    Returns (cut_index, gprime) with vertices [0:cut] -> S1, [cut:] -> S2,
    in the *sorted* order (caller must apply the same order).
    """
    n = len(freq)
    if n < 2:
        return None
    f = np.maximum(freq, 1e-9)
    g_term = deg * deg / f
    pf = np.cumsum(f)
    pg = np.cumsum(g_term)
    tf, tg = pf[-1], pg[-1]
    cuts = np.arange(1, n)
    left = pf[:-1] * pg[:-1]
    right = (tf - pf[:-1]) * (tg - pg[:-1])
    scores = left + right
    k = int(np.argmin(scores))
    return cuts[k], float(scores[k])


def good_turing_outlier_share(freq: np.ndarray) -> float:
    """Estimate the stream share of *unsampled* sources (Good-Turing).

    P(next edge's source unseen) ~= N1 / N where N1 = #sources with exactly
    one sampled edge. Sizes the outlier sketch by its expected traffic rather
    than a fixed fraction — at low sample coverage most mass is unseen and a
    fixed 10% outlier would be catastrophically undersized.
    """
    n = float(freq.sum())
    if n <= 0:
        return 0.5
    n1 = float((freq <= 1.0).sum())
    return float(np.clip(n1 / n, 0.05, 0.6))


def plan_partitions(
    stats: VertexStats,
    total_width: int,
    *,
    square: bool,
    max_partitions: int = 64,
    min_width: int = 64,
    outlier_frac: float | None = None,
) -> PartitionPlan:
    """Run the greedy recursive partitioner.

    Args:
      stats: sample-derived vertex statistics.
      total_width: width budget W. 1-D (gSketch): memory is ``d*W`` counters
        and children split W additively. 2-D (kMatrix): memory is ``d*W^2``
        and children get ``W/sqrt(2)`` each (memory conserving).
      square: True for the 2-D matrix case.
      outlier_frac: fraction of the *memory* budget reserved for vertices
        that never appeared in the sample (gSketch's outlier sketch).
        None -> Good-Turing estimate of unseen-source traffic.
    """
    vertex = np.asarray(stats.vertex)
    freq = np.asarray(stats.freq, dtype=np.float64)
    deg = np.asarray(stats.deg, dtype=np.float64)

    if outlier_frac is None:
        outlier_frac = good_turing_outlier_share(freq)

    if square:
        outlier_w = max(min_width, int(total_width * np.sqrt(outlier_frac)))
        root_w = int(np.sqrt(max(total_width * total_width - outlier_w * outlier_w, 1)))
    else:
        outlier_w = max(min_width, int(total_width * outlier_frac))
        root_w = total_width - outlier_w

    # Sort by average edge frequency (f/deg): the gSketch uniformity ordering.
    order = np.argsort(freq / np.maximum(deg, 1.0), kind="stable")
    vertex, freq, deg = vertex[order], freq[order], deg[order]

    def child_width(w: int) -> int:
        return int(w / np.sqrt(2.0)) if square else w // 2

    # Leaf := (vertex index slice, width). Greedy best-first on error gain.
    heap: list[tuple[float, int, tuple]] = []
    counter = 0

    def push(lo: int, hi: int, w: int) -> None:
        nonlocal counter
        f, d_ = freq[lo:hi], deg[lo:hi]
        err_now = _partition_error(f, d_, w)
        cw = child_width(w)
        best = _best_split(f, d_) if (hi - lo >= 2 and cw >= min_width) else None
        if best is None:
            gain = -np.inf
            cut = -1
        else:
            cut, _ = best
            err_split = _partition_error(f[:cut], d_[:cut], cw) + _partition_error(
                f[cut:], d_[cut:], cw
            )
            gain = err_now - err_split
        heapq.heappush(heap, (-gain, counter, (lo, hi, w, cut, gain)))
        counter += 1

    push(0, len(vertex), root_w)
    leaves: list[tuple[int, int, int]] = []
    n_leaves = 1
    while heap:
        _, _, (lo, hi, w, cut, gain) = heapq.heappop(heap)
        if gain <= 0 or n_leaves >= max_partitions or cut < 0:
            leaves.append((lo, hi, w))
            continue
        cw = child_width(w)
        push(lo, lo + cut, cw)
        push(lo + cut, hi, cw)
        n_leaves += 1

    leaves.sort()

    # --- Budget-filling rescale -------------------------------------------
    # The sqrt(2) child widths + integer floors typically strand 10-15% of
    # the counter budget; rescale every width so the final layout consumes
    # (almost) exactly the budgeted area, then spend any remainder one
    # column at a time on the leaves with the largest expected error.
    widths = np.array([w for (_, _, w) in leaves] + [outlier_w], dtype=np.int64)
    if square:
        budget_area = int(total_width) ** 2
        used = int((widths**2).sum())
        scale = np.sqrt(budget_area / max(used, 1))
        widths = np.maximum((widths * scale).astype(np.int64), 2)
        while int((widths**2).sum()) > budget_area:
            widths[int(np.argmax(widths))] -= 1
        # Greedy remainder spend: +1 width costs 2w+1 area.
        improved = True
        while improved:
            improved = False
            order = np.argsort(widths)
            for i in order:
                cost = 2 * int(widths[i]) + 1
                if int((widths**2).sum()) + cost <= budget_area:
                    widths[i] += 1
                    improved = True
    else:
        budget_area = int(total_width)
        used = int(widths.sum())
        widths = np.maximum((widths * (budget_area / max(used, 1))).astype(np.int64), 2)
        while int(widths.sum()) > budget_area:
            widths[int(np.argmax(widths))] -= 1
        rem = budget_area - int(widths.sum())
        if rem > 0:
            widths[np.argsort(widths)[:rem]] += 1

    partitions = [
        Partition(
            vertices=vertex[lo:hi].astype(np.int32),
            width=int(widths[k]),
            expected_error=_partition_error(freq[lo:hi], deg[lo:hi], int(widths[k])),
        )
        for k, (lo, hi, _) in enumerate(leaves)
    ]
    # Outlier partition is appended last and owns no sampled vertices.
    partitions.append(
        Partition(vertices=np.empty(0, np.int32), width=int(widths[-1]), expected_error=0.0)
    )

    keys = np.concatenate([p.vertices for p in partitions[:-1]]) if partitions[:-1] else np.empty(0, np.int32)
    parts = np.concatenate(
        [np.full(len(p.vertices), i, np.int32) for i, p in enumerate(partitions[:-1])]
    ) if len(keys) else np.empty(0, np.int32)
    order = np.argsort(keys, kind="stable")
    return PartitionPlan(
        partitions=tuple(partitions),
        route_keys=keys[order].astype(np.int32),
        route_part=parts[order].astype(np.int32),
        outlier=len(partitions) - 1,
    )


def total_expected_error(plan: PartitionPlan) -> float:
    return float(sum(p.expected_error for p in plan.partitions))


def plan_partitions_banded(
    stats: VertexStats,
    total_width: int,
    *,
    square: bool,
    n_bands: int = 16,
    min_width: int = 8,
    outlier_frac: float | None = None,
) -> PartitionPlan:
    """Beyond-paper partitioner: frequency bands + continuous-optimal areas.

    Instead of recursive equal binary splits (paper Eq. 8), observe that the
    split objective  E = sum_S F(S) * H(S) / a(S)  (H = sum deg^2/f) has the
    closed-form optimal allocation  a(S) ~ sqrt(F(S) * H(S)) = sqrt(G(S))
    for a *fixed* grouping.  We group vertices into ``n_bands`` equal-count
    bands of the average-edge-frequency ordering (maximal uniformity per
    band) and allocate areas by the sqrt-G rule.

    Empirically (EXPERIMENTS.md "partitioner" ablation) this dominates both
    the greedy recursion and value-quantile banding on all three
    paper-matched streams — e.g. cit-HepPh ARE 29.3 (TCM) / 27.7 (greedy)
    / 21.9 (banded) at 200 KB.
    """
    vertex = np.asarray(stats.vertex)
    freq = np.asarray(stats.freq, dtype=np.float64)
    deg = np.asarray(stats.deg, dtype=np.float64)
    if outlier_frac is None:
        outlier_frac = good_turing_outlier_share(freq)

    avg = freq / np.maximum(deg, 1.0)
    order = np.argsort(avg, kind="stable")
    v, f, d_ = vertex[order], freq[order], deg[order]

    bounds = np.linspace(0, len(v), n_bands + 1).astype(int)
    groups, gs = [], []
    for i in range(n_bands):
        lo, hi = bounds[i], bounds[i + 1]
        if hi <= lo:
            continue
        g_val = f[lo:hi].sum() * float(
            (d_[lo:hi] ** 2 / np.maximum(f[lo:hi], 1e-9)).sum()
        )
        groups.append((lo, hi))
        gs.append(max(g_val, 1e-9))
    gs_arr = np.asarray(gs)

    if square:
        area = float(total_width) ** 2
        out_area = area * outlier_frac
        alloc = (area - out_area) * np.sqrt(gs_arr) / np.sqrt(gs_arr).sum()
        widths = np.maximum(np.sqrt(alloc).astype(np.int64), min_width)
        out_w = max(int(np.sqrt(out_area)), min_width)
        # Budget fill: spend the integer-floor remainder widening leaves.
        all_w = np.concatenate([widths, [out_w]])
        improved = True
        while improved:
            improved = False
            for i in np.argsort(all_w):
                if int((all_w**2).sum()) + 2 * int(all_w[i]) + 1 <= area:
                    all_w[i] += 1
                    improved = True
        widths, out_w = all_w[:-1], int(all_w[-1])
    else:
        budget = float(total_width)
        out_w = max(int(budget * outlier_frac), min_width)
        alloc = (budget - out_w) * np.sqrt(gs_arr) / np.sqrt(gs_arr).sum()
        widths = np.maximum(alloc.astype(np.int64), min_width)
        rem = int(budget) - out_w - int(widths.sum())
        if rem > 0:
            widths[np.argsort(widths)[:rem]] += 1

    partitions = [
        Partition(
            vertices=v[lo:hi].astype(np.int32),
            width=int(w),
            expected_error=_partition_error(f[lo:hi], d_[lo:hi], int(w)),
        )
        for (lo, hi), w in zip(groups, widths)
    ]
    partitions.append(
        Partition(vertices=np.empty(0, np.int32), width=out_w, expected_error=0.0)
    )
    keys = np.concatenate([p.vertices for p in partitions[:-1]])
    parts = np.concatenate(
        [np.full(len(p.vertices), i, np.int32) for i, p in enumerate(partitions[:-1])]
    )
    o = np.argsort(keys, kind="stable")
    return PartitionPlan(
        partitions=tuple(partitions),
        route_keys=keys[o].astype(np.int32),
        route_part=parts[o].astype(np.int32),
        outlier=len(partitions) - 1,
    )


def _two_term_score(plan: PartitionPlan, stats: VertexStats) -> float:
    """Expected-error model with BOTH collision terms (beyond paper Eq. 5):

        E(S, w) = R(S)/w + X(S)/w^2
        R(S) = sum_m d(m)(d(m)-1)          row-mates: same source, 1/w
        X(S) = F(S) * sum_m d(m)^2/f(m)    strangers: both hashes, 1/w^2

    The paper's model keeps only a 1/w term; the two-term model correctly
    prefers NOT splitting when frequencies are uniform (splitting shrinks
    widths without any homogeneity gain)."""
    vert = np.asarray(stats.vertex)
    freq = np.asarray(stats.freq, np.float64)
    deg = np.asarray(stats.deg, np.float64)
    by_id = {int(v): i for i, v in enumerate(vert)}
    total = 0.0
    for p in plan.partitions:
        if len(p.vertices) == 0 or p.width <= 0:
            continue
        idx = np.asarray([by_id[int(v)] for v in p.vertices])
        f, d_ = freq[idx], deg[idx]
        r_term = float((d_ * (d_ - 1.0)).sum())
        x_term = float(f.sum() * (d_ * d_ / np.maximum(f, 1e-9)).sum())
        total += r_term / p.width + x_term / (p.width**2)
    return total


def plan_partitions_auto(
    stats: VertexStats,
    total_width: int,
    *,
    square: bool = True,
    min_width: int = 8,
    outlier_frac: float | None = None,
    candidates: tuple[int, ...] = (1, 2, 4, 8, 16),
) -> PartitionPlan:
    """Adaptive partitioner: build banded plans for several band counts
    (1 band ~= a global sketch + outlier) and keep the plan with the best
    two-term modeled error. On frequency-uniform streams this collapses to
    no-split (matching gMatrix instead of losing to it); on skewed streams
    it keeps the banded win. See EXPERIMENTS.md 'partitioner' ablation."""
    best, best_score = None, np.inf
    for k in candidates:
        plan = plan_partitions_banded(
            stats, total_width, square=square, n_bands=k,
            min_width=min_width, outlier_frac=outlier_frac,
        )
        score = _two_term_score(plan, stats)
        if score < best_score:
            best, best_score = plan, score
    return best


PARTITIONERS = {
    "greedy": plan_partitions,  # paper-faithful Eq. 8 recursion
    "banded": plan_partitions_banded,  # beyond-paper sqrt-G bands
    "auto": plan_partitions_auto,  # beyond-paper two-term model selection
}


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Deterministic hash-band routing of edges to ``n_shards`` sketch shards.

    This is the scale-out layer ABOVE the intra-sketch partition plan: a
    whole edge (not a counter) is owned by exactly one shard, chosen by a
    multiply-shift hash band of its SOURCE vertex.  Routing by source is the
    invariant every sharded query leans on (DESIGN.md §Sharding): all
    out-edges of a vertex land in one shard, so edge-frequency and
    node-out-degree queries are answerable by the owning shard alone, and
    because the shards partition the stream, the merge of all shard sketches
    (same layout, same hash family) is bit-identical to a single sketch that
    ingested the whole stream — counter additivity does the rest.

    The hash constants derive only from ``(seed, n_shards)`` and are
    independent of any sketch's hash family, so re-seeding a sketch never
    silently re-routes the stream.  Host-side numpy: routing happens in
    stream pumps and the query planner, never inside jit.
    """

    n_shards: int
    seed: int = 0

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        rng = np.random.default_rng((self.seed << 8) ^ 0x5A17D)
        object.__setattr__(
            self, "_a", np.uint32(int(rng.integers(0, 1 << 32)) | 1))
        object.__setattr__(
            self, "_b", np.uint32(int(rng.integers(0, 1 << 32))))

    def shard_of(self, src) -> np.ndarray:
        """Owning shard for each source vertex (scalar or any-shape array)."""
        x = np.asarray(src, dtype=np.uint32)
        with np.errstate(over="ignore"):
            h = self._a * x + self._b
            h ^= h >> np.uint32(16)
            h *= np.uint32(0x7FEB352D)
            h ^= h >> np.uint32(15)
        # fastrange: (h * K) >> 32 maps uniformly onto [0, n_shards)
        band = (h.astype(np.uint64) * np.uint64(self.n_shards)) >> np.uint64(32)
        return band.astype(np.int32)

    def shard_of_one(self, src: int) -> int:
        return int(self.shard_of(np.asarray([src], dtype=np.int64))[0])


def plan_for(
    partitioner: str,
    stats: VertexStats,
    total_width: int,
    *,
    square: bool,
    min_width: int = 8,
    outlier_frac: float | None = None,
    max_partitions: int = 64,
    n_bands: int = 16,
) -> PartitionPlan:
    """Dispatch to a named partitioner with its mode-specific knobs.

    Shared by both kMatrix backends (``core.kmatrix`` flat pool,
    ``core.kmatrix_accel`` width classes) so a backend switch never changes
    which plan a given configuration produces.  The greedy recursion floors
    ``min_width`` at 16: below that its equal binary splits produce slabs
    too small to be worth the routing entry.
    """
    if partitioner == "greedy":
        return plan_partitions(
            stats, total_width, square=square, max_partitions=max_partitions,
            min_width=max(min_width, 16), outlier_frac=outlier_frac)
    if partitioner == "banded":
        return plan_partitions_banded(
            stats, total_width, square=square, n_bands=n_bands,
            min_width=min_width, outlier_frac=outlier_frac)
    if partitioner == "auto":
        return plan_partitions_auto(
            stats, total_width, square=square, min_width=min_width,
            outlier_frac=outlier_frac)
    raise ValueError(f"unknown partitioner {partitioner!r}")
