"""TCM / gMatrix — paper §III-C/D, the Type II global-sketch baselines.

Both store ``d`` layers of ``w x w`` counter matrices; an edge ``(i, j)`` is
hashed to cell ``(h_r(i), h_r(j))`` in layer ``r``.  TCM as published uses
arbitrary hash functions; gMatrix requires *pairwise independent* ones (which
is what `HashFamily` provides — so our TCM is, if anything, slightly stronger
than the paper's).  The distinction we preserve is the query surface: gMatrix
additionally answers reverse (heavy-hitter) queries, implemented in
``repro.core.queries`` as vectorized universe sweeps.

The locality property (same hash for rows and columns per layer) is what
enables node-level and connectivity queries, which plain CountMin cannot do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.hashing import HashFamily, families_match, fastrange
from repro.common.struct import pytree_dataclass, static_field
from repro.core.types import EdgeBatch

# Alias-safe under buffer donation (serving/snapshot.py): ingest / merge /
# empty_like are pure pytree->pytree functions with no retained input
# references, so the sketch may sit in a donate_argnums position.
DONATION_SAFE = True


@pytree_dataclass
class MatrixSketch:
    table: jax.Array  # int32[d, w, w]
    hashes: HashFamily
    w: int = static_field()
    kind: str = static_field(default="gmatrix")  # "tcm" | "gmatrix"

    @property
    def depth(self) -> int:
        return self.table.shape[0]

    @property
    def num_counters(self) -> int:
        return self.table.size

    @staticmethod
    def create(
        *, bytes_budget: int, depth: int = 7, seed: int = 0, kind: str = "gmatrix"
    ) -> "MatrixSketch":
        counters = bytes_budget // 4
        w = max(int((counters // depth) ** 0.5), 2)
        return MatrixSketch(
            table=jnp.zeros((depth, w, w), dtype=jnp.int32),
            hashes=HashFamily.create(seed, depth),
            w=w,
            kind=kind,
        )


def node_cells(sk: MatrixSketch, v: jax.Array) -> jax.Array:
    """Per-layer hash slot of vertex ``v`` -> int32[d, *S]."""
    return fastrange(sk.hashes.mix(v), sk.w)


def ingest(sk: MatrixSketch, batch: EdgeBatch) -> MatrixSketch:
    hi = node_cells(sk, batch.src)  # [d, B]
    hj = node_cells(sk, batch.dst)  # [d, B]
    rows = jnp.arange(sk.depth, dtype=jnp.int32)[:, None]
    table = sk.table.at[rows, hi, hj].add(batch.weight[None, :].astype(sk.table.dtype))
    return sk.replace(table=table)


def edge_freq(sk: MatrixSketch, src: jax.Array, dst: jax.Array) -> jax.Array:
    hi = node_cells(sk, src)
    hj = node_cells(sk, dst)
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape((sk.depth,) + (1,) * src.ndim)
    return jnp.min(sk.table[rows, hi, hj], axis=0)


def node_out_freq(sk: MatrixSketch, v: jax.Array) -> jax.Array:
    """Aggregate out-weight of vertex ``v``: min over layers of its row sum."""
    hv = node_cells(sk, v)  # [d, *S]
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape((sk.depth,) + (1,) * v.ndim)
    sums = jnp.sum(sk.table[rows, hv, :], axis=-1)  # [d, *S]
    return jnp.min(sums, axis=0)


def empty_like(sk: MatrixSketch) -> MatrixSketch:
    """Zero-counter sketch sharing layout + hashes (serving snapshot hook)."""
    return sk.replace(table=jnp.zeros_like(sk.table))


def merge(a: MatrixSketch, b: MatrixSketch) -> MatrixSketch:
    """Counter-additivity; operands must share layout AND hash seeds."""
    assert a.w == b.w and a.table.shape == b.table.shape
    if families_match(a.hashes, b.hashes) is False:
        raise ValueError(
            "merge: operands use different hash families (built with "
            "different seeds); merging them silently corrupts estimates")
    return a.replace(table=a.table + b.table)


def node_in_freq(sk: MatrixSketch, v: jax.Array) -> jax.Array:
    hv = node_cells(sk, v)
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape((sk.depth,) + (1,) * v.ndim)
    # Advanced indices (rows, hv) around the middle slice put the broadcast
    # dims in front: gathered shape is [d, *S, w]; reduce the trailing w.
    gathered = sk.table[rows, :, hv]
    sums = jnp.sum(gathered, axis=-1)
    return jnp.min(sums, axis=0)
