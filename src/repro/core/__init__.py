"""repro.core — the paper's contribution: streaming-graph sketches.

Sketch zoo (paper §III + §IV):
  CountMin  (Type I,  global)      repro.core.countmin
  gSketch   (Type I,  partitioned) repro.core.gsketch
  TCM       (Type II, global)      repro.core.matrix_sketch (kind="tcm")
  gMatrix   (Type II, global)      repro.core.matrix_sketch (kind="gmatrix")
  kMatrix   (Type II, partitioned) repro.core.kmatrix        <- contribution
            width-class backend    repro.core.kmatrix_accel  (same cells,
            TPU-native layout; selected via sketch_backend())

All sketches share: batched EdgeBatch ingest (fused hash + scatter-add),
additive merge (enables data-parallel / fault-tolerant operation), and a
uniform query surface in repro.core.queries.
"""
from repro.core.types import EdgeBatch, VertexStats, vertex_stats_from_sample
from repro.core.countmin import CountMin
from repro.core.gsketch import GSketch
from repro.core.matrix_sketch import MatrixSketch
from repro.core.kmatrix import KMatrix
from repro.core.kmatrix_accel import KMatrixAccel, sketch_backend
from repro.core.partitioning import (
    PartitionPlan,
    ShardPlan,
    plan_partitions,
    total_expected_error,
)

__all__ = [
    "EdgeBatch",
    "VertexStats",
    "vertex_stats_from_sample",
    "CountMin",
    "GSketch",
    "MatrixSketch",
    "KMatrix",
    "KMatrixAccel",
    "sketch_backend",
    "PartitionPlan",
    "ShardPlan",
    "plan_partitions",
    "total_expected_error",
]
