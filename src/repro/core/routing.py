"""Vertex -> partition routing shared by gSketch and kMatrix.

The partition plan is host-side (numpy); at stream time routing is a binary
search over the sorted sampled-vertex table (``jnp.searchsorted``), falling
back to the outlier partition for unseen vertices.  This is the "separate
data structure to track the vertices belonging to different localized
partitions" from paper §IV-A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.struct import pytree_dataclass, static_field
from repro.core.partitioning import PartitionPlan


@pytree_dataclass
class RouteTable:
    keys: jax.Array  # int32[n] sorted sampled vertex ids
    part: jax.Array  # int32[n] partition index per key
    offsets: jax.Array  # int32[P] slab offset per partition
    widths: jax.Array  # int32[P] hash width per partition
    outlier: int = static_field()
    n_partitions: int = static_field()
    max_width: int = static_field()

    @property
    def routed_bytes(self) -> int:
        return int(self.keys.size + self.part.size) * 4

    def lookup(self, v: jax.Array) -> jax.Array:
        """Partition id for each vertex in ``v`` (any shape)."""
        if self.keys.shape[0] == 0:
            return jnp.full(v.shape, self.outlier, dtype=jnp.int32)
        pos = jnp.searchsorted(self.keys, v.astype(jnp.int32))
        pos = jnp.clip(pos, 0, self.keys.shape[0] - 1)
        found = self.keys[pos] == v.astype(jnp.int32)
        return jnp.where(found, self.part[pos], jnp.int32(self.outlier))


def route_table_from_plan(plan: PartitionPlan, *, square: bool) -> tuple[RouteTable, int]:
    """Build the device RouteTable + total pool size from a PartitionPlan.

    Slab size per partition is ``w**2`` (kMatrix, 2-D) or ``w`` (gSketch, 1-D).
    Returns (table, pool_size).
    """
    widths = np.asarray(plan.widths, dtype=np.int64)
    slab = widths**2 if square else widths
    offsets = np.concatenate([[0], np.cumsum(slab)[:-1]]).astype(np.int32)
    pool_size = int(slab.sum())
    table = RouteTable(
        keys=jnp.asarray(plan.route_keys),
        part=jnp.asarray(plan.route_part),
        offsets=jnp.asarray(offsets),
        widths=jnp.asarray(widths.astype(np.int32)),
        outlier=plan.outlier,
        n_partitions=len(plan.partitions),
        max_width=int(widths.max()) if len(widths) else 0,
    )
    return table, pool_size


def routes_match(a: RouteTable, b: RouteTable) -> bool | None:
    """Whether two route tables encode the same partition plan.

    Returns ``None`` when either side is a tracer (not inspectable under
    jit).  Used by sketch ``merge``: same budget + seed but different
    bootstrap samples yield equal layouts and hash families with different
    vertex->slab routing, which summing would silently corrupt.
    """
    arrs = (a.keys, a.part, a.offsets, a.widths,
            b.keys, b.part, b.offsets, b.widths)
    if any(isinstance(x, jax.core.Tracer) for x in arrs):
        return None
    return (
        a.outlier == b.outlier
        and a.keys.shape == b.keys.shape
        and a.offsets.shape == b.offsets.shape
        and all(bool(np.array_equal(np.asarray(x), np.asarray(y)))
                for x, y in [(a.keys, b.keys), (a.part, b.part),
                             (a.offsets, b.offsets), (a.widths, b.widths)])
    )
