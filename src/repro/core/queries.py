"""Type II query surface on matrix sketches (TCM / gMatrix / kMatrix).

Implements the query families from the TCM/gMatrix papers that the kMatrix
paper claims compatibility with:

  * edge frequency              (per-sketch ``edge_freq``)
  * node out/in aggregate       (row/col sums)
  * reachability                boolean transitive closure per layer; a pair
                                is declared reachable only if EVERY layer
                                agrees (one-sided error, like CountMin).
  * heavy nodes / heavy edges   vectorized "reverse" universe sweeps — the
                                gMatrix pairwise-independent hashing makes a
                                candidate scan sound; we batch it so scoring
                                a 1M-vertex universe is a few fused gathers.
  * path / subgraph weight      composition of edge queries.

The closure uses O(log w) boolean matrix squarings; squarings are float32
matmuls (MXU-friendly on TPU) thresholded back to {0,1}.
"""
from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import kmatrix as km
from repro.core import kmatrix_accel as kma
from repro.core import matrix_sketch as ms


def _bool_closure(adj: jax.Array, max_hops: int | None = None) -> jax.Array:
    """Reflexive-transitive closure of a boolean adjacency matrix [w, w]."""
    w = adj.shape[-1]
    reach = (adj | jnp.eye(w, dtype=bool)).astype(jnp.float32)

    def body(_, r):
        return jnp.minimum(r @ r, 1.0)

    # _closure_steps is shared with the Pallas backend: both paths MUST
    # square the same number of times or their closures diverge
    reach = jax.lax.fori_loop(0, _closure_steps(w, max_hops), body, reach)
    return reach > 0.5


# --- engine-callable pure functions (explicit closure injection) -------------
#
# The O(log w) squaring cascade is the expensive half of a reachability query;
# the per-pair lookup is a few gathers.  Splitting them lets the serving
# engine compute ``build_closure`` ONCE per (tenant, epoch) and answer every
# subsequent reachability query against the cached closure (DESIGN.md
# §Serving).  The classic one-shot entry points below are thin wrappers.


def _closure_steps(w: int, max_hops: int | None) -> int:
    """Number of squarings covering paths of length ``max_hops`` (or any)."""
    return (max(1, (w - 1).bit_length()) if max_hops is None
            else max(1, max_hops.bit_length()))


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _build_closure_jnp(adj_layers: jax.Array,
                       max_hops: int | None = None) -> jax.Array:
    return jax.vmap(lambda a: _bool_closure(a > 0, max_hops))(adj_layers)


@functools.partial(jax.jit, static_argnames=("n_steps", "block"))
def _build_closure_pallas(adj_layers: jax.Array, n_steps: int,
                          block: int) -> jax.Array:
    # imported lazily so the pure-jnp query surface never requires Pallas
    from repro.kernels.ops import accel_reach_closure

    return accel_reach_closure(adj_layers, block=block, n_steps=n_steps)


def closure_backend(backend: str | None = None) -> str:
    """Resolve the closure backend: explicit arg > $REPRO_CLOSURE_BACKEND >
    platform default (Pallas kernel on TPU, pure jnp elsewhere — the Pallas
    path still *runs* off-TPU via ``interpret=True``, it is just slower than
    XLA's fused matmuls, so it is opt-in there)."""
    backend = backend or os.environ.get("REPRO_CLOSURE_BACKEND") or (
        "pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown closure backend {backend!r} "
                         "(expected 'jnp' or 'pallas')")
    return backend


def build_closure(adj_layers: jax.Array, max_hops: int | None = None, *,
                  backend: str | None = None) -> jax.Array:
    """Per-layer boolean closure: counter layers [d, w, w] -> bool [d, w, w].

    Backend dispatch (ROADMAP `kernels/reach_closure.py` item): ``"pallas"``
    drives the tiled MXU squaring kernel (``kernels.ops.accel_reach_closure``,
    interpret-mode off TPU), ``"jnp"`` the pure-XLA cascade.  Both compute
    the identical boolean fixpoint — squarings of a 0/1 float matrix are
    exact in f32 for w < 2^24 — and are parity-tested in tests/test_kernels.
    """
    from repro.obs.profile import profile_call

    if closure_backend(backend) == "jnp":
        return profile_call("closure:jnp", _build_closure_jnp,
                            adj_layers, max_hops)
    w = adj_layers.shape[-1]
    # pow-of-two tile <= 128 that covers small widths without overpadding
    block = min(128, 1 << max(3, (max(w, 2) - 1).bit_length()))
    return profile_call("closure:pallas", _build_closure_pallas, adj_layers,
                        _closure_steps(w, max_hops), block)


def reachability_from_closure(closure: jax.Array, hi: jax.Array,
                              hj: jax.Array) -> jax.Array:
    """Pair lookup against a prebuilt closure.

    ``hi``/``hj`` are per-layer node slots [d, *S]; a pair is reachable only
    if EVERY layer agrees (one-sided error, like CountMin).
    """
    d = closure.shape[0]
    rows = jnp.arange(d, dtype=jnp.int32).reshape((d,) + (1,) * (hi.ndim - 1))
    return jnp.all(closure[rows, hi, hj], axis=0)


def closure_layers(sk) -> jax.Array:
    """The [d, w, w] adjacency layers a sketch uses for connectivity queries.

    Only matrix-shaped Type II sketches qualify; CountMin/gSketch hash the
    whole edge to one cell, so no adjacency structure exists to close over —
    rejecting them here beats returning silently meaningless reachability.
    """
    if isinstance(sk, (km.KMatrix, kma.KMatrixAccel)):
        assert sk.conn_w > 0, (
            "kMatrix built with conn_frac=0 cannot answer reachability")
        return sk.conn
    if isinstance(sk, ms.MatrixSketch):
        return sk.table
    raise ValueError(
        f"reachability is not answerable by {type(sk).__name__}: "
        "no [d, w, w] adjacency layers")


def reach_cells(sk, v: jax.Array) -> jax.Array:
    """Per-layer connectivity-matrix slot of vertex ``v`` -> int32[d, *S]."""
    if isinstance(sk, km.KMatrix):
        return km.conn_cells(sk, v)
    if isinstance(sk, kma.KMatrixAccel):
        return kma.conn_cells(sk, v)
    if isinstance(sk, ms.MatrixSketch):
        return ms.node_cells(sk, v)
    raise ValueError(
        f"reachability is not answerable by {type(sk).__name__}: "
        "no [d, w, w] adjacency layers")


def reachability(sk: ms.MatrixSketch, src: jax.Array, dst: jax.Array,
                 max_hops: int | None = None) -> jax.Array:
    """Estimated reachability src ->* dst. True may be a false positive
    (hash collisions merge nodes) but never a false negative."""
    closure = build_closure(sk.table, max_hops)  # [d,w,w]
    return reachability_from_closure(
        closure, ms.node_cells(sk, src), ms.node_cells(sk, dst))


def kmatrix_reachability(sk: km.KMatrix, src: jax.Array, dst: jax.Array,
                         max_hops: int | None = None) -> jax.Array:
    """Reachability on kMatrix via its global connectivity matrix."""
    closure = build_closure(closure_layers(sk), max_hops)
    return reachability_from_closure(
        closure, km.conn_cells(sk, src), km.conn_cells(sk, dst))


def heavy_nodes(
    node_freq_fn: Callable[[jax.Array], jax.Array],
    universe_size: int,
    threshold: float,
    *,
    chunk: int = 65536,
) -> tuple[jax.Array, jax.Array]:
    """Reverse sweep: score every vertex id in [0, universe) and return
    (ids, freqs) of those with estimated aggregate >= threshold.

    Returns dense arrays of length ``universe_size`` rounded up to ``chunk``
    with -1 ids on misses (static shapes; callers filter host-side).
    """
    n_chunks = -(-universe_size // chunk)
    padded = n_chunks * chunk

    def score(block_start):
        ids = block_start + jnp.arange(chunk, dtype=jnp.int32)
        freqs = node_freq_fn(ids)
        valid = (ids < universe_size) & (freqs >= threshold)
        return jnp.where(valid, ids, -1), jnp.where(valid, freqs, 0)

    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    ids, freqs = jax.lax.map(score, starts)
    return ids.reshape(padded), freqs.reshape(padded)


def heavy_edges(
    edge_freq_fn: Callable[[jax.Array, jax.Array], jax.Array],
    cand_src: jax.Array,
    cand_dst: jax.Array,
    threshold: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Candidate-set heavy-edge query: mask + estimates for given pairs."""
    est = edge_freq_fn(cand_src, cand_dst)
    keep = est >= threshold
    return keep, est, jnp.where(keep, est, 0)


def path_weight(
    edge_freq_fn: Callable[[jax.Array, jax.Array], jax.Array],
    path_nodes: jax.Array,
) -> jax.Array:
    """Aggregate (sum of estimated frequencies) along a node path [k]."""
    return jnp.sum(edge_freq_fn(path_nodes[:-1], path_nodes[1:]))


def subgraph_weight(
    edge_freq_fn: Callable[[jax.Array, jax.Array], jax.Array],
    src: jax.Array,
    dst: jax.Array,
) -> jax.Array:
    """Total estimated weight of an explicit edge set."""
    return jnp.sum(edge_freq_fn(src, dst))
