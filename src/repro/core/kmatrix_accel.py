"""kMatrix width-class backend — the TPU-native layout as a full sketch.

``KMatrixAccel`` stores the same counters as the flat-pool ``KMatrix``
(``repro.core.kmatrix``) in a different physical arrangement: partition
widths are quantized to power-of-two *width classes*, and every partition of
width ``w_c`` lives as one row of a rectangular pool int32[d, P_c, w_c, w_c].
Rectangular pools are what makes ingest MXU-shaped — batches become
per-class one-hot matmuls (``repro.kernels.matrix_ingest``) instead of a
serialized XLA scatter.

This module is the *sketch protocol* surface the production layers consume
(serving registry/snapshots, runtime workers, checkpoints, benchmarks):
``create / ingest / edge_freq / node_out_freq / conn_cells / empty_like /
merge`` — mirror-compatible with ``repro.core.kmatrix`` so every layer above
is layout-agnostic.  Only ``ingest`` touches Pallas (lazily, via
``repro.kernels.ops``); queries and merges are pure jnp, so importing this
module never requires a TPU.

Layout equivalence: the class layout and the flat layout index the *same*
cells — cell ``(hi, hj)`` of partition ``p`` is ``pools[class(p)][d,
index(p), hi, hj]`` here and ``pool[d, offset(p) + hi*w_p + hj]`` there.
``to_flat_layout`` / ``to_class_layout`` apply that permutation bit-exactly,
so checkpoints written under either backend load into the other and
``benchmarks/serve_bench.py`` can hard-gate estimate equality.

Backend selection (``sketch_backend``): explicit arg > $REPRO_SKETCH_BACKEND
> platform default — ``pallas`` on TPU, ``flat`` elsewhere (the pallas path
still *runs* off-TPU via interpret mode; it is just slower than XLA's fused
scatter, so it is opt-in there).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.hashing import HashFamily, families_match, fastrange
from repro.common.struct import pytree_dataclass, static_field
from repro.core.kmatrix import KMatrix
from repro.core.partitioning import good_turing_outlier_share, plan_for
from repro.core.routing import RouteTable, routes_match
from repro.core.types import EdgeBatch, VertexStats


def sketch_backend(backend: str | None = None) -> str:
    """Resolve the kMatrix sketch backend: explicit arg >
    $REPRO_SKETCH_BACKEND > platform default (width-class Pallas layout on
    TPU, flat-pool XLA scatter elsewhere)."""
    backend = backend or os.environ.get("REPRO_SKETCH_BACKEND") or (
        "pallas" if jax.default_backend() == "tpu" else "flat")
    if backend not in ("flat", "pallas"):
        raise ValueError(f"unknown sketch backend {backend!r} "
                         "(expected 'flat' or 'pallas')")
    return backend


# Alias-safe under buffer donation (serving/snapshot.py): ingest (including
# the lazily-dispatched Pallas path) / merge / empty_like never retain a
# reference to an input leaf, so the sketch may sit in a donate_argnums
# position.  empty_like reuses hash/route leaves by reference — donating
# callers must deep-copy first (SnapshotBuffer._private_copy does).
DONATION_SAFE = True


@pytree_dataclass
class KMatrixAccel:
    """kMatrix with power-of-two width classes (TPU-native layout).

    ``pools[c]`` holds every partition of width ``class_widths[c]`` as one
    rectangular array int32[d, P_c, w_c, w_c].  ``part_class``/``part_index``
    map a global partition id to (class, row-within-class).  ``overflow``
    counts ingest updates that exceeded the per-partition dispatch capacity
    and took the exact scatter fallback — a *diagnostic* (capacity
    regressions show up as throughput cliffs), never a correctness term: the
    fallback counts those edges exactly.
    """

    pools: tuple  # tuple[int32[d, P_c, w_c, w_c], ...]
    conn: jax.Array  # int32[d, cw, cw]
    overflow: jax.Array  # int32[] scatter-fallback updates (diagnostic)
    hashes: HashFamily
    route: RouteTable  # offsets/widths are the flat-twin layout (see create)
    part_class: jax.Array  # int32[P]
    part_index: jax.Array  # int32[P]
    part_width: jax.Array  # int32[P]
    class_widths: tuple = static_field()
    class_counts: tuple = static_field()
    conn_w: int = static_field()
    # Expected per-partition share of stream edges, from the partition
    # plan's banded load (sampled frequency mass per partition, Good-Turing
    # share for the outlier).  Sizes the ingest dispatch capacity
    # (``dispatch_capacity``) from the plan instead of a uniform 2B/P.
    # None on sketches relayouted from a flat pool (no sample available):
    # those fall back to the uniform formula.
    load_shares: tuple | None = static_field(default=None)

    @property
    def depth(self) -> int:
        return self.conn.shape[0] if self.conn.ndim == 3 else self.pools[0].shape[0]

    @property
    def num_counters(self) -> int:
        return sum(int(p.size) for p in self.pools) + int(self.conn.size)

    @staticmethod
    def create(
        *,
        bytes_budget: int,
        stats: VertexStats,
        depth: int = 7,
        seed: int = 0,
        partitioner: str = "auto",  # same default as KMatrix.create: a
        # backend switch must never change which plan a config produces
        n_bands: int = 16,
        max_partitions: int = 64,
        min_width: int = 8,
        conn_frac: float = 0.1,
        outlier_frac: float | None = None,
    ) -> "KMatrixAccel":
        counters = bytes_budget // 4
        per_layer = max(counters // depth, 4)
        conn_w = int(np.sqrt(per_layer * conn_frac)) if conn_frac > 0 else 0
        total_width = max(int(np.sqrt(per_layer - conn_w * conn_w)), 2)
        plan = plan_for(
            partitioner, stats, total_width, square=True, n_bands=n_bands,
            max_partitions=max_partitions, min_width=min_width,
            outlier_frac=outlier_frac,
        )
        # Quantize each width DOWN to a power of two (keeps the budget).
        widths = np.asarray([1 << (int(p.width).bit_length() - 1)
                             for p in plan.partitions], dtype=np.int32)
        part_class, part_index, classes, counts = _class_structure(widths)
        # offsets are the FLAT layout invariant (cumsum of w_p^2 slabs) even
        # though the class layout never reads them: one route table must
        # serve both layouts, or to_flat_layout / checkpoint interchange
        # would silently mis-place slabs.
        slab = widths.astype(np.int64) ** 2
        offsets = np.concatenate([[0], np.cumsum(slab)[:-1]]).astype(np.int32)
        route = RouteTable(
            keys=jnp.asarray(plan.route_keys),
            part=jnp.asarray(plan.route_part),
            offsets=jnp.asarray(offsets),
            widths=jnp.asarray(widths),
            outlier=plan.outlier,
            n_partitions=len(widths),
            max_width=int(widths.max()),
        )
        pools = tuple(
            jnp.zeros((depth, counts[c], classes[c], classes[c]), jnp.int32)
            for c in range(len(classes))
        )
        load_shares = _plan_load_shares(plan, stats)
        return KMatrixAccel(
            pools=pools,
            conn=jnp.zeros((depth, conn_w, conn_w), jnp.int32),
            overflow=jnp.zeros((), jnp.int32),
            hashes=HashFamily.create(seed, depth),
            route=route,
            part_class=jnp.asarray(part_class),
            part_index=jnp.asarray(part_index),
            part_width=jnp.asarray(widths),
            class_widths=tuple(classes),
            class_counts=tuple(counts),
            conn_w=conn_w,
            load_shares=load_shares,
        )


def _plan_load_shares(plan, stats: VertexStats) -> tuple:
    """Expected stream-edge share per partition, from the sample.

    Sampled partitions split the SEEN share of the stream proportionally to
    their sampled frequency mass; the outlier partition's share is the
    Good-Turing estimate of unseen-source traffic (the same estimate that
    sized its width).  Shares sum to ~1 and are static Python floats, so the
    ingest capacity derived from them stays a trace-time constant.
    """
    vert = np.asarray(stats.vertex)  # sorted unique (types.py contract)
    freq = np.asarray(stats.freq, np.float64)
    total = max(float(freq.sum()), 1e-9)
    unseen = good_turing_outlier_share(freq)
    shares = []
    for p in plan.partitions[:-1]:
        pos = np.searchsorted(vert, np.asarray(p.vertices))
        shares.append(float(freq[pos].sum()) / total * (1.0 - unseen))
    shares.append(float(unseen))  # outlier partition (appended last)
    return tuple(round(s, 6) for s in shares)


def dispatch_capacity(sk: KMatrixAccel, batch_size: int,
                      block_b: int = 128) -> int:
    """Per-partition ingest dispatch capacity for one batch of ``batch_size``.

    Sized from the partition plan's banded load: the hottest partition's
    expected share of the stream (``load_shares``) with 2x headroom, capped
    at the batch size (a partition can never receive more than B edges, and
    capacity == B guarantees a zero overflow tail).  The legacy uniform
    ``2B/P`` is kept only as the fallback for relayouted sketches that carry
    no sample — on skewed streams it undersizes the hot partition by the
    skew factor and every excess edge pays the scatter-fallback path
    (ROADMAP dispatch-capacity item; regression visible as
    ``overflow_edges`` in runtime metrics / serve_bench / BENCH_ingest).
    Rounded up to the Pallas ingest block so the kernel grid stays aligned.
    """
    if sk.load_shares:
        cap = int(np.ceil(2.0 * max(sk.load_shares) * batch_size))
        cap = min(cap, batch_size)
    else:
        cap = (2 * batch_size) // max(sk.route.n_partitions, 1)
    cap = max(cap, min(block_b, batch_size))
    return -(-cap // block_b) * block_b


def _class_structure(widths: np.ndarray):
    """Group partition widths into sorted classes.

    Returns (part_class, part_index, class_widths, class_counts) with the
    deterministic convention shared by ``create`` and ``to_class_layout``:
    classes ascend by width; within a class, rows follow global partition
    order.
    """
    classes = sorted(set(int(w) for w in widths))
    part_class = np.asarray([classes.index(int(w)) for w in widths], np.int32)
    part_index = np.zeros(len(widths), np.int32)
    counts = []
    for c in range(len(classes)):
        members = np.nonzero(part_class == c)[0]
        part_index[members] = np.arange(len(members))
        counts.append(len(members))
    return part_class, part_index, classes, counts


# --------------------------------------------------------------- protocol --

def ingest(sk: KMatrixAccel, batch: EdgeBatch, *,
           capacity: int | None = None, block_b: int = 128) -> KMatrixAccel:
    """Exact batched ingest via the per-class Pallas MXU kernel.

    Thin protocol wrapper; the kernel dispatch lives in
    ``repro.kernels.ops.kmatrix_accel_ingest`` (imported lazily so the pure
    query surface of this module never pulls in Pallas).
    """
    from repro.kernels.ops import kmatrix_accel_ingest

    return kmatrix_accel_ingest(sk, batch, capacity=capacity, block_b=block_b)


def edge_freq(sk: KMatrixAccel, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Point queries on the class layout (pure gather; query volume is tiny
    next to ingest volume, so this path stays unfused)."""
    p = sk.route.lookup(src)
    w_p = sk.part_width[p]
    hi = fastrange(sk.hashes.mix(src), w_p)  # [d, *S]
    hj = fastrange(sk.hashes.mix(dst), w_p)
    d = sk.depth
    rows = jnp.arange(d, dtype=jnp.int32).reshape((d,) + (1,) * src.ndim)
    est = jnp.full(src.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
    for c, (w_c, p_c) in enumerate(zip(sk.class_widths, sk.class_counts)):
        if p_c == 0:
            continue
        sel = sk.part_class[p] == c
        q = jnp.where(sel, sk.part_index[p], 0)
        vals = jnp.min(sk.pools[c][rows, q[None], hi, hj], axis=0)
        est = jnp.where(sel, vals, est)
    return est


def node_out_freq(sk: KMatrixAccel, v: jax.Array) -> jax.Array:
    """Row-sum of v's row inside its class block, min over layers.

    Bit-identical to ``kmatrix.node_out_freq`` on the flat twin: the class
    block row holds exactly the slab cells the flat masked gather sums.
    """
    p = sk.route.lookup(v)
    hi_full = sk.hashes.mix(v)  # [d, *S] uint32
    d = sk.depth
    rows = jnp.arange(d, dtype=jnp.int32).reshape((d,) + (1,) * v.ndim)
    est = jnp.full(v.shape, jnp.iinfo(jnp.int32).max, jnp.int32)
    for c, (w_c, p_c) in enumerate(zip(sk.class_widths, sk.class_counts)):
        if p_c == 0:
            continue
        sel = sk.part_class[p] == c
        q = jnp.where(sel, sk.part_index[p], 0)
        hi = fastrange(hi_full, w_c)  # [d, *S]
        vals = jnp.min(
            jnp.sum(sk.pools[c][rows, q[None], hi, :], axis=-1), axis=0)
        est = jnp.where(sel, vals, est)
    return est


def conn_cells(sk: KMatrixAccel, v: jax.Array) -> jax.Array:
    """Per-layer slot of vertex ``v`` in the global connectivity matrix."""
    return fastrange(sk.hashes.mix(v), sk.conn_w)


def empty_like(sk: KMatrixAccel) -> KMatrixAccel:
    """A zero-counter sketch sharing ``sk``'s layout, routing and hashes
    (snapshot hook, DESIGN.md §Serving — same contract as ``kmatrix``)."""
    return sk.replace(
        pools=tuple(jnp.zeros_like(p) for p in sk.pools),
        conn=jnp.zeros_like(sk.conn),
        overflow=jnp.zeros_like(sk.overflow),
    )


def merge(a: KMatrixAccel, b: KMatrixAccel) -> KMatrixAccel:
    """Counter-additivity over class pools (data-parallel ingest, serving
    snapshot publishes).  Same rejection rules as ``KMatrix.merge``: layouts
    can coincide across hash seeds or partition plans, so both are checked
    explicitly (outside jit) rather than trusted from shapes."""
    assert (a.class_widths == b.class_widths
            and a.class_counts == b.class_counts
            and a.conn_w == b.conn_w)
    if families_match(a.hashes, b.hashes) is False:
        raise ValueError(
            "merge: operands use different hash families (built with "
            "different seeds); merging them silently corrupts estimates")
    if routes_match(a.route, b.route) is False:
        raise ValueError(
            "merge: operands use different partition plans (built from "
            "different samples); edges route to different slabs, so summing "
            "the pools silently corrupts estimates")
    return a.replace(
        pools=tuple(pa + pb for pa, pb in zip(a.pools, b.pools)),
        conn=a.conn + b.conn,
        overflow=a.overflow + b.overflow,
    )


# ------------------------------------------------------------- relayout ----

def to_flat_layout(sk: KMatrixAccel) -> KMatrix:
    """Bit-exact relayout: class pools -> the flat-pool ``KMatrix`` twin.

    Pure permutation — cell ``(hi, hj)`` of partition ``p`` moves from
    ``pools[class(p)][:, index(p)]`` to ``pool[:, offset(p) + hi*w_p + hj]``.
    The route table (with its flat offsets), hashes, conn matrix AND the
    ``overflow`` diagnostic carry over unchanged, so every estimate of the
    result equals the source's and a relayout round-trip (or a checkpoint
    migration through the flat layout) preserves the scatter-fallback tally
    instead of zeroing it.
    """
    d = sk.depth
    widths = np.asarray(sk.part_width)
    offsets = np.asarray(sk.route.offsets)
    part_class = np.asarray(sk.part_class)
    part_index = np.asarray(sk.part_index)
    pool_size = int((widths.astype(np.int64) ** 2).sum())
    pool = jnp.zeros((d, pool_size), jnp.int32)
    for p in range(sk.route.n_partitions):
        w = int(widths[p])
        block = sk.pools[int(part_class[p])][:, int(part_index[p])]
        pool = jax.lax.dynamic_update_slice(
            pool, block.reshape(d, w * w), (0, int(offsets[p])))
    return KMatrix(
        pool=pool,
        conn=sk.conn,
        overflow=sk.overflow,
        hashes=sk.hashes,
        route=sk.route,
        pool_size=pool_size,
        conn_w=sk.conn_w,
    )


def to_class_layout(sk: KMatrix, *, overflow: jax.Array | int | None = None
                    ) -> KMatrixAccel:
    """Bit-exact relayout: flat pool -> width-class pools (inverse of
    ``to_flat_layout``).

    Requires the flat sketch to be a *class-layout twin*: every partition
    width a power of two and offsets the standard ``cumsum(w^2)`` slabs —
    i.e. a sketch built by either backend's ``create`` (or a checkpoint of
    one), not an arbitrary un-quantized plan.  The scatter-fallback tally
    defaults to the flat sketch's own ``overflow`` leaf (which
    ``to_flat_layout`` preserves), so a round-trip is identity on the
    diagnostic too; pass ``overflow`` explicitly only to override it.
    """
    widths = np.asarray(sk.route.widths)
    if len(widths) == 0:
        raise ValueError("to_class_layout: empty partition plan")
    if np.any((widths & (widths - 1)) != 0) or np.any(widths < 1):
        raise ValueError(
            f"to_class_layout: widths {widths.tolist()} are not all powers "
            "of two — this flat sketch was not built from a width-class "
            "plan; rebuild it under the pallas backend instead of relaying")
    slab = widths.astype(np.int64) ** 2
    expect_off = np.concatenate([[0], np.cumsum(slab)[:-1]])
    if not np.array_equal(np.asarray(sk.route.offsets), expect_off):
        raise ValueError(
            "to_class_layout: route offsets are not the standard cumsum "
            "slab layout; refusing a lossy relayout")
    part_class, part_index, classes, counts = _class_structure(widths)
    d = sk.depth
    pools = []
    for c, w_c in enumerate(classes):
        members = np.nonzero(part_class == c)[0]
        blocks = [
            jax.lax.dynamic_slice(
                sk.pool, (0, int(expect_off[p])), (d, w_c * w_c)
            ).reshape(d, w_c, w_c)
            for p in members
        ]
        pools.append(jnp.stack(blocks, axis=1))
    if overflow is None:
        overflow = sk.overflow
    return KMatrixAccel(
        pools=tuple(pools),
        conn=sk.conn,
        overflow=jnp.asarray(overflow, jnp.int32).reshape(()),
        hashes=sk.hashes,
        route=sk.route,
        part_class=jnp.asarray(part_class),
        part_index=jnp.asarray(part_index),
        part_width=jnp.asarray(widths.astype(np.int32)),
        class_widths=tuple(classes),
        class_counts=tuple(counts),
        conn_w=sk.conn_w,
    )
