"""Core stream / sketch types shared across the library."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.struct import pytree_dataclass, static_field


@pytree_dataclass
class EdgeBatch:
    """A fixed-size batch of stream updates ``(src, dst, weight)``.

    ``weight == 0`` marks padding slots (sketches are additive, so adding a
    zero-weight edge is a no-op; this lets every batch be a static shape).
    """

    src: jax.Array  # int32[B]
    dst: jax.Array  # int32[B]
    weight: jax.Array  # int32[B]

    @property
    def size(self) -> int:
        return self.src.shape[0]

    @staticmethod
    def from_numpy(src: np.ndarray, dst: np.ndarray, weight: np.ndarray | None = None) -> "EdgeBatch":
        if weight is None:
            weight = np.ones_like(src, dtype=np.int32)
        return EdgeBatch(
            src=jnp.asarray(src, dtype=jnp.int32),
            dst=jnp.asarray(dst, dtype=jnp.int32),
            weight=jnp.asarray(weight, dtype=jnp.int32),
        )

    @staticmethod
    def pad_to(src: np.ndarray, dst: np.ndarray, weight: np.ndarray, size: int) -> "EdgeBatch":
        n = src.shape[0]
        assert n <= size, (n, size)
        pad = size - n
        return EdgeBatch.from_numpy(
            np.concatenate([src, np.zeros(pad, np.int32)]),
            np.concatenate([dst, np.zeros(pad, np.int32)]),
            np.concatenate([weight.astype(np.int32), np.zeros(pad, np.int32)]),
        )


@pytree_dataclass
class VertexStats:
    """Per-vertex statistics estimated from a stream sample.

    These drive the gSketch/kMatrix partitioning objective (paper Eq. 8):
      f_v(m): summed weight of out-edges of m observed in the sample
      deg(m): number of *distinct* out-neighbours of m in the sample
    """

    vertex: jax.Array  # int32[n] sorted unique vertex ids
    freq: jax.Array  # float32[n]
    deg: jax.Array  # float32[n]


def vertex_stats_from_sample(src: np.ndarray, dst: np.ndarray,
                             weight: np.ndarray | None = None) -> VertexStats:
    """Host-side (numpy) computation of VertexStats from sampled edges."""
    if weight is None:
        weight = np.ones_like(src, dtype=np.int64)
    order = np.argsort(src, kind="stable")
    s, d_, w_ = src[order], dst[order], weight[order]
    verts, starts = np.unique(s, return_index=True)
    ends = np.append(starts[1:], len(s))
    freq = np.add.reduceat(w_, starts).astype(np.float32)
    deg = np.empty(len(verts), np.float32)
    for i, (lo, hi) in enumerate(zip(starts, ends)):
        deg[i] = len(np.unique(d_[lo:hi]))
    return VertexStats(
        vertex=jnp.asarray(verts.astype(np.int32)),
        freq=jnp.asarray(freq),
        deg=jnp.asarray(deg),
    )
