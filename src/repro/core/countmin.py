"""CountMin sketch (Cormode & Muthukrishnan) — paper §III-A, Type I baseline.

A ``(d, w)`` counter table; every edge is reduced to a single 32-bit key and
hashed into each row by an independent 2-universal function.  Updates are
batched: an ``EdgeBatch`` of B edges becomes one fused hash + scatter-add.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.hashing import HashFamily, families_match, fastrange, hash_pair_mix
from repro.common.struct import pytree_dataclass, static_field
from repro.core.types import EdgeBatch

# Alias-safe under buffer donation (serving/snapshot.py): ingest / merge /
# empty_like are pure pytree->pytree functions that never retain a
# reference to an input leaf, so a caller may pass the sketch into a
# donate_argnums position and let XLA update the counter buffers in place.
DONATION_SAFE = True


@pytree_dataclass
class CountMin:
    table: jax.Array  # int32[d, w]
    hashes: HashFamily
    w: int = static_field()

    @property
    def depth(self) -> int:
        return self.table.shape[0]

    @property
    def num_counters(self) -> int:
        return self.table.size

    @staticmethod
    def create(*, bytes_budget: int, depth: int = 7, seed: int = 0) -> "CountMin":
        counters = bytes_budget // 4
        w = max(counters // depth, 1)
        return CountMin(
            table=jnp.zeros((depth, w), dtype=jnp.int32),
            hashes=HashFamily.create(seed, depth),
            w=w,
        )


def _edge_cells(sk: CountMin, src: jax.Array, dst: jax.Array) -> jax.Array:
    key = hash_pair_mix(src, dst)
    return fastrange(sk.hashes.mix(key), sk.w)  # int32[d, B]


def ingest(sk: CountMin, batch: EdgeBatch) -> CountMin:
    idx = _edge_cells(sk, batch.src, batch.dst)  # [d, B]
    d = sk.depth
    rows = jnp.arange(d, dtype=jnp.int32)[:, None]
    table = sk.table.at[rows, idx].add(batch.weight[None, :].astype(sk.table.dtype))
    return sk.replace(table=table)


def edge_freq(sk: CountMin, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Point query: estimated frequency of each edge. Shape-preserving."""
    idx = _edge_cells(sk, src, dst)  # [d, *S]
    d = sk.depth
    rows = jnp.arange(d, dtype=jnp.int32).reshape((d,) + (1,) * src.ndim)
    vals = sk.table[rows, idx]
    return jnp.min(vals, axis=0)


def empty_like(sk: CountMin) -> CountMin:
    """Zero-counter sketch sharing layout + hashes (serving snapshot hook)."""
    return sk.replace(table=jnp.zeros_like(sk.table))


def merge(a: CountMin, b: CountMin) -> CountMin:
    """Counter-additivity; operands must share layout AND hash seeds."""
    assert a.w == b.w and a.table.shape == b.table.shape
    if families_match(a.hashes, b.hashes) is False:
        raise ValueError(
            "merge: operands use different hash families (built with "
            "different seeds); merging them silently corrupts estimates")
    return a.replace(table=a.table + b.table)
