"""Evaluation metrics from paper §V-C: ARE (Eq. 9-10), NEQ/PEQ (Eq. 11-12)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def relative_error(est: jax.Array, true: jax.Array) -> jax.Array:
    """Per-query relative error  er(Q) = est/true - 1   (Eq. 9)."""
    true = jnp.maximum(true.astype(jnp.float32), 1e-9)
    return est.astype(jnp.float32) / true - 1.0


def average_relative_error(est: jax.Array, true: jax.Array,
                           valid: jax.Array | None = None) -> jax.Array:
    """ARE over a query set (Eq. 10). ``valid`` masks padding queries."""
    er = relative_error(est, true)
    if valid is None:
        return jnp.mean(er)
    valid = valid.astype(jnp.float32)
    return jnp.sum(er * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def effective_queries(est: jax.Array, true: jax.Array, g0: float,
                      valid: jax.Array | None = None) -> jax.Array:
    """NEQ (Eq. 11): #queries with |est - true| <= G0."""
    ok = jnp.abs(est.astype(jnp.float32) - true.astype(jnp.float32)) <= g0
    if valid is not None:
        ok = ok & valid
    return jnp.sum(ok.astype(jnp.int32))


def percent_effective_queries(est: jax.Array, true: jax.Array, g0: float,
                              valid: jax.Array | None = None) -> jax.Array:
    """PEQ (Eq. 12)."""
    n = est.shape[0] if valid is None else jnp.maximum(jnp.sum(valid), 1)
    return effective_queries(est, true, g0, valid) * 100.0 / n


def exact_edge_frequencies(src: np.ndarray, dst: np.ndarray,
                           weight: np.ndarray | None = None) -> dict:
    """Host-side ground-truth frequency map for benchmark oracles."""
    if weight is None:
        weight = np.ones_like(src, dtype=np.int64)
    keys = src.astype(np.int64) << 32 | dst.astype(np.uint32)
    uniq, inv = np.unique(keys, return_inverse=True)
    sums = np.bincount(inv, weights=weight.astype(np.float64))
    return {int(k): float(v) for k, v in zip(uniq, sums)}


def lookup_exact(freq_map: dict, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    keys = src.astype(np.int64) << 32 | dst.astype(np.uint32)
    return np.asarray([freq_map.get(int(k), 0.0) for k in keys], dtype=np.float64)
