"""gSketch (Zhao, Aggarwal & Wang) — paper §III-B, Type I partitioned baseline.

A CountMin whose width budget is carved into per-partition segments by the
sample-driven partitioner; an edge ``(i, j)`` is routed to the partition of
its source vertex ``i`` and hashed within that partition's local width.
Unseen vertices go to the outlier partition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.hashing import HashFamily, families_match, fastrange, hash_pair_mix
from repro.common.struct import pytree_dataclass, static_field
from repro.core.partitioning import plan_partitions
from repro.core.routing import RouteTable, route_table_from_plan, routes_match
from repro.core.types import EdgeBatch, VertexStats

# Alias-safe under buffer donation (serving/snapshot.py): ingest / merge /
# empty_like never retain a reference to an input leaf, so the sketch may
# sit in a donate_argnums position.  Note empty_like reuses the hash and
# route leaves by reference — donating callers must deep-copy first
# (SnapshotBuffer._private_copy does).
DONATION_SAFE = True


@pytree_dataclass
class GSketch:
    pool: jax.Array  # int32[d, pool_size] concatenated partition rows
    hashes: HashFamily
    route: RouteTable
    pool_size: int = static_field()

    @property
    def depth(self) -> int:
        return self.pool.shape[0]

    @property
    def num_counters(self) -> int:
        return self.pool.size

    @staticmethod
    def create(
        *,
        bytes_budget: int,
        stats: VertexStats,
        depth: int = 7,
        seed: int = 0,
        max_partitions: int = 64,
        min_width: int = 64,
        outlier_frac: float | None = None,
        partitioner: str = "greedy",
        n_bands: int = 16,
    ) -> "GSketch":
        counters = bytes_budget // 4
        total_width = max(counters // depth, 1)
        if partitioner == "greedy":
            plan = plan_partitions(
                stats,
                total_width,
                square=False,
                max_partitions=max_partitions,
                min_width=min_width,
                outlier_frac=outlier_frac,
            )
        elif partitioner == "banded":
            from repro.core.partitioning import plan_partitions_banded

            plan = plan_partitions_banded(
                stats,
                total_width,
                square=False,
                n_bands=n_bands,
                min_width=min_width,
                outlier_frac=outlier_frac,
            )
        else:
            raise ValueError(f"unknown partitioner {partitioner!r}")
        route, pool_size = route_table_from_plan(plan, square=False)
        return GSketch(
            pool=jnp.zeros((depth, pool_size), dtype=jnp.int32),
            hashes=HashFamily.create(seed, depth),
            route=route,
            pool_size=pool_size,
        )


def _edge_cells(sk: GSketch, src: jax.Array, dst: jax.Array) -> jax.Array:
    p = sk.route.lookup(src)  # [*S]
    w = sk.route.widths[p]
    off = sk.route.offsets[p]
    key = hash_pair_mix(src, dst)
    local = fastrange(sk.hashes.mix(key), w)  # [d, *S] (w broadcasts)
    return off[None] + local


def ingest(sk: GSketch, batch: EdgeBatch) -> GSketch:
    idx = _edge_cells(sk, batch.src, batch.dst)  # [d, B]
    rows = jnp.arange(sk.depth, dtype=jnp.int32)[:, None]
    pool = sk.pool.at[rows, idx].add(batch.weight[None, :].astype(sk.pool.dtype))
    return sk.replace(pool=pool)


def edge_freq(sk: GSketch, src: jax.Array, dst: jax.Array) -> jax.Array:
    idx = _edge_cells(sk, src, dst)
    rows = jnp.arange(sk.depth, dtype=jnp.int32).reshape((sk.depth,) + (1,) * src.ndim)
    return jnp.min(sk.pool[rows, idx], axis=0)


def empty_like(sk: GSketch) -> GSketch:
    """Zero-counter sketch sharing layout, routing + hashes (serving hook)."""
    return sk.replace(pool=jnp.zeros_like(sk.pool))


def merge(a: GSketch, b: GSketch) -> GSketch:
    """Counter-additivity; operands must share layout AND hash seeds."""
    assert a.pool_size == b.pool_size
    if families_match(a.hashes, b.hashes) is False:
        raise ValueError(
            "merge: operands use different hash families (built with "
            "different seeds); merging them silently corrupts estimates")
    if routes_match(a.route, b.route) is False:
        raise ValueError(
            "merge: operands use different partition plans (built from "
            "different samples); edges route to different slabs, so summing "
            "the pools silently corrupts estimates")
    return a.replace(pool=a.pool + b.pool)
