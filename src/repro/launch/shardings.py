"""Sharding rules: pytree-of-PartitionSpec builders per architecture family.

Conventions (see DESIGN.md §Distribution):
  LM params     — Megatron TP: qkv/in-proj column-split, o/out-proj
                  row-split on "model"; embeddings vocab-split (the chunked
                  CE is vocab-parallel); MoE experts tensor-parallel on d_ff.
  LM batch      — tokens over the data-parallel bundle ("pod","data").
  KV cache      — decode: S over "model" (+ over data too when batch==1,
                  the long-context case); updates are one-hot selects so
                  SPMD never gathers the cache.
  GNN           — nodes/edges over all axes (pure graph DP at 256-4096-way);
                  params replicated (hidden dims are small).
  FM            — table rows over "model" (table-parallel), batch over DP.
  Optimizer     — moments inherit their parameter's spec; step replicated.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import all_axes, dp_axes


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


# ------------------------------------------------------------------- LM ---

def lm_param_specs(params_shape: Any, mesh) -> Any:
    """Map each param leaf to a PartitionSpec by name + rank.

    FFN weights (the parameter bulk — ALL of it for MoE archs) are sharded
    over EVERY mesh axis on d_ff (FSDP/ZeRO-3 style: gathered per layer at
    use). Without this, grok-1's 628 GB of bf16 experts put 39 GB on each
    device at model-only sharding; with it: 1.2 GB. Attention weights stay
    Megatron-TP on "model" only (small, and TP avoids gathers on the
    latency-critical path).
    """
    ff_axes = tuple(mesh.axis_names)  # ("pod","data","model") when present

    def rule(path, leaf):
        key = _leaf_key(path)
        nd = len(leaf.shape)
        base = key.split("/")[-1]
        # stacked layer leaves carry a leading (n_per,) dim -> prepend None
        def spec(*tail):
            lead = (None,) * (nd - len(tail))
            return P(*(lead + tail))

        if "embed" in base or "lm_head" in base:
            # (V, D) vocab-split  /  lm_head (D, V) -> split on V too
            return P("model", None) if base == "embed" else P(None, "model")
        if base in ("wq", "wk", "wv"):
            return spec(None, "model")
        if base == "wo":
            return spec("model", None)
        if base == "w_in":  # dense (D,F) or moe (E,D,F): F over all axes
            return spec(None, ff_axes)
        if base == "w_out":  # dense (F,D) or moe (E,F,D): F over all axes
            return spec(ff_axes, None)
        if base == "router":
            return spec(None, None)
        return P(*((None,) * nd))  # norms, biases, gates

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def lm_batch_specs(mesh) -> dict:
    dp = dp_axes(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_specs(cache_shape, mesh, *, batch: int, kind: str = "decode") -> Any:
    """KV-cache sharding.

    decode: S over "model" (reads are distributed-softmax psums; writes are
      one-position one-hot selects). batch==1 (long-context): S over every
      axis. prefill: the whole prompt stripe is written at once, so S must
      stay unsharded — shard head_dim over "model" instead (divisible for
      every arch; KV head counts are not).
    """
    dp = dp_axes(mesh)

    def rule(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:  # length scalar
            return P()
        if nd == 6:  # (n_per, per, B, S, KV, Dh)
            if kind == "prefill":
                return P(None, None, dp, None, None, "model")
            if batch == 1:
                return P(None, None, None, tuple(mesh.axis_names), None, None)
            return P(None, None, dp, "model", None, None)
        if nd == 5:  # tail cache (rem, B, S, KV, Dh)
            if kind == "prefill":
                return P(None, dp, None, None, "model")
            if batch == 1:
                return P(None, None, tuple(mesh.axis_names), None, None)
            return P(None, dp, "model", None, None)
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def opt_state_specs(param_specs: Any) -> Any:
    """AdamWState(step, mu, nu): moments mirror params."""
    from repro.training.optimizer import AdamWState

    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


# ------------------------------------------------------------------ GNN ---

def gnn_graph_specs(mesh, n_graphs: int = 1) -> Any:
    """GraphBatch leaf specs: shard nodes/edges over every axis.

    ``n_graphs`` must MATCH the argument's static metadata (it lives in the
    treedef; a mismatched spec tree is a pjit pytree error)."""
    ax = tuple(mesh.axis_names)
    from repro.models.gnn.graph import GraphBatch

    return GraphBatch(
        node_feat=P(ax, None),
        edge_src=P(ax),
        edge_dst=P(ax),
        edge_feat=P(ax, None),
        positions=P(ax, None),
        node_mask=P(ax),
        edge_mask=P(ax),
        graph_id=P(ax),
        n_graphs=n_graphs,
    )


def gnn_param_specs(params_shape: Any) -> Any:
    return jax.tree_util.tree_map(lambda leaf: P(*((None,) * len(leaf.shape))),
                                  params_shape)


# ------------------------------------------------------------------- FM ---

def fm_param_specs(params_shape: Any, mesh) -> Any:
    def rule(path, leaf):
        key = _leaf_key(path)
        if key.endswith("emb") or key.endswith("lin"):
            return P("model", None)
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def fm_batch_specs(mesh) -> dict:
    dp = dp_axes(mesh)
    return {"ids": P(dp, None), "labels": P(dp)}
