"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — launch scripts set XLA_FLAGS before first init.

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single-pod mesh: (data=16, model=16)
  multi-pod mesh:  (pod=2, data=16, model=16) = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests/CI)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager installing ``mesh`` for jit/shard_map tracing.

    ``jax.set_mesh`` (which also installs the abstract mesh seen by in-model
    sharding constraints) only exists on newer jax; on older releases the
    classic ``with mesh:`` resource env is the supported equivalent — our
    shard_map call sites all pass ``mesh`` explicitly, so the resource env
    only needs to cover pjit constraint resolution.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axis bundle: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
