"""End-to-end online serving driver: ingest + snapshot publishing + queries.

Runs the full serving story in one process: a registry tenant ingests its
stream batch-by-batch, publishes an epoch-stamped snapshot every
``--publish-every`` batches, and an open-loop load generator fires a mixed
query workload (edge frequency, reachability, node aggregates, paths,
subgraphs, heavy-node sweeps) at the batched query engine the whole time.
Prints a JSON summary line (QPS, p50/p99 latency, epochs) on completion.

  python -m repro.launch.query_serve --dataset cit-HepPh --sketch kmatrix \
      --budget-kb 256 --qps 2000 --n-requests 8000 [--scale 0.25]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.serving import (
    OpenLoopLoadGen,
    QueryEngine,
    SketchRegistry,
    WorkloadMix,
    synth_requests,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cit-HepPh")
    ap.add_argument("--sketch", default="kmatrix",
                    choices=["countmin", "gsketch", "tcm", "gmatrix",
                             "kmatrix"])
    ap.add_argument("--budget-kb", type=int, default=256)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--partitioner", default="banded",
                    choices=["banded", "greedy", "auto"])
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--n-requests", type=int, default=8000)
    ap.add_argument("--batch-max", type=int, default=512)
    ap.add_argument("--publish-every", type=int, default=4,
                    help="ingest batches between snapshot publishes")
    ap.add_argument("--warm-batches", type=int, default=4,
                    help="ingest batches before serving starts")
    ap.add_argument("--mix", default="",
                    help="comma list family=weight, e.g. "
                         "'edge_freq=0.7,reach=0.3' (default: built-in mix)")
    args = ap.parse_args()

    registry = SketchRegistry(depth=args.depth, scale=args.scale,
                              partitioner=args.partitioner)
    tenant = registry.open(args.dataset, args.sketch, args.budget_kb,
                           seed=args.seed)
    n_nodes = tenant.stream.spec.n_nodes
    print(f"tenant {tenant.key.tenant_id}: stream "
          f"{tenant.stream.num_batches} batches, universe {n_nodes}",
          file=sys.stderr)

    t0 = time.time()
    tenant.step(min(args.warm_batches,
                    max(1, tenant.stream.num_batches // 2)))
    snap = tenant.publish()
    print(f"warm: epoch {snap.epoch}, {snap.n_edges} edges in "
          f"{time.time()-t0:.2f}s", file=sys.stderr)

    mix = WorkloadMix()
    if args.mix:
        weights = {k: 0.0 for k in WorkloadMix().normalized()}
        for part in args.mix.split(","):
            k, v = part.split("=")
            if k.strip() not in weights:
                ap.error(f"unknown query family {k.strip()!r} in --mix")
            weights[k.strip()] = float(v)
        mix = WorkloadMix(**weights)
    # countmin/gsketch cannot answer node/reach families; degrade gracefully
    if args.sketch in ("countmin", "gsketch") and not args.mix:
        mix = WorkloadMix(edge_freq=0.8, reach=0.0, node_out=0.0,
                          path_weight=0.1, subgraph_weight=0.1,
                          heavy_nodes=0.0)

    requests = synth_requests(
        args.n_requests, mix, n_nodes=n_nodes, seed=args.seed + 7,
        heavy_universe=min(n_nodes, 1 << 14), heavy_threshold=100.0)

    engine = QueryEngine()
    size = 16  # compile the bucket ladder before the clock starts
    warm = synth_requests(args.batch_max, mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    while size <= len(warm):
        engine.execute(tenant.snapshot, warm[:size])
        size *= 2

    ingested = [0]

    def live_ingest() -> None:
        stepped = tenant.step(1)
        ingested[0] += stepped
        # key off this call's progress, not the cumulative count: once the
        # stream drains, a frozen total would either publish after every
        # served batch (thrashing the closure cache) or never again
        if stepped and ingested[0] % args.publish_every == 0:
            tenant.publish()

    loadgen = OpenLoopLoadGen(target_qps=args.qps, batch_max=args.batch_max)
    report = loadgen.run(engine, lambda: tenant.snapshot, requests,
                         between_batches=live_ingest)

    # drain whatever stream remains so the run is a full ingest too
    while tenant.step(16):
        pass
    final = tenant.publish()

    summary = {
        "driver": "query_serve",
        "dataset": args.dataset,
        "sketch": args.sketch,
        "budget_kb": args.budget_kb,
        "achieved_qps": round(report.achieved_qps, 1),
        "offered_qps": args.qps,
        "p50_ms": round(report.p50_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "n_requests": report.n_requests,
        "final_epoch": final.epoch,
        "total_edges": final.n_edges,
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
