"""End-to-end online serving driver: ingest + snapshot publishing + queries.

Two ingest modes share the same tenant, engine and load generator:

  cooperative (default)   ingest advances between served query batches in
      ONE thread — the PR 1 behaviour, kept as the deterministic baseline.

  --background-ingest     ingest runs in a ``repro.runtime`` worker thread
      behind a bounded queue (``--backpressure``), publishing epochs under
      ``--publish-policy``, while the load generator fires queries from the
      main thread the whole time — queries and ingest genuinely overlap.
      The summary gains runtime metrics (ingest edges/s, queue depth,
      publish latency) and a conservation report (offered == published +
      accounted drops); ``--checkpoint-dir`` adds crash-safe checkpoints
      and ``--restore`` resumes from the latest one.

  --runtime-backend process   (with --background-ingest) run each ingest
      worker in a spawn-safe child process that owns its sketch
      (DESIGN.md §Runtime §Backends): published epochs ship back into this
      process's snapshot buffer, so queries serve locally while K-shard
      ingest scales past the GIL.  Checkpoints stay interchangeable with
      the thread backend.  SIGTERM/SIGINT trigger a graceful drain (final
      epoch + checkpoint flushed) before exit in every background mode.

  --shards K              (with --background-ingest) sharded serving: edges
      route to K independent sketch shards by a source-node hash band; one
      worker + queue per shard, each publishing epochs independently, and
      queries scatter/gather through ``ShardedQueryEngine``.  With
      ``--checkpoint-dir`` each shard checkpoints separately and a shard
      manifest records the topology; ``--restore`` validates it and resumes
      every shard from its own offset.  The summary gains per-shard
      published counts and a cross-shard conservation verdict.

  --serve HOST:PORT       (with --background-ingest) put the engine behind
      a ``repro.net`` TCP query server with admission control (bounded
      in-flight budget via --max-inflight, per-tenant token-bucket rate
      limiting via --tenant-qps) and drive the measurement over real
      sockets: --connections concurrent open-loop client connections
      (``NetLoadGen``).  ``--n-requests 0`` serves until SIGTERM/SIGINT
      instead — the standing front-end a remote
      ``python -m repro.serving.loadgen --connect`` client can load.
      Combine with ``--runtime-backend socket:HOST:PORT`` to place the
      ingest workers on ``stream_ingest --listen`` hosts: a fully
      networked ingest+serve deployment (DESIGN.md §Net).

Prints a JSON summary line (QPS, p50/p99 latency, epochs) on completion.

  python -m repro.launch.query_serve --dataset cit-HepPh --sketch kmatrix \
      --budget-kb 256 --qps 2000 --n-requests 8000 [--scale 0.25] \
      [--background-ingest] [--backpressure drop_oldest] \
      [--publish-policy interval:0.25] [--serve 127.0.0.1:7311]
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

from repro.serving import (
    OpenLoopLoadGen,
    QueryEngine,
    SketchRegistry,
    WorkloadMix,
    mix_for_sketch,
    synth_requests,
    warm_bucket_ladder,
)


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cit-HepPh")
    ap.add_argument("--sketch", default="kmatrix",
                    choices=["countmin", "gsketch", "tcm", "gmatrix",
                             "kmatrix"])
    ap.add_argument("--budget-kb", type=int, default=256)
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--partitioner", default="banded",
                    choices=["banded", "greedy", "auto"])
    ap.add_argument("--sketch-backend", default="",
                    choices=["", "flat", "pallas"],
                    help="kmatrix physical layout: flat XLA scatter pool or "
                         "width-class Pallas MXU layout (default: "
                         "$REPRO_SKETCH_BACKEND, else pallas on TPU / flat "
                         "elsewhere)")
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--n-requests", type=int, default=8000)
    ap.add_argument("--batch-max", type=int, default=512)
    ap.add_argument("--publish-every", type=int, default=4,
                    help="cooperative mode: ingest batches between publishes")
    ap.add_argument("--warm-batches", type=int, default=4,
                    help="ingest batches before serving starts")
    ap.add_argument("--mix", default="",
                    help="comma list family=weight, e.g. "
                         "'edge_freq=0.7,reach=0.3' (default: built-in mix)")
    # ---- background ingest runtime (repro.runtime) ----
    ap.add_argument("--background-ingest", action="store_true",
                    help="ingest in a worker thread behind a bounded queue; "
                         "queries run truly concurrently")
    ap.add_argument("--runtime-backend", default="thread",
                    help="execution backend for ingest workers: thread "
                         "(in-process, GIL-shared), process (spawn "
                         "children owning their sketches — K-shard ingest "
                         "scales past the GIL), or "
                         "socket[:HOST:PORT,...] (workers across TCP: "
                         "self-hosted loopback children, or stream_ingest "
                         "--listen hosts when addresses are given); "
                         "requires --background-ingest")
    ap.add_argument("--publish-mode", default="delta",
                    choices=["delta", "full"],
                    help="remote-backend snapshot publication: 'delta' "
                         "(default) ships only the per-epoch sketch delta, "
                         "sparse-encoded; 'full' ships whole fronts every "
                         "epoch (pre-v3 behaviour, kept for A/B benching)")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve K hash-band shards: one ingest worker + "
                         "queue per shard, scatter/gather queries "
                         "(requires --background-ingest)")
    ap.add_argument("--shard-seed", type=int, default=0,
                    help="seed of the shard routing hash (must match the "
                         "manifest when restoring a sharded checkpoint)")
    ap.add_argument("--queue-capacity", type=int, default=64)
    ap.add_argument("--backpressure", default="block",
                    choices=["block", "drop_oldest", "spill"])
    ap.add_argument("--publish-policy", default="",
                    help="every:N | interval:S | drain[:W] "
                         "(default: every:<--publish-every>)")
    ap.add_argument("--spill-dir", default="",
                    help="required for --backpressure spill")
    ap.add_argument("--checkpoint-dir", default="",
                    help="enable crash-safe checkpoints in background mode")
    ap.add_argument("--checkpoint-every", type=int, default=16,
                    help="batches between checkpoints (with --checkpoint-dir)")
    ap.add_argument("--restore", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir before serving")
    ap.add_argument("--ingest-dedup", action="store_true",
                    help="pre-aggregate duplicate (src, dst) rows on the "
                         "host before each coalesced ingest dispatch — "
                         "bit-exact (counters are linear), fewer device "
                         "scatter rows on skewed streams")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable jit buffer donation in the ingest path "
                         "(sets REPRO_DONATE=0 for this process and its "
                         "workers; A/B and debugging aid)")
    # ---- network front-end (repro.net) ----
    ap.add_argument("--serve", default="", metavar="HOST:PORT",
                    help="serve queries over TCP with admission control; "
                         "measurement runs through --connections real "
                         "client connections (requires "
                         "--background-ingest); --n-requests 0 serves "
                         "until signalled instead")
    ap.add_argument("--connections", type=int, default=4,
                    help="with --serve: concurrent loadgen client "
                         "connections")
    ap.add_argument("--max-inflight", type=int, default=4096,
                    help="with --serve: admission budget — requests queued "
                         "or executing before fast-reject")
    ap.add_argument("--tenant-qps", type=float, default=0.0,
                    help="with --serve: per-tenant token-bucket rate limit "
                         "(0 = off)")
    ap.add_argument("--auth-token", default="",
                    help="with --serve: shared connection token (default: "
                         "$KMATRIX_NET_TOKEN); REQUIRED to serve on a "
                         "non-loopback address — clients present it via "
                         "loadgen --auth-token / the same env var")
    # ---- telemetry exposition (repro.obs) ----
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="periodically dump the merged metrics hub to PATH "
                         "as JSON (atomic replace; same payload as the "
                         "'metrics' wire frame — dashboard/CI food)")
    ap.add_argument("--metrics-interval-s", type=float, default=1.0,
                    help="with --metrics-json: seconds between dumps")
    ap.add_argument("--span-log", default="", metavar="PATH",
                    help="on exit, append the bounded trace-span ring "
                         "(ingest enqueue->adopt, query accept->reply) to "
                         "PATH as JSONL")
    args = ap.parse_args(argv)
    _valid_backends = ("thread", "process", "socket")
    if args.runtime_backend not in _valid_backends \
            and not args.runtime_backend.startswith("socket:"):
        ap.error(f"--runtime-backend must be one of {_valid_backends} or "
                 f"socket:HOST:PORT[,...], got {args.runtime_backend!r}")
    if not args.background_ingest:
        # these only take effect inside the runtime; silently ignoring them
        # would serve a different run than the one asked for
        for flag, is_set in [("--restore", args.restore),
                             ("--checkpoint-dir", bool(args.checkpoint_dir)),
                             ("--spill-dir", bool(args.spill_dir)),
                             ("--backpressure",
                              args.backpressure != "block"),
                             ("--publish-policy", bool(args.publish_policy)),
                             ("--runtime-backend",
                              args.runtime_backend != "thread"),
                             ("--queue-capacity",
                              args.queue_capacity != 64),
                             ("--ingest-dedup", args.ingest_dedup),
                             ("--serve", bool(args.serve))]:
            if is_set:
                ap.error(f"{flag} requires --background-ingest")
    if not args.serve:
        for flag, is_set in [("--connections", args.connections != 4),
                             ("--max-inflight", args.max_inflight != 4096),
                             ("--tenant-qps", args.tenant_qps != 0.0),
                             ("--auth-token", bool(args.auth_token))]:
            if is_set:
                ap.error(f"{flag} requires --serve")
    if args.shards < 1:
        ap.error("--shards must be >= 1")
    if args.shards > 1 and not args.background_ingest:
        # sharding exists to parallelize ingest; a cooperative single
        # thread stepping K shards round-robin would just serve the same
        # stream slower
        ap.error("--shards > 1 requires --background-ingest")
    if args.restore and not args.checkpoint_dir:
        ap.error("--restore requires --checkpoint-dir")
    if args.backpressure == "spill" and not args.spill_dir:
        # fail at parse time, not after the multi-second jit warm-up
        ap.error("--backpressure spill requires --spill-dir")
    return args


def build_mix(args) -> WorkloadMix:
    if not args.mix:
        return mix_for_sketch(args.sketch)
    weights = {k: 0.0 for k in WorkloadMix().normalized()}
    for part in args.mix.split(","):
        k, v = part.split("=")
        if k.strip() not in weights:
            raise SystemExit(f"unknown query family {k.strip()!r} in --mix")
        weights[k.strip()] = float(v)
    return WorkloadMix(**weights)


def install_graceful_drain(runtime) -> None:
    """SIGTERM/SIGINT -> graceful drain-and-stop, then exit 128+signum.

    An orchestrator's shutdown (or a terminal Ctrl-C) must not be a crash:
    the runtime drains its queues, publishes the final epoch and flushes a
    final checkpoint (when checkpointing is configured — the worker's drain
    path does that) before the process exits, so the next ``--restore``
    resumes from the shutdown point instead of replaying from the last
    periodic checkpoint.  Worker failures discovered during the drain are
    reported but do not mask the signal exit code.
    """
    def handler(signum, frame):
        name = signal.Signals(signum).name
        print(f"{name}: draining ingest and flushing checkpoints before "
              "exit", file=sys.stderr)
        try:
            report = runtime.stop(drain=True, raise_on_failure=False)
            health = runtime.health()
            for tenant_id, rep in report.items():
                if rep.get("state") == "failed" or rep.get(
                        "unaccounted_edges"):
                    err = health.get(tenant_id, {}).get("error")
                    print(f"worker {tenant_id}: state={rep.get('state')} "
                          f"unaccounted={rep.get('unaccounted_edges')} "
                          f"error={err}", file=sys.stderr)
        finally:
            sys.exit(128 + signum)

    signal.signal(signal.SIGTERM, handler)
    signal.signal(signal.SIGINT, handler)


def run_load(args, engine, snapshot_fn, requests, *, n_nodes: int) -> tuple:
    """Measurement phase: in-process open loop, or — with ``--serve`` — a
    TCP query server loaded over ``--connections`` real client connections.
    Returns ``(report, net_extras)``; ``report`` quacks the same either way
    (n_requests / achieved_qps / p50_ms / p99_ms)."""
    if not args.serve:
        loadgen = OpenLoopLoadGen(target_qps=args.qps,
                                  batch_max=args.batch_max)
        return loadgen.run(engine, snapshot_fn, requests), {}

    from repro.net import wire
    from repro.net.query_server import QueryServer
    from repro.serving.loadgen import NetLoadGen

    host, port = wire.parse_hostport(args.serve)
    try:
        server = QueryServer(
            engine, snapshot_fn, host=host, port=port,
            max_inflight=args.max_inflight, batch_max=args.batch_max,
            tenant_qps=args.tenant_qps,
            auth_token=args.auth_token or None,
            info={"n_nodes": n_nodes, "kind": args.sketch,
                  "dataset": args.dataset}).start()
    except ValueError as exc:  # non-loopback --serve without a token
        raise SystemExit(str(exc)) from exc
    print(json.dumps({"serving":
                      f"{server.address[0]}:{server.address[1]}"}),
          file=sys.stderr, flush=True)
    try:
        if args.n_requests <= 0:
            # standing front-end: serve remote clients until the graceful
            # drain handler (SIGTERM/SIGINT) exits the process
            while True:
                time.sleep(3600)
        gen = NetLoadGen(target_qps=args.qps, connections=args.connections,
                         batch_max=args.batch_max,
                         auth_token=args.auth_token or None)
        report = gen.run(server.address, requests)
        stats = server.stats()
        return report, {
            "serve": f"{server.address[0]}:{server.address[1]}",
            "connections": args.connections,
            "shed": report.shed,
            "shed_rate": round(report.shed_rate, 4),
            "aborted": report.aborted,
            "mean_retry_after_ms": round(report.mean_retry_after_ms, 3),
            "answer_epoch": report.last_epoch,
            "server_stats": stats,
        }
    finally:
        server.stop()


def cooperative_serve(args, tenant, engine, requests) -> tuple:
    """PR 1 behaviour: ingest interleaves with query batches, one thread."""
    ingested = [0]

    def live_ingest() -> None:
        stepped = tenant.step(1)
        ingested[0] += stepped
        # key off this call's progress, not the cumulative count: once the
        # stream drains, a frozen total would either publish after every
        # served batch (thrashing the closure cache) or never again
        if stepped and ingested[0] % args.publish_every == 0:
            tenant.publish()

    loadgen = OpenLoopLoadGen(target_qps=args.qps, batch_max=args.batch_max)
    report = loadgen.run(engine, lambda: tenant.snapshot, requests,
                         between_batches=live_ingest)
    # drain whatever stream remains so the run is a full ingest too
    while tenant.step(16):
        pass
    final = tenant.publish()
    return report, final, {"ingest_mode": "cooperative"}


def _backend_arg(spec: str, publish_mode: str):
    """Backend arg for ``Runtime``, honouring ``--publish-mode``.  Only the
    remote backends publish over a transport; ``thread`` has no
    ``publish_mode`` attribute and ignores the flag."""
    if publish_mode == "delta":
        return spec  # the default everywhere; spec strings stay lazy
    from repro.runtime.backend import resolve_backend

    backend = resolve_backend(spec)
    if hasattr(backend, "publish_mode"):
        backend.publish_mode = publish_mode
    return backend


def background_serve(args, tenant, engine, requests) -> tuple:
    """Queries (main thread) truly concurrent with a runtime ingest worker."""
    from repro.runtime import Runtime

    runtime = Runtime(
        queue_capacity=args.queue_capacity,
        backpressure=args.backpressure,
        publish_policy=args.publish_policy or f"every:{args.publish_every}",
        checkpoint_dir=args.checkpoint_dir or None,
        checkpoint_every=args.checkpoint_every,
        spill_dir=args.spill_dir or None,
        dedup=args.ingest_dedup,
        backend=_backend_arg(args.runtime_backend, args.publish_mode),
    )
    runtime.attach(tenant, restore=args.restore)
    install_graceful_drain(runtime)
    runtime.start(pumps=False)
    runtime.wait_ready()  # process children build their tenants first
    runtime.start_pumps()
    report, net_extras = run_load(args, engine, lambda: tenant.snapshot,
                                  requests,
                                  n_nodes=tenant.stream.spec.n_nodes)
    mid_metrics = runtime.metrics()[tenant.key.tenant_id]
    runtime.join_pumps()  # finish offering the stream, then drain
    final_report = runtime.stop(drain=True)
    tr = final_report[tenant.key.tenant_id]
    extras = {
        "ingest_mode": "background",
        "runtime_backend": args.runtime_backend,
        "backpressure": args.backpressure,
        "publish_policy": args.publish_policy or f"every:{args.publish_every}",
        "ingest_edges_per_s": mid_metrics["edges_per_s_ewma"],
        "publishes": tr["publishes"],
        "mean_publish_latency_ms": tr["mean_publish_latency_ms"],
        "max_queue_depth": tr["max_queue_depth"],
        "dropped_edges": tr["dropped_edges"],
        "overflow_edges": tr["overflow_edges"],
        "spilled_batches": tr["spilled_batches"],
        "unaccounted_edges": tr["unaccounted_edges"],
        "checkpoints": tr["checkpoints"],
        "worker_state": tr["state"],
        **net_extras,
    }
    return report, tenant.snapshot, extras


def sharded_main(args) -> None:
    """Sharded serving: K hash-band shards, one runtime worker per shard,
    scatter/gather queries (DESIGN.md §Sharding)."""
    from repro.runtime import Runtime
    from repro.serving import (QueryEngine as _QE, ShardedQueryEngine,
                               attach_shards, sharded_conservation)

    registry = SketchRegistry(depth=args.depth, scale=args.scale,
                              partitioner=args.partitioner,
                              sketch_backend=args.sketch_backend or None)
    tenant = registry.open_sharded(args.dataset, args.sketch, args.budget_kb,
                                   seed=args.seed, n_shards=args.shards,
                                   shard_seed=args.shard_seed)
    stream = tenant.stream
    n_nodes = stream.spec.n_nodes
    print(f"sharded tenant {tenant.key.tenant_id} x{args.shards}: stream "
          f"{stream.num_batches} batches, universe {n_nodes}",
          file=sys.stderr)

    if not args.restore:  # a restored tenant is already warm
        tenant.step(min(args.warm_batches,
                        max(1, stream.num_batches // 2)))
        snap = tenant.publish()
        print(f"warm: epochs {snap.epochs}, {snap.n_edges} edges",
              file=sys.stderr)

    mix = build_mix(args)
    requests = synth_requests(
        args.n_requests, mix, n_nodes=n_nodes, seed=args.seed + 7,
        heavy_universe=min(n_nodes, 1 << 14), heavy_threshold=100.0)
    engine = ShardedQueryEngine(_QE())
    warm = synth_requests(args.batch_max, mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    warm_bucket_ladder(engine, tenant.snapshot, warm)

    runtime = Runtime(
        queue_capacity=args.queue_capacity,
        backpressure=args.backpressure,
        publish_policy=args.publish_policy or f"every:{args.publish_every}",
        checkpoint_dir=args.checkpoint_dir or None,
        checkpoint_every=args.checkpoint_every,
        spill_dir=args.spill_dir or None,
        # under backlog, fold sub-batches back to full-batch dispatches so
        # K small shards don't pay K-fold fixed dispatch cost
        coalesce_batches=max(4, args.shards),
        coalesce_target=stream.batch_size,
        dedup=args.ingest_dedup,
        backend=_backend_arg(args.runtime_backend, args.publish_mode),
    )
    handles = attach_shards(runtime, tenant, restore=args.restore)
    install_graceful_drain(runtime)
    runtime.start(pumps=False)
    runtime.wait_ready()  # process children build their tenants first
    runtime.start_pumps()
    report, net_extras = run_load(args, engine, lambda: tenant.snapshot,
                                  requests, n_nodes=n_nodes)
    mid = runtime.metrics()
    ingest_eps = sum(m["edges_per_s_ewma"] for m in mid.values())
    runtime.join_pumps()
    runtime.stop(drain=True)
    cons = sharded_conservation(handles, stream.spec.n_edges)

    summary = {
        "driver": "query_serve",
        "dataset": args.dataset,
        "sketch": args.sketch,
        "sketch_backend": registry.sketch_backend,
        "budget_kb": args.budget_kb,
        "ingest_mode": "sharded-background",
        "runtime_backend": args.runtime_backend,
        "n_shards": args.shards,
        "achieved_qps": round(report.achieved_qps, 1),
        "offered_qps": args.qps,
        "p50_ms": round(report.p50_ms, 3),
        "p90_ms": round(report.p90_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "p999_ms": round(report.p999_ms, 3),
        "latency_hist": report.latency_hist,
        "n_requests": report.n_requests,
        "final_epochs": list(tenant.epochs),
        "total_edges": tenant.snapshot.n_edges,
        "ingest_edges_per_s": round(ingest_eps, 1),
        "per_shard_published": cons["per_shard_published"],
        "dropped_edges": cons["dropped_edges"],
        "stream_total_edges": cons["stream_total_edges"],
        "conservation_ok": cons["conservation_ok"],
        **net_extras,
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }
    print(json.dumps(summary))
    if not cons["conservation_ok"]:
        sys.exit(1)


def main() -> None:
    args = parse_args()
    if args.no_donate:
        # must land before any SnapshotBuffer is built (tenant open);
        # runtime/backend.py forwards it to spawned/remote workers too
        os.environ["REPRO_DONATE"] = "0"
    dumper = None
    if args.metrics_json:
        from repro.obs import MetricsJsonDumper

        dumper = MetricsJsonDumper(args.metrics_json,
                                   interval_s=args.metrics_interval_s).start()
    try:
        _run(args)
    finally:
        if dumper is not None:
            dumper.stop()
        if args.span_log:
            from repro.obs import get_trace_log

            n = get_trace_log().dump_jsonl(args.span_log)
            print(f"span log: {n} events -> {args.span_log}",
                  file=sys.stderr)


def _run(args) -> None:
    if args.shards > 1:
        sharded_main(args)
        return
    registry = SketchRegistry(depth=args.depth, scale=args.scale,
                              partitioner=args.partitioner,
                              sketch_backend=args.sketch_backend or None)
    tenant = registry.open(args.dataset, args.sketch, args.budget_kb,
                           seed=args.seed)
    n_nodes = tenant.stream.spec.n_nodes
    print(f"tenant {tenant.key.tenant_id}: stream "
          f"{tenant.stream.num_batches} batches, universe {n_nodes}",
          file=sys.stderr)

    t0 = time.time()
    if not args.restore:  # a restored tenant is already warm
        tenant.step(min(args.warm_batches,
                        max(1, tenant.stream.num_batches // 2)))
        snap = tenant.publish()
        print(f"warm: epoch {snap.epoch}, {snap.n_edges} edges in "
              f"{time.time()-t0:.2f}s", file=sys.stderr)

    mix = build_mix(args)
    requests = synth_requests(
        args.n_requests, mix, n_nodes=n_nodes, seed=args.seed + 7,
        heavy_universe=min(n_nodes, 1 << 14), heavy_threshold=100.0)

    engine = QueryEngine()
    warm = synth_requests(args.batch_max, mix, n_nodes=n_nodes, seed=99,
                          heavy_universe=min(n_nodes, 1 << 14),
                          heavy_threshold=100.0)
    warm_bucket_ladder(engine, tenant.snapshot, warm)

    serve = background_serve if args.background_ingest else cooperative_serve
    report, final, extras = serve(args, tenant, engine, requests)

    summary = {
        "driver": "query_serve",
        "dataset": args.dataset,
        "sketch": args.sketch,
        "sketch_backend": registry.sketch_backend,
        "budget_kb": args.budget_kb,
        "achieved_qps": round(report.achieved_qps, 1),
        "offered_qps": args.qps,
        "p50_ms": round(report.p50_ms, 3),
        "p90_ms": round(report.p90_ms, 3),
        "p99_ms": round(report.p99_ms, 3),
        "p999_ms": round(report.p999_ms, 3),
        "latency_hist": report.latency_hist,
        "n_requests": report.n_requests,
        "final_epoch": final.epoch,
        "total_edges": final.n_edges,
        **extras,
        **{f"engine_{k}": v for k, v in engine.stats.items()},
    }
    print(json.dumps(summary))
    if extras.get("unaccounted_edges"):
        sys.exit(1)


if __name__ == "__main__":
    main()
