"""Production streaming-ingest driver: the paper's workload as a service.

Runs the full pipeline: stream -> reservoir sample -> partition -> batched
ingest (optionally data-parallel across local devices) with periodic
checkpointing and crash-safe resume. This is the end-to-end driver for the
paper's own system (examples/quickstart.py is the 60-second version).

  python -m repro.launch.stream_ingest --dataset cit-HepPh --budget-kb 512 \
      --sketch kmatrix --steps-per-ckpt 16 --ckpt-dir /tmp/kmatrix_ckpt \
      [--resume] [--scale 0.25]

Worker-host mode (DESIGN.md §Net): ``--listen HOST:PORT`` turns this
process into a standing socket-ingest worker host — it serves ingest
worker sessions for any parent running a ``socket``-backend Runtime
pointed at this address (``--runtime-backend socket:HOST:PORT`` here or
in query_serve / serve_bench).  All other pipeline flags are ignored in
this mode: the tenant spec arrives over the wire in the hello frame.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.core import vertex_stats_from_sample
from repro.core.metrics import (
    average_relative_error,
    exact_edge_frequencies,
    lookup_exact,
)
from repro.serving.registry import SKETCHES, build_sketch
from repro.streams import make_stream, sample_stream


def _backend_arg(spec: str, publish_mode: str):
    """Backend arg for ``Runtime``, honouring ``--publish-mode``.  Only the
    remote backends publish over a transport; ``thread`` has no
    ``publish_mode`` attribute and ignores the flag."""
    if publish_mode == "delta":
        return spec  # the default everywhere; spec strings stay lazy
    from repro.runtime.backend import resolve_backend

    backend = resolve_backend(spec)
    if hasattr(backend, "publish_mode"):
        backend.publish_mode = publish_mode
    return backend


def runtime_main(args) -> None:
    """Paper pipeline driven through the background ingest runtime.

    Same stream -> sample -> partition -> ingest -> ARE pipeline, but the
    ingest loop is a ``repro.runtime`` worker on the chosen execution
    backend (``--runtime-backend thread|process``) behind a pump + bounded
    queue, with conservation verified after the drain — the process
    backend's write path runs in a spawn child owning the sketch, while
    this process keeps the published snapshot for evaluation.
    """
    from repro.runtime import Runtime
    from repro.serving import SketchRegistry

    registry = SketchRegistry(depth=args.depth, batch_size=args.batch_size,
                              sample_size=args.sample_size,
                              scale=args.scale,
                              partitioner=args.partitioner,
                              sketch_backend=args.sketch_backend or None)
    tenant = registry.open(args.dataset, args.sketch, args.budget_kb,
                           seed=args.seed)
    stream = tenant.stream
    print(f"stream: {stream.spec.name} nodes={stream.spec.n_nodes} "
          f"edges={stream.spec.n_edges} batches={stream.num_batches} "
          f"[runtime backend: {args.runtime_backend}]")
    runtime = Runtime(publish_policy="drain:0", reservoir_k=0,
                      checkpoint_dir=args.ckpt_dir or None,
                      checkpoint_every=args.steps_per_ckpt,
                      dedup=args.ingest_dedup,
                      backend=_backend_arg(args.runtime_backend,
                                           args.publish_mode))
    restore = bool(args.resume and args.ckpt_dir)
    try:
        handle = runtime.attach(tenant, restore=restore)
    except FileNotFoundError:
        print("no checkpoint found; starting fresh")
        handle = runtime.attach(tenant, restore=False)
    if restore and tenant.offset:
        print(f"resumed from batch {tenant.offset}")
    t0 = time.time()
    runtime.start(pumps=False)
    runtime.wait_ready()
    runtime.start_pumps()
    runtime.join_pumps()
    report = runtime.stop(drain=True)[tenant.key.tenant_id]
    dt = time.time() - t0
    n_edges = report["ingested_edges"]
    print(f"ingest: {n_edges} edges in {dt:.2f}s "
          f"({n_edges/max(dt,1e-9)/1e6:.2f} M edges/s) "
          f"unaccounted={report['unaccounted_edges']}")
    if report["unaccounted_edges"]:
        raise SystemExit("edge conservation failed after drain")

    sk, mod = tenant.snapshot.sketch, tenant.mod
    src, dst, w = stream.all_edges_numpy()
    fmap = exact_edge_frequencies(src, dst, w)
    qs, qd, _ = sample_stream(stream, args.eval_queries, seed=99)
    true = lookup_exact(fmap, qs, qd)
    est = np.asarray(mod.edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd)))
    are = float(average_relative_error(jnp.asarray(est), jnp.asarray(true)))
    print(json.dumps({"sketch": args.sketch, "dataset": args.dataset,
                      "budget_kb": args.budget_kb,
                      "runtime_backend": args.runtime_backend,
                      "ARE": round(are, 4)}))


def listen_main(args) -> None:
    """Standing worker host: serve socket ingest sessions until signalled
    (or until ``--max-sessions`` sessions completed, for scripted runs)."""
    import signal as signal_mod

    from repro.net import wire
    from repro.net.ingest_server import WorkerServer

    host, port = wire.parse_hostport(args.listen)
    try:
        server = WorkerServer(host, port,
                              auth_token=args.auth_token or None)
    except ValueError as exc:  # non-loopback bind without a token
        raise SystemExit(str(exc)) from exc
    print(json.dumps({"listening": f"{server.address[0]}:{server.address[1]}",
                      "max_sessions": args.max_sessions or None}), flush=True)

    def _stop(signum, frame):
        server.stop()

    signal_mod.signal(signal_mod.SIGTERM, _stop)
    signal_mod.signal(signal_mod.SIGINT, _stop)
    server.serve_forever(
        max_sessions=args.max_sessions or None,
        idle_timeout_s=args.idle_timeout_s or None)
    print(json.dumps({"sessions_served": server.sessions_served,
                      "results": server.session_results}), flush=True)
    if any(str(r).startswith("aborted") or r == "failed"
           for r in server.session_results):
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cit-HepPh")
    ap.add_argument("--sketch", default="kmatrix", choices=sorted(SKETCHES))
    ap.add_argument("--budget-kb", type=int, default=512)
    ap.add_argument("--depth", type=int, default=7)
    ap.add_argument("--batch-size", type=int, default=8192)
    ap.add_argument("--sample-size", type=int, default=30_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--partitioner", default="banded",
                    choices=["banded", "greedy"])
    ap.add_argument("--sketch-backend", default="",
                    choices=["", "flat", "pallas"],
                    help="kmatrix physical layout (default: "
                         "$REPRO_SKETCH_BACKEND, else pallas on TPU / flat "
                         "elsewhere); checkpoints are layout-specific but "
                         "convertible via core.kmatrix_accel relayout")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--steps-per-ckpt", type=int, default=16)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-queries", type=int, default=10_000)
    ap.add_argument("--ingest-dedup", action="store_true",
                    help="runtime backends only: pre-aggregate duplicate "
                         "(src, dst) rows on the host before each coalesced "
                         "ingest dispatch (bit-exact — counters are linear)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable jit buffer donation in the ingest path "
                         "(sets REPRO_DONATE=0 for this process and its "
                         "workers; A/B and debugging aid)")
    ap.add_argument("--runtime-backend", default="inline",
                    help="inline: this loop ingests directly (default). "
                         "thread/process/socket[:HOST:PORT,...]: drive "
                         "ingest through the repro.runtime worker runtime "
                         "on that execution backend (pump + bounded queue "
                         "+ conservation accounting; checkpoints use the "
                         "runtime's worker-state schema under a per-tenant "
                         "subdir). socket with no address self-hosts a "
                         "loopback worker; with addresses it dials "
                         "--listen worker hosts")
    ap.add_argument("--publish-mode", default="delta",
                    choices=["delta", "full"],
                    help="remote-backend snapshot publication: 'delta' "
                         "(default) ships only the per-epoch sketch delta, "
                         "sparse-encoded; 'full' ships whole fronts every "
                         "epoch (pre-v3 behaviour, kept for A/B benching)")
    ap.add_argument("--listen", default="", metavar="HOST:PORT",
                    help="worker-host mode: serve socket ingest worker "
                         "sessions at this address instead of running a "
                         "pipeline (DESIGN.md §Net)")
    ap.add_argument("--max-sessions", type=int, default=0,
                    help="with --listen: exit after N completed sessions "
                         "(0 = serve until signalled)")
    ap.add_argument("--idle-timeout-s", type=float, default=0.0,
                    help="with --listen: exit after this long with no live "
                         "session (0 = wait forever); keeps scripted runs "
                         "from wedging on a lost parent")
    ap.add_argument("--auth-token", default="",
                    help="shared connection token (default: "
                         "$KMATRIX_NET_TOKEN); REQUIRED to --listen on a "
                         "non-loopback address — parents present it via "
                         "the same flag/env on their socket backend")
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="periodically dump the merged metrics hub to PATH "
                         "as JSON (atomic replace; same payload as the "
                         "'metrics' wire frame); works in every mode, "
                         "including --listen worker hosts")
    ap.add_argument("--metrics-interval-s", type=float, default=1.0,
                    help="with --metrics-json: seconds between dumps")
    args = ap.parse_args()
    valid = ("inline", "thread", "process", "socket")
    if args.runtime_backend not in valid \
            and not args.runtime_backend.startswith("socket:"):
        ap.error(f"--runtime-backend must be one of {valid} or "
                 f"socket:HOST:PORT[,...], got {args.runtime_backend!r}")
    if args.ingest_dedup and args.runtime_backend == "inline" \
            and not args.listen:
        ap.error("--ingest-dedup requires a runtime backend "
                 "(--runtime-backend thread/process/socket)")
    if args.no_donate:
        # must land before any SnapshotBuffer is built; the runtime
        # backends forward it to spawned/remote workers via the child spec
        os.environ["REPRO_DONATE"] = "0"
    dumper = None
    if args.metrics_json:
        from repro.obs import MetricsJsonDumper

        dumper = MetricsJsonDumper(args.metrics_json,
                                   interval_s=args.metrics_interval_s).start()
    try:
        if args.listen:
            listen_main(args)
        elif args.runtime_backend != "inline":
            runtime_main(args)
        else:
            inline_main(args)
    finally:
        if dumper is not None:
            dumper.stop()


def inline_main(args) -> None:
    """The original single-loop pipeline: jit ingest in this thread."""
    stream = make_stream(args.dataset, batch_size=args.batch_size,
                         seed=args.seed, scale=args.scale)
    print(f"stream: {stream.spec.name} nodes={stream.spec.n_nodes} "
          f"edges={stream.spec.n_edges} batches={stream.num_batches}")

    # Paper §V-A: 30k-edge reservoir sample bootstraps the partitioner.
    t0 = time.time()
    ssrc, sdst, sw = sample_stream(stream, args.sample_size, seed=args.seed + 1)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    sk, mod = build_sketch(args.sketch, args.budget_kb * 1024, stats,
                           args.depth, args.seed, args.partitioner,
                           backend=args.sketch_backend or None)
    print(f"init: {args.sketch} [{type(sk).__name__}] "
          f"counters={sk.num_counters} "
          f"({time.time()-t0:.2f}s init incl. sampling)")

    offset = 0
    if args.resume and args.ckpt_dir:
        try:
            sk, meta = store.restore(args.ckpt_dir, sk)
            offset = meta["extra"]["stream_offset"]
            print(f"resumed from batch {offset}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    ingest = jax.jit(mod.ingest)
    t0 = time.time()
    n_edges = 0
    for i, batch in stream.iter_from(offset):
        sk = ingest(sk, batch)
        n_edges += int(np.asarray(batch.weight > 0).sum())
        if args.ckpt_dir and (i + 1) % args.steps_per_ckpt == 0:
            jax.block_until_ready(sk)
            store.save(args.ckpt_dir, i + 1, sk,
                       extra={"stream_offset": i + 1, "seed": args.seed})
    jax.block_until_ready(sk)
    dt = time.time() - t0
    print(f"ingest: {n_edges} edges in {dt:.2f}s "
          f"({n_edges/max(dt,1e-9)/1e6:.2f} M edges/s)")

    # evaluation against exact ground truth (paper Fig. 7 protocol)
    src, dst, w = stream.all_edges_numpy()
    fmap = exact_edge_frequencies(src, dst, w)
    qs, qd, _ = sample_stream(stream, args.eval_queries, seed=99)
    true = lookup_exact(fmap, qs, qd)
    est = np.asarray(mod.edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd)))
    are = float(average_relative_error(jnp.asarray(est), jnp.asarray(true)))
    print(json.dumps({"sketch": args.sketch, "dataset": args.dataset,
                      "budget_kb": args.budget_kb, "ARE": round(are, 4)}))


if __name__ == "__main__":
    main()
