"""Serving driver: prefill + batched decode with KV cache.

  python -m repro.launch.serve --arch gemma2-2b --reduced --batch 4 \
      --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.lm import LM_CONFIGS, reduced as lm_reduced


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(LM_CONFIGS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.models.transformer import model as tmodel

    cfg = LM_CONFIGS[args.arch]
    if args.reduced:
        cfg = lm_reduced(cfg)
    params = tmodel.init_params(cfg, jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen
    cache = tmodel.init_cache(cfg, args.batch, max_len, dtype=jnp.float32)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )

    prefill = jax.jit(lambda p, t, c: tmodel.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, t, c: tmodel.decode_step(cfg, p, t, c))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")

    key = jax.random.PRNGKey(args.seed + 1)
    out_tokens = []
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, nxt, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, 0] / args.temperature, -1
            )[:, None].astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    gen = np.stack(out_tokens, 1)
    print(f"decode: {args.gen} steps x batch {args.batch} in {dt*1e3:.1f} ms "
          f"({args.gen*args.batch/dt:,.0f} tok/s)")
    print("sample token ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
