"""Training driver for the assigned architectures (reduced or full configs).

CPU-runnable end-to-end example (the ~100M-class run used in examples/):
  python -m repro.launch.train --arch gemma2-2b --reduced --steps 200 \
      --batch 8 --seq 256 --ckpt-dir /tmp/lm_ckpt

On a real cluster the same entry point takes --mesh data,model dims; here
the mesh is whatever local devices exist (usually 1 CPU device).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.lm import LM_CONFIGS, reduced as lm_reduced
from repro.training import AdamWConfig, init_train_state, make_train_step
from repro.training.steps import lm_loss_fn


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                       vocab: int):
    """Zipf-distributed token stream (deterministic; replayable by step)."""
    toks = (rng.zipf(1.3, size=(batch, seq + 1)) % vocab).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(LM_CONFIGS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.models.transformer import model as tmodel

    cfg = LM_CONFIGS[args.arch]
    if args.reduced:
        cfg = lm_reduced(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"active~{cfg.active_param_count()/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 5),
                          total_steps=args.steps)
    params = tmodel.init_params(cfg, jax.random.PRNGKey(args.seed))
    state = init_train_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(lm_loss_fn(cfg), opt_cfg))

    start = 0
    if args.resume and args.ckpt_dir:
        try:
            state, meta = store.restore(args.ckpt_dir, state)
            start = meta["step"]
            print(f"resumed at step {start}")
        except FileNotFoundError:
            pass

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        rng = np.random.default_rng((args.seed << 20) + step)  # replayable
        batch = synthetic_lm_batch(rng, args.batch, args.seq, cfg.vocab)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            tok_s = args.batch * args.seq * args.log_every / (time.time() - t0)
            print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} tok/s {tok_s:,.0f}")
            t0 = time.time()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            store.save(args.ckpt_dir, step + 1, state,
                       extra={"loss": losses[-1]})
    if len(losses) > 20:
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
