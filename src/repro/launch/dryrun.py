import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod, 2x16x16 multi-pod),
  2. lowers the REAL step function (full train step incl. optimizer, or
     prefill/decode with KV cache) against ShapeDtypeStruct inputs with the
     family sharding rules — no host allocation ever happens,
  3. compiles, printing memory_analysis() (proves the per-device footprint
     fits a 16 GiB v5e) and cost_analysis() (FLOPs/bytes for §Roofline),
  4. parses the post-SPMD HLO for collective ops and estimates
     bytes-on-wire per device (all-reduce counted 2x for the ring),
     multiplying collectives that live inside the layer-stack while-loop by
     the scan trip count,
  5. appends one JSON record per cell to the results file.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time

import numpy as np  # noqa: E402
import jax  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _result_bytes(line: str) -> int:
    """Total bytes of the result shape(s) on an HLO op line."""
    lhs = line.split("=", 1)[0] if "=" in line else line
    # result shape appears right after '=' on the rhs
    rhs = line.split("=", 1)[1] if "=" in line else line
    m = _SHAPE_RE.findall(rhs.split("(", 1)[0])
    total = 0
    for dt, dims in m:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, loop_multiplier: int) -> dict:
    """Sum estimated bytes-on-wire per device by collective type.

    Ops inside while-loop body computations are multiplied by
    ``loop_multiplier`` (the layer-stack scan length) — HLO shows loop
    bodies once but they execute every iteration.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    current_mult = 1
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if line.startswith(("ENTRY", "%fused", "while_body", "body",
                            "%while_body", "region_")) or line.endswith("{"):
            name = line.split(" ")[0].lstrip("%")
            in_loop = ("while" in name or "body" in name or
                       re.match(r"region_\d+", name) is not None)
            current_mult = loop_multiplier if in_loop else 1
        for coll in _COLLECTIVES:
            if f" {coll}(" in line or f"{coll}-start(" in line:
                nbytes = _result_bytes(line)
                factor = 2.0 if coll == "all-reduce" else 1.0
                out[coll] += nbytes * factor * current_mult
                counts[coll] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": float(sum(out.values()))}


def loop_multiplier_for(arch_name: str) -> int:
    from repro.configs.registry import archs

    arch = archs()[arch_name]
    if arch.family == "lm":
        per = len(arch.config.layer_pattern)
        return max(arch.config.n_layers // per, 1)
    if arch.family == "gnn":
        return arch.config.n_layers
    return 1


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> dict:
    from repro.configs.registry import build_cell
    from repro.launch.mesh import make_production_mesh, use_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch_name, shape_name, mesh)
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": mesh.devices.size, "ok": False}
    try:
        from jax.sharding import NamedSharding, PartitionSpec

        def to_sharding(tree):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, PartitionSpec),
            )

        # use_mesh (jax.set_mesh when available) — set_mesh installs the
        # abstract mesh that in-model shard_map/constraints see under jit.
        with use_mesh(mesh):
            jitted = jax.jit(cell.step_fn,
                             in_shardings=to_sharding(cell.in_specs),
                             out_shardings=None if cell.out_specs is None
                             else to_sharding(cell.out_specs),
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.arg_shapes)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        mult = loop_multiplier_for(arch_name)
        coll = parse_collectives(hlo, mult)
        rec.update(
            ok=True,
            loop_multiplier=mult,
            # cost_analysis counts while-loop bodies ONCE; the layer stack
            # dominates, so adjusted ~= raw * scan length (validated against
            # analytic 6*N*D in EXPERIMENTS.md §Roofline).
            flops_adjusted=float(cost.get("flops", 0.0)) * mult
            if isinstance(cost, dict) else None,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                + (getattr(mem, "argument_size_in_bytes", 0) or 0),
            },
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            collectives=coll,
            model_flops=cell.model_flops_per_step,
        )
        if verbose:
            print(f"[OK] {arch_name} x {shape_name} on {rec['mesh']}: "
                  f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
                  f"peak/device {rec['memory']['peak_bytes']/2**30:.2f} GiB "
                  f"HLO GFLOPs {rec['flops']/1e9:.1f} "
                  f"coll {coll['total_bytes']/2**20:.1f} MiB")
            print(f"     memory_analysis: {mem}")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch_name} x {shape_name}: {rec['error']}",
                  file=sys.stderr)
    return rec


def run_sketch_cell(*, multi_pod: bool, mode: str = "a2a",
                    budget_mb: int = 64, batch: int = 1 << 20,
                    verbose: bool = True) -> dict:
    """Dry-run the PAPER'S system at pod scale: partition-parallel kMatrix
    ingest (partitions sharded over 'model' like experts, edges over the
    DP axes, all_to_all or all_gather dispatch) + merged query."""
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import KMatrix, vertex_stats_from_sample
    from repro.distributed.sketch_parallel import make_pp_ingest
    from repro.launch.mesh import make_production_mesh, use_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": f"kmatrix-stream-{mode}", "shape": f"ingest_{batch}",
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": mesh.devices.size, "ok": False}
    t0 = time.time()
    try:
        rng = np.random.default_rng(0)
        src = rng.zipf(1.2, 200_000).astype(np.int32) % (1 << 20)
        dst = rng.integers(0, 1 << 20, 200_000).astype(np.int32)
        stats = vertex_stats_from_sample(src, dst)
        sk = KMatrix.create(bytes_budget=budget_mb << 20, stats=stats,
                            depth=7, seed=0, partitioner="banded",
                            n_bands=64)  # >= model axis for balanced owners
        n_rep = mesh.devices.size
        pool = jax.ShapeDtypeStruct((n_rep * sk.pool.shape[0],
                                     sk.pool.shape[1]), jnp.int32)
        conn = jax.ShapeDtypeStruct((n_rep * sk.conn.shape[0],)
                                    + sk.conn.shape[1:], jnp.int32)
        edges = jax.ShapeDtypeStruct((batch,), jnp.int32)
        with use_mesh(mesh):
            fn, owner = make_pp_ingest(sk, mesh, mode=mode)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
                pool, conn, edges, edges, edges)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll = parse_collectives(compiled.as_text(), 1)
        rec.update(ok=True, compile_s=round(time.time() - t0, 2),
                   memory={"peak_bytes": (mem.temp_size_in_bytes or 0)
                           + (mem.argument_size_in_bytes or 0)},
                   flops=cost.get("flops", 0.0),
                   bytes_accessed=cost.get("bytes accessed", 0.0),
                   collectives=coll, model_flops=0.0, loop_multiplier=1)
        if verbose:
            print(f"[OK] kmatrix-stream[{mode}] on {rec['mesh']}: "
                  f"compile {rec['compile_s']}s peak/device "
                  f"{rec['memory']['peak_bytes']/2**30:.3f} GiB "
                  f"coll {coll['total_bytes']/2**20:.1f} MiB/batch "
                  f"owners balanced over {mesh.shape['model']} shards")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] kmatrix-stream[{mode}]: {rec['error']}",
                  file=sys.stderr)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sketch", action="store_true",
                    help="dry-run the paper's partition-parallel sketch")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    if args.sketch:
        n_fail = 0
        with open(args.out, "a") as f:
            for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                for mode in ["a2a", "allgather"]:
                    rec = run_sketch_cell(multi_pod=mp, mode=mode)
                    f.write(json.dumps(rec) + "\n")
                    n_fail += 0 if rec["ok"] else 1
        sys.exit(1 if n_fail else 0)

    from repro.configs.registry import all_cells

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape in cells:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp)
                f.write(json.dumps(rec) + "\n")
                f.flush()
                n_fail += 0 if rec["ok"] else 1
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
