"""Run any assigned architecture at reduced scale on CPU: one forward +
train step, asserting finite outputs — the CLI face of the smoke tests.

  python -m repro.launch.smoke --arch equiformer-v2
  python -m repro.launch.smoke --all
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp


def smoke_lm(name: str) -> dict:
    from repro.configs.lm import LM_CONFIGS, reduced
    from repro.models.transformer import model as tmodel

    cfg = reduced(LM_CONFIGS[name])
    params = tmodel.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    loss, metrics = jax.jit(
        lambda p, t: tmodel.lm_loss(cfg, p, t, t)
    )(params, toks)
    return {"loss": float(loss), "ce": float(metrics["ce"])}


def smoke_gnn(name: str) -> dict:
    from repro.models.gnn import (
        equiformer_v2, gatedgcn, graphcast, nequip, synthetic_graph,
    )

    g = synthetic_graph(24, 64, 13, seed=0)
    if name == "gatedgcn":
        cfg = gatedgcn.GatedGCNConfig(n_layers=3, d_hidden=16, d_out=4)
        params = gatedgcn.init_params(cfg, jax.random.PRNGKey(0), d_in=13)
        out = gatedgcn.forward(cfg, params, g)
    elif name == "graphcast":
        cfg = graphcast.GraphCastConfig(n_layers=2, d_hidden=32, n_vars=13)
        params = graphcast.init_params(cfg, jax.random.PRNGKey(0))
        out = graphcast.forward(cfg, params, g)
    elif name == "nequip":
        cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, edge_chunk=32)
        params = nequip.init_params(cfg, jax.random.PRNGKey(0), d_in=13)
        out = nequip.energy(cfg, params, g, g.positions)
    else:
        cfg = equiformer_v2.EquiformerV2Config(
            n_layers=2, d_hidden=16, l_max=3, n_heads=4, edge_chunk=32)
        params = equiformer_v2.init_params(cfg, jax.random.PRNGKey(0), d_in=13)
        out = equiformer_v2.forward(cfg, params, g)
    assert np.isfinite(np.asarray(out)).all()
    return {"out_shape": list(np.asarray(out).shape)}


def smoke_recsys(name: str) -> dict:
    from repro.models.recsys.fm import FMConfig, bce_loss, init_params

    cfg = FMConfig(total_vocab=5000, n_fields=7)
    params = init_params(cfg, jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (32, 7), 0, 1 << 30)
    labels = jnp.zeros((32,), jnp.float32)
    loss = bce_loss(cfg, params, ids, labels)
    assert np.isfinite(float(loss))
    return {"bce": float(loss)}


FAMILIES = {
    "gemma2-2b": smoke_lm, "internlm2-20b": smoke_lm, "gemma3-27b": smoke_lm,
    "mixtral-8x7b": smoke_lm, "grok-1-314b": smoke_lm,
    "gatedgcn": smoke_gnn, "graphcast": smoke_gnn, "nequip": smoke_gnn,
    "equiformer-v2": smoke_gnn,
    "fm": smoke_recsys,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(FAMILIES))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    names = sorted(FAMILIES) if args.all else [args.arch]
    assert names[0], "--arch or --all"
    for name in names:
        t0 = time.time()
        out = FAMILIES[name](name)
        print(f"[smoke OK] {name:15s} {time.time()-t0:5.1f}s {out}")


if __name__ == "__main__":
    main()
