"""repro.serving — the online query-serving layer over live sketches.

Turns the offline reproduction into an always-on service (DESIGN.md
§Serving):

  registry  multi-tenant sketch registry; owns per-tenant ingest loops
  snapshot  double-buffered epoch-stamped read snapshots (snapshot isolation)
  engine    batched query planner: heterogeneous requests -> dense jitted
            calls, with per-(tenant, epoch) closure caching for reachability
  loadgen   open-loop load generator reporting QPS and p50/p99 latency

Entry points: ``launch/query_serve.py`` (ingest + serving end to end) and
``benchmarks/serve_bench.py`` (the BENCH trajectory's serving row).
"""
from repro.serving.engine import (
    ClosureCache,
    QueryEngine,
    Request,
    Result,
    edge_freq,
    heavy_nodes,
    node_in,
    node_out,
    path_weight,
    reach,
    subgraph_weight,
)
from repro.serving.loadgen import (
    LoadReport,
    OpenLoopLoadGen,
    WorkloadMix,
    mix_for_sketch,
    synth_requests,
    warm_bucket_ladder,
)
from repro.serving.registry import SketchRegistry, Tenant, TenantKey
from repro.serving.sharding import (
    ShardKey,
    ShardStreamView,
    ShardedQueryEngine,
    ShardedSnapshot,
    ShardedTenant,
    attach_shards,
    measure_sharded_ingest,
    read_shard_manifest,
    sharded_conservation,
    sharded_direct_answers,
    warm_ingest_shapes,
    write_shard_manifest,
)
from repro.serving.snapshot import Snapshot, SnapshotBuffer

__all__ = [
    "ShardKey",
    "ShardStreamView",
    "ShardedQueryEngine",
    "ShardedSnapshot",
    "ShardedTenant",
    "attach_shards",
    "measure_sharded_ingest",
    "read_shard_manifest",
    "sharded_conservation",
    "sharded_direct_answers",
    "warm_ingest_shapes",
    "write_shard_manifest",
    "ClosureCache",
    "QueryEngine",
    "Request",
    "Result",
    "edge_freq",
    "heavy_nodes",
    "node_in",
    "node_out",
    "path_weight",
    "reach",
    "subgraph_weight",
    "LoadReport",
    "OpenLoopLoadGen",
    "WorkloadMix",
    "mix_for_sketch",
    "synth_requests",
    "warm_bucket_ladder",
    "SketchRegistry",
    "Tenant",
    "TenantKey",
    "Snapshot",
    "SnapshotBuffer",
]
