"""Shared hard-gate helpers: conservation + exactness checks for serving.

One implementation for every drive path that gates on correctness —
``serve_bench --concurrent``, ``serve_bench --shards`` (thread AND process
runtime backends), ``benchmarks/run.py`` and the test suite — instead of
the per-bench copies these started as.  Everything here is pure checking:
no timing, no I/O, no policy.

The two invariant families (DESIGN.md §Runtime / §Sharding):

  conservation   after a graceful drain, published counter mass + accounted
                 drops == stream total, per worker and summed;
  exactness      engine answers == direct module-level answers, and a
                 (merged) sketch is bit-identical — counters AND estimates —
                 to a single-sketch replay of the same stream.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.serving import engine as eng
from repro.serving.snapshot import Snapshot


def values_match(a, b) -> bool:
    """Equality for query answers (heavy-nodes answers are array pairs)."""
    if isinstance(a, tuple):
        return (np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1]))
    return a == b


def mismatched_indices(got: list, want: list) -> list[int]:
    """Indices where engine answers diverge from oracle answers."""
    return [i for i, (g, w) in enumerate(zip(got, want))
            if not values_match(g, w)]


def layout_counters_equal(a, b) -> bool:
    """Bit-equality of a sketch's counter state (pool(s) + conn), layout
    aware; the ``overflow`` diagnostic is deliberately excluded — dispatch
    capacity differs between sub-batch shapes, so sharded and unsharded
    runs legitimately tally different fallback volumes for identical
    counters."""
    if hasattr(a, "pools"):
        return (all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(a.pools, b.pools))
                and np.array_equal(np.asarray(a.conn), np.asarray(b.conn)))
    if hasattr(a, "pool"):
        return (np.array_equal(np.asarray(a.pool), np.asarray(b.pool))
                and np.array_equal(np.asarray(a.conn), np.asarray(b.conn)))
    if hasattr(a, "table"):
        return np.array_equal(np.asarray(a.table), np.asarray(b.table))
    return np.array_equal(np.asarray(a.counters), np.asarray(b.counters))


def replay_sketch(mod, template, stream, n_batches: int):
    """Single-sketch oracle: ingest stream batches ``[0, n_batches)`` into
    ``template`` (usually an ``empty_like`` clone sharing the layout under
    test) through the module's jitted ingest."""
    ing = jax.jit(mod.ingest)
    sk = template
    for i in range(n_batches):
        sk = ing(sk, stream.batch(i))
    return sk


def replay_exactness(snapshot: Snapshot, replay, requests,
                     *, answers=None) -> dict:
    """Gate a snapshot against a replayed sketch: bit-identical counters
    AND bit-identical direct estimates for ``requests``.

    ``replay`` must share the snapshot sketch's layout.  ``answers`` lets a
    caller reuse direct answers it already computed for the snapshot (the
    per-request oracle is the slow half of the gate).  Returns the
    ``counters_equal`` / ``estimates_equal`` / ``ok`` verdict dict every
    serve-bench record embeds.
    """
    counters_equal = layout_counters_equal(snapshot.sketch, replay)
    replay_snap = Snapshot(snapshot.tenant_id + "/replay", snapshot.epoch,
                           replay, snapshot.kind, snapshot.n_edges)
    if answers is None:
        answers = eng.direct_answers(snapshot, requests)
    replay_answers = eng.direct_answers(replay_snap, requests)
    estimates_equal = all(values_match(a, b)
                          for a, b in zip(answers, replay_answers))
    return {
        "counters_equal": bool(counters_equal),
        "estimates_equal": bool(estimates_equal),
        "ok": bool(counters_equal and estimates_equal),
    }


def conservation_verdict(published: int, dropped: int, stream_total: int,
                         unaccounted) -> dict:
    """Edge-mass verdict shared by the single-tenant and sharded gates:
    published + accounted drops must equal the stream total AND every
    worker must individually balance (``unaccounted`` is one int or a
    per-worker list)."""
    per_worker = (list(unaccounted) if hasattr(unaccounted, "__len__")
                  else [unaccounted])
    return {
        "published_edges": published,
        "dropped_edges": dropped,
        "stream_total_edges": stream_total,
        "unaccounted_edges": sum(per_worker),
        "conservation_ok": bool(
            published + dropped == stream_total
            and all(u == 0 for u in per_worker)),
    }
