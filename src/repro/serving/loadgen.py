"""Open-loop load generator for mixed-query serving benchmarks.

Open loop means arrivals are scheduled by a clock, not by completions: a
request that arrives while the engine is busy *waits*, and its measured
latency includes that queueing delay.  This is the honest way to measure a
service under a target offered load (closed-loop generators hide overload by
slowing down with the server).

The generator synthesizes a Zipf-skewed workload over the tenant's node
universe (matching the graph-stream setting: hot vertices are queried more),
batches whatever has arrived each time the engine frees up (up to
``batch_max``) and reports achieved QPS plus p50/p99/mean/max latency.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable

import numpy as np

from repro.serving import engine as eng
from repro.serving.snapshot import Snapshot


@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    """Relative weights of query families in the synthetic workload."""

    edge_freq: float = 0.55
    reach: float = 0.25
    node_out: float = 0.10
    path_weight: float = 0.05
    subgraph_weight: float = 0.03
    heavy_nodes: float = 0.02

    def normalized(self) -> dict[str, float]:
        pairs = dataclasses.asdict(self)
        total = sum(pairs.values())
        assert total > 0, "empty workload mix"
        return {k: v / total for k, v in pairs.items()}


def mix_for_sketch(kind: str) -> WorkloadMix:
    """Default workload for a sketch kind: Type I sketches (countmin,
    gsketch) cannot answer node/reach families, so their mix degrades to
    edge-level queries instead of erroring mid-benchmark."""
    if kind in ("countmin", "gsketch"):
        return WorkloadMix(edge_freq=0.8, reach=0.0, node_out=0.0,
                           path_weight=0.1, subgraph_weight=0.1,
                           heavy_nodes=0.0)
    return WorkloadMix()


def warm_bucket_ladder(engine, snapshot, requests, start: int = 16) -> None:
    """Compile the engine's power-of-two bucket ladder off the clock.

    Arrival batching produces batches of many sizes; walking doubling
    prefixes (plus one full-size batch) makes the measured run hit compiled
    buckets for every family."""
    size = start
    while size < len(requests):
        engine.execute(snapshot, requests[:size])
        size *= 2
    engine.execute(snapshot, requests)


def synth_requests(n: int, mix: WorkloadMix, *, n_nodes: int, seed: int = 0,
                   zipf_a: float = 1.2, path_len: int = 4,
                   subgraph_edges: int = 3, heavy_universe: int | None = None,
                   heavy_threshold: float = 100.0) -> list[eng.Request]:
    """Draw ``n`` requests with Zipf-skewed endpoints over ``[0, n_nodes)``."""
    rng = np.random.default_rng(seed)
    norm = mix.normalized()
    fams = list(norm)
    choice = rng.choice(len(fams), size=n, p=[norm[f] for f in fams])

    def node() -> int:
        return int(min(rng.zipf(zipf_a) - 1, n_nodes - 1))

    reqs: list[eng.Request] = []
    for c in choice:
        fam = fams[c]
        if fam == "edge_freq":
            reqs.append(eng.edge_freq(node(), node()))
        elif fam == "reach":
            reqs.append(eng.reach(node(), node()))
        elif fam == "node_out":
            reqs.append(eng.node_out(node()))
        elif fam == "path_weight":
            reqs.append(eng.path_weight([node() for _ in range(path_len)]))
        elif fam == "subgraph_weight":
            reqs.append(eng.subgraph_weight(
                [(node(), node()) for _ in range(subgraph_edges)]))
        else:
            reqs.append(eng.heavy_nodes(heavy_universe or n_nodes,
                                        heavy_threshold))
    return reqs


@dataclasses.dataclass
class LoadReport:
    n_requests: int
    duration_s: float
    offered_qps: float
    achieved_qps: float
    p50_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    n_batches: int
    family_counts: dict[str, int]

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d = {k: (round(v, 4) if isinstance(v, float) else v)
             for k, v in d.items()}
        return json.dumps(d)


class OpenLoopLoadGen:
    """Drives a QueryEngine at a target offered QPS."""

    def __init__(self, *, target_qps: float = 2000.0,
                 batch_max: int = 1024) -> None:
        self.target_qps = target_qps
        self.batch_max = batch_max

    def run(self, engine: eng.QueryEngine,
            snapshot_fn: Callable[[], Snapshot],
            requests: list[eng.Request],
            between_batches: Callable[[], None] | None = None) -> LoadReport:
        """Serve ``requests`` open-loop; latency includes queueing delay.

        ``snapshot_fn`` is polled per batch so a concurrently-publishing
        tenant hands new epochs to the engine mid-run; ``between_batches``
        (e.g. an ingest step) runs after each served batch — engine time
        spent there shows up as queueing latency, exactly as a co-located
        ingest loop would in production.
        """
        n = len(requests)
        interval = 1.0 / self.target_qps
        arrivals = np.arange(n) * interval
        latencies = np.zeros(n)
        family_counts: dict[str, int] = {}
        for r in requests:
            family_counts[r.family] = family_counts.get(r.family, 0) + 1

        t0 = time.perf_counter()
        served = 0
        n_batches = 0
        while served < n:
            now = time.perf_counter() - t0
            if arrivals[served] > now:
                time.sleep(min(arrivals[served] - now, 0.05))
                continue
            hi = served
            while hi < n and arrivals[hi] <= now and hi - served < self.batch_max:
                hi += 1
            batch = requests[served:hi]
            engine.execute(snapshot_fn(), batch)
            done = time.perf_counter() - t0
            latencies[served:hi] = done - arrivals[served:hi]
            served = hi
            n_batches += 1
            if between_batches is not None:
                between_batches()
        duration = time.perf_counter() - t0

        lat_ms = latencies * 1e3
        return LoadReport(
            n_requests=n,
            duration_s=duration,
            offered_qps=self.target_qps,
            achieved_qps=n / duration,
            p50_ms=float(np.percentile(lat_ms, 50)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            mean_ms=float(lat_ms.mean()),
            max_ms=float(lat_ms.max()),
            n_batches=n_batches,
            family_counts=family_counts,
        )
