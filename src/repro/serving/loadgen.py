"""Open-loop load generator for mixed-query serving benchmarks.

Open loop means arrivals are scheduled by a clock, not by completions: a
request that arrives while the engine is busy *waits*, and its measured
latency includes that queueing delay.  This is the honest way to measure a
service under a target offered load (closed-loop generators hide overload by
slowing down with the server).

The generator synthesizes a Zipf-skewed workload over the tenant's node
universe (matching the graph-stream setting: hot vertices are queried more),
batches whatever has arrived each time the engine frees up (up to
``batch_max``) and reports achieved QPS plus p50/p99/mean/max latency.

``NetLoadGen`` is the same open-loop discipline pointed at a
``repro.net.query_server.QueryServer`` over real TCP: ``connections``
client connections share one global arrival schedule round-robin, each
batching its own arrived-but-unsent requests per frame, and admission
rejections are counted as *shed* (with the server's retry-after hints
recorded) rather than folded into latency — overload shows up as an
accounted shed rate with bounded tail latency for admitted work, which is
exactly the claim the admission controller makes.  A connection whose
transport dies (reset, timeout) aborts its remainder into a separate
``aborted`` count with the exception surfaced in the report — client
failures never masquerade as server sheds.  Runnable as a CLI:
``python -m repro.serving.loadgen --connect HOST:PORT``.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable

import numpy as np

from repro.obs.hub import Histogram, hist_summary, merge_hist_states
from repro.serving import engine as eng
from repro.serving.snapshot import Snapshot


def _latency_summary_ms(hstate: dict) -> dict:
    """``hist_summary`` of a seconds-ladder state, rescaled to ms for the
    JSON report (counts stay counts; every value field becomes *_ms)."""
    s = hist_summary(hstate)
    return {k: (v if k == "count" else round(v * 1e3, 4))
            for k, v in s.items()}


@dataclasses.dataclass(frozen=True)
class WorkloadMix:
    """Relative weights of query families in the synthetic workload."""

    edge_freq: float = 0.55
    reach: float = 0.25
    node_out: float = 0.10
    path_weight: float = 0.05
    subgraph_weight: float = 0.03
    heavy_nodes: float = 0.02

    def normalized(self) -> dict[str, float]:
        pairs = dataclasses.asdict(self)
        total = sum(pairs.values())
        assert total > 0, "empty workload mix"
        return {k: v / total for k, v in pairs.items()}


def mix_for_sketch(kind: str) -> WorkloadMix:
    """Default workload for a sketch kind: Type I sketches (countmin,
    gsketch) cannot answer node/reach families, so their mix degrades to
    edge-level queries instead of erroring mid-benchmark."""
    if kind in ("countmin", "gsketch"):
        return WorkloadMix(edge_freq=0.8, reach=0.0, node_out=0.0,
                           path_weight=0.1, subgraph_weight=0.1,
                           heavy_nodes=0.0)
    return WorkloadMix()


def warm_bucket_ladder(engine, snapshot, requests, start: int = 16) -> None:
    """Compile the engine's power-of-two bucket ladder off the clock.

    Arrival batching produces batches of many sizes; walking doubling
    prefixes (plus one full-size batch) makes the measured run hit compiled
    buckets for every family."""
    size = start
    while size < len(requests):
        engine.execute(snapshot, requests[:size])
        size *= 2
    engine.execute(snapshot, requests)


def synth_requests(n: int, mix: WorkloadMix, *, n_nodes: int, seed: int = 0,
                   zipf_a: float = 1.2, path_len: int = 4,
                   subgraph_edges: int = 3, heavy_universe: int | None = None,
                   heavy_threshold: float = 100.0) -> list[eng.Request]:
    """Draw ``n`` requests with Zipf-skewed endpoints over ``[0, n_nodes)``."""
    rng = np.random.default_rng(seed)
    norm = mix.normalized()
    fams = list(norm)
    choice = rng.choice(len(fams), size=n, p=[norm[f] for f in fams])

    def node() -> int:
        return int(min(rng.zipf(zipf_a) - 1, n_nodes - 1))

    reqs: list[eng.Request] = []
    for c in choice:
        fam = fams[c]
        if fam == "edge_freq":
            reqs.append(eng.edge_freq(node(), node()))
        elif fam == "reach":
            reqs.append(eng.reach(node(), node()))
        elif fam == "node_out":
            reqs.append(eng.node_out(node()))
        elif fam == "path_weight":
            reqs.append(eng.path_weight([node() for _ in range(path_len)]))
        elif fam == "subgraph_weight":
            reqs.append(eng.subgraph_weight(
                [(node(), node()) for _ in range(subgraph_edges)]))
        else:
            reqs.append(eng.heavy_nodes(heavy_universe or n_nodes,
                                        heavy_threshold))
    return reqs


@dataclasses.dataclass
class LoadReport:
    n_requests: int
    duration_s: float
    offered_qps: float
    achieved_qps: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    p999_ms: float
    mean_ms: float
    max_ms: float
    n_batches: int
    family_counts: dict[str, int]
    # summary of the mergeable log-bucket histogram the latencies were also
    # fed through (repro.obs.hub ladder "latency"); p* here are bucket-
    # interpolated, the raw-array percentiles above stay exact
    latency_hist: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(_round_floats(dataclasses.asdict(self)))


def _round_floats(d):
    if isinstance(d, float):
        return round(d, 4)
    if isinstance(d, dict):
        return {k: _round_floats(v) for k, v in d.items()}
    return d


class OpenLoopLoadGen:
    """Drives a QueryEngine at a target offered QPS."""

    def __init__(self, *, target_qps: float = 2000.0,
                 batch_max: int = 1024) -> None:
        self.target_qps = target_qps
        self.batch_max = batch_max

    def run(self, engine: eng.QueryEngine,
            snapshot_fn: Callable[[], Snapshot],
            requests: list[eng.Request],
            between_batches: Callable[[], None] | None = None) -> LoadReport:
        """Serve ``requests`` open-loop; latency includes queueing delay.

        ``snapshot_fn`` is polled per batch so a concurrently-publishing
        tenant hands new epochs to the engine mid-run; ``between_batches``
        (e.g. an ingest step) runs after each served batch — engine time
        spent there shows up as queueing latency, exactly as a co-located
        ingest loop would in production.
        """
        n = len(requests)
        interval = 1.0 / self.target_qps
        arrivals = np.arange(n) * interval
        latencies = np.zeros(n)
        family_counts: dict[str, int] = {}
        for r in requests:
            family_counts[r.family] = family_counts.get(r.family, 0) + 1

        t0 = time.perf_counter()
        served = 0
        n_batches = 0
        while served < n:
            now = time.perf_counter() - t0
            if arrivals[served] > now:
                time.sleep(min(arrivals[served] - now, 0.05))
                continue
            hi = served
            while hi < n and arrivals[hi] <= now and hi - served < self.batch_max:
                hi += 1
            batch = requests[served:hi]
            engine.execute(snapshot_fn(), batch)
            done = time.perf_counter() - t0
            latencies[served:hi] = done - arrivals[served:hi]
            served = hi
            n_batches += 1
            if between_batches is not None:
                between_batches()
        duration = time.perf_counter() - t0

        # feed the same latencies (seconds) through a mergeable log-bucket
        # histogram so the report carries a state other runs can sum with
        hist = Histogram("loadgen_latency_seconds", {})
        hist.observe_many(latencies)
        hstate = hist.state()

        lat_ms = latencies * 1e3
        return LoadReport(
            n_requests=n,
            duration_s=duration,
            offered_qps=self.target_qps,
            achieved_qps=n / duration,
            p50_ms=float(np.percentile(lat_ms, 50)),
            p90_ms=float(np.percentile(lat_ms, 90)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            p999_ms=float(np.percentile(lat_ms, 99.9)),
            mean_ms=float(lat_ms.mean()),
            max_ms=float(lat_ms.max()),
            n_batches=n_batches,
            family_counts=family_counts,
            latency_hist=_latency_summary_ms(hstate),
        )


# ------------------------------------------------------------ network mode --


@dataclasses.dataclass
class NetLoadReport:
    """Open-loop report for a run against a network query server."""

    n_requests: int
    accepted: int
    shed: int
    shed_rate: float  # shed / offered — the accounted overload signal
    errors: int
    # transport casualties are NOT sheds: a connection that died (reset,
    # timeout) aborts its unsent/unanswered remainder, accounted here so a
    # client-side failure can't masquerade as server admission control
    aborted: int
    transport_error: str | None
    connections: int
    duration_s: float
    offered_qps: float
    achieved_qps: float  # accepted / duration
    p50_ms: float
    p90_ms: float
    p99_ms: float
    p999_ms: float
    mean_ms: float
    max_ms: float
    n_batches: int
    mean_retry_after_ms: float
    last_epoch: int | None  # freshest epoch stamped on any answer
    # per-connection log-bucket histograms merged parent-side — the same
    # exact-sum merge the obs tier uses across workers, so per-connection
    # latency distributions compose without shipping raw samples
    latency_hist: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(_round_floats(dataclasses.asdict(self)))


class NetLoadGen:
    """Multi-connection open-loop load against a TCP query server.

    One global arrival clock, ``connections`` concurrent client
    connections taking requests round-robin — so the *offered* load is
    connection-count-invariant and connection count only changes how much
    concurrency the server sees.  Latency (arrival→answer, queueing
    included) is measured for ACCEPTED requests; rejections count as shed.
    """

    def __init__(self, *, target_qps: float = 500.0, connections: int = 4,
                 batch_max: int = 64, tenant: str = "default",
                 auth_token: str | None = None) -> None:
        assert connections >= 1
        self.target_qps = target_qps
        self.connections = connections
        self.batch_max = batch_max
        self.tenant = tenant
        self.auth_token = auth_token

    def run(self, address: tuple[str, int],
            requests: list[eng.Request]) -> NetLoadReport:
        from repro.net import wire
        from repro.net.query_server import QueryClient

        n = len(requests)
        interval = 1.0 / self.target_qps
        arrivals = np.arange(n) * interval
        lat_ms = np.full(n, np.nan)
        accepted = np.zeros(n, dtype=bool)
        errored = np.zeros(n, dtype=bool)
        aborted = np.zeros(n, dtype=bool)
        retry_hints: list[float] = []
        transport_errors: list[str] = []
        batches = [0]
        last_epoch: list[int | None] = [None]
        lock = threading.Lock()
        t0 = [0.0]
        # one mergeable histogram per connection; merged after the join so
        # the report's distribution is the exact sum of per-connection ones
        conn_hists = [Histogram(f"conn{c}_latency_seconds", {})
                      for c in range(self.connections)]

        def connection_loop(conn_idx: int) -> None:
            mine = list(range(conn_idx, n, self.connections))
            hist = conn_hists[conn_idx]
            served = 0
            client = None
            try:
                client = QueryClient(address, tenant=self.tenant,
                                     auth_token=self.auth_token)
                while served < len(mine):
                    now = time.perf_counter() - t0[0]
                    first = arrivals[mine[served]]
                    if first > now:
                        time.sleep(min(first - now, 0.02))
                        continue
                    hi = served
                    while (hi < len(mine) and arrivals[mine[hi]] <= now
                           and hi - served < self.batch_max):
                        hi += 1
                    idx = mine[served:hi]
                    payload = client.call([requests[i] for i in idx])
                    done = time.perf_counter() - t0[0]
                    with lock:
                        batches[0] += 1
                        if payload["kind"] == "result":
                            accepted[idx] = True
                            lat_ms[idx] = (done - arrivals[idx]) * 1e3
                            hist.observe_many(done - arrivals[idx])
                            if payload["epoch"] is not None:
                                last_epoch[0] = max(
                                    last_epoch[0] or 0, payload["epoch"])
                        elif payload["kind"] == "reject":
                            retry_hints.append(payload["retry_after_ms"])
                        else:  # server-side error: accounted, not shed
                            errored[idx] = True
                    served = hi
            except (ConnectionError, TimeoutError, OSError,
                    wire.WireError) as exc:
                # the transport died, not the server's admission control:
                # the in-flight batch and the unsent remainder are aborted,
                # never folded into the shed count
                with lock:
                    transport_errors.append(repr(exc))
                    if mine[served:]:
                        aborted[mine[served:]] = True
            finally:
                if client is not None:
                    client.close()

        threads = [threading.Thread(target=connection_loop, args=(c,),
                                    daemon=True, name=f"loadgen-conn-{c}")
                   for c in range(self.connections)]
        t0[0] = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        duration = time.perf_counter() - t0[0]

        ok = lat_ms[accepted]
        n_acc = int(accepted.sum())
        n_err = int(errored.sum())
        n_abort = int(aborted.sum())
        shed = n - n_acc - n_err - n_abort
        merged = conn_hists[0].state()
        for h in conn_hists[1:]:
            merged = merge_hist_states(merged, h.state())
        return NetLoadReport(
            n_requests=n,
            accepted=n_acc,
            shed=shed,
            shed_rate=shed / n if n else 0.0,
            errors=n_err,
            aborted=n_abort,
            transport_error=transport_errors[0] if transport_errors else None,
            connections=self.connections,
            duration_s=duration,
            offered_qps=self.target_qps,
            achieved_qps=n_acc / duration if duration > 0 else 0.0,
            p50_ms=float(np.percentile(ok, 50)) if n_acc else float("nan"),
            p90_ms=float(np.percentile(ok, 90)) if n_acc else float("nan"),
            p99_ms=float(np.percentile(ok, 99)) if n_acc else float("nan"),
            p999_ms=(float(np.percentile(ok, 99.9))
                     if n_acc else float("nan")),
            mean_ms=float(ok.mean()) if n_acc else float("nan"),
            max_ms=float(ok.max()) if n_acc else float("nan"),
            n_batches=batches[0],
            mean_retry_after_ms=(float(np.mean(retry_hints))
                                 if retry_hints else 0.0),
            last_epoch=last_epoch[0],
            latency_hist=_latency_summary_ms(merged),
        )


def main(argv: list[str] | None = None) -> int:
    """CLI client: load a remote query server (README §Network quickstart)."""
    import argparse

    from repro.net import wire

    p = argparse.ArgumentParser(
        description="open-loop load generator for a repro.net query server")
    p.add_argument("--connect", required=True, metavar="HOST:PORT")
    p.add_argument("--qps", type=float, default=500.0)
    p.add_argument("--n-requests", type=int, default=2000)
    p.add_argument("--connections", type=int, default=4)
    p.add_argument("--batch-max", type=int, default=64)
    p.add_argument("--tenant", default="default")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--auth-token", default="",
                   help="shared token for a remote server "
                        "(default: $KMATRIX_NET_TOKEN)")
    args = p.parse_args(argv)

    from repro.net.query_server import QueryClient

    address = wire.parse_hostport(args.connect)
    probe = QueryClient(address, tenant=args.tenant,
                        auth_token=args.auth_token or None)
    info = probe.info()
    probe.close()
    n_nodes = int(info.get("n_nodes", 0)) or 1024
    mix = mix_for_sketch(str(info.get("kind", "kmatrix")))
    requests = synth_requests(args.n_requests, mix, n_nodes=n_nodes,
                              seed=args.seed, heavy_universe=256,
                              heavy_threshold=5.0)
    gen = NetLoadGen(target_qps=args.qps, connections=args.connections,
                     batch_max=args.batch_max, tenant=args.tenant,
                     auth_token=args.auth_token or None)
    report = gen.run(address, requests)
    print(report.to_json())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
