"""Multi-tenant sketch registry: one live-ingesting sketch per tenant key.

A *tenant* is one (dataset, sketch kind, budget, seed) combination — the unit
of isolation for the always-on query service.  The registry owns, per tenant:

  * the seekable stream (batch i is a pure function of (seed, i)),
  * the bootstrap sample -> VertexStats -> partition plan,
  * the ingest loop position (next unread batch), and
  * the ``SnapshotBuffer`` holding the live delta + published snapshot.

``launch/query_serve.py`` and ``benchmarks/serve_bench.py`` drive tenants by
alternating ``tenant.step(n)`` (ingest) with engine query batches against
``tenant.snapshot`` — the double buffer guarantees the queries stay
epoch-consistent while ingest runs.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterator

from repro.core import (
    CountMin,
    GSketch,
    KMatrix,
    KMatrixAccel,
    MatrixSketch,
    vertex_stats_from_sample,
)
from repro.core import sketch_backend as resolve_sketch_backend
from repro.core import countmin, gsketch, kmatrix, kmatrix_accel, matrix_sketch
from repro.serving.snapshot import Snapshot, SnapshotBuffer
from repro.streams import make_stream, sample_stream

SKETCHES = {
    "countmin": (CountMin, countmin),
    "gsketch": (GSketch, gsketch),
    "tcm": (MatrixSketch, matrix_sketch),
    "gmatrix": (MatrixSketch, matrix_sketch),
    "kmatrix": (KMatrix, kmatrix),
}


def build_sketch(name: str, budget: int, stats, depth: int, seed: int,
                 partitioner: str = "banded", backend: str | None = None):
    """Construct any sketch kind from a byte budget (+ stats if partitioned).

    For ``kmatrix`` the physical layout is a *backend* choice
    (``sketch_backend``: arg > $REPRO_SKETCH_BACKEND > platform default):
    ``pallas`` builds the width-class ``KMatrixAccel`` whose ingest runs the
    MXU kernel, ``flat`` the classic flat-pool scatter ``KMatrix``.  Every
    layer above (snapshots, workers, engine, checkpoints) is
    layout-agnostic, dispatching on the returned module.
    """
    cls, mod = SKETCHES[name]
    if name == "countmin":
        return cls.create(bytes_budget=budget, depth=depth, seed=seed), mod
    if name in ("tcm", "gmatrix"):
        return cls.create(bytes_budget=budget, depth=depth, seed=seed,
                          kind=name), mod
    if name == "gsketch":
        return cls.create(bytes_budget=budget, stats=stats, depth=depth,
                          seed=seed), mod
    if resolve_sketch_backend(backend) == "pallas":
        return KMatrixAccel.create(
            bytes_budget=budget, stats=stats, depth=depth, seed=seed,
            partitioner=partitioner), kmatrix_accel
    return cls.create(bytes_budget=budget, stats=stats, depth=depth,
                      seed=seed, partitioner=partitioner), mod


@dataclasses.dataclass(frozen=True)
class TenantKey:
    dataset: str
    kind: str
    budget_kb: int
    seed: int = 0

    @property
    def tenant_id(self) -> str:
        return f"{self.dataset}/{self.kind}/{self.budget_kb}kb/s{self.seed}"


@dataclasses.dataclass(frozen=True)
class TenantOrigin:  # wire-type
    """How to rebuild a registry-opened tenant from scratch, anywhere.

    Tenant construction is deterministic — stream, bootstrap sample,
    partition plan and hash family are all pure functions of the registry
    config + the open() arguments — so this small picklable spec is enough
    for another address space (the process execution backend's spawn-safe
    children, ``runtime/backend.py``) to rebuild a tenant with the
    *identical* sketch layout, making shipped counter pytrees loadable
    leaf-for-leaf on either side.
    """

    registry: dict  # SketchRegistry(**registry) reproduces the config
    dataset: str
    kind: str
    budget_kb: int
    seed: int = 0
    # set only for shard tenants (one shard of an open_sharded tenant)
    n_shards: int | None = None
    shard_seed: int | None = None
    shard_index: int | None = None

    def rebuild(self) -> "Tenant":
        reg = SketchRegistry(**self.registry)
        if self.n_shards is None:
            return reg.open(self.dataset, self.kind, self.budget_kb,
                            seed=self.seed)
        sharded = reg.open_sharded(self.dataset, self.kind, self.budget_kb,
                                   seed=self.seed, n_shards=self.n_shards,
                                   shard_seed=self.shard_seed)
        return sharded.shards[self.shard_index]


class Tenant:
    """One registered sketch + its stream position + snapshot buffer.

    ``offset``/``step`` are owned by exactly one ingest driver at a time:
    either the cooperative caller of ``step()`` or (exclusively) a
    ``repro.runtime`` worker thread.  ``snapshot`` is safe to read from any
    thread at any time (immutable reference swap).
    """

    def __init__(self, key: TenantKey, stream, buffer: SnapshotBuffer,
                 mod) -> None:
        self.key = key
        self.stream = stream
        self.buffer = buffer
        self.mod = mod
        self.offset = 0  # next stream batch to ingest
        # rebuild spec stamped by the registry (None for hand-built tenants;
        # the process execution backend requires it)
        self.origin: TenantOrigin | None = None

    @property
    def snapshot(self) -> Snapshot:
        return self.buffer.snapshot

    @property
    def epoch(self) -> int:
        return self.buffer.epoch

    @property
    def exhausted(self) -> bool:
        return self.offset >= self.stream.num_batches

    def step(self, n_batches: int = 1) -> int:
        """Ingest up to ``n_batches`` more stream batches into the live delta.

        Returns the number actually consumed (0 once the stream is drained).
        """
        done = 0
        while done < n_batches and not self.exhausted:
            self.buffer.ingest(self.stream.batch(self.offset))
            self.offset += 1
            done += 1
        return done

    def publish(self) -> Snapshot:
        return self.buffer.publish()


class SketchRegistry:
    """Registry of live tenants, keyed by (dataset, kind, budget, seed)."""

    def __init__(self, *, depth: int = 5, batch_size: int = 8192,
                 sample_size: int = 30_000, scale: float = 1.0,
                 partitioner: str = "banded",
                 sketch_backend: str | None = None) -> None:
        self.depth = depth
        self.batch_size = batch_size
        self.sample_size = sample_size
        self.scale = scale
        self.partitioner = partitioner
        # resolved once at registry build, not per tenant open: a registry
        # whose tenants straddle two layouts would break merge/restore
        # interchange assumptions downstream
        self.sketch_backend = resolve_sketch_backend(sketch_backend)
        self._tenants: dict[TenantKey, Tenant] = {}
        self._sharded: dict = {}  # (key, n_shards, shard_seed) -> ShardedTenant
        # get-or-create must be atomic once background workers can race
        # opens: two tenants for one key would double-ingest the stream
        self._lock = threading.Lock()

    def config(self) -> dict:
        """The constructor kwargs that reproduce this registry (all plain
        picklable values; ``sketch_backend`` ships resolved so a rebuild on
        a different platform still picks the same layout)."""
        return {
            "depth": self.depth,
            "batch_size": self.batch_size,
            "sample_size": self.sample_size,
            "scale": self.scale,
            "partitioner": self.partitioner,
            "sketch_backend": self.sketch_backend,
        }

    def open(self, dataset: str, kind: str, budget_kb: int,
             seed: int = 0) -> Tenant:
        """Get-or-create the tenant for a key (idempotent, thread-safe)."""
        key = TenantKey(dataset, kind, budget_kb, seed)
        with self._lock:
            if key in self._tenants:
                return self._tenants[key]
        stream = make_stream(dataset, batch_size=self.batch_size, seed=seed,
                             scale=self.scale)
        # Paper §V-A: a reservoir sample of the stream bootstraps the
        # partitioner before any counter is allocated.
        n_sample = max(int(self.sample_size * self.scale), 1000)
        ssrc, sdst, sw = sample_stream(stream, n_sample, seed=seed + 1)
        stats = vertex_stats_from_sample(ssrc, sdst, sw)
        sketch, mod = build_sketch(kind, budget_kb * 1024, stats, self.depth,
                                   seed, self.partitioner,
                                   backend=self.sketch_backend)
        with self._lock:
            if key in self._tenants:  # lost the build race; first one wins
                return self._tenants[key]
            buffer = SnapshotBuffer(sketch, mod, tenant_id=key.tenant_id,
                                    kind=kind)
            tenant = Tenant(key, stream, buffer, mod)
            tenant.origin = TenantOrigin(self.config(), dataset, kind,
                                         budget_kb, seed)
            self._tenants[key] = tenant
            return tenant

    def open_sharded(self, dataset: str, kind: str, budget_kb: int,
                     seed: int = 0, *, n_shards: int, shard_seed: int = 0):
        """Get-or-create a ``ShardedTenant``: K shard tenants over ONE layout.

        The master sketch is built exactly like ``open`` would build it
        (same stream, same bootstrap sample, same partition plan and hash
        family) and every shard gets an ``empty_like`` clone — that shared
        layout is what makes the merge of the shards bit-identical to an
        unsharded ingest of the same stream (DESIGN.md §Sharding).  Each
        shard's stream is a ``ShardStreamView`` filtering the base stream by
        the ``ShardPlan`` hash band of the source vertex.
        """
        from repro.core.partitioning import ShardPlan
        from repro.serving.sharding import (ShardKey, ShardStreamView,
                                            ShardedTenant)

        key = TenantKey(dataset, kind, budget_kb, seed)
        skey = (key, n_shards, shard_seed)
        with self._lock:
            if skey in self._sharded:
                return self._sharded[skey]
        stream = make_stream(dataset, batch_size=self.batch_size, seed=seed,
                             scale=self.scale)
        n_sample = max(int(self.sample_size * self.scale), 1000)
        ssrc, sdst, sw = sample_stream(stream, n_sample, seed=seed + 1)
        stats = vertex_stats_from_sample(ssrc, sdst, sw)
        sketch, mod = build_sketch(kind, budget_kb * 1024, stats, self.depth,
                                   seed, self.partitioner,
                                   backend=self.sketch_backend)
        plan = ShardPlan(n_shards, seed=shard_seed)
        shards = []
        for s in range(n_shards):
            shard_key = ShardKey(key, s, n_shards)
            view = ShardStreamView(stream, plan, s)
            buffer = SnapshotBuffer(mod.empty_like(sketch), mod,
                                    tenant_id=shard_key.tenant_id, kind=kind)
            shard = Tenant(shard_key, view, buffer, mod)
            shard.origin = TenantOrigin(self.config(), dataset, kind,
                                        budget_kb, seed, n_shards=n_shards,
                                        shard_seed=shard_seed, shard_index=s)
            shards.append(shard)
        tenant = ShardedTenant(key, plan, shards, mod)
        with self._lock:
            if skey in self._sharded:  # lost the build race; first one wins
                return self._sharded[skey]
            self._sharded[skey] = tenant
            return tenant

    def get(self, key: TenantKey) -> Tenant:
        return self._tenants[key]

    def __contains__(self, key: TenantKey) -> bool:
        return key in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    def tenants(self) -> Iterator[Tenant]:
        return iter(self._tenants.values())

    def step_all(self, n_batches: int = 1) -> int:
        """Advance every tenant's ingest loop; returns total batches consumed."""
        return sum(t.step(n_batches) for t in self.tenants())

    def publish_all(self) -> list[Snapshot]:
        return [t.publish() for t in self.tenants()]
