"""Sharded serving: hash-band edge routing over K independent sketch shards.

The scale-out layer (DESIGN.md §Sharding).  A ``ShardPlan``
(``core.partitioning``) deterministically owns every edge by a hash band of
its SOURCE vertex; each shard is a full ``Tenant`` — its own
``SnapshotBuffer`` over an ``empty_like`` clone of ONE master sketch (same
layout, partition plan and hash family), fed by a ``ShardStreamView`` that
filters the seekable base stream down to the shard's edges.  Because the
shards partition the stream and share a layout:

  * ingest parallelizes: one ``repro.runtime`` queue + worker per shard
    (``attach_shards``), each publishing epochs independently;
  * the merge of all shard sketches is bit-identical to a single sketch
    that ingested the whole stream (counter additivity over a stream
    partition) — ``merged_snapshot`` is the gate `serve_bench --shards`
    hard-fails on;
  * queries scatter/gather (``ShardedQueryEngine``): edge-frequency and
    node-out route to the owning shard alone (all out-edges of a vertex
    live there), node-in / path / subgraph decompose per edge pair and sum,
    reachability builds ONE closure over the summed per-shard connectivity
    layers (bit-identical to the unsharded closure), and heavy-node sweeps
    keep each vertex's score from its owning shard.  Closures are cached
    under the per-shard epoch VECTOR — any shard publishing invalidates.

Checkpoints stay per-shard (each shard tenant has its own id, offset and
store directory); ``write_shard_manifest`` records the shard topology next
to them so a restore can rebuild — and validate — the same plan.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import tempfile
import time

import numpy as np
import jax.numpy as jnp

from repro.core import queries
from repro.core.partitioning import ShardPlan
from repro.core.types import EdgeBatch
from repro.serving import engine as eng
from repro.serving.registry import Tenant, TenantKey
from repro.serving.snapshot import Snapshot


@dataclasses.dataclass(frozen=True)
class ShardKey:
    """Identity of one shard of a sharded tenant (quacks like TenantKey)."""

    base: TenantKey
    shard: int
    n_shards: int

    @property
    def tenant_id(self) -> str:
        return f"{self.base.tenant_id}/shard{self.shard}of{self.n_shards}"

    @property
    def dataset(self) -> str:
        return self.base.dataset

    @property
    def kind(self) -> str:
        return self.base.kind

    @property
    def budget_kb(self) -> int:
        return self.base.budget_kb

    @property
    def seed(self) -> int:
        # distinct per shard so per-shard reservoirs draw independent coins
        return self.base.seed ^ (self.shard * 0x9E3779B1)


class ShardStreamView:
    """Shard ``shard``'s deterministic slice of a seekable base stream.

    Batch ``i`` contains exactly the base batch's non-padding edges whose
    source routes to this shard (``plan.shard_of``), compacted and
    zero-padded up to a bucket from a coarse ladder: multiples of
    ``granule = max(min_bucket, base_batch // 4)``.  The ladder keeps the
    per-shard ingest jit cache to a handful of shapes (a power-of-two
    ladder at shard loads near a boundary alternates shapes every batch and
    turns the ingest wall into XLA recompiles), and because a shard's load
    share is roughly stationary, steady state hits ONE bucket.  Same
    replayability contract as the base: batch ``i`` is a pure function of
    ``(base, plan, shard, i)``, so per-shard checkpoint/restore replays
    bit-exactly.  ``spec`` passes through — note its ``n_edges`` is the
    FULL stream count; cross-shard accounting sums per-shard totals
    against it.
    """

    def __init__(self, base, plan: ShardPlan, shard: int, *,
                 min_bucket: int = 256) -> None:
        if not (0 <= shard < plan.n_shards):
            raise ValueError(f"shard {shard} out of range for {plan}")
        self.base = base
        self.plan = plan
        self.shard = shard
        self.min_bucket = min_bucket
        self.granule = max(min_bucket,
                           getattr(base, "batch_size", min_bucket) // 4)

    @property
    def spec(self):
        return self.base.spec

    @property
    def num_batches(self) -> int:
        return self.base.num_batches

    def batch_numpy(self, i: int):
        src, dst, w = self.base.batch_numpy(i)
        own = (w > 0) & (self.plan.shard_of(src) == self.shard)
        n = int(own.sum())
        bucket = max(self.granule, -(-n // self.granule) * self.granule)
        s = np.zeros(bucket, np.int32)
        d = np.zeros(bucket, np.int32)
        ww = np.zeros(bucket, np.int32)
        s[:n], d[:n], ww[:n] = src[own], dst[own], w[own]
        return s, d, ww

    def batch(self, i: int) -> EdgeBatch:
        return EdgeBatch.from_numpy(*self.batch_numpy(i))

    def iter_from(self, offset: int):
        for i in range(offset, self.num_batches):
            yield i, self.batch(i)


@dataclasses.dataclass(frozen=True)
class ShardedSnapshot:
    """Immutable gather of one Snapshot reference per shard.

    Each part is individually consistent (snapshot isolation per shard);
    the gather is NOT a cross-shard atomic cut — shards publish
    independently, so ``epochs`` is a vector, and every result batch is
    stamped with the vector observed at planning time.
    """

    tenant_id: str
    plan: ShardPlan
    parts: tuple  # tuple[Snapshot, ...], len == plan.n_shards

    @property
    def epochs(self) -> tuple:
        return tuple(p.epoch for p in self.parts)

    @property
    def n_edges(self) -> int:
        return sum(p.n_edges for p in self.parts)

    @property
    def kind(self) -> str:
        return self.parts[0].kind

    def __repr__(self) -> str:
        return (f"ShardedSnapshot({self.tenant_id!r}, "
                f"epochs={self.epochs}, n_edges={self.n_edges})")


class ShardedTenant:
    """K shard ``Tenant``s sharing one layout, plus the routing plan."""

    def __init__(self, key: TenantKey, plan: ShardPlan,
                 shards: list[Tenant], mod) -> None:
        self.key = key
        self.plan = plan
        self.shards = shards
        self.mod = mod

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def stream(self):
        """The (unsharded) base stream; per-shard views live on the shards."""
        return self.shards[0].stream.base

    @property
    def snapshot(self) -> ShardedSnapshot:
        return ShardedSnapshot(
            tenant_id=self.key.tenant_id,
            plan=self.plan,
            parts=tuple(s.snapshot for s in self.shards),
        )

    @property
    def epochs(self) -> tuple:
        return tuple(s.epoch for s in self.shards)

    @property
    def exhausted(self) -> bool:
        return all(s.exhausted for s in self.shards)

    def step(self, n_batches: int = 1) -> int:
        """Cooperative ingest: advance every shard by up to ``n_batches``."""
        return sum(s.step(n_batches) for s in self.shards)

    def publish(self) -> ShardedSnapshot:
        for s in self.shards:
            s.publish()
        return self.snapshot

    def merged_snapshot(self) -> Snapshot:
        """One Snapshot holding the merge of all shard fronts.

        By the routing invariant this equals a single sketch that ingested
        the whole published prefix — the sharded-vs-unsharded exactness
        gate queries it through ``engine.direct_answers``.  Synthetic view:
        its scalar epoch cannot encode the epoch vector, so do NOT serve it
        through a closure-caching engine.
        """
        snap = self.snapshot
        sk = functools.reduce(self.mod.merge, [p.sketch for p in snap.parts])
        return Snapshot(
            tenant_id=f"{self.key.tenant_id}/merged",
            epoch=max(snap.epochs),
            sketch=sk,
            kind=snap.kind,
            n_edges=snap.n_edges,
        )


# ---------------------------------------------------------------- engine --

class ShardedQueryEngine:
    """Scatter/gather planner over a ``ShardedSnapshot``.

    Delegates every per-shard sub-batch to ONE inner ``QueryEngine`` (so
    padding, bucket ladders, jit caches and the per-(shard, epoch) closure
    cache are all shared), and owns only the cross-shard composition:

      edge_freq / node_out  ->  owning shard (routing invariant)
      node_in               ->  sum of per-shard estimates (a vertex's
                                in-edges are scattered across shards)
      path / subgraph       ->  pairs grouped by owning shard of each
                                pair's source; per-shard masked sums added
      reach                 ->  one closure over the SUM of per-shard
                                connectivity layers — bit-identical to the
                                unsharded closure by counter additivity —
                                cached under the epoch VECTOR
      heavy_nodes           ->  per-shard sweeps; each vertex keeps its
                                owning shard's score; union, sorted by id

    Exact by construction: ``sharded_direct_answers`` computes the same
    composition through the module-level query functions, and
    tests/serve_bench hard-gate equality.
    """

    def __init__(self, engine: eng.QueryEngine | None = None,
                 closure_capacity: int = 8) -> None:
        self.engine = engine or eng.QueryEngine()
        # separate instance from the inner engine's per-shard cache: keys
        # here are epoch VECTORS over all shards, and mixing them with
        # per-shard entries would let one evict the other prematurely
        self.closures = eng.ClosureCache(closure_capacity)

    # -------------------------------------------------------------- closure
    def _closure(self, ssnap: ShardedSnapshot, max_hops: int | None):
        key = (tuple(p.tenant_id for p in ssnap.parts), ssnap.epochs,
               max_hops)

        def build():
            layers = functools.reduce(
                jnp.add,
                [queries.closure_layers(p.sketch) for p in ssnap.parts])
            return queries.build_closure(layers, max_hops)

        return self.closures.get_or_build(key, build)

    # -------------------------------------------------------------- execute
    def execute(self, ssnap: ShardedSnapshot,
                requests: list[eng.Request]) -> list[eng.Result]:
        """Answer ``requests`` against one sharded snapshot gather.

        Results are stamped with the epoch vector observed at planning time
        (one consistent stamp per batch, mirroring the unsharded engine's
        single-epoch stamp).
        """
        plan = ssnap.plan
        k = plan.n_shards
        epochs = ssnap.epochs
        values: list = [None] * len(requests)

        # scatter: per-shard sub-requests + how to fold each answer back
        shard_reqs: list[list[eng.Request]] = [[] for _ in range(k)]
        shard_fold: list[list[tuple[str, int]]] = [[] for _ in range(k)]
        reach_groups: dict[int | None, list[int]] = {}
        heavy_idxs: list[int] = []

        for i, r in enumerate(requests):
            if r.family == eng.EDGE_FREQ:
                s = plan.shard_of_one(r.src)
                shard_reqs[s].append(r)
                shard_fold[s].append(("set", i))
            elif r.family == eng.NODE_OUT:
                s = plan.shard_of_one(r.node)
                shard_reqs[s].append(r)
                shard_fold[s].append(("set", i))
            elif r.family == eng.NODE_IN:
                values[i] = 0
                for s in range(k):
                    shard_reqs[s].append(r)
                    shard_fold[s].append(("add", i))
            elif r.family in (eng.PATH_WEIGHT, eng.SUBGRAPH_WEIGHT):
                if r.family == eng.PATH_WEIGHT:
                    pairs = list(zip(r.nodes[:-1], r.nodes[1:]))
                else:
                    pairs = list(r.edges)
                values[i] = 0
                owners = plan.shard_of(
                    np.asarray([p[0] for p in pairs], np.int64))
                for s in sorted(set(int(o) for o in owners)):
                    sub = [p for p, o in zip(pairs, owners) if int(o) == s]
                    shard_reqs[s].append(eng.subgraph_weight(sub))
                    shard_fold[s].append(("add", i))
            elif r.family == eng.REACH:
                reach_groups.setdefault(r.max_hops, []).append(i)
            elif r.family == eng.HEAVY_NODES:
                heavy_idxs.append(i)
            else:
                raise ValueError(f"unknown family {r.family!r}")

        # gather: one inner-engine batch per shard
        for s in range(k):
            if not shard_reqs[s]:
                continue
            res = self.engine.execute(ssnap.parts[s], shard_reqs[s])
            for (op, i), r in zip(shard_fold[s], res):
                if op == "set":
                    values[i] = r.value
                else:
                    values[i] += r.value

        # reachability against the merged-connectivity closure
        for max_hops, group in reach_groups.items():
            closure = self._closure(ssnap, max_hops)
            sk0 = ssnap.parts[0].sketch
            # split oversized groups like the inner engine's planner does
            for lo in range(0, len(group), self.engine.max_bucket):
                idxs = group[lo:lo + self.engine.max_bucket]
                n = len(idxs)
                b = eng._bucket(n, self.engine.min_bucket,
                                self.engine.max_bucket)
                src = self.engine._pad([requests[i].src for i in idxs], b)
                dst = self.engine._pad([requests[i].dst for i in idxs], b)
                hi = queries.reach_cells(sk0, src)
                hj = queries.reach_cells(sk0, dst)
                out = np.asarray(self.engine._jitted(
                    queries.reachability_from_closure)(closure, hi, hj))[:n]
                for j, i in enumerate(idxs):
                    values[i] = bool(out[j])

        # heavy nodes: per-shard sweeps, each vertex scored by its owner
        unique: dict[tuple, tuple] = {}
        for i in heavy_idxs:
            r = requests[i]
            qkey = (r.universe, r.threshold)
            if qkey not in unique:
                ids_parts, freq_parts = [], []
                for s in range(k):
                    ids, freqs = self.engine.execute(
                        ssnap.parts[s], [r])[0].value
                    own = plan.shard_of(np.asarray(ids, np.int64)) == s
                    ids_parts.append(np.asarray(ids)[own])
                    freq_parts.append(np.asarray(freqs)[own])
                ids = np.concatenate(ids_parts)
                freqs = np.concatenate(freq_parts)
                order = np.argsort(ids, kind="stable")
                unique[qkey] = (ids[order], freqs[order])
            values[i] = unique[qkey]

        return [eng.Result(requests[i].family, epochs, values[i])
                for i in range(len(requests))]

    @property
    def stats(self) -> dict:
        return {
            **self.engine.stats,
            "sharded_closure_hits": self.closures.hits,
            "sharded_closure_misses": self.closures.misses,
        }


def sharded_direct_answers(ssnap: ShardedSnapshot,
                           requests: list[eng.Request]) -> list:
    """Reference oracle for sharded serving: the same scatter/gather
    composition as ``ShardedQueryEngine`` but answered request-by-request
    through the module-level query functions (no planner, no padding, no
    caches).  The sharded engine must match this exactly — asserted by
    tests/test_sharding.py and ``serve_bench --shards``."""
    plan = ssnap.plan
    parts = ssnap.parts
    mod = eng.sketch_module(parts[0].sketch)

    def pair_sum(pairs) -> int:
        total = 0
        for s, d in pairs:
            sk = parts[plan.shard_of_one(s)].sketch
            total += int(mod.edge_freq(sk, jnp.asarray([s], jnp.int32),
                                       jnp.asarray([d], jnp.int32))[0])
        return total

    merged_closure: dict = {}
    out: list = []
    for r in requests:
        if r.family == eng.EDGE_FREQ:
            sk = parts[plan.shard_of_one(r.src)].sketch
            out.append(int(mod.edge_freq(
                sk, jnp.asarray([r.src], jnp.int32),
                jnp.asarray([r.dst], jnp.int32))[0]))
        elif r.family == eng.NODE_OUT:
            sk = parts[plan.shard_of_one(r.node)].sketch
            out.append(int(mod.node_out_freq(
                sk, jnp.asarray([r.node], jnp.int32))[0]))
        elif r.family == eng.NODE_IN:
            out.append(sum(
                int(mod.node_in_freq(
                    p.sketch, jnp.asarray([r.node], jnp.int32))[0])
                for p in parts))
        elif r.family == eng.REACH:
            if r.max_hops not in merged_closure:
                layers = functools.reduce(
                    jnp.add, [queries.closure_layers(p.sketch)
                              for p in parts])
                merged_closure[r.max_hops] = queries.build_closure(
                    layers, r.max_hops)
            sk0 = parts[0].sketch
            out.append(bool(np.asarray(queries.reachability_from_closure(
                merged_closure[r.max_hops],
                queries.reach_cells(sk0, jnp.asarray([r.src], jnp.int32)),
                queries.reach_cells(sk0, jnp.asarray([r.dst], jnp.int32))
            ))[0]))
        elif r.family == eng.PATH_WEIGHT:
            out.append(pair_sum(list(zip(r.nodes[:-1], r.nodes[1:]))))
        elif r.family == eng.SUBGRAPH_WEIGHT:
            out.append(pair_sum(list(r.edges)))
        elif r.family == eng.HEAVY_NODES:
            ids_parts, freq_parts = [], []
            for s, p in enumerate(parts):
                ids, freqs = queries.heavy_nodes(
                    lambda v: mod.node_out_freq(p.sketch, v),
                    r.universe, r.threshold)
                ids = np.asarray(ids)
                keep = (ids >= 0) & (plan.shard_of(
                    np.asarray(ids, np.int64)) == s)
                ids_parts.append(ids[keep])
                freq_parts.append(np.asarray(freqs)[keep])
            ids = np.concatenate(ids_parts)
            freqs = np.concatenate(freq_parts)
            order = np.argsort(ids, kind="stable")
            out.append((ids[order], freqs[order]))
        else:
            raise ValueError(f"unknown family {r.family!r}")
    return out


# --------------------------------------------------------------- runtime --

def attach_shards(runtime, tenant: ShardedTenant, *, restore: bool = False,
                  max_batches: int | None = None,
                  throttle_s=0.0, publish_policy: str | None = None,
                  on_publish=None) -> list:
    """Attach every shard of ``tenant`` to a ``repro.runtime.Runtime``.

    One queue + worker (+ pump) per shard, via the standard
    ``Runtime.attach`` contract — shard tenants ARE tenants.  With a
    checkpoint dir, writes the shard manifest next to the per-shard stores
    on a fresh attach and validates it on ``restore=True`` (shard count or
    routing seed drift would silently re-route the stream mid-history).
    ``throttle_s`` may be a scalar or a per-shard sequence (used by tests
    to drive shards to different offsets).
    """
    if restore and runtime.checkpoint_dir:
        manifest = read_shard_manifest(runtime.checkpoint_dir)
        if (manifest["n_shards"] != tenant.n_shards
                or manifest["shard_seed"] != tenant.plan.seed):
            raise ValueError(
                f"shard manifest ({manifest['n_shards']} shards, seed "
                f"{manifest['shard_seed']}) does not match this tenant "
                f"({tenant.n_shards} shards, seed {tenant.plan.seed}); "
                "restoring under a different plan would re-route the stream")
    throttles = (list(throttle_s) if hasattr(throttle_s, "__len__")
                 else [throttle_s] * tenant.n_shards)
    handles = [
        runtime.attach(shard, restore=restore, max_batches=max_batches,
                       throttle_s=throttles[i],
                       publish_policy=publish_policy, on_publish=on_publish)
        for i, shard in enumerate(tenant.shards)
    ]
    if runtime.checkpoint_dir and not restore:
        write_shard_manifest(runtime.checkpoint_dir, tenant,
                             runtime_backend=runtime.backend.name)
    return handles


def sharded_conservation(handles, stream_total: int) -> dict:
    """Cross-shard edge-mass accounting over per-shard runtime handles.

    The hard gate (`serve_bench --shards`): the shard views partition the
    stream, so after a graceful drain Σ per-shard published + Σ accounted
    drops must equal the base stream's total — and every shard must
    individually balance (zero unaccounted).
    """
    from repro.serving.gates import conservation_verdict

    per_shard = [h.conservation() for h in handles]
    unaccounted = [c["unaccounted_edges"] for c in per_shard]
    verdict = conservation_verdict(
        sum(c["published_edges"] for c in per_shard),
        sum(c["dropped_edges"] for c in per_shard),
        stream_total, unaccounted)
    return {
        **verdict,
        "per_shard_published": [c["published_edges"] for c in per_shard],
        "per_shard_unaccounted": unaccounted,
    }


def warm_ingest_shapes(tenant: ShardedTenant) -> int:
    """Compile every shard-ingest bucket shape off the clock.

    Ingests zero-weight batches (a counter no-op: additive sketches ignore
    weight-0 updates) of each ladder bucket through each shard's buffer.
    Covers up to 2x the base batch: worker coalescing may overshoot its
    target by one item, so coalesced dispatches can reach ~2B.  With the
    shared per-module kernel cache (serving/snapshot.py) each shape
    compiles ONCE per process regardless of K.  Returns the number of
    shapes touched.
    """
    shapes = 0
    for shard in tenant.shards:
        view = shard.stream
        base_b = getattr(view.base, "batch_size", view.granule * 4)
        for bucket in range(view.granule, 2 * base_b + view.granule,
                            view.granule):
            z = np.zeros(bucket, np.int32)
            shard.buffer.ingest(EdgeBatch.from_numpy(z, z, z))
            shapes += 1
    # also compile the publish (merge + re-zero) kernel: publishing the
    # still-zero delta is a no-op on counters (it does bump each shard's
    # epoch by one, which is harmless — epoch numbers are arbitrary)
    for shard in tenant.shards:
        shard.publish()
    return shapes


def measure_sharded_ingest(tenant: ShardedTenant, *,
                           backend: str = "thread",
                           coalesce_batches: int = 16,
                           max_batches: int | None = None) -> dict:
    """Backlog-drain ingest throughput over K shard workers, any backend.

    Pre-fills each shard's parent-side queue with its (remaining) stream
    view, then drains through one ``Runtime`` worker per shard — no pumps,
    no query load.  This is the pure concurrent-ingest capacity number
    ``benchmarks/run.py`` charts against K (and thread-vs-process in
    ``BENCH_process.json``).  The wall runs from each worker's first-ingest
    monotonic timestamp to its drain-publish timestamp (system-wide clock
    on Linux, so valid across the process boundary): stream generation,
    spawn, jit warm-up and readiness handshakes are all off the clock for
    every backend, while the publish end-point synchronizes on the device
    ingest chain (the pending-count fetch), so async dispatch cannot hide
    compute off the clock.  Conservation-checked: every queued edge must
    land in a published epoch.
    """
    from repro.runtime import QueueItem, Runtime

    nb = tenant.stream.num_batches
    coalesce_target = getattr(tenant.stream, "batch_size", 8192)
    per_shard_items: list[list] = []
    queued_edges = 0
    for shard in tenant.shards:
        end = nb if max_batches is None else min(nb, shard.offset
                                                 + max_batches)
        items = []
        for i in range(shard.offset, end):
            src, dst, w = shard.stream.batch_numpy(i)
            item = QueueItem.from_arrays(i, src, dst, w)
            items.append(item)
            queued_edges += item.n_edges
        per_shard_items.append(items)
    capacity = max(max((len(x) for x in per_shard_items), default=0), 1) + 1
    # publish once at drain: per-epoch cadence is a serving concern and
    # would bill one full-sketch merge per epoch to the ingest wall
    runtime = Runtime(queue_capacity=capacity,
                      publish_policy="every:1000000000", reservoir_k=0,
                      poll_s=0.002, backend=backend,
                      coalesce_batches=coalesce_batches,
                      coalesce_target=coalesce_target)
    handles = [runtime.attach(shard, pump=False) for shard in tenant.shards]
    if not runtime.backend.remote:
        warm_ingest_shapes(tenant)  # process children warm on their side
    runtime.start()
    runtime.wait_ready()  # ALL workers up before the backlog lands: the
    #                       wall must measure the concurrent drain, not
    #                       K staggered child boots
    base_edges = sum(h.worker.base_edges for h in handles)
    for handle, items in zip(handles, per_shard_items):
        for item in items:
            handle.queue.put(item)  # capacity covers the whole backlog
    runtime.stop(drain=True, timeout=600)
    metrics = [h.worker.metrics_snapshot() for h in handles]
    starts = [m["first_ingest_at"] for m in metrics if m["first_ingest_at"]]
    ends = [m["last_publish_at"] for m in metrics if m["last_publish_at"]]
    wall = max((max(ends) - min(starts)) if starts and ends else 0.0, 1e-9)
    ingested = sum(h.worker.ingested_edges for h in handles)
    published = sum(s.snapshot.n_edges for s in tenant.shards)
    return {
        "n_shards": tenant.n_shards,
        "backend": runtime.backend.name,
        "queued_edges": queued_edges,
        "ingested_edges": ingested,
        "published_edges": published,
        "wall_s": round(wall, 4),
        "edges_per_s": round(ingested / wall, 1),
        "worker_states": [h.worker.state for h in handles],
        "conserved": bool(ingested == queued_edges
                          and published - base_edges == ingested),
    }


# -------------------------------------------------------------- manifest --

_MANIFEST = "shard_manifest.json"


def write_shard_manifest(directory: str, tenant: ShardedTenant, *,
                         runtime_backend: str = "thread") -> str:
    """Atomically record the shard topology next to the per-shard stores.

    ``runtime_backend`` records which execution backend wrote the
    checkpoints — informational only: thread- and process-written
    checkpoints share one format (the child runs the same worker/store
    code), so restore never rejects on it, but an operator reading the
    manifest should know where the state came from.
    """
    os.makedirs(directory, exist_ok=True)
    payload = {
        "base_tenant_id": tenant.key.tenant_id,
        "dataset": tenant.key.dataset,
        "kind": tenant.key.kind,
        "budget_kb": tenant.key.budget_kb,
        "seed": tenant.key.seed,
        "n_shards": tenant.n_shards,
        "shard_seed": tenant.plan.seed,
        "shard_tenant_ids": [s.key.tenant_id for s in tenant.shards],
        "runtime_backend": runtime_backend,
    }
    path = os.path.join(directory, _MANIFEST)
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp_manifest_")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    return path


def read_shard_manifest(directory: str) -> dict:
    """Load and validate the shard manifest; fail LOUDLY on corruption.

    A truncated or torn manifest must never be treated as "no manifest"
    (which a restore could shrug off) or crash with a bare JSON error:
    restoring under an unverifiable shard plan could silently re-route the
    stream mid-history, so corruption is a hard, descriptive failure.
    """
    path = os.path.join(directory, _MANIFEST)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no shard manifest at {path} — was this checkpoint dir written "
            "by a sharded run (attach_shards with checkpointing enabled)?")
    with open(path) as f:
        try:
            manifest = json.load(f)
        except json.JSONDecodeError as exc:
            raise ValueError(
                f"shard manifest at {path} is truncated or corrupt ({exc}); "
                "refusing to restore — the shard plan cannot be verified, "
                "and resuming under a different plan would re-route the "
                "stream mid-history") from exc
    missing = [k for k in ("n_shards", "shard_seed", "shard_tenant_ids")
               if k not in manifest]
    if missing:
        raise ValueError(
            f"shard manifest at {path} is missing required keys {missing}; "
            "refusing to restore under an unverifiable shard plan")
    return manifest
