"""Batched query planner over published snapshots.

Serving-side counterpart of ``repro.core.queries``: accepts a heterogeneous
list of ``Request``s, groups them by query family, pads each group to a
static bucket size (so XLA sees a handful of shapes, not one per batch) and
answers every group with one dense jitted call.  Two properties matter:

  exactness — the engine is a *planner*, not an approximation layer: for a
    given snapshot its answers are bit-identical to calling the module-level
    query functions directly (tested by tests/test_serving.py).

  closure caching — reachability pays an O(log w) boolean matmul cascade to
    build per-layer closure matrices.  Those depend only on (tenant, epoch,
    max_hops), so the engine caches them LRU-style; every reachability query
    after the first on an epoch is a few gathers.  Publish bumps the epoch,
    which *is* the invalidation rule (DESIGN.md §Serving) — stale closures
    age out of the LRU, they are never mutated.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CountMin, GSketch, KMatrix, KMatrixAccel, MatrixSketch
from repro.core import (
    countmin,
    gsketch,
    kmatrix,
    kmatrix_accel,
    matrix_sketch,
    queries,
)
from repro.serving.snapshot import Snapshot

EDGE_FREQ = "edge_freq"
NODE_OUT = "node_out"
NODE_IN = "node_in"
REACH = "reach"
PATH_WEIGHT = "path_weight"
SUBGRAPH_WEIGHT = "subgraph_weight"
HEAVY_NODES = "heavy_nodes"

FAMILIES = (EDGE_FREQ, NODE_OUT, NODE_IN, REACH, PATH_WEIGHT,
            SUBGRAPH_WEIGHT, HEAVY_NODES)


@dataclasses.dataclass(frozen=True)
class Request:  # wire-type
    """One query; use the constructors below rather than raw instantiation."""

    family: str
    src: int = 0
    dst: int = 0
    node: int = 0
    nodes: tuple[int, ...] = ()
    edges: tuple[tuple[int, int], ...] = ()
    universe: int = 0
    threshold: float = 0.0
    max_hops: int | None = None


def edge_freq(src: int, dst: int) -> Request:
    return Request(EDGE_FREQ, src=int(src), dst=int(dst))


def node_out(node: int) -> Request:
    return Request(NODE_OUT, node=int(node))


def node_in(node: int) -> Request:
    return Request(NODE_IN, node=int(node))


def reach(src: int, dst: int, max_hops: int | None = None) -> Request:
    return Request(REACH, src=int(src), dst=int(dst), max_hops=max_hops)


def path_weight(nodes) -> Request:
    return Request(PATH_WEIGHT, nodes=tuple(int(v) for v in nodes))


def subgraph_weight(edges) -> Request:
    return Request(SUBGRAPH_WEIGHT,
                   edges=tuple((int(s), int(d)) for s, d in edges))


def heavy_nodes(universe: int, threshold: float) -> Request:
    return Request(HEAVY_NODES, universe=int(universe),
                   threshold=float(threshold))


@dataclasses.dataclass(frozen=True)
class Result:
    family: str
    epoch: int
    value: Any  # int | bool | (ids ndarray, freqs ndarray) for heavy_nodes


_MODULES = {KMatrix: kmatrix, KMatrixAccel: kmatrix_accel,
            MatrixSketch: matrix_sketch,
            GSketch: gsketch, CountMin: countmin}


def sketch_module(sk: Any):
    mod = _MODULES.get(type(sk))
    if mod is None:
        raise TypeError(f"no query module for sketch type {type(sk).__name__}")
    return mod


def _bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power-of-two >= n within [lo, hi] (caps jit recompiles)."""
    b = lo
    while b < n and b < hi:
        b <<= 1
    return b


class ClosureCache:
    """LRU of per-layer boolean closure matrices keyed by
    (tenant_id, epoch, max_hops)."""

    def __init__(self, capacity: int = 8) -> None:
        self.capacity = capacity
        self._entries: OrderedDict[tuple, jax.Array] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, snapshot: Snapshot, max_hops: int | None) -> jax.Array:
        return self.get_or_build(
            (snapshot.tenant_id, snapshot.epoch, max_hops),
            lambda: queries.build_closure(
                queries.closure_layers(snapshot.sketch), max_hops))

    def get_or_build(self, key: tuple, build: Callable) -> jax.Array:
        """LRU lookup under an arbitrary key, calling ``build()`` on miss.

        The generalized entry point: sharded serving keys its merged-layer
        closures on the per-shard epoch VECTOR (serving/sharding.py) but
        shares this cache's eviction and stats semantics.
        """
        closure = self._entries.get(key)
        if closure is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return closure
        self.misses += 1
        closure = build()
        self._entries[key] = closure
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        return closure

    def clear(self) -> None:
        self._entries.clear()


class QueryEngine:
    """Plans heterogeneous request batches into dense jitted calls."""

    def __init__(self, *, min_bucket: int = 64, max_bucket: int = 1 << 14,
                 heavy_chunk: int = 4096, closure_capacity: int = 8) -> None:
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.heavy_chunk = heavy_chunk
        self.closures = ClosureCache(closure_capacity)
        self._jit_cache: dict[Any, Callable] = {}
        self.batches_planned = 0

    # ------------------------------------------------------------- plumbing
    def _jitted(self, fn: Callable) -> Callable:
        """jit ``fn`` once per engine (jax.jit called twice on the same fn
        would not share compilation caches)."""
        wrapped = self._jit_cache.get(fn)
        if wrapped is None:
            wrapped = self._jit_cache[fn] = jax.jit(fn)
        return wrapped

    def _pair_sum(self, mod) -> Callable:
        """Jitted masked sum of edge frequencies along the last axis
        (shared by path_weight and subgraph_weight)."""
        key = ("pair_sum", mod)
        wrapped = self._jit_cache.get(key)
        if wrapped is None:
            def pair_sum(sk, src, dst, mask):
                est = mod.edge_freq(sk, src, dst)
                return jnp.sum(jnp.where(mask, est, 0), axis=-1)

            wrapped = self._jit_cache[key] = jax.jit(pair_sum)
        return wrapped

    def _pad(self, vals: list[int], bucket: int) -> jax.Array:
        arr = np.zeros(bucket, np.int32)
        arr[: len(vals)] = vals
        return jnp.asarray(arr)

    # ------------------------------------------------------------- planning
    def execute(self, snapshot: Snapshot, requests: list[Request]
                ) -> list[Result]:
        """Answer ``requests`` (any mix of families) against one snapshot.

        Returns results in request order.  Exact: each family is routed to
        the same ``repro.core`` pure functions a direct caller would use.
        """
        sk = snapshot.sketch
        mod = sketch_module(sk)
        values: list[Any] = [None] * len(requests)

        groups: dict[tuple, list[int]] = {}
        for i, r in enumerate(requests):
            groups.setdefault(self._group_key(r), []).append(i)

        from repro.obs.hub import get_hub
        hub = get_hub()
        for key, idxs in groups.items():
            family = key[0]
            handler = self._HANDLERS[family]
            t0 = time.perf_counter()
            # a group can exceed the largest bucket; split it rather than
            # overflowing the padded arrays
            for lo in range(0, len(idxs), self.max_bucket):
                handler(self, snapshot, sk, mod, key,
                        idxs[lo:lo + self.max_bucket], requests, values)
                self.batches_planned += 1
            # per-query-class telemetry (gSketch frames sketch quality per
            # query class; latency gets the same treatment)
            hub.counter("repro_engine_requests_total",
                        "requests planned, by query class",
                        family=family).inc(len(idxs))
            hub.histogram("repro_engine_group_seconds",
                          "handler wall time per planned group, "
                          "by query class",
                          family=family).observe(time.perf_counter() - t0)

        return [Result(requests[i].family, snapshot.epoch, values[i])
                for i in range(len(requests))]

    def _group_key(self, r: Request) -> tuple:
        if r.family == REACH:
            return (REACH, r.max_hops)
        if r.family == PATH_WEIGHT:
            if len(r.nodes) > self.max_bucket:
                raise ValueError(
                    f"path_weight request with {len(r.nodes)} nodes exceeds "
                    f"max_bucket={self.max_bucket}; split the path")
            return (PATH_WEIGHT,
                    _bucket(len(r.nodes), 2, self.max_bucket))
        if r.family == SUBGRAPH_WEIGHT:
            if len(r.edges) > self.max_bucket:
                raise ValueError(
                    f"subgraph_weight request with {len(r.edges)} edges "
                    f"exceeds max_bucket={self.max_bucket}; split the edge set")
            return (SUBGRAPH_WEIGHT,
                    _bucket(max(len(r.edges), 1), 1, self.max_bucket))
        return (r.family,)

    # ------------------------------------------------------------- handlers
    def _run_edge_freq(self, snapshot, sk, mod, key, idxs, requests, values):
        n = len(idxs)
        b = _bucket(n, self.min_bucket, self.max_bucket)
        src = self._pad([requests[i].src for i in idxs], b)
        dst = self._pad([requests[i].dst for i in idxs], b)
        est = np.asarray(self._jitted(mod.edge_freq)(sk, src, dst))[:n]
        for j, i in enumerate(idxs):
            values[i] = int(est[j])

    def _run_node_agg(self, snapshot, sk, mod, key, idxs, requests, values):
        family = key[0]
        fn = getattr(mod, "node_out_freq" if family == NODE_OUT
                     else "node_in_freq", None)
        if fn is None:
            raise ValueError(
                f"{family} is not answerable by {type(sk).__name__} "
                f"(no {'node_out_freq' if family == NODE_OUT else 'node_in_freq'})")
        n = len(idxs)
        b = _bucket(n, self.min_bucket, self.max_bucket)
        nodes = self._pad([requests[i].node for i in idxs], b)
        est = np.asarray(self._jitted(fn)(sk, nodes))[:n]
        for j, i in enumerate(idxs):
            values[i] = int(est[j])

    def _run_reach(self, snapshot, sk, mod, key, idxs, requests, values):
        _, max_hops = key
        closure = self.closures.get(snapshot, max_hops)
        n = len(idxs)
        b = _bucket(n, self.min_bucket, self.max_bucket)
        src = self._pad([requests[i].src for i in idxs], b)
        dst = self._pad([requests[i].dst for i in idxs], b)
        hi = queries.reach_cells(sk, src)
        hj = queries.reach_cells(sk, dst)
        out = np.asarray(self._jitted(queries.reachability_from_closure)(
            closure, hi, hj))[:n]
        for j, i in enumerate(idxs):
            values[i] = bool(out[j])

    def _run_path(self, snapshot, sk, mod, key, idxs, requests, values):
        _, node_bucket = key
        n = len(idxs)
        b = _bucket(n, 1, self.max_bucket)
        src = np.zeros((b, node_bucket - 1), np.int32)
        dst = np.zeros((b, node_bucket - 1), np.int32)
        mask = np.zeros((b, node_bucket - 1), bool)
        for j, i in enumerate(idxs):
            nodes = requests[i].nodes
            k = len(nodes) - 1
            src[j, :k] = nodes[:-1]
            dst[j, :k] = nodes[1:]
            mask[j, :k] = True
        out = np.asarray(self._pair_sum(mod)(
            sk, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask)))[:n]
        for j, i in enumerate(idxs):
            values[i] = int(out[j])

    def _run_subgraph(self, snapshot, sk, mod, key, idxs, requests, values):
        _, edge_bucket = key
        n = len(idxs)
        b = _bucket(n, 1, self.max_bucket)
        src = np.zeros((b, edge_bucket), np.int32)
        dst = np.zeros((b, edge_bucket), np.int32)
        mask = np.zeros((b, edge_bucket), bool)
        for j, i in enumerate(idxs):
            edges = requests[i].edges
            for k, (s, d) in enumerate(edges):
                src[j, k], dst[j, k], mask[j, k] = s, d, True
        out = np.asarray(self._pair_sum(mod)(
            sk, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(mask)))[:n]
        for j, i in enumerate(idxs):
            values[i] = int(out[j])

    def _heavy_sweep(self, mod, universe: int, chunk: int) -> Callable:
        """Jitted universe sweep with the threshold left as a traced arg, so
        every (universe, chunk) pair compiles once."""
        key = ("heavy", mod, universe, chunk)
        wrapped = self._jit_cache.get(key)
        if wrapped is None:
            def sweep(sk, threshold):
                return queries.heavy_nodes(
                    lambda v: mod.node_out_freq(sk, v), universe, threshold,
                    chunk=chunk)

            wrapped = self._jit_cache[key] = jax.jit(sweep)
        return wrapped

    def _run_heavy(self, snapshot, sk, mod, key, idxs, requests, values):
        if getattr(mod, "node_out_freq", None) is None:
            raise ValueError(
                f"heavy_nodes is not answerable by {type(sk).__name__}")
        # identical sweeps are common in real workloads: answer each
        # (universe, threshold) once per batch
        unique: dict[tuple, Any] = {}
        for i in idxs:
            r = requests[i]
            qkey = (r.universe, r.threshold)
            if qkey not in unique:
                chunk = min(self.heavy_chunk,
                            _bucket(r.universe, 64, self.heavy_chunk))
                ids, freqs = self._heavy_sweep(mod, r.universe, chunk)(
                    sk, r.threshold)
                ids = np.asarray(ids)
                keep = ids >= 0
                unique[qkey] = (ids[keep], np.asarray(freqs)[keep])
            values[i] = unique[qkey]

    _HANDLERS = {
        EDGE_FREQ: _run_edge_freq,
        NODE_OUT: _run_node_agg,
        NODE_IN: _run_node_agg,
        REACH: _run_reach,
        PATH_WEIGHT: _run_path,
        SUBGRAPH_WEIGHT: _run_subgraph,
        HEAVY_NODES: _run_heavy,
    }

    @property
    def stats(self) -> dict:
        return {
            "batches_planned": self.batches_planned,
            "closure_hits": self.closures.hits,
            "closure_misses": self.closures.misses,
        }


def direct_answers(snapshot: Snapshot, requests: list[Request]) -> list[Any]:
    """Reference oracle: answer each request one-by-one through the
    module-level ``repro.core`` query functions (no planner, no padding, no
    closure cache).  The engine must match this exactly for the same
    snapshot — asserted by tests/test_serving.py and benchmarks/serve_bench.
    """
    sk = snapshot.sketch
    mod = sketch_module(sk)
    ef = lambda s, d: mod.edge_freq(sk, s, d)  # noqa: E731
    out: list[Any] = []
    for r in requests:
        if r.family == EDGE_FREQ:
            out.append(int(ef(jnp.asarray([r.src], jnp.int32),
                              jnp.asarray([r.dst], jnp.int32))[0]))
        elif r.family == NODE_OUT:
            out.append(int(mod.node_out_freq(
                sk, jnp.asarray([r.node], jnp.int32))[0]))
        elif r.family == NODE_IN:
            out.append(int(mod.node_in_freq(
                sk, jnp.asarray([r.node], jnp.int32))[0]))
        elif r.family == REACH:
            # through closure_layers/reach_cells so Type I sketches are
            # rejected exactly like the engine rejects them
            closure = queries.build_closure(queries.closure_layers(sk),
                                            r.max_hops)
            out.append(bool(np.asarray(queries.reachability_from_closure(
                closure,
                queries.reach_cells(sk, jnp.asarray([r.src], jnp.int32)),
                queries.reach_cells(sk, jnp.asarray([r.dst], jnp.int32))))[0]))
        elif r.family == PATH_WEIGHT:
            out.append(int(queries.path_weight(
                ef, jnp.asarray(r.nodes, jnp.int32))))
        elif r.family == SUBGRAPH_WEIGHT:
            out.append(int(queries.subgraph_weight(
                ef, jnp.asarray([e[0] for e in r.edges], jnp.int32),
                jnp.asarray([e[1] for e in r.edges], jnp.int32))))
        elif r.family == HEAVY_NODES:
            ids, freqs = queries.heavy_nodes(
                lambda v: mod.node_out_freq(sk, v), r.universe, r.threshold)
            ids = np.asarray(ids)
            keep = ids >= 0
            out.append((ids[keep], np.asarray(freqs)[keep]))
        else:
            raise ValueError(f"unknown family {r.family!r}")
    return out
