"""Double-buffered, epoch-stamped read snapshots over live-ingesting sketches.

The serving contract (DESIGN.md §Serving): queries never observe a
half-ingested sketch.  Each tenant owns a ``SnapshotBuffer`` with two sides:

  front  — the *published* ``Snapshot``: an immutable, epoch-stamped sketch
           that every query in flight reads.  JAX arrays are immutable, so
           holding the pytree reference IS the isolation mechanism — no
           copies, no locks.
  back   — the *delta*: an ``empty_like`` twin (same layout, routing and
           hash seeds) that absorbs ingest batches.

``publish()`` folds the delta into the front via counter-additive ``merge``
(one elementwise add over the pool — cheap regardless of how many batches
accumulated), bumps the epoch, and resets the delta to zeros.  Readers of the
previous epoch keep their reference and stay consistent; the epoch number is
the cache key for everything derived from a snapshot (notably the boolean
closure matrices cached by the query engine).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import EdgeBatch


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An immutable point-in-time view of a tenant's sketch.

    ``epoch`` is monotonically increasing per tenant and uniquely identifies
    the counter state: two queries against the same (tenant_id, epoch) are
    guaranteed to see identical answers.
    """

    tenant_id: str
    epoch: int
    sketch: Any  # KMatrix | MatrixSketch | GSketch | CountMin
    kind: str
    n_edges: int  # cumulative non-padding stream updates folded in

    def __repr__(self) -> str:  # keep array payload out of logs
        return (f"Snapshot({self.tenant_id!r}, epoch={self.epoch}, "
                f"kind={self.kind!r}, n_edges={self.n_edges})")


class StaleDelta(RuntimeError):
    """A delta publish was based on an epoch that is not the current front.

    Raised by :meth:`SnapshotBuffer.adopt_published` in delta mode when the
    shipped ``base_epoch`` disagrees with the front's epoch — folding the
    delta in would double- or under-count.  The adopting transport reacts
    by skipping the publish and requesting a full-leaves resync from the
    worker (DESIGN.md §Net, ack-gap rules).
    """


_anon_ids = itertools.count()

# One jitted (ingest, publish) kernel pair per sketch MODULE, shared by
# every buffer of that module.  jax.jit caches compilations per wrapped
# callable: a per-buffer lambda would recompile the identical graph once
# per tenant — K shards of one tenant (serving/sharding.py) share a layout,
# so per-buffer caches would pay K compiles for one graph and the sharded
# ingest wall would be mostly XLA compilation.  Distinct layouts/shapes
# still compile separately (jit keys on shapes + statics), so sharing is
# always safe.
_KERNELS: dict = {}


def _shared_kernels(mod):
    pair = _KERNELS.get(mod)
    if pair is None:
        jit_ingest = jax.jit(
            lambda sk, batch, pending: (
                mod.ingest(sk, batch),
                pending + jnp.sum((batch.weight > 0).astype(pending.dtype))))
        # One fused publish kernel: fold delta into front, zero the delta.
        # Safe to jit (which skips merge's hash-family check): the delta is
        # empty_like(front) by construction, so the families always match.
        jit_publish = jax.jit(
            lambda front, delta: (mod.merge(front, delta),
                                  mod.empty_like(delta)))
        pair = _KERNELS[mod] = (jit_ingest, jit_publish)
    return pair


class SnapshotBuffer:
    """Double buffer: live delta sketch (ingest side) + published Snapshot."""

    def __init__(self, sketch: Any, mod: Any, *, tenant_id: str | None = None,
                 kind: str = "") -> None:
        self._mod = mod
        # tenant_id keys every per-(tenant, epoch) cache downstream (notably
        # the engine's closure cache).  Two buffers must never share an id:
        # same-named tenants from differently-configured registries reach
        # the same epoch with different counters, and a shared engine would
        # serve one tenant the other's closures.  The instance suffix makes
        # the id unique per buffer while keeping the readable prefix.
        self._tenant_id = f"{tenant_id or 'anon'}#{next(_anon_ids)}"
        self._kind = kind or getattr(sketch, "kind", type(sketch).__name__.lower())
        self._front = Snapshot(self._tenant_id, 0, sketch,  # guarded-by(writes): _lock
                               self._kind, 0)
        self._delta = mod.empty_like(sketch)  # guarded-by: _lock
        # device-side counter: avoids a host sync per ingest batch; folded
        # into the ingest kernel so each batch is ONE dispatch
        self._pending = jnp.zeros((), jnp.int64 if jax.config.x64_enabled  # guarded-by: _lock
                                  else jnp.int32)
        self._jit_ingest, self._jit_publish = _shared_kernels(mod)
        # Delta-publication support (runtime/backend.py): with the flag on,
        # each publish() stashes the pre-merge delta pytree (an immutable
        # reference — zero copies) so a remote worker can ship ONLY what
        # accumulated since the previous epoch instead of the whole sketch.
        self.capture_publish_delta = False
        self.last_publish_delta: Any = None
        # Guards the back buffer (_delta/_pending) and the front swap against
        # a checkpointing thread reading ``state()`` mid-operation.  Readers
        # of ``snapshot`` need no lock: the property is one atomic reference
        # read and the pytree behind it is immutable.
        self._lock = threading.Lock()

    @property
    def snapshot(self) -> Snapshot:
        return self._front

    @property
    def epoch(self) -> int:
        return self._front.epoch

    @property
    def pending_edges(self) -> int:
        """Non-padding updates sitting in the delta (host sync; diagnostics
        and conservation accounting only — not the ingest hot path)."""
        with self._lock:
            pending = self._pending
        return int(jax.device_get(pending))

    @property
    def overflow_edges(self) -> int:
        """Ingest updates that took the accel backend's scatter-fallback
        (per-partition capacity exceeded), front + live delta.  0 for
        layouts without overflow accounting.  Host sync; diagnostics only —
        surfaced through runtime metrics and the serve bench."""
        with self._lock:
            front = getattr(self._front.sketch, "overflow", None)
            delta = getattr(self._delta, "overflow", None)
        if front is None:
            return 0
        total = int(jax.device_get(front))
        return total + (int(jax.device_get(delta)) if delta is not None else 0)

    def ingest(self, batch: EdgeBatch) -> None:
        """Absorb a batch into the back buffer; published readers unaffected."""
        with self._lock:
            self._delta, self._pending = self._jit_ingest(
                self._delta, batch, self._pending)

    def publish(self) -> Snapshot:
        """Fold the delta into the front buffer and stamp a new epoch.

        This is the only host sync point in the ingest path (the pending
        edge count is fetched to stamp the snapshot).
        """
        with self._lock:
            pending = int(jax.device_get(self._pending))
            if self.capture_publish_delta:
                # the outgoing delta is exactly what this publish folds in;
                # the reference stays valid (JAX arrays are immutable)
                self.last_publish_delta = self._delta
            merged, delta = self._jit_publish(self._front.sketch, self._delta)
            self._front = Snapshot(
                self._tenant_id,
                self._front.epoch + 1,
                merged,
                self._kind,
                self._front.n_edges + pending,
            )
            self._delta = delta
            self._pending = jnp.zeros_like(self._pending)
            return self._front

    def adopt_published(self, sketch: Any, epoch: int, n_edges: int, *,
                        delta: Any = None,
                        base_epoch: int | None = None) -> Snapshot:
        """Install an externally-produced published front (runtime/backend.py).

        The remote execution backends fold batches into a sketch living in
        a child process and ship each published epoch back; this swaps that
        state in as the new front WITHOUT touching the local delta (which
        stays empty — the remote side owns the write path).  Same isolation
        contract as ``publish``: readers holding the previous front keep a
        consistent immutable epoch.  The caller must adopt epochs in
        publication order (the backend's FIFO result pipe guarantees that).

        Two modes:

          full   ``sketch`` is the worker's whole published front;
                 installed verbatim (replace).
          delta  ``sketch`` is ignored; ``delta`` is the pytree the worker
                 accumulated since its previous publish, and is folded into
                 the current front through the SAME jitted merge the
                 worker's own publish used — bit-identical counters on both
                 sides.  ``base_epoch`` must equal the current front epoch
                 or the fold would mis-count: any gap raises
                 :class:`StaleDelta` (the transport then requests a
                 full-leaves resync).
        """
        with self._lock:
            if delta is not None:
                if base_epoch is None or int(base_epoch) != self._front.epoch:
                    raise StaleDelta(
                        f"delta publish for epoch {epoch} is based on epoch "
                        f"{base_epoch}, but the front is at epoch "
                        f"{self._front.epoch}; a full resync is required")
                sketch, _ = self._jit_publish(self._front.sketch, delta)
            self._front = Snapshot(self._tenant_id, int(epoch),
                                   sketch, self._kind, int(n_edges))
            return self._front

    # ------------------------------------------------------------ checkpoint
    def state(self) -> dict:
        """Mutually-consistent (front, delta, pending, epoch, n_edges) view.

        The returned pytrees are immutable JAX arrays, so the caller can
        serialize them outside the lock (crash-safe checkpointing in
        ``repro.runtime``).
        """
        with self._lock:
            return {
                "front": self._front.sketch,
                "delta": self._delta,
                "pending": self._pending,
                "epoch": self._front.epoch,
                "n_edges": self._front.n_edges,
            }

    def load_state(self, state: dict) -> Snapshot:
        """Restore a checkpointed ``state()`` (same sketch layout required)."""
        with self._lock:
            self._front = Snapshot(
                self._tenant_id,
                int(state["epoch"]),
                jax.tree_util.tree_map(jnp.asarray, state["front"]),
                self._kind,
                int(state["n_edges"]),
            )
            self._delta = jax.tree_util.tree_map(jnp.asarray, state["delta"])
            self._pending = jnp.asarray(state["pending"],
                                        dtype=self._pending.dtype)
            return self._front
