"""Double-buffered, epoch-stamped read snapshots over live-ingesting sketches.

The serving contract (DESIGN.md §Serving): queries never observe a
half-ingested sketch.  Each tenant owns a ``SnapshotBuffer`` with two sides:

  front  — the *published* ``Snapshot``: an immutable, epoch-stamped sketch
           that every query in flight reads.  JAX arrays are immutable, so
           holding the pytree reference IS the isolation mechanism — no
           copies, no locks.
  back   — the *delta*: an ``empty_like`` twin (same layout, routing and
           hash seeds) that absorbs ingest batches.

``publish()`` folds the delta into the front via counter-additive ``merge``
(one elementwise add over the pool — cheap regardless of how many batches
accumulated), bumps the epoch, and resets the delta to zeros.  Readers of the
previous epoch keep their reference and stay consistent; the epoch number is
the cache key for everything derived from a snapshot (notably the boolean
closure matrices cached by the query engine).

Ingest fast path (DESIGN.md §Ingest-fast-path): with ``REPRO_DONATE`` on
(the default) and a ``DONATION_SAFE`` sketch module, the ingest/publish
kernels donate the delta pytree to XLA, which updates the counter buffers
in place instead of round-tripping a fresh depth×budget pytree per
dispatch.  The front is NEVER donated — published snapshots stay immutable
and isolation still costs zero copies.  Donation's one hazard is
use-after-donate (reading a reference that the kernel consumed); every
such path here resolves values under ``_lock`` before the next dispatch
can donate them, ``state()`` hands out private copies, and the
``use-after-donate`` rule in ``repro.analysis`` lints the discipline.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
from collections import namedtuple
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import EdgeBatch


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An immutable point-in-time view of a tenant's sketch.

    ``epoch`` is monotonically increasing per tenant and uniquely identifies
    the counter state: two queries against the same (tenant_id, epoch) are
    guaranteed to see identical answers.
    """

    tenant_id: str
    epoch: int
    sketch: Any  # KMatrix | MatrixSketch | GSketch | CountMin
    kind: str
    n_edges: int  # cumulative non-padding stream updates folded in

    def __repr__(self) -> str:  # keep array payload out of logs
        return (f"Snapshot({self.tenant_id!r}, epoch={self.epoch}, "
                f"kind={self.kind!r}, n_edges={self.n_edges})")


class StaleDelta(RuntimeError):
    """A delta publish was based on an epoch that is not the current front.

    Raised by :meth:`SnapshotBuffer.adopt_published` in delta mode when the
    shipped ``base_epoch`` disagrees with the front's epoch — folding the
    delta in would double- or under-count.  The adopting transport reacts
    by skipping the publish and requesting a full-leaves resync from the
    worker (DESIGN.md §Net, ack-gap rules).
    """


_anon_ids = itertools.count()


def donation_enabled() -> bool:
    """The ``REPRO_DONATE`` kill-switch (default ON).

    Donation makes each ingest dispatch mutate the delta's device buffers in
    place instead of allocating a fresh depth×budget counter pytree per
    batch.  ``REPRO_DONATE=0`` (or ``false``/``off``) restores the copying
    kernels for debugging — bit-identical counters either way, gated by the
    kill-switch parity test and the A/B cells in ``BENCH_ingest.json``.
    """
    return os.environ.get("REPRO_DONATE", "1").strip().lower() \
        not in ("0", "false", "off")


# One jitted kernel kit per (sketch MODULE, donate) pair, shared by every
# buffer of that module.  jax.jit caches compilations per wrapped callable:
# a per-buffer lambda would recompile the identical graph once per tenant —
# K shards of one tenant (serving/sharding.py) share a layout, so per-buffer
# caches would pay K compiles for one graph and the sharded ingest wall
# would be mostly XLA compilation.  Distinct layouts/shapes still compile
# separately (jit keys on shapes + statics), so sharing is always safe.
#
#   ingest          (sk, batch, pending)      counts weight>0 on device
#   ingest_counted  (sk, batch, inc, pending) host-supplied count — the
#                   dedup path pre-aggregates (src, dst) rows on the host,
#                   so the device batch no longer carries one row per
#                   stream update and the weight>0 count must come from
#                   the raw items instead
#   publish         (front, delta) -> (merged, zeroed delta)
#   publish_keep    same graph, NEVER donates — for adopt_published (the
#                   incoming delta aliases wire/decoded buffers the caller
#                   still owns) and capture_publish_delta (the stashed
#                   pre-merge reference must outlive the call)
#
# When donating, only the sketch argument is donated — never ``pending``.
# The pending scalar is a fresh 4/8-byte output per dispatch, and holding
# its reference gives callers a completion fence: it becomes ready exactly
# when that dispatch finished executing (SnapshotBuffer.dispatch_token).
_KernelKit = namedtuple(
    "_KernelKit", ["ingest", "ingest_counted", "publish", "publish_keep"])
_KERNELS: dict = {}


def _shared_kernels(mod, donate: bool) -> "_KernelKit":
    key = (mod, bool(donate))
    kit = _KERNELS.get(key)
    if kit is None:
        def _ingest(sk, batch, pending):
            return (mod.ingest(sk, batch),
                    pending + jnp.sum((batch.weight > 0).astype(pending.dtype)))

        def _ingest_counted(sk, batch, inc, pending):
            return mod.ingest(sk, batch), pending + inc

        # One fused publish kernel: fold delta into front, zero the delta.
        # Safe to jit (which skips merge's hash-family check): the delta is
        # empty_like(front) by construction, so the families always match.
        def _publish(front, delta):
            return mod.merge(front, delta), mod.empty_like(delta)

        if donate:
            kit = _KernelKit(
                ingest=jax.jit(_ingest, donate_argnums=(0,)),
                ingest_counted=jax.jit(_ingest_counted, donate_argnums=(0,)),
                publish=jax.jit(_publish, donate_argnums=(1,)),
                # reuse the non-donating kit's publish so the keep variant
                # compiles once per module, not once per (module, donate)
                publish_keep=_shared_kernels(mod, False).publish,
            )
        else:
            jit_publish = jax.jit(_publish)
            kit = _KernelKit(
                ingest=jax.jit(_ingest),
                ingest_counted=jax.jit(_ingest_counted),
                publish=jit_publish,
                publish_keep=jit_publish,
            )
        _KERNELS[key] = kit
    return kit


def _private_copy(tree):
    """Deep-copy every leaf so the result shares no device buffer (and no
    Array object) with ``tree``.  Required before a pytree may be donated:
    ``empty_like``/checkpoint templates can alias hash-family or routing
    leaves with the front sketch by reference, and donating a shared leaf
    would delete it out from under every other holder."""
    return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), tree)


class SnapshotBuffer:
    """Double buffer: live delta sketch (ingest side) + published Snapshot."""

    def __init__(self, sketch: Any, mod: Any, *, tenant_id: str | None = None,
                 kind: str = "", donate: bool | None = None) -> None:
        self._mod = mod
        # Buffer donation (ISSUE 10): when on, the jitted ingest/publish
        # kernels donate the delta pytree so XLA scatters into the existing
        # device buffers instead of allocating a fresh counter pytree per
        # dispatch.  Requires the sketch module to declare alias-safety
        # (DONATION_SAFE) — its kernels must never need a donated leaf after
        # the call — and honours the REPRO_DONATE kill-switch.  After every
        # donating call the old delta/pending references are DEAD (reading
        # them raises "Array has been deleted"); every read path below
        # therefore resolves values inside _lock and state() hands out
        # private copies.  The use-after-donate analysis rule lints this
        # contract statically.
        env_donate = donation_enabled() if donate is None else bool(donate)
        self.donate = env_donate and bool(getattr(mod, "DONATION_SAFE", False))
        # tenant_id keys every per-(tenant, epoch) cache downstream (notably
        # the engine's closure cache).  Two buffers must never share an id:
        # same-named tenants from differently-configured registries reach
        # the same epoch with different counters, and a shared engine would
        # serve one tenant the other's closures.  The instance suffix makes
        # the id unique per buffer while keeping the readable prefix.
        self._tenant_id = f"{tenant_id or 'anon'}#{next(_anon_ids)}"
        self._kind = kind or getattr(sketch, "kind", type(sketch).__name__.lower())
        self._front = Snapshot(self._tenant_id, 0, sketch,  # guarded-by(writes): _lock
                               self._kind, 0)
        self._delta = mod.empty_like(sketch)  # guarded-by: _lock
        if self.donate:
            # empty_like may reuse hash-family/routing leaves of `sketch`
            # by reference; the delta is about to be donated every dispatch,
            # so it must own every one of its buffers outright
            self._delta = _private_copy(self._delta)
        # device-side counter: avoids a host sync per ingest batch; folded
        # into the ingest kernel so each batch is ONE dispatch
        self._pending = jnp.zeros((), jnp.int64 if jax.config.x64_enabled  # guarded-by: _lock
                                  else jnp.int32)
        self._kernels = _shared_kernels(mod, self.donate)
        # Delta-publication support (runtime/backend.py): with the flag on,
        # each publish() stashes the pre-merge delta pytree (an immutable
        # reference — zero copies) so a remote worker can ship ONLY what
        # accumulated since the previous epoch instead of the whole sketch.
        self.capture_publish_delta = False
        self.last_publish_delta: Any = None
        # Guards the back buffer (_delta/_pending) and the front swap against
        # a checkpointing thread reading ``state()`` mid-operation.  Readers
        # of ``snapshot`` need no lock: the property is one atomic reference
        # read and the pytree behind it is immutable.
        self._lock = threading.Lock()

    @property
    def snapshot(self) -> Snapshot:
        return self._front

    @property
    def epoch(self) -> int:
        return self._front.epoch

    @property
    def pending_edges(self) -> int:
        """Non-padding updates sitting in the delta (host sync; diagnostics
        and conservation accounting only — not the ingest hot path).

        The device_get happens INSIDE the lock: with donation on, a
        reference captured under the lock can be donated (and deleted) by a
        concurrent ingest the instant the lock is released."""
        with self._lock:
            return int(jax.device_get(self._pending))

    @property
    def overflow_edges(self) -> int:
        """Ingest updates that took the accel backend's scatter-fallback
        (per-partition capacity exceeded), front + live delta.  0 for
        layouts without overflow accounting.  Host sync; diagnostics only —
        surfaced through runtime metrics and the serve bench.  Delta leaf
        resolved inside the lock — see ``pending_edges``."""
        with self._lock:
            front = getattr(self._front.sketch, "overflow", None)
            delta = getattr(self._delta, "overflow", None)
            delta_total = (int(jax.device_get(delta))
                           if delta is not None else 0)
        if front is None:
            return 0
        return int(jax.device_get(front)) + delta_total

    def ingest(self, batch: EdgeBatch, count: int | None = None) -> None:
        """Absorb a batch into the back buffer; published readers unaffected.

        ``count`` (optional) is the number of weight>0 updates the batch
        *represents*.  When the caller pre-aggregated duplicate (src, dst)
        rows on the host (runtime/worker.py dedup path), the dispatched
        rows no longer map 1:1 to stream updates, so the device-side
        weight>0 count would under-report; the host count keeps the pending
        ledger bit-identical to the un-deduped replay.
        """
        with self._lock:
            if count is None:
                self._delta, self._pending = self._kernels.ingest(  # donates: 0
                    self._delta, batch, self._pending)
            else:
                self._delta, self._pending = self._kernels.ingest_counted(  # donates: 0
                    self._delta, batch, int(count), self._pending)

    def dispatch_token(self):
        """Opaque completion fence for everything dispatched so far.

        Returns the current pending scalar — a (never-donated) output of
        the most recent ingest kernel, so ``jax.block_until_ready`` on it
        returns exactly when that dispatch (and, by device-stream order,
        every earlier one) has finished executing.  The pipelined worker
        uses this to know when a zero-copy host staging buffer may be
        refilled (core/types.EdgeBatch.from_numpy shares memory with its
        numpy inputs on CPU, so reuse-while-in-flight would corrupt the
        dispatch).
        """
        with self._lock:
            return self._pending

    def publish(self) -> Snapshot:
        """Fold the delta into the front buffer and stamp a new epoch.

        This is the only host sync point in the ingest path (the pending
        edge count is fetched to stamp the snapshot).
        """
        with self._lock:
            pending = int(jax.device_get(self._pending))
            if self.capture_publish_delta:
                # the outgoing delta is exactly what this publish folds in;
                # the reference stays valid (JAX arrays are immutable) —
                # which is also why this path must take the NEVER-donating
                # publish kernel: donating the delta here would delete the
                # stashed reference before the transport ships it
                self.last_publish_delta = self._delta
                kern = self._kernels.publish_keep
            else:
                kern = self._kernels.publish
            merged, delta = kern(self._front.sketch, self._delta)  # donates: 1
            self._front = Snapshot(
                self._tenant_id,
                self._front.epoch + 1,
                merged,
                self._kind,
                self._front.n_edges + pending,
            )
            self._delta = delta
            self._pending = jnp.zeros_like(self._pending)
            return self._front

    def adopt_published(self, sketch: Any, epoch: int, n_edges: int, *,
                        delta: Any = None,
                        base_epoch: int | None = None) -> Snapshot:
        """Install an externally-produced published front (runtime/backend.py).

        The remote execution backends fold batches into a sketch living in
        a child process and ship each published epoch back; this swaps that
        state in as the new front WITHOUT touching the local delta (which
        stays empty — the remote side owns the write path).  Same isolation
        contract as ``publish``: readers holding the previous front keep a
        consistent immutable epoch.  The caller must adopt epochs in
        publication order (the backend's FIFO result pipe guarantees that).

        Two modes:

          full   ``sketch`` is the worker's whole published front;
                 installed verbatim (replace).
          delta  ``sketch`` is ignored; ``delta`` is the pytree the worker
                 accumulated since its previous publish, and is folded into
                 the current front through the SAME jitted merge the
                 worker's own publish used — bit-identical counters on both
                 sides.  ``base_epoch`` must equal the current front epoch
                 or the fold would mis-count: any gap raises
                 :class:`StaleDelta` (the transport then requests a
                 full-leaves resync).
        """
        with self._lock:
            if delta is not None:
                if base_epoch is None or int(base_epoch) != self._front.epoch:
                    raise StaleDelta(
                        f"delta publish for epoch {epoch} is based on epoch "
                        f"{base_epoch}, but the front is at epoch "
                        f"{self._front.epoch}; a full resync is required")
                # publish_keep, never the donating kernel: the incoming
                # delta's leaves are decoded wire views whose host buffers
                # the transport still owns — donation would write into them
                sketch, _ = self._kernels.publish_keep(
                    self._front.sketch, delta)
            self._front = Snapshot(self._tenant_id, int(epoch),
                                   sketch, self._kind, int(n_edges))
            return self._front

    # ------------------------------------------------------------ checkpoint
    def state(self) -> dict:
        """Mutually-consistent (front, delta, pending, epoch, n_edges) view.

        The returned pytrees are immutable JAX arrays, so the caller can
        serialize them outside the lock (crash-safe checkpointing in
        ``repro.runtime``).  The front is always safe to hand out by
        reference (it is never donated); with donation on, the delta and
        pending are handed out as PRIVATE COPIES — the live references get
        donated (deleted) by the very next ingest, which would leave the
        caller serializing dead buffers.
        """
        with self._lock:
            delta, pending = self._delta, self._pending
            if self.donate:
                delta = _private_copy(delta)
                pending = jnp.array(pending, copy=True)
            return {
                "front": self._front.sketch,
                "delta": delta,
                "pending": pending,
                "epoch": self._front.epoch,
                "n_edges": self._front.n_edges,
            }

    def load_state(self, state: dict) -> Snapshot:
        """Restore a checkpointed ``state()`` (same sketch layout required)."""
        with self._lock:
            self._front = Snapshot(
                self._tenant_id,
                int(state["epoch"]),
                jax.tree_util.tree_map(jnp.asarray, state["front"]),
                self._kind,
                int(state["n_edges"]),
            )
            # jnp.asarray is a zero-copy identity on device arrays and can
            # share memory with host numpy buffers on CPU; a delta about to
            # be donated must own private buffers, so copy outright
            restore = _private_copy if self.donate \
                else (lambda t: jax.tree_util.tree_map(jnp.asarray, t))
            self._delta = restore(state["delta"])
            self._pending = jnp.array(state["pending"],
                                      dtype=self._pending.dtype, copy=True)
            return self._front
