"""Architecture registry: every assigned arch as a selectable config, plus
the cell builder the dry-run uses (step fn + input specs + shardings).

Cells = (arch x applicable shape). Skips (DESIGN.md §Arch-applicability):
  internlm2-20b, grok-1-314b: pure full attention -> long_500k skipped.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import lm as lm_configs
from repro.configs.shapes import (
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
    sampled_subgraph_sizes,
)
from repro.training.optimizer import AdamWConfig
from repro.training.steps import TrainState, init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str  # "lm" | "gnn" | "recsys"
    config: Any
    shape_names: tuple[str, ...]


def _gnn_configs():
    from repro.models.gnn.equiformer_v2 import EquiformerV2Config
    from repro.models.gnn.gatedgcn import GatedGCNConfig
    from repro.models.gnn.graphcast import GraphCastConfig
    from repro.models.gnn.nequip import NequIPConfig

    return {
        "graphcast": GraphCastConfig(n_layers=16, d_hidden=512, n_vars=227,
                                     remat=True, latent_dtype="bfloat16"),
        "gatedgcn": GatedGCNConfig(n_layers=16, d_hidden=70, d_out=64, remat=True),
        "equiformer-v2": EquiformerV2Config(
            n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8, remat=True
        ),
        "nequip": NequIPConfig(
            n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0, remat=True
        ),
    }


def _fm_config():
    from repro.models.recsys.fm import FMConfig

    return FMConfig(n_fields=39, embed_dim=10, total_vocab=10_000_000)


@functools.lru_cache(maxsize=1)
def archs() -> dict[str, Arch]:
    lm_shapes_full = tuple(LM_SHAPES)
    lm_shapes_fullattn = ("train_4k", "prefill_32k", "decode_32k")  # skip 500k
    gnn_shapes = tuple(GNN_SHAPES)
    out: dict[str, Arch] = {}
    for name, cfg in lm_configs.LM_CONFIGS.items():
        shapes = lm_shapes_fullattn if cfg.is_pure_global else lm_shapes_full
        out[name] = Arch(name, "lm", cfg, shapes)
    for name, cfg in _gnn_configs().items():
        out[name] = Arch(name, "gnn", cfg, gnn_shapes)
    out["fm"] = Arch("fm", "recsys", _fm_config(), tuple(RECSYS_SHAPES))
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a.name, s) for a in archs().values() for s in a.shape_names]


# ------------------------------------------------------------ cell build --

@dataclasses.dataclass
class Cell:
    """Everything the dry-run needs for one (arch, shape)."""

    arch: str
    shape: str
    step_fn: Callable  # (state..., inputs...) per family
    arg_shapes: tuple  # ShapeDtypeStructs matching step_fn args
    in_specs: tuple
    out_specs: Any
    model_flops_per_step: float  # 6*N*D (dense) / 6*N_active*D (MoE)
    donate: tuple = ()  # arg indices donated (train state / KV cache alias)


def _lm_opt_cfg() -> AdamWConfig:
    return AdamWConfig(lr_peak=3e-4, warmup_steps=200, total_steps=10_000,
                       moment_dtype="bfloat16")


def build_lm_cell(arch: Arch, shape_name: str, mesh) -> Cell:
    from repro.launch.shardings import (
        lm_batch_specs,
        lm_cache_specs,
        lm_param_specs,
        opt_state_specs,
    )
    from repro.models.transformer import model as tmodel

    cfg = arch.config
    shape = LM_SHAPES[shape_name]
    from repro.launch.mesh import dp_axes

    if shape.kind == "train":
        # sequence-parallel saved activations (§Perf iteration 4) +
        # shard-local MoE dispatch groups (§Perf iteration 6)
        dp = tuple(dp_axes(mesh))
        dp_ways = int(np.prod([mesh.shape[a] for a in dp]))
        cfg = dataclasses.replace(
            cfg, seq_parallel=dp, zero3_gather=True,
            moe_groups=dp_ways if arch.config.is_moe else 1)
    else:
        # serve cells: ZeRO-3 storage + gather-at-use; MoE dispatch still
        # needs shard-local groups (prefill routes B*S tokens!). Setting
        # seq_parallel only feeds group_axes/embed-bwd here — the prefill/
        # decode bodies never apply the train-side carry constraint.
        dp = tuple(dp_axes(mesh))
        dp_ways = int(np.prod([mesh.shape[a] for a in dp]))
        cfg = dataclasses.replace(
            cfg, zero3_gather=True,
            seq_parallel=dp if arch.config.is_moe else None,
            moe_groups=dp_ways if arch.config.is_moe else 1)
    b, s = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        lambda: tmodel.init_params(cfg, jax.random.PRNGKey(0))
    )
    p_specs = lm_param_specs(params_shape, mesh)

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        opt_cfg = _lm_opt_cfg()
        state_shape = jax.eval_shape(
            lambda: init_train_state(
                jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params_shape),
                opt_cfg,
            )
        )
        state_specs = TrainState(params=p_specs, opt=opt_state_specs(p_specs))
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        from repro.training.steps import lm_loss_fn

        # accum=1: microbatching measured NO peak reduction here (§Perf
        # iteration 3, refuted — peak is carry-stack-bound, not per-pass);
        # seq_parallel is the lever that works.
        step = make_train_step(lm_loss_fn(cfg), opt_cfg, accum_steps=1)
        flops = 6.0 * n_active * b * s  # fwd+bwd per step
        return Cell(arch.name, shape_name, step, (state_shape, batch_shape),
                    (state_specs, lm_batch_specs(mesh)),
                    (state_specs, None),  # pin state out-shardings: without
                    # this XLA may choose replicated optimizer updates for
                    # big embeddings (measured 6x 5.25 GiB f32) and donation
                    # silently fails
                    flops, donate=(0,))

    cache_shape = jax.eval_shape(
        lambda: tmodel.init_cache(cfg, b, s, dtype=jnp.bfloat16)
    )
    c_specs = lm_cache_specs(cache_shape, mesh, batch=b, kind=shape.kind)
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh) if b > 1 else ()
    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def step(params, toks, cache):
            return tmodel.prefill(cfg, params, toks, cache)

        flops = 2.0 * n_active * b * s
        return Cell(arch.name, shape_name, step,
                    (params_shape, tokens, cache_shape),
                    (p_specs, P(dp, None), c_specs),
                    (None, c_specs),  # pin cache out-sharding (donation)
                    flops, donate=(2,))

    # decode: one new token against a cache of length s
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def step(params, toks, cache):
        return tmodel.decode_step(cfg, params, toks, cache)

    flops = 2.0 * n_active * b  # per generated token
    return Cell(arch.name, shape_name, step,
                (params_shape, tokens, cache_shape),
                (p_specs, P(dp, None), c_specs),
                (None, c_specs),  # pin cache out-sharding (donation)
                flops, donate=(2,))


def _gnn_forward_and_loss(arch: Arch):
    from repro.models.gnn import equiformer_v2, gatedgcn, graphcast, nequip
    from repro.training import steps as tsteps

    cfg = arch.config
    if arch.name == "gatedgcn":
        return gatedgcn, tsteps.gnn_node_class_loss_fn(cfg, gatedgcn.forward, cfg.d_out)
    if arch.name == "graphcast":
        def loss_fn(params, batch):
            g = batch["graph"]
            pred = graphcast.forward(cfg, params, g)
            loss = jnp.mean((pred - batch["target"]) ** 2)
            return loss, {"mse": loss}
        return graphcast, loss_fn
    if arch.name == "nequip":
        def loss_fn(params, batch):
            g = batch["graph"]
            e = nequip.energy(cfg, params, g, g.positions)
            loss = jnp.mean((e - batch["energy"]) ** 2)
            return loss, {"e_mse": loss}
        return nequip, loss_fn
    if arch.name == "equiformer-v2":
        def loss_fn(params, batch):
            g = batch["graph"]
            e = equiformer_v2.forward(cfg, params, g)
            loss = jnp.mean((e - batch["energy"]) ** 2)
            return loss, {"e_mse": loss}
        return equiformer_v2, loss_fn
    raise KeyError(arch.name)


def _gnn_graph_shape(arch: Arch, shape_name: str):
    """ShapeDtypeStruct GraphBatch for a GNN shape."""
    from repro.models.gnn.graph import GraphBatch

    shape = GNN_SHAPES[shape_name]
    if shape.kind == "sampled":
        n, e = sampled_subgraph_sizes(shape)
        n_graphs = 1
    elif shape.kind == "batched":
        n = shape.n_nodes * shape.batch_graphs
        e = shape.n_edges * shape.batch_graphs
        n_graphs = shape.batch_graphs
    else:
        n, e = shape.n_nodes, shape.n_edges
        n_graphs = 1
    # pad node/edge counts to multiples of 512 so they shard on any mesh
    # (masks zero the padding; segment ops ignore it)
    n = -(-n // 512) * 512
    e = -(-e // 512) * 512
    d_feat = shape.d_feat
    f32 = jnp.float32
    return GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n, d_feat), f32),
        edge_src=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_dst=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_feat=jax.ShapeDtypeStruct((e, 8), f32),
        positions=jax.ShapeDtypeStruct((n, 3), f32),
        node_mask=jax.ShapeDtypeStruct((n,), f32),
        edge_mask=jax.ShapeDtypeStruct((e,), f32),
        graph_id=jax.ShapeDtypeStruct((n,), jnp.int32),
        n_graphs=n_graphs,
    ), n, e


def build_gnn_cell(arch: Arch, shape_name: str, mesh) -> Cell:
    from repro.launch.shardings import (
        gnn_graph_specs,
        gnn_param_specs,
        opt_state_specs,
    )

    cfg = arch.config
    g_shape, n, e = _gnn_graph_shape(arch, shape_name)
    shape = GNN_SHAPES[shape_name]
    module, loss_fn = _gnn_forward_and_loss(arch)

    d_in = shape.d_feat
    if arch.name == "graphcast":
        d_in = cfg.n_vars
        g_shape = g_shape.replace(
            node_feat=jax.ShapeDtypeStruct((n, cfg.n_vars), jnp.float32)
        )
        init = lambda: module.init_params(cfg, jax.random.PRNGKey(0))
    else:
        init = lambda: module.init_params(cfg, jax.random.PRNGKey(0), d_in)

    params_shape = jax.eval_shape(init)
    p_specs = gnn_param_specs(params_shape)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=100, total_steps=5000)
    state_shape = jax.eval_shape(
        lambda: init_train_state(
            jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params_shape),
            opt_cfg,
        )
    )
    state_specs = TrainState(params=p_specs, opt=opt_state_specs(p_specs))

    batch_shape: dict[str, Any] = {"graph": g_shape}
    g_specs = gnn_graph_specs(mesh, n_graphs=g_shape.n_graphs)
    batch_specs: dict[str, Any] = {"graph": g_specs}
    ax = tuple(mesh.axis_names)
    if arch.name == "gatedgcn":
        batch_shape["labels"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        batch_specs["labels"] = P(ax)
    elif arch.name == "graphcast":
        batch_shape["target"] = jax.ShapeDtypeStruct((n, cfg.n_vars), jnp.float32)
        batch_specs["target"] = P(ax, None)
    else:
        ng = g_shape.n_graphs
        batch_shape["energy"] = jax.ShapeDtypeStruct((ng,), jnp.float32)
        batch_specs["energy"] = P()

    step = make_train_step(loss_fn, opt_cfg)
    # FLOPs estimate for GNNs: dominated by per-edge work; report param-based
    # proxy 6 * params * nodes (documented in EXPERIMENTS.md §Roofline).
    from repro.models.common import count_params

    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params_shape)
    )
    flops = 6.0 * n_params * n
    return Cell(arch.name, shape_name, step, (state_shape, batch_shape),
                (state_specs, batch_specs), (state_specs, None), flops,
                donate=(0,))


def build_fm_cell(arch: Arch, shape_name: str, mesh) -> Cell:
    from repro.launch.mesh import dp_axes
    from repro.launch.shardings import fm_batch_specs, fm_param_specs, opt_state_specs
    from repro.models.recsys import fm as fm_mod

    cfg = arch.config
    shape = RECSYS_SHAPES[shape_name]
    params_shape = jax.eval_shape(lambda: fm_mod.init_params(cfg, jax.random.PRNGKey(0)))
    p_specs = fm_param_specs(params_shape, mesh)
    dp = dp_axes(mesh)
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params_shape)
    )

    if shape.kind == "train":
        opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=100, total_steps=5000)
        state_shape = jax.eval_shape(
            lambda: init_train_state(
                jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params_shape),
                opt_cfg,
            )
        )
        from repro.training.steps import fm_loss_fn

        state_specs = TrainState(params=p_specs, opt=opt_state_specs(p_specs))
        batch_shape = {
            "ids": jax.ShapeDtypeStruct((shape.batch, cfg.n_fields), jnp.int32),
            "labels": jax.ShapeDtypeStruct((shape.batch,), jnp.float32),
        }
        step = make_train_step(fm_loss_fn(cfg), opt_cfg)
        # FM step FLOPs ~ 3 passes * 2 * B * F * k (interaction) — tiny vs gather
        flops = 6.0 * shape.batch * cfg.n_fields * cfg.embed_dim
        return Cell(arch.name, shape_name, step, (state_shape, batch_shape),
                    (state_specs, fm_batch_specs(mesh)), (state_specs, None),
                    flops, donate=(0,))

    if shape.kind == "serve":
        ids = jax.ShapeDtypeStruct((shape.batch, cfg.n_fields), jnp.int32)

        def step(params, ids_):
            return fm_mod.forward(cfg, params, ids_)

        flops = 2.0 * shape.batch * cfg.n_fields * cfg.embed_dim
        return Cell(arch.name, shape_name, step, (params_shape, ids),
                    (p_specs, P(dp, None)), None, flops)

    # retrieval: 1 query x n_candidates (candidates over DP axes only:
    # 1e6 isn't divisible by 256/512, but is by 16/32)
    q = jax.ShapeDtypeStruct((cfg.n_fields,), jnp.int32)
    cands = jax.ShapeDtypeStruct((shape.n_candidates, cfg.n_fields), jnp.int32)

    def step(params, q_, cands_):
        return fm_mod.retrieval_scores(cfg, params, q_, cands_)

    flops = 2.0 * shape.n_candidates * cfg.n_fields * cfg.embed_dim
    return Cell(arch.name, shape_name, step, (params_shape, q, cands),
                (p_specs, P(), P(dp, None)), None, flops)


def build_cell(arch_name: str, shape_name: str, mesh) -> Cell:
    arch = archs()[arch_name]
    if shape_name not in arch.shape_names:
        raise ValueError(f"{arch_name} does not run shape {shape_name} "
                         f"(see DESIGN.md §Arch-applicability)")
    if arch.family == "lm":
        return build_lm_cell(arch, shape_name, mesh)
    if arch.family == "gnn":
        return build_gnn_cell(arch, shape_name, mesh)
    return build_fm_cell(arch, shape_name, mesh)
