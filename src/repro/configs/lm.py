"""The five assigned LM-transformer architecture configs.

Every config cites its source; numbers come verbatim from the assignment
table. ``reduced()`` returns the same topology at smoke-test scale (same
layer pattern / MoE / softcap structure, tiny dims) for CPU tests.
"""
from __future__ import annotations

import dataclasses

from repro.models.transformer.config import TransformerConfig

# [arXiv:2408.00118; hf] — local+global alternating, logit softcaps.
GEMMA2_2B = TransformerConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab=256_000,
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    gated_mlp=True,
    tie_embed=True,
    embed_scale=True,
    post_norms=True,
)

# [arXiv:2403.17297; hf] — GQA, pure global attention.
INTERNLM2_20B = TransformerConfig(
    name="internlm2-20b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=92_544,
    layer_pattern=("global",),
    act="silu",
    gated_mlp=True,
    tie_embed=False,
    rope_theta=1_000_000.0,
)

# [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, qk-norm, 128k ctx.
GEMMA3_27B = TransformerConfig(
    name="gemma3-27b",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    act="gelu",
    gated_mlp=True,
    tie_embed=True,
    embed_scale=True,
    post_norms=True,
    rope_theta=1_000_000.0,
)

# [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attention.
MIXTRAL_8X7B = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=32_000,
    layer_pattern=("local",),
    window=4096,
    n_experts=8,
    top_k=2,
    act="silu",
    gated_mlp=True,
    tie_embed=False,
    rope_theta=1_000_000.0,
)

# [hf:xai-org/grok-1; unverified] — 8 experts top-2, attn softcap, global.
GROK1_314B = TransformerConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab=131_072,
    layer_pattern=("global",),
    attn_softcap=30.0,
    final_softcap=30.0,
    n_experts=8,
    top_k=2,
    act="gelu",
    gated_mlp=True,
    tie_embed=True,
)

LM_CONFIGS = {
    c.name: c
    for c in (GEMMA2_2B, INTERNLM2_20B, GEMMA3_27B, MIXTRAL_8X7B, GROK1_314B)
}


def reduced(cfg: TransformerConfig) -> TransformerConfig:
    """Smoke-test scale: same structure (pattern/MoE/softcaps), tiny dims.

    n_layers is chosen so the scan sees >=1 full period AND, when the
    pattern doesn't divide, a remainder tail (exercising the tail path
    exactly like gemma3-27b's 62 = 10*6 + 2 does at full scale).
    """
    per = len(cfg.layer_pattern)
    n_layers = per + max(per // 2, 1) if per > 1 else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab=512,
        window=32,
        n_experts=4 if cfg.is_moe else 0,
        # Dropless at smoke scale (cap >= t * top_k): capacity drops depend
        # on the co-batched token set, so train-forward (s tokens), prefill
        # (s-1) and decode (1) would disagree on which assignments drop and
        # the decode-vs-forward parity tests would compare different models.
        capacity_factor=4.0 if cfg.is_moe else cfg.capacity_factor,
        attn_chunk_q=16,
        attn_chunk_kv=32,
        ce_chunk=32,
        dtype="float32",
        remat=False,
    )
