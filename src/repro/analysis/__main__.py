"""CLI: ``python -m repro.analysis [--gate] [--baseline FILE] ...``.

Exit status under ``--gate``: 0 when every finding is either absent or
suppressed by the baseline AND the baseline carries no stale entries;
1 otherwise.  Without ``--gate`` it prints findings and always exits 0
(exploration mode).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.engine import (
    Project, all_rules, load_baseline, run_rules, split_by_baseline,
)
from repro.analysis import wire_schema


def _find_repo_root(start: Path) -> Path:
    """Walk up until a directory containing ``src/repro`` appears."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(f"cannot locate a src/repro tree above {start}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant lint: trace purity, wire schema "
                    "drift, unpickler allowlist, hot-path pickle, lock "
                    "discipline")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect from this file)")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero on any non-baselined finding")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="suppression file of finding keys (default: "
                    "<root>/analysis_baseline.txt when present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                    "and exit")
    ap.add_argument("--write-wire-lock", action="store_true",
                    help="regenerate src/repro/net/wire_schema.lock from "
                    "the live schema and exit")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="NAME", help="run only this rule (repeatable); "
                    "known: " + ", ".join(n for n, _ in all_rules()))
    args = ap.parse_args(argv)

    root = args.root or _find_repo_root(Path(__file__).parent)
    project = Project.from_root(root)

    if args.write_wire_lock:
        sf = project.get(wire_schema.WIRE_MODULE)
        if sf is None:
            print("wire module not found", file=sys.stderr)
            return 2
        schema = wire_schema.extract_schema(sf.tree)
        lock_path = root / "src" / "repro" / "net" / "wire_schema.lock"
        lock_path.write_text(wire_schema.render_lock(schema))
        print(f"wrote {lock_path} (version {schema['version']})")
        return 0

    known = {n for n, _ in all_rules()}
    if args.rule:
        unknown = set(args.rule) - known
        if unknown:
            print("unknown rule(s): " + ", ".join(sorted(unknown)),
                  file=sys.stderr)
            return 2

    findings = run_rules(project, only=args.rule)

    baseline_path = args.baseline
    if baseline_path is None:
        default = root / "analysis_baseline.txt"
        if default.exists():
            baseline_path = default

    if args.write_baseline:
        target = args.baseline or (root / "analysis_baseline.txt")
        target.write_text(
            "# repro.analysis baseline — one finding key per line.\n"
            "# Keys are line-number free: rule|module|message.\n"
            + "".join(f.key + "\n" for f in findings))
        print(f"wrote {len(findings)} key(s) to {target}")
        return 0

    baseline = load_baseline(baseline_path) if baseline_path else set()
    new, suppressed, stale = split_by_baseline(findings, baseline)

    for f in new:
        print(f.render(project))
    if suppressed:
        print(f"[baseline] {len(suppressed)} finding(s) suppressed",
              file=sys.stderr)
    for key in sorted(stale):
        print(f"[baseline] stale entry (no longer fires): {key}",
              file=sys.stderr)

    if not args.gate:
        return 0
    if new:
        print(f"\nFAIL: {len(new)} finding(s); fix them or record "
              "accepted debt with --write-baseline", file=sys.stderr)
        return 1
    if stale:
        print(f"\nFAIL: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'}; regenerate with "
              "--write-baseline", file=sys.stderr)
        return 1
    print("analysis gate: clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
