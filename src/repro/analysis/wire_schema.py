"""Rule: frame-registry discipline + committed schema fingerprint.

DESIGN.md's bump rules say any change to the wire surface — the frame
registry, the header struct, or the columnar item layout — must bump
``WIRE_VERSION`` (and extend ``COMPAT_VERSIONS`` when the old decoder is
still accepted).  Reviewers enforced that in PRs 6–8; this rule makes it
mechanical:

- frame ids must be unique and frame kinds well-formed
- within each transport-tier dispatcher function, a registered kind is
  handled at most once (double handling == dead elif == decode skew),
  and no dispatcher compares against an unregistered kind string
- when the full transport tier is in view, every registered kind must be
  dispatched *somewhere* (a registered-but-never-handled frame is dead
  weight at best, a silent drop at worst)
- the schema fingerprint (magic, versions, sorted frame registry, every
  top-level ``struct.Struct`` format) must equal the committed
  ``src/repro/net/wire_schema.lock`` — editing the schema without a
  version bump, or bumping without regenerating the lock, fails the gate

Everything is read from the AST of ``repro.net.wire``, so the drift test
can feed a synthetically-edited wire source through the same code path
CI runs.
"""
from __future__ import annotations

import ast
import hashlib

from repro.analysis.engine import Finding, Project, functions_of

RULE = "wire-schema"

WIRE_MODULE = "repro.net.wire"
LOCK_AUX_PATH = "repro/net/wire_schema.lock"

# dispatcher surface: every module that switches on frame kinds
TRANSPORT_MODULES = (
    "repro.net.wire",
    "repro.net.backend",
    "repro.net.ingest_server",
    "repro.net.query_server",
    "repro.runtime.backend",
)


# ------------------------------------------------------------ extraction
def extract_schema(tree: ast.Module) -> dict:
    """Pull the wire schema constants out of a parsed wire module.

    Returns ``{"magic": str, "version": int|None, "compat": list[int],
    "frames": list[(kind, id)], "structs": {name: fmt}}``.  Missing
    pieces stay None/empty — the checker reports them as findings.
    """
    schema: dict = {"magic": None, "version": None, "compat": [],
                    "frames": [], "structs": {}}
    version_name = "WIRE_VERSION"
    for node in tree.body:
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if not targets or value is None:
            continue
        names = [t.id for t in targets if isinstance(t, ast.Name)]
        if not names:
            continue
        name = names[0]
        if name == "MAGIC" and isinstance(value, ast.Constant) \
                and isinstance(value.value, (bytes, str)):
            raw = value.value
            schema["magic"] = raw.decode("ascii", "replace") \
                if isinstance(raw, bytes) else raw
        elif name == version_name and isinstance(value, ast.Constant) \
                and isinstance(value.value, int):
            schema["version"] = value.value
        elif name == "COMPAT_VERSIONS":
            schema["compat"] = _int_collection(value, schema)
        elif name == "FRAME_TYPES" and isinstance(value, ast.Dict):
            for k, v in zip(value.keys, value.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str) \
                        and isinstance(v, ast.Constant) \
                        and isinstance(v.value, int):
                    schema["frames"].append((k.value, v.value))
        elif isinstance(value, ast.Call):
            fmt = _struct_format(value)
            if fmt is not None:
                schema["structs"][name] = fmt
    return schema


def _int_collection(value: ast.expr, schema: dict) -> list[int]:
    """Ints of ``frozenset({2, WIRE_VERSION})``-style literals."""
    out: list[int] = []
    for node in ast.walk(value):
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            out.append(node.value)
        elif isinstance(node, ast.Name) and node.id == "WIRE_VERSION" \
                and schema["version"] is not None:
            out.append(schema["version"])
    return sorted(set(out))


def _struct_format(call: ast.Call) -> str | None:
    func = call.func
    is_struct = (isinstance(func, ast.Attribute) and func.attr == "Struct"
                 and isinstance(func.value, ast.Name)
                 and func.value.id == "struct") or \
                (isinstance(func, ast.Name) and func.id == "Struct")
    if is_struct and call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


# ----------------------------------------------------------- fingerprint
def fingerprint(schema: dict) -> str:
    lines = [f"magic={schema['magic']}",
             f"version={schema['version']}",
             "compat=" + ",".join(str(v) for v in schema["compat"])]
    for kind, fid in sorted(schema["frames"]):
        lines.append(f"frame:{kind}={fid}")
    for name, fmt in sorted(schema["structs"].items()):
        lines.append(f"struct:{name}={fmt}")
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


def render_lock(schema: dict) -> str:
    frames = " ".join(f"{k}={v}" for k, v in sorted(schema["frames"]))
    return (
        "# Wire schema lock — regenerate ONLY alongside a WIRE_VERSION\n"
        "# bump: `python -m repro.analysis --write-wire-lock`.\n"
        "# The gate fails when the live schema in repro/net/wire.py no\n"
        "# longer matches this fingerprint (DESIGN.md §Analysis).\n"
        f"version = {schema['version']}\n"
        f"fingerprint = {fingerprint(schema)}\n"
        f"# frames: {frames}\n"
    )


def parse_lock(text: str) -> tuple[int | None, str | None]:
    version: int | None = None
    digest: str | None = None
    for line in text.splitlines():
        line = line.strip()
        if line.startswith("#") or "=" not in line:
            continue
        key, _, val = line.partition("=")
        key, val = key.strip(), val.strip()
        if key == "version":
            try:
                version = int(val)
            except ValueError:
                pass
        elif key == "fingerprint":
            digest = val
    return version, digest


# ------------------------------------------------------------ dispatcher
def _kind_side(node: ast.expr) -> bool:
    """Is this expression a frame-kind carrier (``kind`` or ``msg[0]``)?"""
    if isinstance(node, ast.Name) and node.id == "kind":
        return True
    if isinstance(node, ast.Subscript):
        sl = node.slice
        return isinstance(sl, ast.Constant) and sl.value == 0
    return False


def _kind_literals(func_node: ast.AST) -> list[tuple[str, int]]:
    """(literal, lineno) for every frame-kind comparison in a function."""
    out: list[tuple[str, int]] = []
    for node in ast.walk(func_node):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        left, right = node.left, node.comparators[0]
        op = node.ops[0]
        if isinstance(op, (ast.Eq, ast.NotEq)):
            for a, b in ((left, right), (right, left)):
                if _kind_side(a) and isinstance(b, ast.Constant) \
                        and isinstance(b.value, str):
                    out.append((b.value, node.lineno))
        elif isinstance(op, (ast.In, ast.NotIn)) and _kind_side(left) \
                and isinstance(right, (ast.Tuple, ast.List, ast.Set)):
            for el in right.elts:
                if isinstance(el, ast.Constant) \
                        and isinstance(el.value, str):
                    out.append((el.value, node.lineno))
    return out


# ----------------------------------------------------------------- check
def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    sf = project.get(WIRE_MODULE)
    if sf is None:
        return findings
    schema = extract_schema(sf.tree)

    if schema["version"] is None:
        findings.append(Finding(RULE, WIRE_MODULE, 1,
                                "WIRE_VERSION constant not found"))
    if not schema["frames"]:
        findings.append(Finding(RULE, WIRE_MODULE, 1,
                                "FRAME_TYPES registry not found or empty"))

    by_id: dict[int, str] = {}
    kinds: set[str] = set()
    for kind, fid in schema["frames"]:
        if kind in kinds:
            findings.append(Finding(
                RULE, WIRE_MODULE, 1,
                f"frame kind {kind!r} registered twice"))
        kinds.add(kind)
        if fid in by_id:
            findings.append(Finding(
                RULE, WIRE_MODULE, 1,
                f"frame id {fid} reused by {by_id[fid]!r} and {kind!r}"))
        else:
            by_id[fid] = kind

    # dispatcher discipline over whatever transport modules are in view
    mentioned: set[str] = set()
    for mod in TRANSPORT_MODULES:
        tsf = project.get(mod)
        if tsf is None:
            continue
        for qual, _cls, node in functions_of(tsf.tree):
            counts: dict[str, int] = {}
            lines: dict[str, int] = {}
            for lit, lineno in _kind_literals(node):
                counts[lit] = counts.get(lit, 0) + 1
                lines.setdefault(lit, lineno)
            for lit, n in sorted(counts.items()):
                mentioned.add(lit)
                if kinds and lit not in kinds:
                    findings.append(Finding(
                        RULE, mod, lines[lit],
                        f"dispatcher {qual!r} switches on unregistered "
                        f"frame kind {lit!r}"))
                if n > 1:
                    findings.append(Finding(
                        RULE, mod, lines[lit],
                        f"dispatcher {qual!r} handles frame kind {lit!r} "
                        f"{n} times"))
    if all(project.get(m) is not None for m in TRANSPORT_MODULES):
        for kind in sorted(kinds - mentioned):
            findings.append(Finding(
                RULE, WIRE_MODULE, 1,
                f"frame kind {kind!r} is registered but never dispatched "
                "by any transport module"))

    # committed fingerprint vs live schema
    lock_text = project.aux.get(LOCK_AUX_PATH)
    if lock_text is None:
        findings.append(Finding(
            RULE, WIRE_MODULE, 1,
            "missing committed wire_schema.lock "
            "(generate: python -m repro.analysis --write-wire-lock)"))
        return findings
    lock_version, lock_digest = parse_lock(lock_text)
    live_digest = fingerprint(schema)
    if lock_version != schema["version"]:
        findings.append(Finding(
            RULE, WIRE_MODULE, 1,
            f"wire_schema.lock records version {lock_version} but "
            f"WIRE_VERSION is {schema['version']} — regenerate the lock "
            "alongside the bump (--write-wire-lock)"))
    elif lock_digest != live_digest:
        findings.append(Finding(
            RULE, WIRE_MODULE, 1,
            "wire schema changed without a WIRE_VERSION bump "
            f"(lock fingerprint {str(lock_digest)[:12]}… != live "
            f"{live_digest[:12]}…)"))
    return findings
