"""Rule: no reads of a buffer after it was donated into a jit call.

``jax.jit(..., donate_argnums=...)`` transfers ownership of the argument
buffer to the compiled computation: on this backend the donated Array is
*deleted* the moment the call dispatches, and any later read raises
``RuntimeError: Array has been deleted`` — at runtime, on whichever
input first takes that path.  The ingest fast path (serving/snapshot.py)
leans on donation for its in-place scatter, so the hazard is now a
standing one; this rule makes it a static finding instead of a
production stack trace.

Donating callables are recognised two ways:

- **jit assignments**: ``name = jax.jit(f, donate_argnums=(0,))`` (or an
  attribute target like ``self._step = ...``) binds ``name`` to a
  donating callable; every later ``name(...)`` call site consumes the
  arguments at the donated positions.
- **call-site markers**: a trailing ``# donates: N[,M]`` comment on any
  line of a call marks that call as donating positions N, M.  This
  covers callables the assignment scan cannot resolve (kernels stashed
  in a namedtuple kit, locals passed through aliases) — the marker is a
  reviewed assertion, and this rule is what makes the assertion load-
  bearing.

Checking is a per-function *linear* event simulation.  Every event gets
a ``(line, phase)`` position — loads at phase 0, consumes at the call's
**end line** phase 1 (arguments on continuation lines load before the
call completes), stores at the enclosing statement's end line phase 2 —
so the canonical same-statement rebind

    self._delta, self._pending = self._kernels.ingest(  # donates: 0
        self._delta, batch, self._pending)

orders as load < consume < store and is clean, while any read of the
donated name before a rebind is flagged.  Control flow is deliberately
ignored (events in source order): like the other rules this
under-approximates — a read reachable only on the non-donating branch
of an earlier ``if`` can be missed, but nothing clean is flagged for
the patterns this codebase uses.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.engine import (
    Finding, Project, SourceFile, dotted_name, functions_of, module_imports,
)

RULE = "use-after-donate"

_MARKER = re.compile(r"#\s*donates:\s*([0-9]+(?:\s*,\s*[0-9]+)*)")

_LOAD, _CONSUME, _STORE = 0, 1, 2


def _is_jit_name(canonical: str) -> bool:
    return canonical == "jax.jit" or canonical.endswith(".jax.jit")


def _donate_argnums(call: ast.Call) -> frozenset[int] | None:
    """Donated positions of a ``jax.jit(...)`` call, None if not donating."""
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return frozenset((v.value,))
        if isinstance(v, (ast.Tuple, ast.List)):
            nums = []
            for elt in v.elts:
                if not (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, int)):
                    return None  # dynamic spec: unresolvable, skip
                nums.append(elt.value)
            return frozenset(nums)
        return None
    return None


def _donating_bindings(sf: SourceFile) -> dict[str, frozenset[int]]:
    """Names bound (anywhere in the module) to donating jit callables."""
    mod_aliases, from_imports = module_imports(sf.tree)

    def resolve(name: str) -> str:
        head, _, rest = name.partition(".")
        if head in from_imports:
            m, n = from_imports[head]
            head = f"{m}.{n}"
        elif head in mod_aliases:
            head = mod_aliases[head]
        return f"{head}.{rest}" if rest else head

    out: dict[str, frozenset[int]] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Assign) or \
                not isinstance(node.value, ast.Call):
            continue
        fname = dotted_name(node.value.func)
        if fname is None or not _is_jit_name(resolve(fname)):
            continue
        nums = _donate_argnums(node.value)
        if nums is None:
            continue
        for t in node.targets:
            tname = dotted_name(t)
            if tname is not None:
                out[tname] = nums
    return out


def _marker_argnums(sf: SourceFile, call: ast.Call) -> frozenset[int] | None:
    """``# donates: ...`` positions on any physical line of ``call``."""
    end = getattr(call, "end_lineno", call.lineno) or call.lineno
    for lineno in range(call.lineno, end + 1):
        m = _MARKER.search(sf.line(lineno))
        if m:
            return frozenset(int(p) for p in m.group(1).split(","))
    return None


def _stmt_end(stmt: ast.stmt) -> int:
    return getattr(stmt, "end_lineno", stmt.lineno) or stmt.lineno


def _statements(fn: ast.AST):
    """Every statement in ``fn``'s body, source order (nested included)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.stmt) and node is not fn:
            yield node


def _check_function(sf: SourceFile, qual: str, fn: ast.AST,
                    bindings: dict[str, frozenset[int]],
                    findings: list[Finding]) -> None:
    # pass 1: find consume events (donating calls with resolvable args)
    consumes: list[tuple[int, int, str, str]] = []  # (line, phase, var, via)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        nums = _marker_argnums(sf, node)
        if nums is None and fname is not None:
            nums = bindings.get(fname)
        if nums is None:
            continue
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        for pos in nums:
            if pos >= len(node.args):
                continue
            var = dotted_name(node.args[pos])
            if var is not None:
                consumes.append((end, _CONSUME, var, fname or "<call>"))
    if not consumes:
        return
    tracked = {var for _, _, var, _ in consumes}

    # pass 2: loads and stores of the tracked names
    events: list[tuple[int, int, str, str]] = list(consumes)
    for stmt in _statements(fn):
        for node in ast.walk(stmt):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            var = dotted_name(node)
            if var not in tracked:
                continue
            if isinstance(node.ctx, ast.Store):
                events.append((_stmt_end(stmt), _STORE, var, ""))
            elif isinstance(node.ctx, ast.Load):
                events.append((node.lineno, _LOAD, var, ""))

    events.sort(key=lambda e: (e[0], e[1]))
    consumed: dict[str, tuple[int, str]] = {}
    flagged: set[tuple[str, int]] = set()
    for line, phase, var, via in events:
        if phase == _CONSUME:
            consumed[var] = (line, via)
        elif phase == _STORE:
            consumed.pop(var, None)
        elif var in consumed and (var, line) not in flagged:
            dline, via = consumed[var]
            flagged.add((var, line))
            findings.append(Finding(
                RULE, sf.module, line,
                f"{qual!r} reads `{var}` after it was donated into "
                f"`{via}` (line {dline}); donated buffers are deleted "
                f"at dispatch — rebind before reading"))


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod, sf in sorted(project.files.items()):
        bindings = _donating_bindings(sf)
        if not bindings and "donates:" not in sf.text:
            continue
        for qual, _cls, fn in functions_of(sf.tree):
            _check_function(sf, qual, fn, bindings, findings)
    return findings
