"""Dynamic lock-order witness (the runtime half of the locks rule).

With ``REPRO_LOCK_WITNESS=1`` the test suite calls :func:`install`,
which replaces ``threading.Lock``/``threading.RLock`` with factories
that wrap any lock *allocated from repro code* in a tracking proxy
(``threading.Condition()`` picks the patched RLock up automatically;
locks allocated by the stdlib — queues, logging — stay raw and free).

Each proxy carries its **allocation site** (``file:line`` of the
``threading.Lock()`` call), so every ``SnapshotBuffer`` instance shares
one node, matching the static rule's class-qualified model.  On every
successful acquire the witness appends edges ``held-site -> new-site``
to a global order graph and checks for a path back: a cycle means two
threads can deadlock under the observed orders, and the suite fails even
though this particular run got lucky with timing.  Reentrant RLock
acquires and same-site pairs (two instances of one class, e.g. paired
buffers) are excluded — the latter is a documented under-approximation,
not a bug: site-level identity cannot distinguish instance order.

The witness also enforces the publish invariant dynamically:
:func:`guard_publishes` patches ``SnapshotBuffer.__setattr__`` so any
``_front`` store while ``_lock`` is not held by the storing thread is
recorded as a violation (``# guarded-by(writes): _lock``, enforced at
runtime even for code paths the static rule cannot see).
"""
from __future__ import annotations

import os
import sys
import threading
import traceback

# real factories, captured before any patching
_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock

_REPRO_FRAGMENT = f"{os.sep}repro{os.sep}"
_SELF_FILE = os.path.abspath(__file__)


def _allocation_site() -> str | None:
    """``file:line`` of the nearest caller outside threading/witness
    code; None when the allocation is not repro code."""
    f = sys._getframe(2)
    while f is not None:
        fname = f.f_code.co_filename
        if not (fname.endswith("threading.py")
                or os.path.abspath(fname) == _SELF_FILE):
            rel = os.path.abspath(fname)
            if _REPRO_FRAGMENT not in rel:
                return None
            tail = rel.split(_REPRO_FRAGMENT)[-1]
            return f"repro/{tail.replace(os.sep, '/')}:{f.f_lineno}"
        f = f.f_back
    return None


class LockWitness:
    """Global acquisition-order graph + violation log."""

    def __init__(self) -> None:
        self._mu = _REAL_LOCK()
        self._tls = threading.local()
        # site -> set of successor sites; edge evidence kept separately
        self._graph: dict[str, set[str]] = {}
        self._evidence: dict[tuple[str, str], str] = {}
        self.cycles: list[dict] = []
        self.unlocked_publishes: list[dict] = []
        self._reported: set[tuple[str, ...]] = set()

    # ------------------------------------------------------------ held state
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def holds(self, proxy: "object") -> bool:
        return any(p is proxy for p in self._stack())

    # ------------------------------------------------------------- recording
    def note_acquire(self, proxy: "_WitnessedLockBase") -> None:
        stack = self._stack()
        reentrant = any(p is proxy for p in stack)
        if not reentrant:
            held_sites = []
            seen: set[int] = set()
            for p in stack:
                if id(p) not in seen:
                    seen.add(id(p))
                    held_sites.append(p.site)
            if held_sites:
                self._note_edges(held_sites, proxy.site)
        stack.append(proxy)

    def note_release(self, proxy: "_WitnessedLockBase") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is proxy:
                del stack[i]
                return

    def _note_edges(self, held_sites: list[str], new_site: str) -> None:
        tb = "".join(traceback.format_stack(sys._getframe(3), limit=6))
        with self._mu:
            for held in held_sites:
                if held == new_site:
                    continue  # two instances of one class: site-level blind
                self._graph.setdefault(held, set()).add(new_site)
                self._graph.setdefault(new_site, set())
                self._evidence.setdefault((held, new_site), tb)
                path = self._path(new_site, held)
                if path is not None:
                    cycle = [held, new_site] + path[1:]
                    key = tuple(sorted(set(cycle)))
                    if key not in self._reported:
                        self._reported.add(key)
                        self.cycles.append({
                            "cycle": cycle,
                            "thread": threading.current_thread().name,
                            "forward": tb,
                            "reverse": self._evidence.get(
                                (new_site, path[1] if len(path) > 1
                                 else held), ""),
                        })

    def _path(self, src: str, dst: str) -> list[str] | None:
        """Directed path src ~> dst in the order graph (callers hold _mu)."""
        if src == dst:
            return [src]
        stack = [(src, [src])]
        visited = {src}
        while stack:
            cur, path = stack.pop()
            for nxt in self._graph.get(cur, ()):
                if nxt == dst:
                    return path + [dst]
                if nxt not in visited:
                    visited.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def note_unlocked_publish(self, what: str) -> None:
        with self._mu:
            self.unlocked_publishes.append({
                "what": what,
                "thread": threading.current_thread().name,
                "stack": "".join(traceback.format_stack(
                    sys._getframe(2), limit=8)),
            })

    # --------------------------------------------------------------- reports
    def edge_count(self) -> int:
        with self._mu:
            return sum(len(v) for v in self._graph.values())

    def report(self) -> dict:
        with self._mu:
            return {
                "sites": len(self._graph),
                "edges": sum(len(v) for v in self._graph.values()),
                "cycles": list(self.cycles),
                "unlocked_publishes": list(self.unlocked_publishes),
            }

    def render_violations(self) -> str:
        rep = self.report()
        out: list[str] = []
        for c in rep["cycles"]:
            out.append("lock-order cycle: " + " -> ".join(c["cycle"])
                       + f" (thread {c['thread']})\n"
                       + "forward acquisition:\n" + c["forward"]
                       + ("reverse acquisition:\n" + c["reverse"]
                          if c["reverse"] else ""))
        for p in rep["unlocked_publishes"]:
            out.append(f"publish while unlocked: {p['what']} "
                       f"(thread {p['thread']})\n" + p["stack"])
        return "\n".join(out)


class _WitnessedLockBase:
    """Common acquire/release tracking; subclasses pick the inner lock."""

    def __init__(self, inner, site: str, witness: LockWitness) -> None:
        self._inner = inner
        self.site = site
        self._witness = witness

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._witness.note_acquire(self)
        return ok

    def release(self) -> None:
        self._witness.note_release(self)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<witnessed {type(self._inner).__name__} @ {self.site}>"


class WitnessedLock(_WitnessedLockBase):
    pass


class WitnessedRLock(_WitnessedLockBase):
    # threading.Condition probes for _is_owned; without the delegation its
    # acquire(False) fallback wrongly succeeds on a reentrant lock the
    # current thread already owns.  _release_save/_acquire_restore are
    # deliberately NOT forwarded: Condition then falls back to plain
    # release()/acquire() on this proxy, which keeps the witness's held
    # stack exact across cv.wait().
    def _is_owned(self) -> bool:
        return self._inner._is_owned()


# ------------------------------------------------------------ installation
_witness: LockWitness | None = None
_installed = False


def get_witness() -> LockWitness | None:
    return _witness


def _make_lock():
    site = _allocation_site()
    if site is None or _witness is None:
        return _REAL_LOCK()
    return WitnessedLock(_REAL_LOCK(), site, _witness)


def _make_rlock():
    site = _allocation_site()
    if site is None or _witness is None:
        return _REAL_RLOCK()
    return WitnessedRLock(_REAL_RLOCK(), site, _witness)


def install() -> LockWitness:
    """Patch the lock factories; idempotent.  Returns the witness."""
    global _witness, _installed
    if _witness is None:
        _witness = LockWitness()
    if not _installed:
        threading.Lock = _make_lock
        threading.RLock = _make_rlock
        guard_publishes(_witness)
        _installed = True
    return _witness


def uninstall() -> None:
    global _installed
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    _unguard_publishes()
    _installed = False


_publish_guarded = False


def guard_publishes(witness: LockWitness) -> None:
    """Enforce ``SnapshotBuffer._front  # guarded-by(writes): _lock`` at
    runtime: every `_front` store must come from a thread holding the
    buffer's lock.  (During ``__init__`` the lock does not exist yet —
    those stores are exempt, same as the static rule.)"""
    global _publish_guarded
    if _publish_guarded:
        return
    from repro.serving.snapshot import SnapshotBuffer

    def checked_setattr(self, name, value, _w=witness):
        if name == "_front":
            lock = self.__dict__.get("_lock")
            if lock is not None:
                held = _w.holds(lock) if isinstance(
                    lock, _WitnessedLockBase) else lock.locked()
                if not held:
                    _w.note_unlocked_publish(
                        "SnapshotBuffer._front store outside _lock")
        object.__setattr__(self, name, value)

    SnapshotBuffer.__setattr__ = checked_setattr
    _publish_guarded = True


def _unguard_publishes() -> None:
    global _publish_guarded
    if not _publish_guarded:
        return
    from repro.serving.snapshot import SnapshotBuffer

    try:
        del SnapshotBuffer.__setattr__
    except AttributeError:
        pass
    _publish_guarded = False
