"""Core model for the invariant checker: sources, findings, baseline.

Everything operates on parsed ASTs plus raw source lines (the lock rules
read trailing ``# guarded-by:`` comments, which ``ast`` drops), so a
:class:`Project` can be built either from the repo on disk
(:meth:`Project.from_root`) or from in-memory fixture snippets
(:meth:`Project.from_sources`) — the test suite feeds each rule
deliberately-broken and deliberately-clean sources through the exact
code path the CI gate runs.

Baselines: a baseline file holds one finding *key* per line.  Keys are
``rule|module|message`` — deliberately line-number free, so unrelated
edits above a deferred finding don't un-suppress it.  The shipped tree
targets an *empty* baseline; the mechanism exists for genuinely-deferred
findings only.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a specific site."""

    rule: str
    module: str  # dotted module, e.g. "repro.net.wire"
    line: int
    message: str

    @property
    def key(self) -> str:
        """Stable baseline key — no line number, survives drift above."""
        return f"{self.rule}|{self.module}|{self.message}"

    def render(self, project: "Project | None" = None) -> str:
        loc = self.module
        if project is not None:
            sf = project.files.get(self.module)
            if sf is not None and sf.path:
                loc = sf.path
        return f"{loc}:{self.line}: [{self.rule}] {self.message}"


class SourceFile:
    """One parsed module: dotted name, path (may be ""), text, AST."""

    def __init__(self, module: str, path: str, text: str) -> None:
        self.module = module
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path or f"<{module}>")

    def line(self, lineno: int) -> str:
        """1-based physical source line ("" when out of range)."""
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


class Project:
    """A set of parseable modules plus auxiliary (non-Python) files.

    ``files`` maps dotted module name -> :class:`SourceFile`; ``aux``
    maps posix-style src-relative paths (e.g. ``repro/net/
    wire_schema.lock``) -> text, for committed artifacts rules check.
    """

    def __init__(self, files: dict[str, SourceFile],
                 aux: dict[str, str] | None = None,
                 root: str | None = None) -> None:
        self.files = files
        self.aux = aux or {}
        self.root = root

    @classmethod
    def from_root(cls, root: str) -> "Project":
        """Parse every ``src/repro/**/*.py`` under the repo root."""
        src = os.path.join(root, "src")
        pkg = os.path.join(src, "repro")
        if not os.path.isdir(pkg):
            raise FileNotFoundError(f"no src/repro package under {root!r}")
        files: dict[str, SourceFile] = {}
        for dirpath, dirnames, filenames in os.walk(pkg):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__")
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, src)
                parts = rel[:-3].replace(os.sep, "/").split("/")
                if parts[-1] == "__init__":
                    parts = parts[:-1]
                module = ".".join(parts)
                with open(path, "r", encoding="utf-8") as f:
                    files[module] = SourceFile(module, path, f.read())
        aux: dict[str, str] = {}
        lock_rel = "repro/net/wire_schema.lock"
        lock_path = os.path.join(src, *lock_rel.split("/"))
        if os.path.exists(lock_path):
            with open(lock_path, "r", encoding="utf-8") as f:
                aux[lock_rel] = f.read()
        return cls(files, aux, root=root)

    @classmethod
    def from_sources(cls, sources: dict[str, str],
                     aux: dict[str, str] | None = None) -> "Project":
        """Build a project from in-memory {module: source} (tests)."""
        files = {mod: SourceFile(mod, "", text)
                 for mod, text in sources.items()}
        return cls(files, aux)

    def get(self, module: str) -> SourceFile | None:
        return self.files.get(module)


Rule = Callable[[Project], list[Finding]]


def all_rules() -> list[tuple[str, Rule]]:
    """The registered (name, checker) pairs, in report order.

    Imported lazily so fixture tests can import a single rule module
    without dragging the rest in.
    """
    from repro.analysis import (
        donation, locks, pickle_rules, trace_purity, wire_schema,
    )

    return [
        ("trace-purity", trace_purity.check),
        ("wire-schema", wire_schema.check),
        ("unpickler-allowlist", pickle_rules.check_unpickler),
        ("no-pickle-hot-path", pickle_rules.check_hot_path),
        ("lock-discipline", locks.check),
        ("use-after-donate", donation.check),
    ]


def run_rules(project: Project,
              only: Iterable[str] | None = None) -> list[Finding]:
    wanted = set(only) if only is not None else None
    out: list[Finding] = []
    for name, rule in all_rules():
        if wanted is not None and name not in wanted:
            continue
        out.extend(rule(project))
    out.sort(key=lambda f: (f.module, f.line, f.rule, f.message))
    return out


def load_baseline(path: str) -> set[str]:
    """Read one finding key per line; blank lines and ``#`` comments ok."""
    if not os.path.exists(path):
        return set()
    keys: set[str] = set()
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                keys.add(line)
    return keys


def split_by_baseline(findings: list[Finding], baseline: set[str]
                      ) -> tuple[list[Finding], list[Finding], set[str]]:
    """-> (new, suppressed, stale_baseline_keys)."""
    new: list[Finding] = []
    suppressed: list[Finding] = []
    seen: set[str] = set()
    for f in findings:
        if f.key in baseline:
            suppressed.append(f)
            seen.add(f.key)
        else:
            new.append(f)
    return new, suppressed, baseline - seen


# --------------------------------------------------------------- helpers
# Shared AST utilities used by several rules.

def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def module_imports(tree: ast.Module) -> tuple[dict[str, str], dict[str, tuple[str, str]]]:
    """Top-level imports of a module.

    Returns ``(mod_aliases, from_imports)`` where ``mod_aliases`` maps
    local alias -> imported module (``import numpy as np`` -> ``{"np":
    "numpy"}``) and ``from_imports`` maps local name -> (module, name)
    (``from repro.net import wire`` -> ``{"wire": ("repro.net",
    "wire")}``).  Function-local imports are deliberately included too —
    hot-path modules import lazily.
    """
    mod_aliases: dict[str, str] = {}
    from_imports: dict[str, tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.asname:
                    mod_aliases[alias.asname] = alias.name
                else:
                    top = alias.name.split(".")[0]
                    mod_aliases[top] = top
        elif isinstance(node, ast.ImportFrom):
            if node.module is None or node.level:
                continue
            for alias in node.names:
                from_imports[alias.asname or alias.name] = \
                    (node.module, alias.name)
    return mod_aliases, from_imports


def functions_of(tree: ast.Module):
    """Yield (qualname, class_name_or_None, node) for every def in a
    module: top-level functions and class methods (one level deep, which
    is all this codebase uses)."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, None, node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield f"{node.name}.{sub.name}", node.name, sub
