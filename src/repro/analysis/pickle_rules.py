"""Rules: unpickler-allowlist consistency + no pickle on hot paths.

**unpickler-allowlist** — ``net/wire.py`` decodes every control frame
with a restricted unpickler whose repro surface is the explicit
``_SAFE_REPRO_CLASSES`` map.  The classes that legitimately cross a
pipe/socket are marked ``# wire-type`` at their definition; this rule
keeps the two in lockstep, both ways:

- every ``# wire-type`` marked class appears in the allowlist (or a
  hostile-looking frame rejection is one refactor away)
- every allowlist entry names a live, marked class (a dead entry is
  latent gadget surface: it re-opens the exact module path an attacker
  would want back)

**no-pickle-hot-path** — the v3 item path exists so no pickle byte is
touched per batch.  Modules marked ``# analysis: hot-path`` (whole
module) and functions marked ``# hot-path`` (single def) must not
reference ``pickle`` or ``restricted_loads`` directly.  The check is
deliberately non-transitive: ``decode_message`` legally dispatches
pickled *control* frames and is reachable from hot code — what the rule
forbids is pickle appearing in the hot functions themselves.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import Finding, Project, dotted_name

UNPICKLER_RULE = "unpickler-allowlist"
HOT_RULE = "no-pickle-hot-path"

WIRE_MODULE = "repro.net.wire"
ALLOWLIST_NAME = "_SAFE_REPRO_CLASSES"
WIRE_TYPE_MARKER = "# wire-type"
HOT_MODULE_MARKER = "# analysis: hot-path"
HOT_FUNC_MARKER = "# hot-path"


# ------------------------------------------------------- allowlist rule
def extract_allowlist(tree: ast.Module) -> dict[str, set[str]] | None:
    """``_SAFE_REPRO_CLASSES`` as {module: {class, ...}}; None if absent."""
    for node in tree.body:
        targets: list[ast.expr] = []
        value: ast.expr | None = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        if not any(isinstance(t, ast.Name) and t.id == ALLOWLIST_NAME
                   for t in targets):
            continue
        if not isinstance(value, ast.Dict):
            return None
        out: dict[str, set[str]] = {}
        for k, v in zip(value.keys, value.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                continue
            names: set[str] = set()
            for el in ast.walk(v):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
            out[k.value] = names
        return out
    return None


def _marked_classes(project: Project) -> dict[tuple[str, str], int]:
    """{(module, class): lineno} of every ``# wire-type`` marked class."""
    marked: dict[tuple[str, str], int] = {}
    for mod, sf in project.files.items():
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            candidates = [node.lineno, node.lineno - 1]
            if node.decorator_list:
                candidates.append(node.decorator_list[0].lineno - 1)
            if any(WIRE_TYPE_MARKER in sf.line(ln) for ln in candidates):
                marked[(mod, node.name)] = node.lineno
    return marked


def check_unpickler(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    sf = project.get(WIRE_MODULE)
    if sf is None:
        return findings
    allowlist = extract_allowlist(sf.tree)
    if allowlist is None:
        findings.append(Finding(
            UNPICKLER_RULE, WIRE_MODULE, 1,
            f"{ALLOWLIST_NAME} dict literal not found in the wire module "
            "(the restricted unpickler must enumerate repro classes "
            "explicitly)"))
        return findings
    marked = _marked_classes(project)

    for mod, names in sorted(allowlist.items()):
        target = project.get(mod)
        for name in sorted(names):
            if target is None:
                findings.append(Finding(
                    UNPICKLER_RULE, WIRE_MODULE, 1,
                    f"allowlist entry {mod}.{name} is dead: module "
                    f"{mod!r} does not exist (latent gadget surface)"))
                continue
            defined = any(isinstance(n, ast.ClassDef) and n.name == name
                          for n in ast.walk(target.tree))
            if not defined:
                findings.append(Finding(
                    UNPICKLER_RULE, WIRE_MODULE, 1,
                    f"allowlist entry {mod}.{name} is dead: no such class "
                    f"in {mod} (latent gadget surface)"))
            elif (mod, name) not in marked:
                findings.append(Finding(
                    UNPICKLER_RULE, mod, 1,
                    f"class {name!r} is in the unpickler allowlist but not "
                    f"marked `{WIRE_TYPE_MARKER}` at its definition"))

    for (mod, name), lineno in sorted(marked.items()):
        if name not in allowlist.get(mod, set()):
            findings.append(Finding(
                UNPICKLER_RULE, mod, lineno,
                f"class {name!r} is marked `{WIRE_TYPE_MARKER}` but missing "
                f"from {ALLOWLIST_NAME} in the wire module — it cannot "
                "cross a transport"))
    return findings


# -------------------------------------------------------- hot-path rule
def _pickle_refs(node: ast.AST, pickle_aliases: set[str]) -> list[tuple[int, str]]:
    refs: list[tuple[int, str]] = []
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Import, ast.ImportFrom)):
            continue  # the import line itself is reported separately
        name = None
        if isinstance(sub, ast.Attribute):
            name = dotted_name(sub)
        elif isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            name = sub.id
        if name is None:
            continue
        parts = name.split(".")
        if parts[0] == "pickle" or parts[0] in pickle_aliases \
                or parts[-1] == "restricted_loads":
            refs.append((sub.lineno, name))
    # an Attribute walk also yields its inner Name: keep one (the longest
    # dotted form) reference per line
    best: dict[int, str] = {}
    for lineno, name in refs:
        if len(name) > len(best.get(lineno, "")):
            best[lineno] = name
    return sorted(best.items())


def check_hot_path(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for mod, sf in sorted(project.files.items()):
        # names bound from pickle by a from-import anywhere in the module
        pickle_aliases: set[str] = set()
        import_lines: list[tuple[int, str]] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] == "pickle":
                        pickle_aliases.add(alias.asname
                                           or alias.name.split(".")[0])
                        import_lines.append((node.lineno, alias.name))
            elif isinstance(node, ast.ImportFrom):
                if node.module and node.module.split(".")[0] == "pickle":
                    for alias in node.names:
                        pickle_aliases.add(alias.asname or alias.name)
                        import_lines.append(
                            (node.lineno, f"{node.module}.{alias.name}"))

        module_hot = any(HOT_MODULE_MARKER in line
                         for line in sf.lines[:40])
        if module_hot:
            for lineno, what in import_lines:
                findings.append(Finding(
                    HOT_RULE, mod, lineno,
                    f"hot-path module imports {what} (marked "
                    f"`{HOT_MODULE_MARKER}`: no pickle allowed)"))
            for lineno, what in sorted(set(_pickle_refs(
                    sf.tree, pickle_aliases))):
                findings.append(Finding(
                    HOT_RULE, mod, lineno,
                    f"hot-path module references `{what}`"))
            continue

        for node in ast.walk(sf.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            def_line = sf.line(node.lineno)
            if HOT_FUNC_MARKER not in def_line:
                continue
            for lineno, what in sorted(set(_pickle_refs(
                    node, pickle_aliases))):
                findings.append(Finding(
                    HOT_RULE, mod, lineno,
                    f"hot-path function {node.name!r} references `{what}`"))
    return findings
