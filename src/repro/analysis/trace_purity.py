"""Rule: functions reachable from jit/Pallas sites stay trace-pure.

Anything jax traces runs at *trace* time, not per call: a ``time.time()``
inside a jitted function samples the clock once and bakes the constant
into the compiled graph; a lock acquisition can deadlock under jit
caching; a MetricsHub ``inc()`` silently counts compilations instead of
calls.  PR 7 kept instruments out of traced code by convention — this
rule enforces it.

Roots are collected per module:

- ``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` decorated defs
- ``jax.jit(f)`` / ``pl.pallas_call(kernel, ...)`` call sites, where the
  traced argument is a plain name, a ``self.method``, a project-module
  attribute, or an inline lambda

From the roots we BFS a conservative call graph: plain-name calls into
the same module, ``self.method`` calls within the same class, and
``alias.fn`` calls through project-module imports.  Dynamic references
(``mod.ingest`` where ``mod`` is a parameter) are unresolvable and
deliberately skipped — the rule under-approximates reachability rather
than spray false positives.
"""
from __future__ import annotations

import ast

from repro.analysis.engine import (
    Finding, Project, SourceFile, dotted_name, functions_of, module_imports,
)

RULE = "trace-purity"

_HUB_METHODS = frozenset({"inc", "observe", "observe_n", "observe_many"})


def _resolve_dotted(name: str, mod_aliases: dict[str, str],
                    from_imports: dict[str, tuple[str, str]]) -> str:
    """Canonicalize a dotted name through the module's imports."""
    head, _, rest = name.partition(".")
    if head in from_imports:
        m, n = from_imports[head]
        head = f"{m}.{n}"
    elif head in mod_aliases:
        head = mod_aliases[head]
    return f"{head}.{rest}" if rest else head


class _ModuleIndex:
    """Per-module lookup tables shared by root collection and the BFS."""

    def __init__(self, sf: SourceFile) -> None:
        self.sf = sf
        self.mod_aliases, self.from_imports = module_imports(sf.tree)
        self.functions: dict[str, ast.AST] = {}   # qualname -> def node
        self.by_class: dict[str, dict[str, str]] = {}
        for qual, cls, node in functions_of(sf.tree):
            self.functions[qual] = node
            if cls is not None:
                self.by_class.setdefault(cls, {})[node.name] = qual
        self.top_level = {q for q in self.functions if "." not in q}
        self.globals: set[str] = set()
        for node in sf.tree.body:
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        self.globals.add(t.id)
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                self.globals.add(node.target.id)

    def resolve(self, name: str) -> str:
        return _resolve_dotted(name, self.mod_aliases, self.from_imports)


def _is_jit_name(canonical: str) -> bool:
    return canonical == "jax.jit" or canonical.endswith(".jax.jit")


def _is_pallas_call(canonical: str) -> bool:
    return canonical.split(".")[-1] == "pallas_call" and \
        canonical.startswith("jax.")


def _jit_roots(idx: _ModuleIndex) -> list[tuple[ast.AST, str]]:
    """(node, display-name) pairs of traced entry points in one module."""
    roots: list[tuple[ast.AST, str]] = []

    def note_traced_arg(arg: ast.AST) -> None:
        if isinstance(arg, ast.Lambda):
            roots.append((arg, f"<lambda:{arg.lineno}>"))
            return
        name = dotted_name(arg)
        if name is None:
            return
        if name in idx.top_level:
            roots.append((idx.functions[name], name))
            return
        if name.startswith("self."):
            meth = name[len("self."):]
            for cls, methods in idx.by_class.items():
                if meth in methods:
                    roots.append((idx.functions[methods[meth]],
                                  methods[meth]))
        # anything else (parameter attributes, foreign modules) is a
        # dynamic reference this rule cannot resolve — skipped

    for qual, _cls, node in functions_of(idx.sf.tree):
        for dec in node.decorator_list:
            dname = dotted_name(dec)
            if dname is not None and _is_jit_name(idx.resolve(dname)):
                roots.append((node, qual))
                continue
            if isinstance(dec, ast.Call):
                cname = dotted_name(dec.func)
                if cname is None:
                    continue
                canonical = idx.resolve(cname)
                if _is_jit_name(canonical):
                    roots.append((node, qual))
                elif canonical.split(".")[-1] == "partial" and dec.args:
                    inner = dotted_name(dec.args[0])
                    if inner is not None and \
                            _is_jit_name(idx.resolve(inner)):
                        roots.append((node, qual))

    for node in ast.walk(idx.sf.tree):
        if not isinstance(node, ast.Call):
            continue
        cname = dotted_name(node.func)
        if cname is None:
            continue
        canonical = idx.resolve(cname)
        if (_is_jit_name(canonical) or _is_pallas_call(canonical)) \
                and node.args:
            note_traced_arg(node.args[0])
    return roots


def _out_edges(node: ast.AST, idx: _ModuleIndex, cls: str | None,
               project: Project) -> list[tuple[str, str]]:
    """(module, qualname) functions referenced from ``node``'s body."""
    edges: list[tuple[str, str]] = []
    mod = idx.sf.module
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Call):
            name = dotted_name(sub.func)
        elif isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load):
            name = sub.id
        if name is None:
            continue
        if name in idx.top_level:
            edges.append((mod, name))
            continue
        if name.startswith("self.") and cls is not None:
            meth = name[len("self."):]
            qual = idx.by_class.get(cls, {}).get(meth)
            if qual is not None:
                edges.append((mod, qual))
            continue
        head, _, rest = name.partition(".")
        if not rest or "." in rest:
            continue
        target_mod = None
        if head in idx.from_imports:
            m, n = idx.from_imports[head]
            target_mod = f"{m}.{n}"
        elif head in idx.mod_aliases:
            target_mod = idx.mod_aliases[head]
        if target_mod is not None and project.get(target_mod) is not None:
            edges.append((target_mod, rest))
    return edges


def _check_body(node: ast.AST, qual: str, idx: _ModuleIndex,
                findings: list[Finding]) -> None:
    mod = idx.sf.module

    def flag(lineno: int, what: str) -> None:
        findings.append(Finding(RULE, mod, lineno,
                                f"traced function {qual!r} {what}"))

    for sub in ast.walk(node):
        if isinstance(sub, ast.Global):
            flag(sub.lineno, "declares `global` (module-state mutation)")
        elif isinstance(sub, ast.With):
            for item in sub.items:
                ctx = dotted_name(item.context_expr)
                if ctx is None and isinstance(item.context_expr, ast.Call):
                    ctx = dotted_name(item.context_expr.func)
                if ctx is None:
                    continue
                last = ctx.split(".")[-1].lower()
                if "lock" in last or last == "_cv":
                    flag(sub.lineno, f"acquires lock `{ctx}`")
        elif isinstance(sub, (ast.Assign, ast.AugAssign)):
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            for t in targets:
                base = t
                while isinstance(base, (ast.Subscript, ast.Attribute)):
                    base = base.value
                if isinstance(base, ast.Name) and t is not base \
                        and base.id in idx.globals:
                    flag(sub.lineno,
                         f"mutates module-level `{base.id}`")
        elif isinstance(sub, ast.Call):
            cname = dotted_name(sub.func)
            if cname is None:
                continue
            canonical = idx.resolve(cname)
            root = canonical.split(".")[0]
            if root == "time" and "." in canonical:
                flag(sub.lineno, f"calls `{canonical}` (clock)")
            elif root == "random" and "." in canonical:
                flag(sub.lineno, f"calls `{canonical}` (host RNG)")
            elif canonical.startswith("numpy.random."):
                flag(sub.lineno, f"calls `{canonical}` (host RNG)")
            elif canonical.split(".")[-1] == "get_hub":
                flag(sub.lineno, "touches the metrics hub (`get_hub`)")
            elif canonical.split(".")[-1] == "acquire" and \
                    "lock" in canonical.lower():
                flag(sub.lineno, f"acquires lock `{cname}`")
            elif isinstance(sub.func, ast.Attribute):
                base = dotted_name(sub.func.value) or ""
                meth = sub.func.attr
                if "hub" in base.lower() and (
                        meth in _HUB_METHODS or meth == "set"
                        or meth in ("counter", "gauge", "histogram")):
                    flag(sub.lineno,
                         f"touches metrics instrument `{base}.{meth}`")


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    indexes = {mod: _ModuleIndex(sf) for mod, sf in project.files.items()}

    # seed: every traced root in every module
    queue: list[tuple[str, str, ast.AST, str | None]] = []
    seen: set[tuple[str, int]] = set()  # (module, node lineno) identity
    for mod, idx in indexes.items():
        qual_by_node = {id(n): q for q, n in idx.functions.items()}
        cls_of = {}
        for qual, cls, node in functions_of(idx.sf.tree):
            cls_of[qual] = cls
        for node, display in _jit_roots(idx):
            qual = qual_by_node.get(id(node), display)
            key = (mod, node.lineno)
            if key not in seen:
                seen.add(key)
                queue.append((mod, qual, node, cls_of.get(qual)))

    while queue:
        mod, qual, node, cls = queue.pop()
        idx = indexes[mod]
        _check_body(node, qual, idx, findings)
        for tmod, tqual in _out_edges(node, idx, cls, project):
            tidx = indexes.get(tmod)
            if tidx is None:
                continue
            tnode = tidx.functions.get(tqual)
            if tnode is None:
                continue
            key = (tmod, tnode.lineno)
            if key in seen:
                continue
            seen.add(key)
            tcls = tqual.split(".")[0] if "." in tqual else None
            queue.append((tmod, tqual, tnode, tcls))
    return findings
