"""Project-specific static analysis (DESIGN.md §Analysis).

An AST-based, dependency-free invariant checker: ``python -m
repro.analysis --gate`` walks ``src/repro`` and enforces the invariants
the paper's accuracy claims (and the transport tier's safety) rest on:

  trace-purity   functions reachable from jax.jit / Pallas call sites
                 stay side-effect free (no clocks, RNG, locks, global
                 mutation, or MetricsHub instruments in traced code)
  wire-schema    the frame registry in net/wire.py is unique, every
                 registered kind is dispatched exactly once per
                 dispatcher, and the committed ``wire_schema.lock``
                 fingerprint matches — schema drift without a
                 WIRE_VERSION bump fails the gate
  unpickler      the restricted unpickler's repro-class allowlist is
                 exactly the set of ``# wire-type`` marked classes and
                 every entry is live (dead entries are latent gadget
                 surface)
  hot-path       modules on the ingest hot path never touch pickle
  locks          ``# guarded-by:`` field annotations hold statically and
                 the nested-``with`` lock-order graph is acyclic

The dynamic half lives in :mod:`repro.analysis.witness`: with
``REPRO_LOCK_WITNESS=1`` the test suite wraps ``threading.Lock``/``RLock``
to record real cross-thread acquisition order, failing the run on
ordering cycles or snapshot publishes outside the buffer lock.
"""
from repro.analysis.engine import (  # noqa: F401
    Finding,
    Project,
    SourceFile,
    all_rules,
    load_baseline,
    run_rules,
)
