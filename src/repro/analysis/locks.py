"""Rule: ``# guarded-by`` field discipline + static lock-order graph.

Annotation syntax (DESIGN.md §Analysis):

``# guarded-by: <lock>``
    trailing comment on the field's declaration (a dataclass field line,
    or the ``self.x = ...`` line in ``__init__``/``__post_init__``).
    Every touch of the field in that class — read or write — must happen
    lexically inside ``with self.<lock>:`` (or in a method annotated
    ``# requires-lock: <lock>``).

``# guarded-by(writes): <lock>``
    writes need the lock; bare reads are allowed lock-free.  This is the
    publish pattern: ``SnapshotBuffer._front`` is an immutable-snapshot
    reference that readers may load without synchronization, but every
    store happens under the buffer lock.

``# requires-lock: <lock>``
    trailing comment on a ``def`` line: the method is a private helper
    whose *callers* hold the lock.  Its body is checked as if the lock
    were held, and every in-class use of the method is checked to occur
    with the lock held.

The second half builds a static lock-order graph: every ``with`` on a
lock-like expression (attribute ending in ``lock``/named ``_cv``, or a
module-level lock) is an acquisition; lexical nesting and acquisitions
made by (resolvable) callees while a lock is held become edges.  A cycle
is a potential deadlock and fails the gate.  The graph is site-level
over class-qualified lock names — two instances of the same class/lock
field share a node, which matches the witness's allocation-site model.

Constructors are exempt from the guard check (no concurrent reader can
exist before ``__init__`` returns); nested functions are checked with an
*empty* held-set, because a closure may run on another thread later.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.engine import (
    Finding, Project, SourceFile, dotted_name, functions_of, module_imports,
)

RULE = "lock-discipline"

_GUARD_RE = re.compile(
    r"#\s*guarded-by(?P<writes>\(writes\))?:\s*(?P<lock>[A-Za-z_]\w*)")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*(?P<lock>[A-Za-z_]\w*)")

_EXEMPT_METHODS = frozenset({"__init__", "__post_init__", "__new__"})


def _is_lockish(name: str) -> bool:
    last = name.split(".")[-1]
    return "lock" in last.lower() or last == "_cv"


def _with_locks(node: ast.With) -> list[tuple[str, bool]]:
    """``(name, is_self_field)`` locks acquired by one ``with`` statement."""
    out: list[tuple[str, bool]] = []
    for item in node.items:
        ctx = dotted_name(item.context_expr)
        if ctx is None:
            continue
        if ctx.startswith("self."):
            field = ctx[len("self."):]
            if "." not in field and _is_lockish(field):
                out.append((field, True))
        elif "." not in ctx and _is_lockish(ctx):
            out.append((ctx, False))
    return out


# ------------------------------------------------------------- guarded-by
class _ClassAnnotations:
    def __init__(self) -> None:
        self.guards: dict[str, tuple[str, bool]] = {}  # field -> (lock, writes_only)
        self.requires: dict[str, str] = {}             # method -> lock


def _collect_annotations(sf: SourceFile, cls: ast.ClassDef
                         ) -> _ClassAnnotations:
    ann = _ClassAnnotations()

    def note_guard(field: str, lineno: int) -> None:
        m = _GUARD_RE.search(sf.line(lineno))
        if m:
            ann.guards[field] = (m.group("lock"),
                                 m.group("writes") is not None)

    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            note_guard(node.target.id, node.lineno)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    note_guard(t.id, node.lineno)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            m = _REQUIRES_RE.search(sf.line(node.lineno)) or \
                _REQUIRES_RE.search(sf.line(node.lineno - 1))
            if m:
                ann.requires[node.name] = m.group("lock")
            if node.name in _EXEMPT_METHODS:
                for sub in ast.walk(node):
                    targets: list[ast.expr] = []
                    if isinstance(sub, ast.Assign):
                        targets = sub.targets
                    elif isinstance(sub, ast.AnnAssign):
                        targets = [sub.target]
                    for t in targets:
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self":
                            note_guard(t.attr, sub.lineno)
    return ann


def _check_method(sf: SourceFile, cls: ast.ClassDef,
                  method: ast.FunctionDef, ann: _ClassAnnotations,
                  findings: list[Finding]) -> None:
    mod = sf.module

    def touch(node: ast.Attribute, held: frozenset[str]) -> None:
        field = node.attr
        guard = ann.guards.get(field)
        if guard is not None:
            lock, writes_only = guard
            is_write = isinstance(node.ctx, (ast.Store, ast.Del))
            if (is_write or not writes_only) and lock not in held:
                verb = "writes" if is_write else "reads"
                kind = "guarded-by(writes)" if writes_only else "guarded-by"
                findings.append(Finding(
                    RULE, mod, node.lineno,
                    f"{cls.name}.{method.name} {verb} "
                    f"`self.{field}` ({kind}: {lock}) without "
                    f"holding `self.{lock}`"))
            return
        req = ann.requires.get(field)
        if req is not None and field != method.name and req not in held:
            findings.append(Finding(
                RULE, mod, node.lineno,
                f"{cls.name}.{method.name} uses `self.{field}` "
                f"(requires-lock: {req}) without holding `self.{req}`"))

    def walk(node: ast.AST, held: frozenset[str]) -> None:
        if isinstance(node, ast.With):
            acquired = frozenset(
                lk for lk, is_self in _with_locks(node) if is_self)
            for item in node.items:
                walk(item.context_expr, held)
                if item.optional_vars is not None:
                    walk(item.optional_vars, held)
            inner = held | acquired
            for stmt in node.body:
                walk(stmt, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not method:
            # a closure may run later, on any thread, without the lock
            body = node.body if isinstance(node.body, list) else [node.body]
            for stmt in body:
                walk(stmt, frozenset())
            return
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            touch(node, held)
            return
        for child in ast.iter_child_nodes(node):
            walk(child, held)

    start = frozenset({ann.requires[method.name]}) \
        if method.name in ann.requires else frozenset()
    for stmt in method.body:
        walk(stmt, start)


# -------------------------------------------------------------- lock order
def _qual_lock(module: str, cls: str | None, lock: str) -> str:
    return f"{module}.{cls}.{lock}" if cls else f"{module}.{lock}"


class _FuncInfo:
    def __init__(self, module: str, cls: str | None, qual: str,
                 node: ast.AST) -> None:
        self.module = module
        self.cls = cls
        self.qual = qual
        self.node = node
        self.direct: set[str] = set()          # locks acquired in body
        # (held qualified lock, acquired qualified lock, lineno)
        self.edges: list[tuple[str, str, int]] = []
        # (held qualified lock, callee key, lineno)
        self.calls_while_holding: list[
            tuple[str, tuple[str, str], int]] = []
        self.calls: set[tuple[str, str]] = set()


def _resolve_callee(name: str, module: str, cls: str | None,
                    idx_funcs: set[str], from_imports, project: Project
                    ) -> tuple[str, str] | None:
    if name.startswith("self.") and cls is not None:
        meth = name[len("self."):]
        if "." not in meth:
            return (module, f"{cls}.{meth}")
        return None
    if "." not in name:
        if name in idx_funcs:
            return (module, name)
        if name in from_imports:
            m, n = from_imports[name]
            if project.get(m) is not None:
                return (m, n)
        return None
    head, _, rest = name.partition(".")
    if "." in rest:
        return None
    if head in from_imports:
        m, n = from_imports[head]
        target = f"{m}.{n}"
        if project.get(target) is not None:
            return (target, rest)
    return None


def _build_lock_graph(project: Project
                      ) -> dict[tuple[str, str], tuple[str, int]]:
    """Edges ``(held_lock, acquired_lock) -> (module, lineno)``."""
    infos: dict[tuple[str, str], _FuncInfo] = {}
    for mod, sf in project.files.items():
        _aliases, from_imports = module_imports(sf.tree)
        top_funcs = {qual for qual, cls, _n in functions_of(sf.tree)
                     if cls is None}
        for qual, cls, node in functions_of(sf.tree):
            info = _FuncInfo(mod, cls, qual, node)
            infos[(mod, qual)] = info

            def walk(n: ast.AST, held: tuple[str, ...],
                     info=info, cls=cls, from_imports=from_imports,
                     top_funcs=top_funcs) -> None:
                if isinstance(n, ast.With):
                    acquired = [
                        _qual_lock(info.module, cls if is_self else None, lk)
                        for lk, is_self in _with_locks(n)]
                    for q in acquired:
                        info.direct.add(q)
                        for h in held:
                            info.edges.append((h, q, n.lineno))
                    inner = held + tuple(acquired)
                    for stmt in n.body:
                        walk(stmt, inner)
                    for item in n.items:
                        walk(item.context_expr, held)
                    return
                if isinstance(n, ast.Call):
                    name = dotted_name(n.func)
                    if name is not None:
                        callee = _resolve_callee(
                            name, info.module, cls, top_funcs,
                            from_imports, project)
                        if callee is not None:
                            info.calls.add(callee)
                            for h in held:
                                info.calls_while_holding.append(
                                    (h, callee, n.lineno))
                for child in ast.iter_child_nodes(n):
                    walk(child, held)

            body = node.body if isinstance(node.body, list) else [node.body]
            for stmt in body:
                walk(stmt, ())

    # transitive acquire sets (locks a call may take, directly or deeper)
    memo: dict[tuple[str, str], set[str]] = {}

    def acquires(key: tuple[str, str],
                 stack: frozenset[tuple[str, str]]) -> set[str]:
        if key in memo:
            return memo[key]
        info = infos.get(key)
        if info is None or key in stack:
            return set()
        out = set(info.direct)
        for callee in info.calls:
            out |= acquires(callee, stack | {key})
        memo[key] = out
        return out

    edges: dict[tuple[str, str], tuple[str, int]] = {}
    for key, info in infos.items():
        for h, q, line in info.edges:
            edges.setdefault((h, q), (info.module, line))
        for held, callee, line in info.calls_while_holding:
            for q in acquires(callee, frozenset({key})):
                edges.setdefault((held, q), (info.module, line))
    return edges


def _find_cycles(edges: dict[tuple[str, str], tuple[str, int]]
                 ) -> list[list[str]]:
    graph: dict[str, set[str]] = {}
    for a, b in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
    cycles: list[list[str]] = []
    seen_cycles: set[tuple[str, ...]] = set()

    for a, b in sorted(edges):
        if a == b:
            key = (a,)
            if key not in seen_cycles:
                seen_cycles.add(key)
                cycles.append([a, a])
            continue
        # path b ~> a means edge a->b closes a cycle
        stack, visited, parent = [b], {b}, {b: None}
        found = False
        while stack and not found:
            cur = stack.pop()
            for nxt in sorted(graph.get(cur, ())):
                if nxt == a:
                    path = [a, b]
                    node = cur
                    trail = []
                    while node is not None and node != b:
                        trail.append(node)
                        node = parent[node]
                    path.extend(reversed(trail))
                    path.append(a)
                    key = tuple(sorted(set(path)))
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        cycles.append(path)
                    found = True
                    break
                if nxt not in visited:
                    visited.add(nxt)
                    parent[nxt] = cur
                    stack.append(nxt)
    return cycles


# ------------------------------------------------------------------ check
def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []

    for mod, sf in sorted(project.files.items()):
        for node in sf.tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            ann = _collect_annotations(sf, node)
            if not ann.guards and not ann.requires:
                continue
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub.name not in _EXEMPT_METHODS:
                    _check_method(sf, node, sub, ann, findings)

    edges = _build_lock_graph(project)
    for cycle in _find_cycles(edges):
        if len(cycle) == 2 and cycle[0] == cycle[1]:
            mod, line = edges.get((cycle[0], cycle[0]), ("repro", 1))
            findings.append(Finding(
                RULE, mod, line,
                f"nested reacquisition of lock `{cycle[0]}` "
                "(self-deadlock on a non-reentrant lock)"))
            continue
        mod, line = edges.get((cycle[0], cycle[1]), ("repro", 1))
        findings.append(Finding(
            RULE, mod, line,
            "lock-order cycle (potential deadlock): "
            + " -> ".join(cycle)))
    return findings
